# ColA build entry points.
#
#   make ci        — mirror the CI pipeline locally (fmt, clippy, doc,
#                    build, test)
#   make build     — hermetic release build (native backend, no Python/XLA)
#   make test      — run the test suite
#   make smoke     — distributed-offload loopback smoke (TCP == local)
#   make bench     — run the paper's table/figure benches (results/ *.md+csv)
#   make artifacts — OPTIONAL: AOT-lower the JAX graphs to artifacts/
#                    (requires Python + JAX; only needed for the PJRT
#                    backend, `cargo build --features xla`)

CARGO ?= cargo
PYTHON ?= python3

.PHONY: ci build test fmt clippy doc smoke bench artifacts clean

ci: fmt clippy doc build test

build:
	$(CARGO) build --release --locked

test:
	$(CARGO) test --locked -q

smoke: build
	bash scripts/distributed_smoke.sh

fmt:
	$(CARGO) fmt --all --check

clippy:
	$(CARGO) clippy --locked --all-targets -- -D warnings

doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --locked --no-deps

BENCHES = throughput table1_complexity table2_seqcls table3_s2s \
          table4_collab table6_clm table9_scratch table10_compute \
          fig_interval

bench:
	@for b in $(BENCHES); do \
		echo "== bench $$b"; \
		$(CARGO) bench --locked --bench $$b -- --quick || exit 1; \
	done

artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../artifacts

clean:
	$(CARGO) clean
	rm -rf results
