# ColA build entry points.
#
#   make ci        — mirror the CI pipeline locally (fmt, clippy, doc,
#                    build, test)
#   make build     — hermetic release build (native backend, no Python/XLA)
#   make test      — run the test suite
#   make smoke     — distributed-offload loopback smoke (TCP == local)
#   make serve-smoke — FTaaS gateway smoke (HTTP job == cola train)
#   make lint-invariants — `cola lint --deny-all` + linter test suite
#   make sanitizers      — nightly TSan/ASan sweep (pool, transport, SIMD)
#   make bench     — run the paper's table/figure benches (results/ *.md+csv)
#   make artifacts — OPTIONAL: AOT-lower the JAX graphs to artifacts/
#                    (requires Python + JAX; only needed for the PJRT
#                    backend, `cargo build --features xla`)

CARGO ?= cargo
PYTHON ?= python3

.PHONY: ci build test fmt clippy doc smoke serve-smoke bench artifacts clean \
        lint-invariants sanitizers

ci: fmt clippy doc build test

build:
	$(CARGO) build --release --locked

test:
	$(CARGO) test --locked -q

smoke: build
	bash scripts/distributed_smoke.sh

serve-smoke: build
	bash scripts/gateway_smoke.sh

fmt:
	$(CARGO) fmt --all --check

clippy:
	$(CARGO) clippy --locked --all-targets -- -D warnings

doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --locked --no-deps

# `cola lint` over rust/src (determinism / panic-safety / mutex-poison /
# wire-coverage / unsafe-audit) plus the linter's own fixture suite.
# --deny-all: stale pragmas fail too.
lint-invariants: build
	./target/release/cola lint --deny-all --fix-report
	$(CARGO) test --locked -p cola --test lint_invariants

# Nightly-toolchain TSan/ASan sweep (mirrors the CI `sanitizers` job;
# needs `rustup component add rust-src --toolchain nightly`).
SAN_TARGET = x86_64-unknown-linux-gnu
sanitizers:
	RUSTFLAGS="-Zsanitizer=thread" RUSTDOCFLAGS="-Zsanitizer=thread" COLA_SIMD=0 \
		$(CARGO) +nightly test --locked -Zbuild-std --target $(SAN_TARGET) \
		-p cola --lib tensor::pool
	RUSTFLAGS="-Zsanitizer=thread" RUSTDOCFLAGS="-Zsanitizer=thread" COLA_SIMD=0 \
		$(CARGO) +nightly test --locked -Zbuild-std --target $(SAN_TARGET) \
		-p cola --test transport_multi
	RUSTFLAGS="-Zsanitizer=address" RUSTDOCFLAGS="-Zsanitizer=address" \
		$(CARGO) +nightly test --locked -Zbuild-std --target $(SAN_TARGET) \
		-p cola --lib tensor::simd

BENCHES = throughput table1_complexity table2_seqcls table3_s2s \
          table4_collab table6_clm table9_scratch table10_compute \
          fig_interval

bench:
	@for b in $(BENCHES); do \
		echo "== bench $$b"; \
		$(CARGO) bench --locked --bench $$b -- --quick || exit 1; \
	done

artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../artifacts

clean:
	$(CARGO) clean
	rm -rf results
