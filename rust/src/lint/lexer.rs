//! A masking tokenizer for Rust source.
//!
//! `cola lint` needs to answer "does this *code* mention token X?"
//! without being fooled by comments, doc text, or string literals —
//! and, separately, "what does the *comment* on line N say?" for
//! `// lint:allow` pragmas and `// SAFETY:` audits. One pass over the
//! bytes produces both views:
//!
//! - [`Masked::code`] — the source with every comment body, string
//!   literal, and char literal blanked to spaces (newlines preserved,
//!   so byte offsets and line numbers still line up with the input).
//!   Rule scans run plain substring searches over this view.
//! - [`Masked::comments`] — per-line concatenated comment text (line
//!   comments, doc comments, and any block-comment segment that
//!   touches the line), with the `//`/`/*` delimiters stripped.
//!
//! The lexer understands line comments, nested block comments, string
//! literals with escapes, raw strings (`r"…"`, `r#"…"#`, byte/raw-byte
//! variants), byte strings, char and byte-char literals, and the
//! lifetime-vs-char-literal ambiguity (`'a` vs `'a'`). It is not a
//! full Rust lexer — it only has to be exact about where code stops
//! and text begins.

/// The views produced by [`mask`]. Same length/line structure as the
/// input source.
pub struct Masked {
    /// Source with comments and string/char literals blanked.
    pub code: String,
    /// Plain `//` and `/* */` comment text per 0-based line index —
    /// the only place `lint:allow` pragmas are recognized.
    pub comments: Vec<String>,
    /// Doc comment text (`///`, `//!`) per 0-based line index — doc
    /// prose may *mention* pragma syntax without enacting it, but its
    /// `# Safety` sections do count for the unsafe audit.
    pub docs: Vec<String>,
}

impl Masked {
    /// Masked source split into lines (no trailing newlines).
    pub fn code_lines(&self) -> Vec<&str> {
        self.code.split('\n').collect()
    }

    /// Plain comment text for a 0-based line ("" when out of range).
    pub fn comment(&self, line0: usize) -> &str {
        self.comments.get(line0).map(String::as_str).unwrap_or("")
    }

    /// Doc comment text for a 0-based line ("" when out of range).
    pub fn doc(&self, line0: usize) -> &str {
        self.docs.get(line0).map(String::as_str).unwrap_or("")
    }
}

/// Blank comments and literals out of `src` (see module docs).
pub fn mask(src: &str) -> Masked {
    let b = src.as_bytes();
    let n = b.len();
    let mut out: Vec<u8> = Vec::with_capacity(n);
    let nlines = src.split('\n').count() + 1;
    let mut comments = vec![String::new(); nlines];
    let mut docs = vec![String::new(); nlines];
    let mut line = 0usize;
    let mut i = 0usize;
    // true when the previous code byte could continue an identifier —
    // distinguishes the `r`/`b` of a raw/byte string prefix from the
    // trailing `r`/`b` of an identifier like `var` or `ptr`
    let mut prev_ident = false;

    // blank bytes [i, j) to spaces, preserving newlines
    let mut blank_to = |i: &mut usize, line: &mut usize, out: &mut Vec<u8>, j: usize| {
        let j = j.min(n);
        while *i < j {
            if b[*i] == b'\n' {
                out.push(b'\n');
                *line += 1;
            } else {
                out.push(b' ');
            }
            *i += 1;
        }
    };
    let mut note = |comments: &mut Vec<String>, line: usize, text: &str| {
        let t = text.trim();
        if !t.is_empty() {
            let slot = &mut comments[line];
            if !slot.is_empty() {
                slot.push(' ');
            }
            slot.push_str(t);
        }
    };

    while i < n {
        let c = b[i];
        // line comment; ///… and //!… are doc text, recorded apart
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let mut j = i + 2;
            let is_doc = j < n && (b[j] == b'/' || b[j] == b'!');
            while j < n && b[j] == b'/' {
                j += 1; // strip the extra slashes of ///
            }
            if j < n && b[j] == b'!' {
                j += 1; // strip the bang of //!
            }
            let start = j;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            if is_doc {
                note(&mut docs, line, &src[start..j]);
            } else {
                note(&mut comments, line, &src[start..j]);
            }
            blank_to(&mut i, &mut line, &mut out, j);
            prev_ident = false;
            continue;
        }
        // block comment, possibly nested and multi-line
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1usize;
            blank_to(&mut i, &mut line, &mut out, i + 2);
            let mut seg = i;
            while i < n && depth > 0 {
                if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    blank_to(&mut i, &mut line, &mut out, i + 2);
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    if depth == 0 {
                        note(&mut comments, line, &src[seg..i]);
                    }
                    blank_to(&mut i, &mut line, &mut out, i + 2);
                } else if b[i] == b'\n' {
                    note(&mut comments, line, &src[seg..i]);
                    blank_to(&mut i, &mut line, &mut out, i + 1);
                    seg = i;
                } else {
                    blank_to(&mut i, &mut line, &mut out, i + 1);
                }
            }
            prev_ident = false;
            continue;
        }
        // raw / byte string prefixes: r"…", r#"…"#, b"…", br#"…"#
        if (c == b'r' || c == b'b') && !prev_ident {
            let mut j = i;
            if b[j] == b'b' {
                j += 1;
            }
            let saw_r = j < n && b[j] == b'r';
            if saw_r {
                j += 1;
            }
            let mut hashes = 0usize;
            while saw_r && j < n && b[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == b'"' {
                j += 1;
                if saw_r {
                    // raw: ends at `"` followed by `hashes` hash marks
                    while j < n {
                        if b[j] == b'"' && b[j + 1..].len() >= hashes
                            && b[j + 1..j + 1 + hashes].iter().all(|&h| h == b'#')
                        {
                            j += 1 + hashes;
                            break;
                        }
                        j += 1;
                    }
                } else {
                    // b"…": ordinary escape rules
                    while j < n {
                        if b[j] == b'\\' {
                            j += 2;
                        } else if b[j] == b'"' {
                            j += 1;
                            break;
                        } else {
                            j += 1;
                        }
                    }
                }
                blank_to(&mut i, &mut line, &mut out, j);
                prev_ident = false;
                continue;
            }
            if c == b'b' && i + 1 < n && b[i + 1] == b'\'' {
                // byte char literal b'x'
                let mut j = i + 2;
                while j < n {
                    if b[j] == b'\\' {
                        j += 2;
                    } else if b[j] == b'\'' {
                        j += 1;
                        break;
                    } else {
                        j += 1;
                    }
                }
                blank_to(&mut i, &mut line, &mut out, j);
                prev_ident = false;
                continue;
            }
            // plain identifier starting with r/b — fall through
        }
        if c == b'"' {
            let mut j = i + 1;
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                } else if b[j] == b'"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            blank_to(&mut i, &mut line, &mut out, j);
            prev_ident = false;
            continue;
        }
        if c == b'\'' {
            // `'a>` is a lifetime, `'a'` is a char literal
            let lifetime = i + 1 < n
                && (b[i + 1] == b'_' || b[i + 1].is_ascii_alphabetic())
                && !(i + 2 < n && b[i + 2] == b'\'');
            if lifetime {
                out.push(c);
                i += 1;
                prev_ident = false;
                continue;
            }
            let mut j = i + 1;
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                } else if b[j] == b'\'' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            blank_to(&mut i, &mut line, &mut out, j);
            prev_ident = false;
            continue;
        }
        prev_ident = c == b'_' || c.is_ascii_alphanumeric();
        out.push(c);
        if c == b'\n' {
            line += 1;
        }
        i += 1;
    }

    // blanking only ever replaces bytes with ASCII spaces, so the
    // result is valid UTF-8 whenever the input was
    let code = String::from_utf8_lossy(&out).into_owned();
    Masked { code, comments, docs }
}

/// True when `tok` occurs in `line` as a standalone word: neither end
/// may extend an identifier. Tokens whose boundary chars are already
/// non-ident (like `.unwrap()`) match as plain substrings.
pub fn has_word(line: &str, tok: &str) -> bool {
    let lb = line.as_bytes();
    let tb = tok.as_bytes();
    if tb.is_empty() {
        return false;
    }
    let is_ident = |c: u8| c == b'_' || c.is_ascii_alphanumeric();
    let mut from = 0usize;
    while let Some(k) = line[from..].find(tok) {
        let at = from + k;
        let pre_ok = !is_ident(tb[0]) || at == 0 || !is_ident(lb[at - 1]);
        let end = at + tb.len();
        let post_ok =
            !is_ident(tb[tb.len() - 1]) || end >= lb.len() || !is_ident(lb[end]);
        if pre_ok && post_ok {
            return true;
        }
        from = at + 1;
    }
    false
}
