//! `cola lint` — the repo's standing invariants as deny-by-default
//! static checks over `rust/src/**`.
//!
//! ColA's reproduction contract is *exact*: the same config must train
//! to byte-identical loss curves across transports, thread counts, and
//! SIMD tiers. The runtime suites prove that today; this pass keeps
//! future PRs from silently breaking it. Zero dependencies, in
//! character with the repo's hand-rolled wire/toml/json code: a small
//! masking lexer ([`lexer`]) plus substring rules.
//!
//! Rules (all deny by default):
//!
//! - **determinism** — curve-affecting modules (`adapters/`,
//!   `coordinator/`, `data/`, `gateway/`, `merge/`, `metrics/`,
//!   `scale/`, `tensor/`, `runtime/native/`, `rng.rs`,
//!   `transport/wire.rs`) must not touch
//!   `HashMap`/`HashSet` (iteration order is randomized per process),
//!   wall clocks (`SystemTime`/`Instant::now`), or unseeded randomness
//!   (`thread_rng`/`from_entropy`). Ordered state lives in
//!   `BTreeMap`/`BTreeSet`; time belongs in the timing ledger behind a
//!   pragma.
//! - **panic-safety** — no `.unwrap()` / `.expect(…)` / `panic!` /
//!   `unreachable!` / `todo!` / `unimplemented!` in non-test library
//!   code. Fallible paths return `anyhow` errors naming the
//!   (user, site) they affect.
//! - **mutex-poison** — no `lock().unwrap()` *and* no ad-hoc
//!   `lock().unwrap_or_else(…)` recovery: shared daemon/pool state goes
//!   through [`crate::util::lock_recover`], the one audited place that
//!   strips `PoisonError` so a panicking fit cannot wedge a
//!   multi-tenant daemon.
//! - **wire-exhaustiveness** — every `wire::Msg` / `wire::BatchItem`
//!   variant must appear in `encode_with`, `decode`, AND the fuzz
//!   generator `arb_msg`, so a new message cannot ship without codec +
//!   fuzz coverage.
//! - **unsafe-audit** — every `unsafe` token carries a `// SAFETY:`
//!   comment (or `# Safety` doc section) on the same line or the
//!   contiguous comment/attribute block above it.
//! - **pragma-hygiene** — `// lint:allow(rule): reason` pragmas must
//!   carry a non-empty reason and must actually suppress something;
//!   stale pragmas are warnings (errors under `--deny-all`).
//!
//! An audited exception is written on the flagged line or the line
//! directly above it:
//!
//! ```text
//! // lint:allow(determinism): timing ledger only; never in curve math
//! let t0 = Instant::now();
//! ```
//!
//! `#[cfg(test)]` items (inline test modules and test-only fns) are
//! exempt from every rule.

pub mod lexer;

use std::fmt;
use std::path::Path;

use anyhow::{Context, Result};

use lexer::{has_word, mask, Masked};

/// Rule identifiers; `name()` is the spelling used inside
/// `lint:allow(…)` pragmas and report output.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Rule {
    Determinism,
    PanicSafety,
    MutexPoison,
    WireExhaustive,
    UnsafeAudit,
    PragmaHygiene,
}

/// All rules, in report order.
pub const RULES: [Rule; 6] = [
    Rule::Determinism,
    Rule::PanicSafety,
    Rule::MutexPoison,
    Rule::WireExhaustive,
    Rule::UnsafeAudit,
    Rule::PragmaHygiene,
];

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::Determinism => "determinism",
            Rule::PanicSafety => "panic-safety",
            Rule::MutexPoison => "mutex-poison",
            Rule::WireExhaustive => "wire-exhaustiveness",
            Rule::UnsafeAudit => "unsafe-audit",
            Rule::PragmaHygiene => "pragma-hygiene",
        }
    }

    pub fn parse(s: &str) -> Option<Rule> {
        RULES.iter().copied().find(|r| r.name() == s)
    }

    /// One-line remediation hint for `--fix-report`.
    pub fn remedy(self) -> &'static str {
        match self {
            Rule::Determinism => {
                "use BTreeMap/BTreeSet and the seeded rng::Rng; wall-clock \
                 reads belong in the timing ledger behind a pragma"
            }
            Rule::PanicSafety => {
                "return an anyhow error naming the (user, site) affected, \
                 or pragma-audit a guarded invariant"
            }
            Rule::MutexPoison => "route the lock through util::lock_recover",
            Rule::WireExhaustive => {
                "add the variant to encode_with, decode, and arb_msg in \
                 transport/wire.rs"
            }
            Rule::UnsafeAudit => {
                "state the alignment / lane-width / feature-detection \
                 argument in a SAFETY: comment directly above the block"
            }
            Rule::PragmaHygiene => {
                "give the pragma a non-empty reason, or delete it if the \
                 flagged code is gone"
            }
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Severity {
    /// Fails the default `cola lint` run.
    Deny,
    /// Reported; fails only under `--deny-all`.
    Warn,
}

/// One finding, addressed `file:line` (1-based).
#[derive(Clone, Debug)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub severity: Severity,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let tag = match self.severity {
            Severity::Deny => "",
            Severity::Warn => "warn: ",
        };
        write!(
            f,
            "{}:{}: [{}] {}{}",
            self.file, self.line, self.rule, tag, self.message
        )
    }
}

/// A violation suppressed by an audited `lint:allow` pragma.
#[derive(Clone, Debug)]
pub struct Allowed {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub reason: String,
}

/// Everything one scan produced.
#[derive(Default)]
pub struct Report {
    pub files_scanned: usize,
    pub violations: Vec<Violation>,
    pub allowed: Vec<Allowed>,
}

impl Report {
    pub fn deny_count(&self) -> usize {
        self.violations
            .iter()
            .filter(|v| v.severity == Severity::Deny)
            .count()
    }

    pub fn warn_count(&self) -> usize {
        self.violations
            .iter()
            .filter(|v| v.severity == Severity::Warn)
            .count()
    }

    pub fn count_for(&self, rule: Rule) -> usize {
        self.violations.iter().filter(|v| v.rule == rule).count()
    }
}

/// Modules where nondeterminism changes loss-curve bytes. Paths are
/// relative to `rust/src`, `/`-separated.
fn curve_scoped(rel: &str) -> bool {
    const DIRS: [&str; 9] = [
        "adapters/",
        "coordinator/",
        "data/",
        // the gateway promises HTTP-submitted jobs replay byte-identical
        // to `cola train`, so it carries the same determinism rules
        "gateway/",
        "merge/",
        "metrics/",
        // the scale harness promises byte-identical curves paging on or
        // off — its LRU is a logical u64 clock, never wall time, and
        // arrival order must be seed-pure (wall-clock measurement lives
        // in main.rs / benches, which are not curve-scoped)
        "scale/",
        "tensor/",
        "runtime/native/",
    ];
    DIRS.iter().any(|d| rel.starts_with(d)) || rel == "rng.rs" || rel == "transport/wire.rs"
}

const DET_TOKENS: [&str; 6] = [
    "HashMap",
    "HashSet",
    "SystemTime",
    "Instant::now",
    "thread_rng",
    "from_entropy",
];

const PANIC_TOKENS: [&str; 6] = [
    ".unwrap()",
    ".expect(",
    "panic!(",
    "unreachable!(",
    "todo!(",
    "unimplemented!(",
];

const MUTEX_TOKENS: [&str; 3] = [
    ".lock().unwrap()",
    ".lock().expect(",
    ".lock().unwrap_or_else(",
];

/// The wire codec file and the three fns that must each cover every
/// message variant.
const WIRE_FILE: &str = "transport/wire.rs";
const WIRE_ENUMS: [&str; 2] = ["Msg", "BatchItem"];
const WIRE_FNS: [&str; 3] = ["encode_with", "decode", "arb_msg"];

/// A `// lint:allow(rule): reason` pragma found on one line.
struct Pragma {
    rule: Rule,
    reason: String,
    used: bool,
    bad_rule: Option<String>,
}

fn parse_pragma(comment: &str) -> Option<Pragma> {
    let key = "lint:allow(";
    let k = comment.find(key)?;
    let rest = &comment[k + key.len()..];
    let close = rest.find(')')?;
    let name = rest[..close].trim();
    let after = rest[close + 1..].trim_start();
    let reason = after
        .strip_prefix(':')
        .map(|r| r.trim().to_string())
        .unwrap_or_default();
    match Rule::parse(name) {
        Some(rule) => Some(Pragma { rule, reason, used: false, bad_rule: None }),
        None => Some(Pragma {
            rule: Rule::PragmaHygiene,
            reason,
            used: false,
            bad_rule: Some(name.to_string()),
        }),
    }
}

/// Mark the 0-based lines covered by `#[cfg(test)]` items: the
/// attribute block plus the item that follows, through its matching
/// close brace (or terminating `;` for brace-less items).
fn test_spans(code_lines: &[&str]) -> Vec<bool> {
    let mut inactive = vec![false; code_lines.len()];
    let mut i = 0usize;
    while i < code_lines.len() {
        let t = code_lines[i].trim();
        if !(t.starts_with("#[cfg(") && has_word(t, "test")) {
            i += 1;
            continue;
        }
        let start = i;
        let mut j = i + 1;
        while j < code_lines.len() {
            let tj = code_lines[j].trim();
            if tj.is_empty() || tj.starts_with("#[") {
                j += 1;
            } else {
                break;
            }
        }
        // walk the item to its end
        let mut depth = 0i64;
        let mut seen_brace = false;
        let mut k = j;
        'item: while k < code_lines.len() {
            for ch in code_lines[k].bytes() {
                match ch {
                    b'{' => {
                        depth += 1;
                        seen_brace = true;
                    }
                    b'}' => depth -= 1,
                    b';' if !seen_brace && depth == 0 => break 'item,
                    _ => {}
                }
            }
            if seen_brace && depth == 0 {
                break;
            }
            k += 1;
        }
        let end = k.min(code_lines.len().saturating_sub(1));
        for slot in inactive.iter_mut().take(end + 1).skip(start) {
            *slot = true;
        }
        i = end + 1;
    }
    inactive
}

/// Parse variant names out of an enum body (text between the outer
/// braces): idents starting uppercase at nesting depth 0, in
/// declaration position (after `{` or `,`), skipping attributes.
fn enum_variants(body: &str) -> Vec<String> {
    let b = body.as_bytes();
    let mut out = Vec::new();
    let mut depth = 0i64;
    let mut expecting = true;
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        match c {
            b'{' | b'(' | b'[' => {
                depth += 1;
                i += 1;
            }
            b'}' | b')' | b']' => {
                depth -= 1;
                i += 1;
            }
            b',' if depth == 0 => {
                expecting = true;
                i += 1;
            }
            b'#' if depth == 0 => {
                while i < b.len() && b[i] != b']' {
                    i += 1;
                }
                i += 1;
            }
            _ if depth == 0 && expecting && c.is_ascii_uppercase() => {
                let s = i;
                while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                    i += 1;
                }
                out.push(body[s..i].to_string());
                expecting = false;
            }
            _ => i += 1,
        }
    }
    out
}

/// Find `anchor` (e.g. `"enum Msg"` / `"fn decode"`) as a whole word in
/// masked code and return (anchor offset, body start, body end) of the
/// brace-delimited body that follows.
fn find_span(masked: &str, anchor: &str) -> Option<(usize, usize, usize)> {
    let mb = masked.as_bytes();
    let is_ident = |c: u8| c == b'_' || c.is_ascii_alphanumeric();
    let mut from = 0usize;
    let at = loop {
        let k = masked[from..].find(anchor)? + from;
        let end = k + anchor.len();
        let pre_ok = k == 0 || !is_ident(mb[k - 1]);
        let post_ok = end >= mb.len() || !is_ident(mb[end]);
        if pre_ok && post_ok {
            break k;
        }
        from = k + 1;
    };
    let open = at + masked[at..].find('{')?;
    let mut depth = 0i64;
    for (off, ch) in masked[open..].bytes().enumerate() {
        match ch {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some((at, open + 1, open + off));
                }
            }
            _ => {}
        }
    }
    None
}

/// Cross-check that every variant of `enum_name` appears (as
/// `Enum::Variant`) inside each named fn. Returns (variant, fn) pairs
/// that are missing, or sentinel entries when the enum/fn itself is
/// absent. Public so the linter tests can run it on synthetic enums.
pub fn check_enum_coverage(src: &str, enum_name: &str, fns: &[&str]) -> Vec<(String, String)> {
    let masked = mask(src).code;
    let mut missing = Vec::new();
    let body = match find_span(&masked, &format!("enum {enum_name}")) {
        Some((_, s, e)) => masked[s..e].to_string(),
        None => {
            missing.push((format!("<enum {enum_name} not found>"), String::new()));
            return missing;
        }
    };
    let variants = enum_variants(&body);
    for fname in fns {
        let span = match find_span(&masked, &format!("fn {fname}")) {
            Some((_, s, e)) => &masked[s..e],
            None => {
                missing.push((format!("<fn {fname} not found>"), fname.to_string()));
                continue;
            }
        };
        for v in &variants {
            if !has_word(span, &format!("{enum_name}::{v}")) {
                missing.push((format!("{enum_name}::{v}"), fname.to_string()));
            }
        }
    }
    missing
}

/// Scan one file's source. `rel` is the `/`-separated path relative to
/// `rust/src` — it decides determinism scope and the wire cross-check.
pub fn scan_source(rel: &str, src: &str) -> (Vec<Violation>, Vec<Allowed>) {
    let masked: Masked = mask(src);
    let lines = masked.code_lines();
    let inactive = test_spans(&lines);
    let mut pragmas: Vec<Option<Pragma>> = (0..lines.len())
        .map(|ln| parse_pragma(masked.comment(ln)))
        .collect();
    let mut violations = Vec::new();
    let mut allowed = Vec::new();

    // a finding on line ln0 is suppressed by a pragma on the same line
    // or the line directly above; a matching pragma without a reason
    // re-files the finding under pragma-hygiene
    let mut emit = |pragmas: &mut Vec<Option<Pragma>>,
                    allowed: &mut Vec<Allowed>,
                    violations: &mut Vec<Violation>,
                    ln0: usize,
                    rule: Rule,
                    message: String| {
        for cand in [Some(ln0), ln0.checked_sub(1)].into_iter().flatten() {
            if let Some(p) = pragmas.get_mut(cand).and_then(Option::as_mut) {
                if p.rule == rule {
                    p.used = true;
                    if p.reason.is_empty() {
                        violations.push(Violation {
                            file: rel.to_string(),
                            line: ln0 + 1,
                            rule: Rule::PragmaHygiene,
                            severity: Severity::Deny,
                            message: format!(
                                "lint:allow({rule}) needs a `: reason` to audit this site"
                            ),
                        });
                    } else {
                        allowed.push(Allowed {
                            file: rel.to_string(),
                            line: ln0 + 1,
                            rule,
                            reason: p.reason.clone(),
                        });
                    }
                    return;
                }
            }
        }
        violations.push(Violation {
            file: rel.to_string(),
            line: ln0 + 1,
            rule,
            severity: Severity::Deny,
            message,
        });
    };

    let scoped = curve_scoped(rel);
    for (ln0, line) in lines.iter().enumerate() {
        if inactive[ln0] {
            continue;
        }
        if scoped {
            for tok in DET_TOKENS {
                if has_word(line, tok) {
                    emit(
                        &mut pragmas,
                        &mut allowed,
                        &mut violations,
                        ln0,
                        Rule::Determinism,
                        format!("`{tok}` in a curve-affecting module breaks byte-identical replay"),
                    );
                }
            }
        }
        if MUTEX_TOKENS.iter().any(|t| line.contains(t)) {
            emit(
                &mut pragmas,
                &mut allowed,
                &mut violations,
                ln0,
                Rule::MutexPoison,
                "poison handled ad hoc; shared locks go through util::lock_recover".to_string(),
            );
        } else if let Some(tok) = PANIC_TOKENS.iter().find(|t| line.contains(*t)) {
            emit(
                &mut pragmas,
                &mut allowed,
                &mut violations,
                ln0,
                Rule::PanicSafety,
                format!("`{tok}` in library code; return an anyhow error instead"),
            );
        }
        if has_word(line, "unsafe") {
            let mut covered = covered_by_safety(&masked, ln0);
            let mut k = ln0;
            let mut steps = 0usize;
            while !covered && k > 0 && steps < 12 {
                k -= 1;
                steps += 1;
                let t = lines[k].trim();
                if !t.is_empty() && !t.starts_with("#[") {
                    break;
                }
                covered = covered_by_safety(&masked, k);
            }
            if !covered {
                emit(
                    &mut pragmas,
                    &mut allowed,
                    &mut violations,
                    ln0,
                    Rule::UnsafeAudit,
                    "state the alignment/lane-width/feature argument in a SAFETY: comment"
                        .to_string(),
                );
            }
        }
    }

    if rel == WIRE_FILE {
        for enum_name in WIRE_ENUMS {
            for (variant, fname) in check_enum_coverage(src, enum_name, &WIRE_FNS) {
                violations.push(Violation {
                    file: rel.to_string(),
                    line: 1,
                    rule: Rule::WireExhaustive,
                    severity: Severity::Deny,
                    message: format!("{variant} is not covered by fn {fname}"),
                });
            }
        }
    }

    // pragmas that suppressed nothing are stale (warn); pragmas naming
    // an unknown rule are outright errors
    for (ln0, p) in pragmas.iter().enumerate() {
        if let Some(p) = p {
            if let Some(bad) = &p.bad_rule {
                violations.push(Violation {
                    file: rel.to_string(),
                    line: ln0 + 1,
                    rule: Rule::PragmaHygiene,
                    severity: Severity::Deny,
                    message: format!("unknown lint rule `{bad}` in lint:allow"),
                });
            } else if !p.used {
                violations.push(Violation {
                    file: rel.to_string(),
                    line: ln0 + 1,
                    rule: Rule::PragmaHygiene,
                    severity: Severity::Warn,
                    message: format!("stale lint:allow({}) suppresses nothing", p.rule),
                });
            }
        }
    }

    (violations, allowed)
}

fn covered_by_safety(masked: &Masked, line0: usize) -> bool {
    let c = masked.comment(line0);
    let d = masked.doc(line0);
    c.contains("SAFETY:") || d.contains("SAFETY:") || d.contains("# Safety")
}

/// Recursively collect `.rs` files under `root`, sorted, as
/// `/`-separated paths relative to `root`. Deterministic by
/// construction — the linter holds itself to its own rules.
fn rs_files(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("lint: cannot read {}", dir.display()))?
        .collect::<std::io::Result<_>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let path = e.path();
        if path.is_dir() {
            rs_files(root, &path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace(std::path::MAIN_SEPARATOR, "/");
            out.push(rel);
        }
    }
    Ok(())
}

/// Scan every `.rs` file under `src_root` (normally `rust/src`).
pub fn scan_tree(src_root: &Path) -> Result<Report> {
    let mut files = Vec::new();
    rs_files(src_root, src_root, &mut files)?;
    let mut report = Report::default();
    for rel in files {
        let src = std::fs::read_to_string(src_root.join(&rel))
            .with_context(|| format!("lint: cannot read {rel}"))?;
        let (violations, allowed) = scan_source(&rel, &src);
        report.files_scanned += 1;
        report.violations.extend(violations);
        report.allowed.extend(allowed);
    }
    Ok(report)
}

/// Locate the `rust/src` tree from a working directory: accepts being
/// run at the repo root, inside `rust/`, or inside `rust/src`.
pub fn default_src_root() -> Result<std::path::PathBuf> {
    let cwd = std::env::current_dir().context("lint: no working directory")?;
    for cand in [cwd.join("rust/src"), cwd.join("src"), cwd.clone()] {
        if cand.join("lib.rs").is_file() && cand.join("transport").is_dir() {
            return Ok(cand);
        }
    }
    anyhow::bail!(
        "lint: cannot find rust/src from {} (pass --root <dir>)",
        cwd.display()
    )
}
