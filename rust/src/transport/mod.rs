//! Transport — how `FitJob`s reach the worker fleet.
//!
//! The paper's Gradient Offloading (§3.2) ships `(x, grad_hhat)` to
//! low-cost devices that fit adapters independently. This module makes
//! that boundary real: the coordinator's
//! [`WorkerPool`](crate::coordinator::WorkerPool) dispatches every
//! worker operation through the [`Transport`] trait, with two
//! implementations:
//!
//! - **Local** — [`coordinator::Worker`](crate::coordinator::Worker):
//!   the in-process worker thread behind mpsc channels (the simulated
//!   offload arm; supports the
//!   [`TransferModel`](crate::coordinator::TransferModel) link sweeps).
//! - **Tcp** — [`tcp::TcpWorker`]: a proxy to a `cola worker` daemon in
//!   another process (or on another host), speaking the [`wire`] binary
//!   format over a socket, with reconnect-with-backoff and a clean
//!   shutdown handshake.
//!
//! Determinism contract: a worker daemon runs the same bit-identical
//! native kernels as an in-process worker, and [`wire`] round-trips
//! every f32 by bit pattern — so the same config trains to byte-equal
//! loss curves regardless of transport, including batched + pipelined
//! TCP (`offload_batch` / `offload_inflight`, which change framing and
//! scheduling but never numerics or apply order). Since wire v3 the
//! contract also survives pool **membership churn**: heartbeats
//! ([`Transport::ping`]) detect dead daemons, and bit-exact state
//! migration ([`Transport::export_state`] / [`Transport::import_state`])
//! moves shards between daemons, so elastic resizes and `failover =
//! "migrate"` recoveries leave loss curves byte-identical too. CI
//! enforces this on every PR (the `distributed-smoke` job incl. its
//! chaos shape), and `rust/tests/transport_tcp.rs` +
//! `rust/tests/transport_multi.rs` + `rust/tests/transport_chaos.rs`
//! mirror it as integration tests.
//!
//! One opt-out: `offload_wire = "bf16"` trades the bit-exact f32 wire
//! for 2-byte fit tensors (`Fit` / `FitBatch` requests only — replies,
//! registration, snapshots, and migration blobs stay raw-bit f32, so
//! adapter/optimizer state is never quantized and bf16 composes with
//! `failover = "migrate"`). The truncation itself is deterministic
//! (round-to-nearest-even, pure function of the source bits), so a
//! bf16 run is exactly reproducible against its own config; it is just
//! no longer byte-identical to the f32 run. The
//! [`Transport::take_wire_bytes`] ledger feeds the bytes/interval
//! trajectory that CI's wire benchmark gates on.
//!
//! The same bit-exact `StateExport` blobs double as the FTaaS
//! gateway's download format: `GET /v1/jobs/{id}/adapter` serves a
//! bundle of [`wire::encode_state`] blobs (via
//! [`Trainer::export_adapter_bundle`](crate::coordinator::Trainer::export_adapter_bundle)),
//! so an adapter fetched over HTTP is the identical byte sequence a
//! daemon would export — see [`crate::gateway`].

pub mod tcp;
pub mod wire;

use std::sync::mpsc::Receiver;

use anyhow::Result;

use crate::adapters::{AdapterParams, SiteAdapter};
use crate::coordinator::offload::{FitJob, FitResult};

/// One end of a worker link. All operations are request/reply;
/// [`Transport::fit`] is the asynchronous exception — the reply arrives
/// on the returned channel so the server can overlap fits with its own
/// steps (`async_offload`).
pub trait Transport: Send {
    /// Worker id — a stable label for logs and error messages (the pool
    /// shards users by rendezvous hashing over member keys, not ids).
    fn id(&self) -> usize;

    /// Human-readable endpoint (for error messages and logs).
    fn describe(&self) -> String;

    /// Install an adapter (+ optimizer state) for (user, site) on the
    /// worker. Blocks until the worker acknowledges.
    fn register(&self, user: usize, site: &str, adapter: SiteAdapter) -> Result<()>;

    /// Dispatch one buffered-interval fit. The returned channel yields
    /// exactly one reply; a dropped channel means the worker link died.
    fn fit(&self, job: FitJob) -> Result<Receiver<Result<FitResult>>>;

    /// Dispatch a whole interval's jobs for this worker, returning one
    /// reply channel per job **in job order**. The default is N
    /// independent [`Transport::fit`] round-trips; transports that can
    /// batch on the wire ([`tcp::TcpWorker`] with `offload_batch`)
    /// override it to ship `FitBatch` frames instead. Results are
    /// bit-identical either way — batching only changes framing, never
    /// numerics — which is what lets the determinism contract span all
    /// three dispatch shapes (local, tcp, tcp-batched).
    fn fit_many(&self, jobs: Vec<FitJob>) -> Result<Vec<Receiver<Result<FitResult>>>> {
        jobs.into_iter().map(|j| self.fit(j)).collect()
    }

    /// How many request/reply wire exchanges [`Transport::fit_many`]
    /// costs for `n_jobs` jobs — the round-trips/interval ledger
    /// (`Timings::round_trips`, EXPERIMENTS.md). In-process transports
    /// count one exchange per job.
    fn fit_frames(&self, n_jobs: usize) -> u64 {
        n_jobs as u64
    }

    /// Fetch a copy of an adapter's parameters.
    fn snapshot(&self, user: usize, site: &str) -> Result<AdapterParams>;

    /// Bytes of adapter + optimizer state held by the worker.
    fn state_bytes(&self) -> Result<usize>;

    /// Liveness heartbeat. Returns the worker's current load (in-flight
    /// fits); an `Err` means the worker is unreachable and the pool
    /// supervisor should fail it over. In-process workers are alive by
    /// construction.
    fn ping(&self) -> Result<u64> {
        Ok(0)
    }

    /// Export the full adapter + optimizer state of one `(user, site)`
    /// shard as an opaque, bit-exact migration blob
    /// ([`wire::encode_state`] layout). Feed it unchanged to
    /// [`Transport::import_state`] on the new owner.
    fn export_state(&self, user: usize, site: &str) -> Result<Vec<u8>>;

    /// Install a migration blob exported from another worker, replacing
    /// any existing state for the blob's `(user, site)` key.
    fn import_state(&self, blob: Vec<u8>) -> Result<()>;

    /// Drop a shard's state after it has been migrated away (keeps the
    /// old owner's resident-memory accounting honest). Evicting an
    /// absent key is a no-op.
    fn evict_state(&self, user: usize, site: &str) -> Result<()>;

    /// Store a shard's replica blob (a [`wire::encode_state`] payload,
    /// bit-exact) in the worker's passive replica store. Replicas never
    /// serve fits until promoted, so a buddy holds copies of shards it
    /// does not own. Only meaningful for remote workers — an in-process
    /// pool shares one failure domain with the trainer, so replicating
    /// inside it buys nothing and the default refuses loudly.
    fn put_replica(&self, blob: Vec<u8>) -> Result<()> {
        let _ = blob;
        anyhow::bail!("transport {} does not hold buddy replicas", self.describe())
    }

    /// Promote a previously pushed replica to live state in place —
    /// the zero-wire-cost half of buddy failover. Errors if no replica
    /// exists for the key.
    fn promote_replica(&self, user: usize, site: &str) -> Result<()> {
        let _ = (user, site);
        anyhow::bail!("transport {} does not hold buddy replicas", self.describe())
    }

    /// Discard a replica after the buddy assignment moved elsewhere.
    /// Dropping an absent key is a no-op.
    fn drop_replica(&self, user: usize, site: &str) -> Result<()> {
        let _ = (user, site);
        anyhow::bail!("transport {} does not hold buddy replicas", self.describe())
    }

    /// Drain the request-byte ledger: bytes this transport has put on
    /// the wire (frame headers included) since the last call. Feeds
    /// `Timings::wire_bytes` — the bytes/interval trajectory that the
    /// wire benchmark and `distributed_smoke.sh wire` gate on.
    /// In-process transports ship nothing and report 0.
    fn take_wire_bytes(&self) -> u64 {
        0
    }

    /// Adapter-state paging counters (faults, evictions, page writes,
    /// page errors) for workers running an LRU-paged state store.
    /// Unpaged and remote transports report zeros — paging is a local
    /// working-set concern, not a wire-protocol one.
    fn page_stats(&self) -> Result<crate::scale::store::PageStats> {
        Ok(crate::scale::store::PageStats::default())
    }

    /// Release this link. For a local worker the thread exits; for a
    /// TCP worker only the connection closes — the daemon (and its
    /// adapter state) stays up for reconnects. Use
    /// [`tcp::request_daemon_shutdown`] to terminate a daemon.
    fn shutdown(&self);
}
