//! The offload wire format — zero-dependency binary framing for every
//! message that crosses the server/worker boundary.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! frame   := magic(4 = "CoLA") | version(1) | len:u32 | payload[len]
//! payload := tag:u8 | body
//! tensor  := dtype:u8 | rank:u8 | dims:u32^rank | data (elements, LE)
//! string  := len:u32 | utf8 bytes
//! dtype   := 0 (f32, 4 bytes/elem) | 1 (i32, 4) | 2 (bf16, 2)
//! ```
//!
//! Versioning: the frame header carries the lowest protocol version
//! whose decoder understands the payload. v1 covers the original
//! request/reply messages; v2 adds the multi-tenant handshake
//! ([`Msg::Hello`]) and batched fits ([`Msg::FitBatch`] /
//! [`Msg::FitBatchOk`]); v3 adds the elastic-pool control plane —
//! heartbeats ([`Msg::Ping`] / [`Msg::Pong`]) and live state migration
//! ([`Msg::StateExport`] / [`Msg::StateExportOk`] / [`Msg::StateImport`]
//! / [`Msg::StateEvict`]). A v3 build decodes every version, and
//! [`send`] stamps each message with [`frame_version`] — v1 messages
//! keep v1 frames, so a v1 peer and a v3 peer interoperate as long as
//! nobody *sends* a newer-versioned message (exactly the
//! `offload_batch = false`, empty-tenant, `failover = "fail"`
//! configuration).
//!
//! State migration blobs ([`encode_state`] / [`decode_state`]) carry a
//! `(user, site)` key plus the full adapter + optimizer state with the
//! same bit-pattern f32 encoding as everything else, so an exported
//! shard re-imported on another daemon is indistinguishable — down to
//! NaN payload bits in AdamW moments — from the original.
//!
//! f32 elements are shipped as raw IEEE-754 bit patterns
//! (`f32::to_bits` / `from_bits`), so every value — including NaN
//! payload bits, `±inf`, and `-0.0` — round-trips exactly. This is what
//! makes the determinism guarantee of the TCP offload path possible:
//! a worker daemon receives bit-identical `(x, grad_hhat)` buffers and
//! returns bit-identical adapter tensors, so loopback-TCP and
//! in-process runs produce byte-equal loss curves.
//!
//! Wire compression (`offload_wire = "bf16"`): opt-in, negotiated via
//! the v3 [`Msg::Hello`] capability byte. When active, ONLY the
//! `(x, grad_hhat)` activation/gradient tensors inside [`Msg::Fit`] /
//! [`Msg::FitBatch`] are shipped as bf16 (dtype 2, 2 bytes/element,
//! round-to-nearest-even — see [`f32_to_bf16`]); every reply, the
//! registration payload, snapshots, and the migration blobs of
//! [`encode_state`] / [`decode_state`] stay raw-bit f32 unconditionally,
//! so adapter state remains bit-exact regardless of the wire format
//! (this is what makes `offload_wire = "bf16"` safe to combine with
//! `failover = "migrate"`). The truncation is deterministic — the
//! decoded value is a pure function of the source bits, and
//! `encode(decode(h))` is the identity on all 2^16 bf16 patterns — so
//! a bf16 run is still exactly reproducible, merely against a
//! quantized gradient stream.
//!
//! Decoding is defensive: a wrong magic, an oversized length header, a
//! truncated frame, an unknown tag, or a body shorter than its own
//! headers claim all surface as errors — never panics or wild
//! allocations.

use std::io::{Read, Write};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::adapters::{AdapterParams, OptState, OptimizerCfg, SiteAdapter};
use crate::config::{AdapterKind, Optimizer, WireFormat};
use crate::coordinator::offload::{FitJob, FitResult};
use crate::runtime::{IntTensor, Value};
use crate::tensor::Tensor;

/// Frame magic: ASCII "CoLA".
pub const MAGIC: [u8; 4] = *b"CoLA";
/// Highest wire protocol version this build speaks (bump on any layout
/// change).
pub const VERSION: u8 = 3;
/// Lowest version this build still decodes.
pub const MIN_VERSION: u8 = 1;
/// Upper bound on a single frame payload (1 GiB) — anything larger is
/// treated as a corrupt length header, not an allocation request.
pub const MAX_FRAME: usize = 1 << 30;

/// Every message exchanged between the coordinator and a worker daemon.
///
/// Requests flow server -> worker; each gets exactly one reply
/// (`*Ok`, [`Msg::Ack`], or [`Msg::Error`]) worker -> server.
#[derive(Debug)]
pub enum Msg {
    /// Install an adapter (+ optimizer state) for (user, site).
    Register { user: usize, site: String, adapter: SiteAdapter },
    /// Fit one buffered adaptation interval.
    Fit(FitJob),
    /// Reply to [`Msg::Fit`].
    FitOk(FitResult),
    /// Fetch a snapshot of an adapter's parameters.
    Snapshot { user: usize, site: String },
    /// Reply to [`Msg::Snapshot`].
    SnapshotOk(AdapterParams),
    /// Ask for the bytes of adapter + optimizer state held remotely.
    StateBytes,
    /// Reply to [`Msg::StateBytes`].
    StateBytesOk(u64),
    /// Clean-shutdown handshake: the daemon acks and exits.
    Shutdown,
    /// Reply to [`Msg::Shutdown`] — sent just before the daemon exits.
    ShutdownOk,
    /// Generic success reply (e.g. to [`Msg::Register`]).
    Ack,
    /// Failure reply carrying the remote error chain.
    Error(String),
    /// v2: declare this connection's tenant namespace. All subsequent
    /// `(user, site)` keys on the connection resolve under the tenant,
    /// so several trainers can share one daemon. v1 clients never send
    /// it and land in the default `""` namespace. Reply: [`Msg::Ack`].
    ///
    /// v3 extends the body with a wire-format capability byte when the
    /// client wants bf16-compressed fit tensors. A plain f32 `Hello`
    /// encodes byte-identically to its v2 form (no trailing byte), so
    /// old daemons keep decoding it; a bf16 `Hello` grows one byte and
    /// ships in a v3 frame — a pre-bf16 daemon rejects the trailing
    /// byte with [`Msg::Error`], which the client treats as "capability
    /// absent" and falls back to f32.
    Hello { tenant: String, wire: WireFormat },
    /// v2: one interval's worth of fits in a single frame. `seq` is the
    /// client's frame sequence number; the reply echoes it so a
    /// pipelined client can pair replies with in-flight windows.
    FitBatch { seq: u64, jobs: Vec<FitJob> },
    /// Reply to [`Msg::FitBatch`]: one item per job, in job order. A
    /// failing job carries its own error (naming user and site) without
    /// poisoning the rest of the batch.
    FitBatchOk { seq: u64, results: Vec<BatchItem> },
    /// v3: liveness heartbeat. The pool supervisor sends one per member
    /// at interval boundaries; a member that cannot answer is declared
    /// dead and failed over. Reply: [`Msg::Pong`].
    Ping,
    /// Reply to [`Msg::Ping`]. `load` is the daemon's current number of
    /// in-flight fits (checked-out adapters), a cheap busyness signal
    /// for future load-aware placement.
    Pong { load: u64 },
    /// v3: export the full adapter + optimizer state of one
    /// `(user, site)` shard, bit-exactly, for migration to another
    /// daemon. Resolved under the connection's tenant namespace. Reply:
    /// [`Msg::StateExportOk`].
    StateExport { user: usize, site: String },
    /// Reply to [`Msg::StateExport`]: an opaque state blob produced by
    /// [`encode_state`] — ship it to the new owner in a
    /// [`Msg::StateImport`] unchanged.
    StateExportOk(Vec<u8>),
    /// v3: install a migrated state blob (from [`Msg::StateExportOk`])
    /// under the connection's tenant namespace, replacing any existing
    /// state for the blob's `(user, site)` key. Reply: [`Msg::Ack`].
    StateImport(Vec<u8>),
    /// v3: drop the state of one `(user, site)` shard after it has been
    /// migrated away, so the old owner's resident-memory accounting
    /// stays honest. Evicting an absent key is a no-op. Reply:
    /// [`Msg::Ack`].
    StateEvict { user: usize, site: String },
    /// v3 (registry): store a shard's replica blob — an [`encode_state`]
    /// payload, bit-exact — in the daemon's *replica store* under the
    /// connection's tenant namespace. Replicas are passive: they never
    /// serve fits, snapshots, or exports until promoted, so a buddy can
    /// hold a copy of a shard it does not own without the two colliding.
    /// Re-putting a key replaces the previous replica. Reply:
    /// [`Msg::Ack`].
    ReplicaPut(Vec<u8>),
    /// v3 (registry): promote a replica to live state — decode the
    /// stored blob and install it exactly as a [`Msg::StateImport`]
    /// would, then drop the replica entry. This is the zero-copy half of
    /// buddy failover: the bytes are already resident on the new owner,
    /// so promotion ships no state on the wire. Errors (and leaves the
    /// replica in place) if no replica exists or the key is mid-fit.
    /// Reply: [`Msg::Ack`].
    ReplicaPromote { user: usize, site: String },
    /// v3 (registry): discard a replica after the buddy assignment moved
    /// elsewhere. Dropping an absent key is a no-op. Reply: [`Msg::Ack`].
    ReplicaDrop { user: usize, site: String },
    /// v3 (registry): a daemon announcing itself to a coordinator's
    /// registry listener (`cola worker --join`). `addr` is the daemon's
    /// own resolved listen address — the coordinator dials back through
    /// the normal [`Msg::Hello`] handshake, which is where capabilities
    /// are negotiated exactly as for a statically configured member.
    /// Reply: [`Msg::Ack`] (registered, lifecycle `joining`) or
    /// [`Msg::Error`]. A pre-registry peer answers `Error`
    /// ("unexpected message"), which a joiner reports loudly — the same
    /// reject-then-fall-back shape as the bf16 `Hello` capability byte.
    Join { addr: String },
}

/// Per-job outcome inside a [`Msg::FitBatchOk`].
#[derive(Debug)]
pub enum BatchItem {
    Ok(FitResult),
    Err { user: usize, site: String, error: String },
}

mod tag {
    pub const REGISTER: u8 = 0x01;
    pub const FIT: u8 = 0x02;
    pub const FIT_OK: u8 = 0x03;
    pub const SNAPSHOT: u8 = 0x04;
    pub const SNAPSHOT_OK: u8 = 0x05;
    pub const STATE_BYTES: u8 = 0x06;
    pub const STATE_BYTES_OK: u8 = 0x07;
    pub const SHUTDOWN: u8 = 0x08;
    pub const SHUTDOWN_OK: u8 = 0x09;
    pub const ACK: u8 = 0x0A;
    pub const ERROR: u8 = 0x0B;
    // v2 additions
    pub const FIT_BATCH: u8 = 0x0C;
    pub const FIT_BATCH_OK: u8 = 0x0D;
    pub const HELLO: u8 = 0x0E;
    // v3 additions
    pub const PING: u8 = 0x0F;
    pub const PONG: u8 = 0x10;
    pub const STATE_EXPORT: u8 = 0x11;
    pub const STATE_EXPORT_OK: u8 = 0x12;
    pub const STATE_IMPORT: u8 = 0x13;
    pub const STATE_EVICT: u8 = 0x14;
    // v3 registry additions (worker self-registration + buddy replicas)
    pub const REPLICA_PUT: u8 = 0x15;
    pub const REPLICA_PROMOTE: u8 = 0x16;
    pub const REPLICA_DROP: u8 = 0x17;
    pub const JOIN: u8 = 0x18;
}

/// The lowest frame version whose decoder understands `msg` — what
/// [`send`] stamps on the frame, keeping v1 traffic v1-framed.
pub fn frame_version(msg: &Msg) -> u8 {
    match msg {
        Msg::Ping
        | Msg::Pong { .. }
        | Msg::StateExport { .. }
        | Msg::StateExportOk(_)
        | Msg::StateImport(_)
        | Msg::StateEvict { .. }
        | Msg::ReplicaPut(_)
        | Msg::ReplicaPromote { .. }
        | Msg::ReplicaDrop { .. }
        | Msg::Join { .. } => 3,
        // a bf16-capability Hello carries the v3 trailing byte
        Msg::Hello { wire: WireFormat::Bf16, .. } => 3,
        Msg::Hello { .. } | Msg::FitBatch { .. } | Msg::FitBatchOk { .. } => 2,
        _ => 1,
    }
}

/// [`frame_version`], format-aware: fit traffic encoded with bf16
/// tensors (dtype 2) needs a v3 decoder, so [`send_with`] stamps it v3
/// even though the same message encodes as a v1/v2 frame under f32.
pub fn frame_version_with(msg: &Msg, fmt: WireFormat) -> u8 {
    match (fmt, msg) {
        (WireFormat::Bf16, Msg::Fit(_) | Msg::FitBatch { .. }) => 3,
        _ => frame_version(msg),
    }
}

// ---------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------

/// Write one v1 frame (header + payload) and flush — kept for callers
/// that ship raw v1 payloads; [`send`] picks the version per message.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    write_frame_v(w, MIN_VERSION, payload)
}

/// Write one frame with an explicit version byte and flush.
pub fn write_frame_v(w: &mut impl Write, version: u8, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        bail!("wire: payload of {} bytes exceeds MAX_FRAME", payload.len());
    }
    w.write_all(&MAGIC)?;
    w.write_all(&[version])?;
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame, validating magic/version/length before allocating.
/// Every version in `MIN_VERSION..=VERSION` is accepted — v1 peers stay
/// decodable forever.
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut head = [0u8; 9];
    r.read_exact(&mut head)?;
    if head[0..4] != MAGIC {
        bail!("wire: bad magic {:02x?} (expected {:02x?})", &head[0..4], MAGIC);
    }
    if !(MIN_VERSION..=VERSION).contains(&head[4]) {
        bail!(
            "wire: protocol version {} (this build speaks {MIN_VERSION}..={VERSION})",
            head[4]
        );
    }
    let len = u32::from_le_bytes([head[5], head[6], head[7], head[8]]) as usize;
    if len > MAX_FRAME {
        bail!("wire: frame length {len} exceeds MAX_FRAME (corrupt header?)");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(payload)
}

/// Encode + frame + send one message, stamping the lowest frame version
/// that understands it (v1 messages stay interoperable with v1 peers).
/// Returns the total bytes written (header + payload) — the unit of the
/// `wire_bytes` ledger.
pub fn send(w: &mut impl Write, msg: &Msg) -> Result<usize> {
    send_with(w, msg, WireFormat::F32)
}

/// [`send`] with an explicit wire format for the fit tensors. Under
/// [`WireFormat::Bf16`] the `(x, grad_hhat)` tensors of [`Msg::Fit`] /
/// [`Msg::FitBatch`] ship as dtype-2 bf16 in a v3 frame; every other
/// message (and every reply) is byte-identical to the f32 path.
pub fn send_with(w: &mut impl Write, msg: &Msg, fmt: WireFormat) -> Result<usize> {
    let payload = encode_with(msg, fmt);
    write_frame_v(w, frame_version_with(msg, fmt), &payload)?;
    // 4 magic + 1 version + 4 length + payload
    Ok(9 + payload.len())
}

/// Receive + decode one message.
pub fn recv(r: &mut impl Read) -> Result<Msg> {
    decode(&read_frame(r)?)
}

// ---------------------------------------------------------------------
// bf16
// ---------------------------------------------------------------------

/// f32 → bf16 with round-to-nearest-even (ties to even), the rounding
/// every bf16-native accelerator stack uses.
///
/// The conversion is a pure function of the source bits, and
/// [`bf16_to_f32`] followed by `f32_to_bf16` is the identity on all
/// 2^16 bf16 patterns — together these give the wire's deterministic
/// round-trip contract: re-encoding a decoded bf16 tensor reproduces
/// the original bytes exactly.
pub fn f32_to_bf16(v: f32) -> u16 {
    let bits = v.to_bits();
    if v.is_nan() {
        let top = (bits >> 16) as u16;
        // Truncation may zero every kept mantissa bit, turning a NaN
        // into an infinity — set the quiet bit only in that case, and
        // leave all other NaN payloads untouched so the round-trip
        // identity above holds for NaN patterns too.
        if top & 0x007F == 0 {
            top | 0x0040
        } else {
            top
        }
    } else {
        // Classic RNE via the carry trick: adding 0x7FFF plus the
        // round-even bit either leaves the top half alone or carries
        // one ulp into it. Max finite input is 0x7F7F_FFFF, so the u32
        // addition cannot overflow, and max-finite f32 correctly
        // rounds up to bf16 infinity.
        let round = ((bits >> 16) & 1) + 0x7FFF;
        ((bits + round) >> 16) as u16
    }
}

/// bf16 → f32: exact (bf16 is a prefix of f32, so widening just
/// restores sixteen zero mantissa bits).
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

// ---------------------------------------------------------------------
// encoding
// ---------------------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new(tag: u8) -> Enc {
        Enc { buf: vec![tag] }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f32(&mut self, v: f32) {
        // bit pattern, not value: NaN payloads and -0.0 survive
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn bytes(&mut self, b: &[u8]) {
        self.u32(b.len() as u32);
        self.buf.extend_from_slice(b);
    }

    fn tensor(&mut self, t: &Tensor) {
        self.u8(0); // dtype: f32
        self.u8(t.shape().len() as u8);
        for &d in t.shape() {
            self.u32(d as u32);
        }
        for &v in t.data() {
            self.f32(v);
        }
    }

    /// bf16-compressed tensor (dtype 2): RNE-truncated to 2 bytes per
    /// element. Only ever emitted for fit `(x, ghat)` payloads — state,
    /// snapshots, and replies always go through [`Enc::tensor`].
    fn tensor_bf16(&mut self, t: &Tensor) {
        self.u8(2); // dtype: bf16
        self.u8(t.shape().len() as u8);
        for &d in t.shape() {
            self.u32(d as u32);
        }
        for &v in t.data() {
            self.buf.extend_from_slice(&f32_to_bf16(v).to_le_bytes());
        }
    }

    /// Fit tensor dispatch on the negotiated wire format.
    fn fit_tensor(&mut self, t: &Tensor, fmt: WireFormat) {
        match fmt {
            WireFormat::F32 => self.tensor(t),
            WireFormat::Bf16 => self.tensor_bf16(t),
        }
    }

    fn int_tensor(&mut self, t: &IntTensor) {
        self.u8(1); // dtype: i32
        self.u8(t.shape().len() as u8);
        for &d in t.shape() {
            self.u32(d as u32);
        }
        for &v in t.data() {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn params(&mut self, p: &AdapterParams) {
        self.u8(kind_tag(p.kind()));
        let ts = p.tensors();
        self.u8(ts.len() as u8);
        for t in ts {
            self.tensor(t);
        }
    }

    fn opt_state(&mut self, o: &OptState) {
        let c = &o.cfg;
        self.u8(match c.kind {
            Optimizer::Sgd => 0,
            Optimizer::AdamW => 1,
        });
        self.f32(c.lr);
        self.f32(c.weight_decay);
        self.f32(c.beta1);
        self.f32(c.beta2);
        self.f32(c.eps);
        self.u32(o.t);
        let (m, v) = o.moments();
        for vecs in [m, v] {
            self.u32(vecs.len() as u32);
            for xs in vecs {
                self.u32(xs.len() as u32);
                for &x in xs {
                    self.f32(x);
                }
            }
        }
    }

    fn duration(&mut self, d: Duration) {
        self.u64(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// FitJob body — shared by [`Msg::Fit`] and [`Msg::FitBatch`] so the
    /// two layouts can never drift apart. The `(x, ghat)` tensors are
    /// the ONLY wire payloads that honour the negotiated format;
    /// `grad_scale` stays a raw-bit f32 either way.
    fn fit_job(&mut self, job: &FitJob, fmt: WireFormat) {
        self.u64(job.user as u64);
        self.str(&job.site);
        self.fit_tensor(&job.x, fmt);
        self.fit_tensor(&job.ghat, fmt);
        self.f32(job.grad_scale);
        self.u8(job.merged as u8);
    }

    /// FitResult body — shared by [`Msg::FitOk`] and [`Msg::FitBatchOk`].
    fn fit_result(&mut self, r: &FitResult) {
        self.u64(r.user as u64);
        self.str(&r.site);
        match &r.new_params {
            Some(ps) => {
                self.u8(1);
                self.u32(ps.len() as u32);
                for t in ps {
                    self.tensor(t);
                }
            }
            None => self.u8(0),
        }
        match &r.delta_diff {
            Some(t) => {
                self.u8(1);
                self.tensor(t);
            }
            None => self.u8(0),
        }
        self.duration(r.compute);
        self.duration(r.transfer);
        self.u64(r.bytes_in as u64);
        self.u64(r.bytes_out as u64);
    }
}

fn kind_tag(k: AdapterKind) -> u8 {
    match k {
        AdapterKind::LowRank => 0,
        AdapterKind::Linear => 1,
        AdapterKind::Mlp => 2,
    }
}

/// Serialize a message payload (framing is separate — see
/// [`write_frame`]). Always raw-bit f32; equivalent to
/// [`encode_with`] at [`WireFormat::F32`].
pub fn encode(msg: &Msg) -> Vec<u8> {
    encode_with(msg, WireFormat::F32)
}

/// Serialize a message payload with an explicit wire format for fit
/// tensors. Every message except [`Msg::Fit`] / [`Msg::FitBatch`]
/// encodes identically under both formats — state blobs, registration,
/// snapshots, and all replies are f32 by construction.
pub fn encode_with(msg: &Msg, fmt: WireFormat) -> Vec<u8> {
    match msg {
        Msg::Register { user, site, adapter } => {
            let mut e = Enc::new(tag::REGISTER);
            e.u64(*user as u64);
            e.str(site);
            e.str(&adapter.site);
            e.params(&adapter.params);
            e.opt_state(&adapter.opt);
            e.buf
        }
        Msg::Fit(job) => {
            let mut e = Enc::new(tag::FIT);
            e.fit_job(job, fmt);
            e.buf
        }
        Msg::FitOk(r) => {
            let mut e = Enc::new(tag::FIT_OK);
            e.fit_result(r);
            e.buf
        }
        Msg::FitBatch { seq, jobs } => {
            let mut e = Enc::new(tag::FIT_BATCH);
            e.u64(*seq);
            e.u32(jobs.len() as u32);
            for job in jobs {
                e.fit_job(job, fmt);
            }
            e.buf
        }
        Msg::FitBatchOk { seq, results } => {
            let mut e = Enc::new(tag::FIT_BATCH_OK);
            e.u64(*seq);
            e.u32(results.len() as u32);
            for item in results {
                match item {
                    BatchItem::Ok(r) => {
                        e.u8(1);
                        e.fit_result(r);
                    }
                    BatchItem::Err { user, site, error } => {
                        e.u8(0);
                        e.u64(*user as u64);
                        e.str(site);
                        e.str(error);
                    }
                }
            }
            e.buf
        }
        Msg::Hello { tenant, wire } => {
            let mut e = Enc::new(tag::HELLO);
            e.str(tenant);
            // f32 Hellos encode byte-identically to their pre-bf16 form
            // (no trailing byte), so old daemons keep decoding them; the
            // capability byte exists only in the bf16 variant.
            if *wire == WireFormat::Bf16 {
                e.u8(1);
            }
            e.buf
        }
        Msg::Snapshot { user, site } => {
            let mut e = Enc::new(tag::SNAPSHOT);
            e.u64(*user as u64);
            e.str(site);
            e.buf
        }
        Msg::SnapshotOk(p) => {
            let mut e = Enc::new(tag::SNAPSHOT_OK);
            e.params(p);
            e.buf
        }
        Msg::StateBytes => vec![tag::STATE_BYTES],
        Msg::StateBytesOk(n) => {
            let mut e = Enc::new(tag::STATE_BYTES_OK);
            e.u64(*n);
            e.buf
        }
        Msg::Ping => vec![tag::PING],
        Msg::Pong { load } => {
            let mut e = Enc::new(tag::PONG);
            e.u64(*load);
            e.buf
        }
        Msg::StateExport { user, site } => {
            let mut e = Enc::new(tag::STATE_EXPORT);
            e.u64(*user as u64);
            e.str(site);
            e.buf
        }
        Msg::StateExportOk(blob) => {
            let mut e = Enc::new(tag::STATE_EXPORT_OK);
            e.bytes(blob);
            e.buf
        }
        Msg::StateImport(blob) => {
            let mut e = Enc::new(tag::STATE_IMPORT);
            e.bytes(blob);
            e.buf
        }
        Msg::StateEvict { user, site } => {
            let mut e = Enc::new(tag::STATE_EVICT);
            e.u64(*user as u64);
            e.str(site);
            e.buf
        }
        Msg::ReplicaPut(blob) => {
            let mut e = Enc::new(tag::REPLICA_PUT);
            e.bytes(blob);
            e.buf
        }
        Msg::ReplicaPromote { user, site } => {
            let mut e = Enc::new(tag::REPLICA_PROMOTE);
            e.u64(*user as u64);
            e.str(site);
            e.buf
        }
        Msg::ReplicaDrop { user, site } => {
            let mut e = Enc::new(tag::REPLICA_DROP);
            e.u64(*user as u64);
            e.str(site);
            e.buf
        }
        Msg::Join { addr } => {
            let mut e = Enc::new(tag::JOIN);
            e.str(addr);
            e.buf
        }
        Msg::Shutdown => vec![tag::SHUTDOWN],
        Msg::ShutdownOk => vec![tag::SHUTDOWN_OK],
        Msg::Ack => vec![tag::ACK],
        Msg::Error(s) => {
            let mut e = Enc::new(tag::ERROR);
            e.str(s);
            e.buf
        }
    }
}

/// Serialize a runtime [`Value`] (either dtype) with the same tensor
/// layout the messages use — the interchange format for future
/// artifact/buffer shipping.
pub fn encode_value(v: &Value) -> Vec<u8> {
    let mut e = Enc { buf: Vec::new() };
    match v {
        Value::F32(t) => e.tensor(t),
        Value::I32(t) => e.int_tensor(t),
    }
    e.buf
}

/// Decode a [`Value`] encoded by [`encode_value`].
pub fn decode_value(buf: &[u8]) -> Result<Value> {
    let mut d = Dec { buf, pos: 0 };
    let v = d.value()?;
    d.finish()?;
    Ok(v)
}

/// Serialize one shard's full state — the `(user, site)` key plus the
/// adapter parameters and optimizer moments — as the opaque migration
/// blob carried by [`Msg::StateExportOk`] / [`Msg::StateImport`].
///
/// Every f32 ships as its raw bit pattern, so an export/import
/// round-trip is bit-exact: the importing daemon's next fit is
/// indistinguishable from one served by the original owner. This is
/// what lets a pool resize (or a failover) leave loss curves
/// byte-identical.
pub fn encode_state(user: usize, site: &str, adapter: &SiteAdapter) -> Vec<u8> {
    let mut e = Enc { buf: Vec::new() };
    e.u64(user as u64);
    e.str(site);
    e.str(&adapter.site);
    e.params(&adapter.params);
    e.opt_state(&adapter.opt);
    e.buf
}

/// Decode a migration blob produced by [`encode_state`]. Shares the
/// defensive decoder with the message bodies: truncation, corrupt
/// element counts, and unknown tags all surface as errors — never
/// panics or unbounded allocations.
pub fn decode_state(blob: &[u8]) -> Result<(usize, String, SiteAdapter)> {
    let mut d = Dec { buf: blob, pos: 0 };
    let user = d.u64()? as usize;
    let site = d.str()?;
    let adapter_site = d.str()?;
    let params = d.params()?;
    let opt = d.opt_state()?;
    d.finish()?;
    Ok((user, site, SiteAdapter { site: adapter_site, params, opt }))
}

// ---------------------------------------------------------------------
// decoding
// ---------------------------------------------------------------------

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!(
                "wire: truncated payload (need {n} bytes at offset {}, have {})",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let b = self.take(n)?;
        Ok(std::str::from_utf8(b)
            .map_err(|e| anyhow!("wire: non-utf8 string: {e}"))?
            .to_string())
    }

    /// Length-prefixed opaque byte blob. `take` bounds-checks the
    /// claimed length against the remaining payload before any copy, so
    /// a corrupt header can never trigger a wild allocation.
    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    /// Remaining undecoded bytes — the hard ceiling for any element
    /// count a header can legitimately claim.
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Guard an element count claimed by a header BEFORE allocating for
    /// it: each element occupies `size` bytes (4 for f32/i32, 2 for
    /// bf16), so anything larger than the remaining payload is a
    /// corrupt header, not an allocation request (a 20-byte frame must
    /// not reserve gigabytes).
    fn guard_elems(&self, len: usize, size: usize, what: &str) -> Result<()> {
        if len > self.remaining() / size {
            bail!(
                "wire: {what} claims {len} elements but only {} payload \
                 bytes remain (corrupt header?)",
                self.remaining()
            );
        }
        Ok(())
    }

    /// Shape header shared by all dtypes; guards rank and element
    /// count (at the dtype's element size) before any allocation.
    fn shape(&mut self, elem_size: usize) -> Result<(Vec<usize>, usize)> {
        let rank = self.u8()? as usize;
        if rank > 4 {
            bail!("wire: tensor rank {rank} exceeds the supported maximum of 4");
        }
        let mut shape = Vec::with_capacity(rank);
        let mut len: usize = 1;
        for _ in 0..rank {
            let d = self.u32()? as usize;
            len = len
                .checked_mul(d)
                .ok_or_else(|| anyhow!("wire: tensor shape overflows"))?;
            shape.push(d);
        }
        self.guard_elems(len, elem_size, "tensor")?;
        Ok((shape, len))
    }

    fn tensor(&mut self) -> Result<Tensor> {
        match self.value()? {
            Value::F32(t) => Ok(t),
            Value::I32(_) => bail!("wire: expected f32 tensor, got i32"),
        }
    }

    fn value(&mut self) -> Result<Value> {
        let dtype = self.u8()?;
        let elem_size = if dtype == 2 { 2 } else { 4 };
        let (shape, len) = self.shape(elem_size)?;
        match dtype {
            0 => {
                let mut data = Vec::with_capacity(len);
                for _ in 0..len {
                    data.push(self.f32()?);
                }
                Ok(Value::F32(Tensor::new(shape, data)))
            }
            1 => {
                let mut data = Vec::with_capacity(len);
                for _ in 0..len {
                    data.push(self.u32()? as i32);
                }
                Ok(Value::I32(IntTensor::new(shape, data)))
            }
            2 => {
                // bf16 widens to f32 on arrival — downstream math is
                // all-f32 either way, the wire is the only place the
                // narrow format exists
                let mut data = Vec::with_capacity(len);
                for _ in 0..len {
                    let b = self.take(2)?;
                    data.push(bf16_to_f32(u16::from_le_bytes([b[0], b[1]])));
                }
                Ok(Value::F32(Tensor::new(shape, data)))
            }
            other => bail!("wire: unknown dtype {other}"),
        }
    }

    fn params(&mut self) -> Result<AdapterParams> {
        let kind = self.u8()?;
        let n = self.u8()? as usize;
        let mut ts = Vec::with_capacity(n);
        for _ in 0..n {
            ts.push(self.tensor()?);
        }
        // arity mismatches surface as decode errors instead of being
        // unwrapped away — `try_into` to a fixed-size array checks the
        // count and moves the tensors in one step
        fn fixed<const N: usize>(ts: Vec<Tensor>, what: &str) -> Result<[Tensor; N]> {
            let got = ts.len();
            ts.try_into()
                .map_err(|_| anyhow!("wire: {what} adapter needs {N} tensors, got {got}"))
        }
        match kind {
            0 => {
                let [a, b] = fixed(ts, "low-rank")?;
                Ok(AdapterParams::LowRank { a, b })
            }
            1 => {
                let [w] = fixed(ts, "linear")?;
                Ok(AdapterParams::Linear { w })
            }
            2 => {
                let [w1, b1, w2, b2] = fixed(ts, "mlp")?;
                Ok(AdapterParams::Mlp { w1, b1, w2, b2 })
            }
            k => bail!("wire: unknown adapter kind tag {k}"),
        }
    }

    fn opt_state(&mut self) -> Result<OptState> {
        let kind = match self.u8()? {
            0 => Optimizer::Sgd,
            1 => Optimizer::AdamW,
            other => bail!("wire: unknown optimizer tag {other}"),
        };
        let lr = self.f32()?;
        let weight_decay = self.f32()?;
        let beta1 = self.f32()?;
        let beta2 = self.f32()?;
        let eps = self.f32()?;
        let cfg = OptimizerCfg { kind, lr, weight_decay, beta1, beta2, eps };
        let t = self.u32()?;
        let mut mv = [Vec::new(), Vec::new()];
        for slot in &mut mv {
            let n = self.u32()? as usize;
            if n > 64 {
                bail!("wire: {n} moment vectors (corrupt header?)");
            }
            for _ in 0..n {
                let len = self.u32()? as usize;
                self.guard_elems(len, 4, "moment vector")?;
                let mut xs = Vec::with_capacity(len);
                for _ in 0..len {
                    xs.push(self.f32()?);
                }
                slot.push(xs);
            }
        }
        let [m, v] = mv;
        Ok(OptState::from_parts(cfg, t, m, v))
    }

    fn duration(&mut self) -> Result<Duration> {
        Ok(Duration::from_nanos(self.u64()?))
    }

    fn fit_job(&mut self) -> Result<FitJob> {
        let user = self.u64()? as usize;
        let site = self.str()?;
        let x = self.tensor()?;
        let ghat = self.tensor()?;
        let grad_scale = self.f32()?;
        let merged = self.u8()? != 0;
        Ok(FitJob { user, site, x, ghat, grad_scale, merged })
    }

    fn fit_result(&mut self) -> Result<FitResult> {
        let user = self.u64()? as usize;
        let site = self.str()?;
        let new_params = if self.u8()? != 0 {
            let n = self.u32()? as usize;
            if n > 16 {
                bail!("wire: {n} adapter tensors (corrupt header?)");
            }
            let mut ps = Vec::with_capacity(n);
            for _ in 0..n {
                ps.push(self.tensor()?);
            }
            Some(ps)
        } else {
            None
        };
        let delta_diff = if self.u8()? != 0 { Some(self.tensor()?) } else { None };
        let compute = self.duration()?;
        let transfer = self.duration()?;
        let bytes_in = self.u64()? as usize;
        let bytes_out = self.u64()? as usize;
        Ok(FitResult {
            user,
            site,
            new_params,
            delta_diff,
            compute,
            transfer,
            bytes_in,
            bytes_out,
        })
    }

    /// Guard a batch item count claimed by a header: the smallest
    /// encodable item is well over 16 bytes, so anything bigger than
    /// `remaining / 16` is a corrupt header. Items are decoded into an
    /// unreserved `Vec`, so even a passing count never pre-allocates
    /// more than the payload can back.
    fn batch_count(&mut self, what: &str) -> Result<usize> {
        let n = self.u32()? as usize;
        if n > self.remaining() / 16 {
            bail!(
                "wire: {what} claims {n} items but only {} payload bytes \
                 remain (corrupt header?)",
                self.remaining()
            );
        }
        Ok(n)
    }

    fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!(
                "wire: {} trailing bytes after message body",
                self.buf.len() - self.pos
            );
        }
        Ok(())
    }
}

/// Deserialize a message payload produced by [`encode`].
pub fn decode(payload: &[u8]) -> Result<Msg> {
    let mut d = Dec { buf: payload, pos: 0 };
    let t = d.u8()?;
    let msg = match t {
        tag::REGISTER => {
            let user = d.u64()? as usize;
            let site = d.str()?;
            let adapter_site = d.str()?;
            let params = d.params()?;
            let opt = d.opt_state()?;
            Msg::Register {
                user,
                site,
                adapter: SiteAdapter { site: adapter_site, params, opt },
            }
        }
        tag::FIT => Msg::Fit(d.fit_job()?),
        tag::FIT_OK => Msg::FitOk(d.fit_result()?),
        tag::FIT_BATCH => {
            let seq = d.u64()?;
            let n = d.batch_count("fit batch")?;
            let mut jobs = Vec::new();
            for _ in 0..n {
                jobs.push(d.fit_job()?);
            }
            Msg::FitBatch { seq, jobs }
        }
        tag::FIT_BATCH_OK => {
            let seq = d.u64()?;
            let n = d.batch_count("fit batch reply")?;
            let mut results = Vec::new();
            for _ in 0..n {
                let item = if d.u8()? != 0 {
                    BatchItem::Ok(d.fit_result()?)
                } else {
                    BatchItem::Err {
                        user: d.u64()? as usize,
                        site: d.str()?,
                        error: d.str()?,
                    }
                };
                results.push(item);
            }
            Msg::FitBatchOk { seq, results }
        }
        tag::HELLO => {
            let tenant = d.str()?;
            // legacy (v2) Hellos end here; the v3 form appends exactly
            // one capability byte requesting bf16 fit tensors
            let wire = if d.remaining() > 0 {
                match d.u8()? {
                    1 => WireFormat::Bf16,
                    other => bail!("wire: unknown Hello capability byte {other}"),
                }
            } else {
                WireFormat::F32
            };
            Msg::Hello { tenant, wire }
        }
        tag::SNAPSHOT => {
            let user = d.u64()? as usize;
            let site = d.str()?;
            Msg::Snapshot { user, site }
        }
        tag::SNAPSHOT_OK => Msg::SnapshotOk(d.params()?),
        tag::STATE_BYTES => Msg::StateBytes,
        tag::STATE_BYTES_OK => Msg::StateBytesOk(d.u64()?),
        tag::PING => Msg::Ping,
        tag::PONG => Msg::Pong { load: d.u64()? },
        tag::STATE_EXPORT => {
            let user = d.u64()? as usize;
            let site = d.str()?;
            Msg::StateExport { user, site }
        }
        tag::STATE_EXPORT_OK => Msg::StateExportOk(d.bytes()?),
        tag::STATE_IMPORT => Msg::StateImport(d.bytes()?),
        tag::STATE_EVICT => {
            let user = d.u64()? as usize;
            let site = d.str()?;
            Msg::StateEvict { user, site }
        }
        tag::REPLICA_PUT => Msg::ReplicaPut(d.bytes()?),
        tag::REPLICA_PROMOTE => {
            let user = d.u64()? as usize;
            let site = d.str()?;
            Msg::ReplicaPromote { user, site }
        }
        tag::REPLICA_DROP => {
            let user = d.u64()? as usize;
            let site = d.str()?;
            Msg::ReplicaDrop { user, site }
        }
        tag::JOIN => Msg::Join { addr: d.str()? },
        tag::SHUTDOWN => Msg::Shutdown,
        tag::SHUTDOWN_OK => Msg::ShutdownOk,
        tag::ACK => Msg::Ack,
        tag::ERROR => Msg::Error(d.str()?),
        other => bail!("wire: unknown message tag 0x{other:02x}"),
    };
    d.finish()?;
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn roundtrip(msg: &Msg) -> Msg {
        let mut buf = Vec::new();
        send(&mut buf, msg).unwrap();
        // v1 messages must go out in v1 frames (old peers still read them)
        assert_eq!(buf[4], frame_version(msg));
        decode(&read_frame(&mut &buf[..]).unwrap()).unwrap()
    }

    fn sample_adapter(kind: AdapterKind) -> SiteAdapter {
        let mut rng = Rng::new(9);
        let params = AdapterParams::init(kind, 6, 4, 3, 5, &mut rng);
        let mut sa = SiteAdapter::new("l0.q", params, &OptimizerCfg::adamw(1e-3, 1e-4));
        // advance the optimizer so moments are non-trivial
        let grads: Vec<Tensor> = sa
            .params
            .tensors()
            .iter()
            .map(|t| Tensor::from_fn(t.shape(), |i| (i as f32).sin()))
            .collect();
        sa.step(&grads);
        sa
    }

    fn assert_tensor_bits_eq(a: &Tensor, b: &Tensor) {
        assert_eq!(a.shape(), b.shape());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{x} vs {y}");
        }
    }

    #[test]
    fn register_roundtrips_all_adapter_kinds() {
        for kind in [AdapterKind::LowRank, AdapterKind::Linear, AdapterKind::Mlp] {
            let adapter = sample_adapter(kind);
            let msg = Msg::Register { user: 7, site: "l1.v".into(), adapter };
            let Msg::Register { user, site, adapter } = roundtrip(&msg) else {
                panic!("wrong variant");
            };
            let Msg::Register { adapter: orig, .. } = msg else { unreachable!() };
            assert_eq!(user, 7);
            assert_eq!(site, "l1.v");
            assert_eq!(adapter.site, orig.site);
            assert_eq!(adapter.params.kind(), kind);
            for (a, b) in adapter.params.tensors().iter().zip(orig.params.tensors()) {
                assert_tensor_bits_eq(a, b);
            }
            assert_eq!(adapter.opt.t, orig.opt.t);
            assert_eq!(adapter.opt.moments(), orig.opt.moments());
            assert_eq!(adapter.opt.cfg.lr.to_bits(), orig.opt.cfg.lr.to_bits());
        }
    }

    #[test]
    fn fit_roundtrips_nan_inf_payloads() {
        let special = Tensor::new(
            vec![2, 3],
            vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0, 1.5e-42, f32::MAX],
        );
        let msg = Msg::Fit(FitJob {
            user: 3,
            site: "head".into(),
            x: special.clone(),
            ghat: Tensor::new(vec![2, 2], vec![f32::from_bits(0x7fc0_0001); 4]),
            grad_scale: 0.25,
            merged: true,
        });
        let Msg::Fit(job) = roundtrip(&msg) else { panic!("wrong variant") };
        assert_eq!(job.user, 3);
        assert!(job.merged);
        assert_eq!(job.grad_scale, 0.25);
        assert_tensor_bits_eq(&job.x, &special);
        // the quiet-NaN payload bit must survive exactly
        assert_eq!(job.ghat.data()[0].to_bits(), 0x7fc0_0001);
    }

    #[test]
    fn fit_ok_roundtrips_both_reply_shapes() {
        let unmerged = Msg::FitOk(FitResult {
            user: 1,
            site: "l0.q".into(),
            new_params: Some(vec![Tensor::zeros(&[4, 2]), Tensor::zeros(&[2, 4])]),
            delta_diff: None,
            compute: Duration::from_micros(123),
            transfer: Duration::from_nanos(456),
            bytes_in: 1024,
            bytes_out: 2048,
        });
        let Msg::FitOk(r) = roundtrip(&unmerged) else { panic!("wrong variant") };
        assert_eq!(r.new_params.as_ref().map(|p| p.len()), Some(2));
        assert!(r.delta_diff.is_none());
        assert_eq!(r.compute, Duration::from_micros(123));
        assert_eq!((r.bytes_in, r.bytes_out), (1024, 2048));

        let merged = Msg::FitOk(FitResult {
            user: 2,
            site: "head".into(),
            new_params: None,
            delta_diff: Some(Tensor::from_fn(&[3, 3], |i| i as f32)),
            compute: Duration::ZERO,
            transfer: Duration::ZERO,
            bytes_in: 0,
            bytes_out: 36,
        });
        let Msg::FitOk(r) = roundtrip(&merged) else { panic!("wrong variant") };
        assert!(r.new_params.is_none());
        assert_eq!(r.delta_diff.unwrap().shape(), &[3, 3]);
    }

    #[test]
    fn empty_tensor_roundtrips() {
        let msg = Msg::Fit(FitJob {
            user: 0,
            site: "s".into(),
            x: Tensor::zeros(&[0, 8]),
            ghat: Tensor::zeros(&[0, 8]),
            grad_scale: 1.0,
            merged: false,
        });
        let Msg::Fit(job) = roundtrip(&msg) else { panic!("wrong variant") };
        assert_eq!(job.x.shape(), &[0, 8]);
        assert_eq!(job.x.len(), 0);
    }

    #[test]
    fn control_messages_roundtrip() {
        for msg in [
            Msg::Snapshot { user: 11, site: "conv1".into() },
            Msg::StateBytes,
            Msg::StateBytesOk(987654321),
            Msg::Shutdown,
            Msg::ShutdownOk,
            Msg::Ack,
            Msg::Error("worker 0: no adapter (1, l0.q)".into()),
        ] {
            let back = roundtrip(&msg);
            assert_eq!(format!("{msg:?}"), format!("{back:?}"));
        }
        let snap = Msg::SnapshotOk(sample_adapter(AdapterKind::Mlp).params);
        let Msg::SnapshotOk(p) = roundtrip(&snap) else { panic!("wrong variant") };
        assert_eq!(p.kind(), AdapterKind::Mlp);
    }

    #[test]
    fn truncated_frame_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &encode(&Msg::StateBytesOk(1))).unwrap();
        for cut in [1, 5, 8, buf.len() - 1] {
            assert!(
                read_frame(&mut &buf[..cut]).is_err(),
                "cut at {cut} should fail"
            );
        }
    }

    #[test]
    fn garbage_header_rejected() {
        // wrong magic
        let mut bad = Vec::new();
        write_frame(&mut bad, &[tag::ACK]).unwrap();
        bad[0] = b'X';
        assert!(read_frame(&mut &bad[..]).is_err());
        // wrong version
        let mut bad2 = Vec::new();
        write_frame(&mut bad2, &[tag::ACK]).unwrap();
        bad2[4] = 0xFF;
        assert!(read_frame(&mut &bad2[..]).is_err());
        // absurd length header must not allocate
        let mut bad3 = MAGIC.to_vec();
        bad3.push(VERSION);
        bad3.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(read_frame(&mut &bad3[..]).is_err());
    }

    #[test]
    fn oversized_element_claims_do_not_allocate() {
        // a tiny Fit body whose tensor header claims ~256M elements:
        // must be rejected by the remaining-bytes guard, not by an OOM
        let mut p = vec![super::tag::FIT];
        p.extend_from_slice(&0u64.to_le_bytes()); // user
        p.extend_from_slice(&1u32.to_le_bytes()); // site len
        p.push(b's');
        p.push(0); // dtype f32
        p.push(1); // rank 1
        p.extend_from_slice(&((MAX_FRAME / 4 - 1) as u32).to_le_bytes());
        let err = decode(&p).unwrap_err();
        assert!(format!("{err}").contains("corrupt header"), "{err}");
    }

    #[test]
    fn garbage_payload_rejected() {
        assert!(decode(&[]).is_err());
        assert!(decode(&[0xEE]).is_err(), "unknown tag");
        // Fit with a truncated tensor body
        let good = encode(&Msg::Fit(FitJob {
            user: 0,
            site: "s".into(),
            x: Tensor::zeros(&[2, 2]),
            ghat: Tensor::zeros(&[2, 2]),
            grad_scale: 1.0,
            merged: false,
        }));
        assert!(decode(&good[..good.len() - 3]).is_err());
        // trailing junk after a complete message
        let mut padded = encode(&Msg::Ack);
        padded.push(0);
        assert!(decode(&padded).is_err());
    }

    #[test]
    fn v2_messages_roundtrip() {
        let job = |user: usize| FitJob {
            user,
            site: format!("l{user}.q"),
            x: Tensor::from_fn(&[2, 3], |i| i as f32 * 0.5),
            ghat: Tensor::from_fn(&[2, 4], |i| -(i as f32)),
            grad_scale: 0.5,
            merged: user % 2 == 0,
        };
        let msg = Msg::FitBatch { seq: 42, jobs: vec![job(0), job(1), job(2)] };
        let Msg::FitBatch { seq, jobs } = roundtrip(&msg) else { panic!("wrong variant") };
        assert_eq!(seq, 42);
        assert_eq!(jobs.len(), 3);
        assert_eq!(jobs[1].site, "l1.q");
        assert!(jobs[0].merged && !jobs[1].merged);

        let ok = FitResult {
            user: 3,
            site: "head".into(),
            new_params: Some(vec![Tensor::zeros(&[2, 2])]),
            delta_diff: None,
            compute: Duration::from_micros(7),
            transfer: Duration::ZERO,
            bytes_in: 64,
            bytes_out: 16,
        };
        let msg = Msg::FitBatchOk {
            seq: 42,
            results: vec![
                BatchItem::Ok(ok),
                BatchItem::Err {
                    user: 9,
                    site: "l0.v".into(),
                    error: "no adapter (9, l0.v)".into(),
                },
            ],
        };
        let Msg::FitBatchOk { seq, results } = roundtrip(&msg) else {
            panic!("wrong variant")
        };
        assert_eq!(seq, 42);
        assert!(matches!(&results[0], BatchItem::Ok(r) if r.user == 3));
        let BatchItem::Err { user, site, error } = &results[1] else {
            panic!("wrong item")
        };
        assert_eq!((*user, site.as_str()), (9, "l0.v"));
        assert!(error.contains("no adapter"));

        let hello = Msg::Hello { tenant: "u7".into(), wire: WireFormat::F32 };
        let Msg::Hello { tenant, wire } = roundtrip(&hello) else {
            panic!("wrong variant")
        };
        assert_eq!(tenant, "u7");
        assert_eq!(wire, WireFormat::F32);
    }

    #[test]
    fn empty_batch_roundtrips() {
        let Msg::FitBatch { seq, jobs } =
            roundtrip(&Msg::FitBatch { seq: 0, jobs: vec![] })
        else {
            panic!("wrong variant")
        };
        assert_eq!((seq, jobs.len()), (0, 0));
    }

    #[test]
    fn version_window_enforced() {
        // v1 and v2 frames from old peers still read
        for v in [1, 2, 3] {
            let mut buf = Vec::new();
            write_frame_v(&mut buf, v, &encode(&Msg::Ack)).unwrap();
            assert!(read_frame(&mut &buf[..]).is_ok(), "version {v} should read");
        }
        // a future version is rejected, not misparsed
        let mut v4 = Vec::new();
        write_frame_v(&mut v4, 4, &encode(&Msg::Ack)).unwrap();
        let err = read_frame(&mut &v4[..]).unwrap_err();
        assert!(format!("{err}").contains("version 4"), "{err}");
        let mut v0 = Vec::new();
        write_frame_v(&mut v0, 0, &encode(&Msg::Ack)).unwrap();
        assert!(read_frame(&mut &v0[..]).is_err());
    }

    #[test]
    fn v3_messages_roundtrip() {
        let Msg::Pong { load } = roundtrip(&Msg::Pong { load: 17 }) else {
            panic!("wrong variant")
        };
        assert_eq!(load, 17);
        let back = roundtrip(&Msg::Ping);
        assert!(matches!(back, Msg::Ping));

        let Msg::StateExport { user, site } =
            roundtrip(&Msg::StateExport { user: 9, site: "l1.v".into() })
        else {
            panic!("wrong variant")
        };
        assert_eq!((user, site.as_str()), (9, "l1.v"));

        let Msg::StateEvict { user, site } =
            roundtrip(&Msg::StateEvict { user: 3, site: "head".into() })
        else {
            panic!("wrong variant")
        };
        assert_eq!((user, site.as_str()), (3, "head"));

        let blob = encode_state(4, "l0.q", &sample_adapter(AdapterKind::LowRank));
        let Msg::StateExportOk(b) = roundtrip(&Msg::StateExportOk(blob.clone())) else {
            panic!("wrong variant")
        };
        assert_eq!(b, blob);
        let Msg::StateImport(b) = roundtrip(&Msg::StateImport(blob.clone())) else {
            panic!("wrong variant")
        };
        assert_eq!(b, blob);
        // empty blobs frame fine too (the decode_state inside errors,
        // but the message layer must not)
        let Msg::StateExportOk(b) = roundtrip(&Msg::StateExportOk(vec![])) else {
            panic!("wrong variant")
        };
        assert!(b.is_empty());
    }

    #[test]
    fn registry_messages_roundtrip_as_v3_frames() {
        // the v3 registry control plane: replica push/promote/drop plus
        // the daemon self-registration announcement
        let blob = encode_state(6, "l1.k", &sample_adapter(AdapterKind::LowRank));
        let Msg::ReplicaPut(b) = roundtrip(&Msg::ReplicaPut(blob.clone())) else {
            panic!("wrong variant")
        };
        assert_eq!(b, blob);

        let Msg::ReplicaPromote { user, site } =
            roundtrip(&Msg::ReplicaPromote { user: 7, site: "l0.v".into() })
        else {
            panic!("wrong variant")
        };
        assert_eq!((user, site.as_str()), (7, "l0.v"));

        let Msg::ReplicaDrop { user, site } =
            roundtrip(&Msg::ReplicaDrop { user: 2, site: "head".into() })
        else {
            panic!("wrong variant")
        };
        assert_eq!((user, site.as_str()), (2, "head"));

        let Msg::Join { addr } =
            roundtrip(&Msg::Join { addr: "10.0.0.9:7701".into() })
        else {
            panic!("wrong variant")
        };
        assert_eq!(addr, "10.0.0.9:7701");

        // tags are wire ABI — pin them so a reorder can't silently
        // renumber the registry messages
        assert_eq!(encode(&Msg::ReplicaPut(vec![]))[0], 0x15);
        assert_eq!(encode(&Msg::ReplicaPromote { user: 0, site: String::new() })[0], 0x16);
        assert_eq!(encode(&Msg::ReplicaDrop { user: 0, site: String::new() })[0], 0x17);
        assert_eq!(encode(&Msg::Join { addr: String::new() })[0], 0x18);
    }

    #[test]
    fn state_blob_roundtrips_bit_exactly() {
        for kind in [AdapterKind::LowRank, AdapterKind::Linear, AdapterKind::Mlp] {
            let adapter = sample_adapter(kind);
            let blob = encode_state(11, "l2.q", &adapter);
            let (user, site, back) = decode_state(&blob).unwrap();
            assert_eq!((user, site.as_str()), (11, "l2.q"));
            assert_eq!(back.site, adapter.site);
            assert_eq!(back.params.kind(), kind);
            for (a, b) in back.params.tensors().iter().zip(adapter.params.tensors()) {
                assert_tensor_bits_eq(a, b);
            }
            assert_eq!(back.opt.t, adapter.opt.t);
            assert_eq!(back.opt.moments(), adapter.opt.moments());
            // and the blob re-encodes identically (left-inverse property)
            assert_eq!(encode_state(user, &site, &back), blob);
        }
    }

    #[test]
    fn corrupt_state_blobs_rejected_not_panicking() {
        let blob = encode_state(2, "s", &sample_adapter(AdapterKind::Mlp));
        // every strict truncation must error
        for cut in 0..blob.len() {
            assert!(decode_state(&blob[..cut]).is_err(), "cut at {cut} decoded");
        }
        // trailing junk must error
        let mut padded = blob.clone();
        padded.push(0);
        assert!(decode_state(&padded).is_err());
        // a blob whose tensor header claims gigabytes must be rejected
        // by the remaining-bytes guard, not by an allocation
        let mut bad = blob.clone();
        // site strings are tiny; stomp bytes shortly after the header
        // area with a huge little-endian count and require a clean error
        let n = bad.len();
        bad[n / 2..n / 2 + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let _ = decode_state(&bad); // must not panic (may or may not Err here)
        // seeded mutation sweep: no panic, no wild allocation
        let mut rng = Rng::new(0x51A7E);
        for _ in 0..4_000 {
            let mut m = blob.clone();
            let pos = rng.below(m.len());
            m[pos] ^= 1u8 << rng.below(8);
            let _ = decode_state(&m);
        }
    }

    #[test]
    fn batch_count_guard_rejects_absurd_headers() {
        // FitBatch whose count header claims 100M jobs in a 20-byte body
        let mut p = vec![super::tag::FIT_BATCH];
        p.extend_from_slice(&0u64.to_le_bytes()); // seq
        p.extend_from_slice(&100_000_000u32.to_le_bytes()); // count
        p.extend_from_slice(&[0u8; 8]);
        let err = decode(&p).unwrap_err();
        assert!(format!("{err}").contains("corrupt header"), "{err}");
    }

    // -----------------------------------------------------------------
    // property + fuzz harness (deterministic: everything derives from
    // one seeded Rng, so a failure reproduces from the printed seed)
    // -----------------------------------------------------------------

    /// Arbitrary f32 bit pattern: quiet/signalling NaNs, ±inf, -0.0,
    /// denormals — everything must survive the wire bit-for-bit.
    fn arb_f32(rng: &mut Rng) -> f32 {
        match rng.below(8) {
            0 => f32::from_bits(rng.next_u64() as u32),
            1 => f32::NAN,
            2 => f32::INFINITY,
            3 => -0.0,
            _ => (rng.next_f32() - 0.5) * 1e3,
        }
    }

    fn arb_tensor(rng: &mut Rng) -> Tensor {
        let (r, c) = (rng.below(4), rng.below(4));
        Tensor::from_fn(&[r, c], |_| arb_f32(rng))
    }

    fn arb_string(rng: &mut Rng) -> String {
        let n = rng.below(12);
        (0..n).map(|_| char::from(b'a' + rng.below(26) as u8)).collect()
    }

    fn arb_fit_job(rng: &mut Rng) -> FitJob {
        FitJob {
            user: rng.below(1 << 20),
            site: arb_string(rng),
            x: arb_tensor(rng),
            ghat: arb_tensor(rng),
            grad_scale: arb_f32(rng),
            merged: rng.below(2) == 1,
        }
    }

    fn arb_fit_result(rng: &mut Rng) -> FitResult {
        FitResult {
            user: rng.below(1 << 20),
            site: arb_string(rng),
            new_params: if rng.below(2) == 1 {
                Some((0..rng.below(4)).map(|_| arb_tensor(rng)).collect())
            } else {
                None
            },
            delta_diff: if rng.below(2) == 1 { Some(arb_tensor(rng)) } else { None },
            compute: Duration::from_nanos(rng.next_u64() >> 12),
            transfer: Duration::from_nanos(rng.next_u64() >> 12),
            bytes_in: rng.below(1 << 30),
            bytes_out: rng.below(1 << 30),
        }
    }

    /// Arbitrary migration blob: usually well-formed (so decode_state's
    /// happy path is exercised through the fuzz), sometimes raw noise.
    fn arb_blob(rng: &mut Rng) -> Vec<u8> {
        if rng.below(2) == 1 {
            encode_state(
                rng.below(1 << 16),
                &arb_string(rng),
                &sample_adapter(AdapterKind::LowRank),
            )
        } else {
            let n = rng.below(48);
            (0..n).map(|_| rng.next_u64() as u8).collect()
        }
    }

    /// One arbitrary message over every v1 + v2 + v3 variant.
    fn arb_msg(rng: &mut Rng) -> Msg {
        match rng.below(24) {
            0 => Msg::Register {
                user: rng.below(1 << 16),
                site: arb_string(rng),
                adapter: sample_adapter(match rng.below(3) {
                    0 => AdapterKind::LowRank,
                    1 => AdapterKind::Linear,
                    _ => AdapterKind::Mlp,
                }),
            },
            1 => Msg::Fit(arb_fit_job(rng)),
            2 => Msg::FitOk(arb_fit_result(rng)),
            3 => Msg::Snapshot { user: rng.below(1 << 16), site: arb_string(rng) },
            4 => Msg::SnapshotOk(sample_adapter(AdapterKind::LowRank).params),
            5 => Msg::StateBytes,
            6 => Msg::StateBytesOk(rng.next_u64()),
            7 => Msg::Shutdown,
            8 => Msg::ShutdownOk,
            9 => Msg::Ack,
            10 => Msg::Error(arb_string(rng)),
            11 => Msg::Hello {
                tenant: arb_string(rng),
                wire: if rng.below(2) == 1 { WireFormat::Bf16 } else { WireFormat::F32 },
            },
            12 => Msg::Ping,
            13 => Msg::Pong { load: rng.next_u64() },
            14 => Msg::StateExport { user: rng.below(1 << 16), site: arb_string(rng) },
            15 => Msg::StateExportOk(arb_blob(rng)),
            16 => Msg::StateImport(arb_blob(rng)),
            17 => Msg::StateEvict { user: rng.below(1 << 16), site: arb_string(rng) },
            18 => Msg::FitBatch {
                seq: rng.next_u64(),
                jobs: (0..rng.below(4)).map(|_| arb_fit_job(rng)).collect(),
            },
            19 => Msg::ReplicaPut(arb_blob(rng)),
            20 => Msg::ReplicaPromote { user: rng.below(1 << 16), site: arb_string(rng) },
            21 => Msg::ReplicaDrop { user: rng.below(1 << 16), site: arb_string(rng) },
            22 => Msg::Join { addr: arb_string(rng) },
            _ => Msg::FitBatchOk {
                seq: rng.next_u64(),
                results: (0..rng.below(4))
                    .map(|_| {
                        if rng.below(2) == 1 {
                            BatchItem::Ok(arb_fit_result(rng))
                        } else {
                            BatchItem::Err {
                                user: rng.below(1 << 16),
                                site: arb_string(rng),
                                error: arb_string(rng),
                            }
                        }
                    })
                    .collect(),
            },
        }
    }

    /// Property: decode is a left inverse of encode, bit-for-bit — the
    /// re-encoded decode of any message equals the original payload
    /// (stronger than Debug equality: NaN payload bits count).
    #[test]
    fn prop_arbitrary_messages_reencode_identically() {
        let mut rng = Rng::new(0xC01A);
        for i in 0..300 {
            let msg = arb_msg(&mut rng);
            let payload = encode(&msg);
            let back = decode(&payload).unwrap_or_else(|e| {
                panic!("iteration {i}: decode of valid {msg:?} failed: {e}")
            });
            assert_eq!(
                encode(&back),
                payload,
                "iteration {i}: re-encode mismatch for {msg:?}"
            );
            // and through the framed path, at the message's own version
            let mut framed = Vec::new();
            send(&mut framed, &msg).unwrap();
            let p2 = read_frame(&mut &framed[..]).unwrap();
            assert_eq!(p2, payload, "iteration {i}: framing changed the payload");
        }
    }

    /// Fuzz: >= 10k mutated frames (byte flips, truncations, garbage)
    /// must never panic and never allocate past the guards; truncations
    /// must always be rejected. Frames are encoded under both wire
    /// formats, so bf16 (dtype 2) bodies get the same flip/truncation
    /// coverage as f32 ones.
    #[test]
    fn fuzz_mutated_frames_never_panic() {
        let mut rng = Rng::new(0xF422);
        for i in 0..12_000 {
            let msg = arb_msg(&mut rng);
            let fmt = if rng.below(2) == 1 { WireFormat::Bf16 } else { WireFormat::F32 };
            let mut buf = Vec::new();
            send_with(&mut buf, &msg, fmt).unwrap();
            match rng.below(3) {
                0 => {
                    // strict truncation: must error, never panic
                    let cut = rng.below(buf.len());
                    let r = read_frame(&mut &buf[..cut]);
                    assert!(r.is_err(), "iteration {i}: truncation at {cut} decoded");
                }
                1 => {
                    // flip one byte anywhere: header flips must error;
                    // payload flips may decode (to a different message) or
                    // error — either way, no panic, no wild allocation
                    let pos = rng.below(buf.len());
                    buf[pos] ^= 1u8 << rng.below(8);
                    if let Ok(payload) = read_frame(&mut &buf[..]) {
                        if let Ok(Msg::StateExportOk(b) | Msg::StateImport(b)) =
                            decode(&payload)
                        {
                            // the opaque blob layer must be just as
                            // flip-proof as the message layer
                            let _ = decode_state(&b);
                        }
                    }
                }
                _ => {
                    // raw garbage payloads straight into decode
                    let n = rng.below(64);
                    let junk: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
                    let _ = decode(&junk);
                }
            }
        }
    }

    #[test]
    fn value_roundtrips_both_dtypes() {
        let f = Value::F32(Tensor::new(vec![2], vec![f32::NAN, -0.0]));
        let Value::F32(t) = decode_value(&encode_value(&f)).unwrap() else {
            panic!("wrong dtype");
        };
        assert!(t.data()[0].is_nan());
        assert_eq!(t.data()[1].to_bits(), (-0.0f32).to_bits());

        let i = Value::I32(IntTensor::new(vec![2, 2], vec![-1, 2, i32::MIN, i32::MAX]));
        let back = decode_value(&encode_value(&i)).unwrap();
        assert_eq!(back, i);
    }

    // -----------------------------------------------------------------
    // bf16 wire compression
    // -----------------------------------------------------------------

    /// The deterministic round-trip contract, exhaustively: decode
    /// followed by encode is the identity on every one of the 2^16
    /// bf16 bit patterns — including every NaN payload, ±inf, ±0, and
    /// all denormals. This is what lets a re-encoded bf16 frame
    /// reproduce its original bytes exactly.
    #[test]
    fn bf16_roundtrip_identity_on_all_patterns() {
        for h in 0..=u16::MAX {
            let back = f32_to_bf16(bf16_to_f32(h));
            assert_eq!(back, h, "pattern 0x{h:04x} round-tripped to 0x{back:04x}");
        }
    }

    #[test]
    fn bf16_encode_rounds_to_nearest_even() {
        // exact values pass through
        assert_eq!(f32_to_bf16(1.0), 0x3F80);
        assert_eq!(f32_to_bf16(-2.0), 0xC000);
        assert_eq!(f32_to_bf16(0.0), 0x0000);
        assert_eq!(f32_to_bf16(-0.0), 0x8000);
        assert_eq!(f32_to_bf16(f32::INFINITY), 0x7F80);
        assert_eq!(f32_to_bf16(f32::NEG_INFINITY), 0xFF80);
        // tie (low half exactly 0x8000) rounds to the even neighbour
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8000)), 0x3F80, "tie, even stays");
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F81_8000)), 0x3F82, "tie, odd rounds up");
        // just past the tie rounds up; just below rounds down
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8001)), 0x3F81);
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_7FFF)), 0x3F80);
        // max finite f32 is closer to bf16-inf than to bf16-max: rounds up
        assert_eq!(f32_to_bf16(f32::MAX), 0x7F80);
        assert_eq!(f32_to_bf16(f32::MIN), 0xFF80);
        // a NaN whose kept payload bits all truncate away stays a NaN
        let skinny_nan = f32::from_bits(0x7F80_0001);
        assert!(bf16_to_f32(f32_to_bf16(skinny_nan)).is_nan());
        // a NaN with surviving payload bits keeps them untouched
        assert_eq!(f32_to_bf16(f32::from_bits(0x7FC1_0000)), 0x7FC1);
    }

    /// Property: bf16 fit frames re-encode to their original bytes
    /// (the bf16 analogue of the f32 reencode property — follows from
    /// the all-patterns identity above), ship in v3 frames, and save
    /// exactly 2 bytes per tensor element over f32.
    #[test]
    fn prop_bf16_fit_frames_reencode_identically() {
        let mut rng = Rng::new(0xBF16);
        for i in 0..300 {
            let msg = if rng.below(2) == 1 {
                Msg::Fit(arb_fit_job(&mut rng))
            } else {
                Msg::FitBatch {
                    seq: rng.next_u64(),
                    jobs: (0..rng.below(4)).map(|_| arb_fit_job(&mut rng)).collect(),
                }
            };
            let payload = encode_with(&msg, WireFormat::Bf16);
            let back = decode(&payload).unwrap_or_else(|e| {
                panic!("iteration {i}: bf16 decode of {msg:?} failed: {e}")
            });
            assert_eq!(
                encode_with(&back, WireFormat::Bf16),
                payload,
                "iteration {i}: bf16 re-encode mismatch"
            );
            // the decoded (widened) message is itself stable: encoding
            // it f32 and re-compressing changes nothing (truncation is
            // idempotent)
            let widened = decode(&encode(&back)).unwrap();
            assert_eq!(encode_with(&widened, WireFormat::Bf16), payload);
            // fit tensors save exactly 2 bytes/element vs the f32 wire
            let elems: usize = match &msg {
                Msg::Fit(j) => j.x.len() + j.ghat.len(),
                Msg::FitBatch { jobs, .. } =>
                    jobs.iter().map(|j| j.x.len() + j.ghat.len()).sum(),
                _ => unreachable!(),
            };
            assert_eq!(encode(&msg).len() - payload.len(), 2 * elems);
            // and the framed path stamps v3 (a pre-bf16 decoder must
            // reject the frame at the version window, not misparse it)
            let mut framed = Vec::new();
            let n = send_with(&mut framed, &msg, WireFormat::Bf16).unwrap();
            assert_eq!(n, framed.len(), "send_with must report the bytes written");
            assert_eq!(framed[4], 3);
        }
    }

    /// One connection may interleave f32 and bf16 fit frames (e.g.
    /// after a mid-stream reconnect renegotiates the format): each
    /// frame declares its own dtype, so a decoder needs no per-link
    /// state.
    #[test]
    fn mixed_f32_and_bf16_frames_on_one_link() {
        let x = Tensor::new(vec![2, 2], vec![1.0, -2.5, 3.25e-3, -0.0]);
        let job = FitJob {
            user: 1,
            site: "l0.q".into(),
            x: x.clone(),
            ghat: x.clone(),
            grad_scale: 1.0,
            merged: false,
        };
        let mut link = Vec::new();
        send_with(&mut link, &Msg::Fit(job.clone()), WireFormat::Bf16).unwrap();
        send(&mut link, &Msg::Fit(job.clone())).unwrap();
        send_with(
            &mut link,
            &Msg::FitBatch { seq: 7, jobs: vec![job.clone()] },
            WireFormat::Bf16,
        )
        .unwrap();
        let mut r = &link[..];
        let Msg::Fit(a) = recv(&mut r).unwrap() else { panic!("wrong variant") };
        let Msg::Fit(b) = recv(&mut r).unwrap() else { panic!("wrong variant") };
        let Msg::FitBatch { seq, jobs } = recv(&mut r).unwrap() else {
            panic!("wrong variant")
        };
        assert!(r.is_empty(), "all frames consumed");
        assert_eq!(seq, 7);
        // f32 frame is bit-exact; bf16 frames are the RNE truncation
        assert_tensor_bits_eq(&b.x, &x);
        for (got, &orig) in a.x.data().iter().zip(x.data()) {
            assert_eq!(got.to_bits(), bf16_to_f32(f32_to_bf16(orig)).to_bits());
        }
        assert_tensor_bits_eq(&jobs[0].x, &a.x);
    }

    /// An f32 Hello must encode byte-identically to its pre-bf16 (v2)
    /// form so old daemons keep decoding it; the bf16 variant appends
    /// exactly one capability byte and moves to a v3 frame.
    #[test]
    fn hello_stays_byte_compatible_with_legacy_peers() {
        let f32_hello = Msg::Hello { tenant: "u7".into(), wire: WireFormat::F32 };
        // the legacy layout: tag | len | bytes — nothing else
        let mut legacy = vec![tag::HELLO];
        legacy.extend_from_slice(&2u32.to_le_bytes());
        legacy.extend_from_slice(b"u7");
        assert_eq!(encode(&f32_hello), legacy);
        let mut framed = Vec::new();
        send(&mut framed, &f32_hello).unwrap();
        assert_eq!(framed[4], 2, "f32 Hello still ships as a v2 frame");

        let bf16_hello = Msg::Hello { tenant: "u7".into(), wire: WireFormat::Bf16 };
        let enc = encode(&bf16_hello);
        assert_eq!(enc.len(), legacy.len() + 1);
        assert_eq!(enc[..legacy.len()], legacy[..]);
        assert_eq!(*enc.last().unwrap(), 1);
        let mut framed = Vec::new();
        send(&mut framed, &bf16_hello).unwrap();
        assert_eq!(framed[4], 3, "bf16 Hello needs a v3 frame");
        let Msg::Hello { tenant, wire } = roundtrip(&bf16_hello) else {
            panic!("wrong variant")
        };
        assert_eq!((tenant.as_str(), wire), ("u7", WireFormat::Bf16));
        // an unknown capability byte is rejected, not guessed at
        let mut bad = legacy.clone();
        bad.push(9);
        assert!(decode(&bad).is_err());
    }

    /// The bugfix pin: the wire format must never touch adapter or
    /// optimizer state. Registration, snapshots, fit replies, and the
    /// migration blob messages encode byte-identically under bf16 —
    /// only Fit/FitBatch requests compress. This is the property that
    /// makes `offload_wire = "bf16"` + `failover = "migrate"` a legal
    /// combination (see `config::validate`).
    #[test]
    fn state_blob_ignores_wire_format() {
        let adapter = sample_adapter(AdapterKind::Mlp);
        let blob = encode_state(4, "l0.q", &adapter);
        let msgs = [
            Msg::Register { user: 4, site: "l0.q".into(), adapter },
            Msg::SnapshotOk(sample_adapter(AdapterKind::LowRank).params),
            Msg::FitOk(FitResult {
                user: 4,
                site: "l0.q".into(),
                new_params: Some(vec![Tensor::from_fn(&[3, 2], |i| i as f32 * 0.1)]),
                delta_diff: None,
                compute: Duration::from_micros(5),
                transfer: Duration::ZERO,
                bytes_in: 8,
                bytes_out: 8,
            }),
            Msg::StateExportOk(blob.clone()),
            Msg::StateImport(blob),
        ];
        for msg in &msgs {
            assert_eq!(
                encode_with(msg, WireFormat::Bf16),
                encode(msg),
                "{msg:?} must encode identically under both wire formats"
            );
            assert_eq!(frame_version_with(msg, WireFormat::Bf16), frame_version(msg));
        }
    }
}
