//! TCP offload: the client proxy the server pool holds, and the worker
//! daemon (`cola worker --listen <addr>`) that owns adapters behind a
//! socket.
//!
//! Topology: each [`TcpWorker`] owns one connection to one daemon and
//! serializes requests over it. The daemon is **multi-tenant**: it
//! accepts any number of concurrent connections (one serving thread
//! each) over one shared [`WorkerCore`], so several `cola train`
//! processes — or several pool slots of one process — can lease the
//! same low-cost device. A connection may declare a tenant namespace
//! with the wire-v2 `Hello` handshake; adapters are keyed by
//! `(tenant, user, site)`, so tenants never clobber each other's
//! optimizer state. v1 clients never send `Hello` and land in the
//! default `""` namespace.
//!
//! Batching + pipelining: with `offload_batch = true` the client ships
//! a whole interval's jobs as sequence-numbered `FitBatch` frames —
//! `offload_inflight` frames per flush (default 1 = one frame per
//! interval; 2+ splits the flush so a later chunk is on the wire while
//! the earlier one computes). The daemon fans each batch across the
//! shared tensor-pool budget and replies per job, so one failing job
//! names its (user, site) without poisoning the batch. Framing and
//! scheduling change; numerics and apply order do not — loss curves
//! stay byte-identical to the unbatched run.
//!
//! Failure semantics: a request that dies mid-flight is **not**
//! replayed — a `Fit`/`FitBatch` may already have stepped the remote
//! optimizer, and replaying would double-apply it, silently breaking
//! the determinism guarantee. The error surfaces (naming the worker
//! and, for fits, every lost (user, site)), and the *next* request
//! reconnects (re-declaring the tenant).
//!
//! Shutdown: closing a connection leaves the daemon running; the clean
//! shutdown handshake ([`request_daemon_shutdown`], or `cola worker
//! --stop <addr>`) stops the accept loop and exits. Connections still
//! open at that point drain until their peers disconnect.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::wire::{self, BatchItem, Msg};
use super::Transport;
use crate::adapters::{AdapterParams, SiteAdapter};
use crate::config::{OffloadTarget, WireFormat};
use crate::coordinator::offload::{FitJob, FitResult, TransferModel, WorkerCore};
use crate::runtime::Manifest;

/// Default connection attempts before giving up (first contact).
pub const CONNECT_ATTEMPTS: u32 = 8;
/// Base backoff delay; doubles per attempt, capped at 2 s.
pub const BASE_BACKOFF: Duration = Duration::from_millis(50);
/// How long the connect-time liveness probe waits for the daemon to
/// answer before declaring the link dead-on-arrival.
pub const PROBE_TIMEOUT: Duration = Duration::from_secs(10);
/// How long a liveness [`Transport::ping`] waits for its `Pong` before
/// declaring the daemon unreachable. Deliberately much shorter than
/// [`PROBE_TIMEOUT`]: a heartbeat sweep pings every member in sequence,
/// so one hung daemon must not stall the whole sweep.
pub const PING_DEADLINE: Duration = Duration::from_secs(2);

/// Everything a [`TcpWorker`] link is built with beyond its address:
/// the reconnect schedule, the tenant namespace, and the FitBatch /
/// in-flight-window knobs (`offload_batch` / `offload_inflight`).
#[derive(Clone, Debug)]
pub struct TcpLinkOpts {
    pub attempts: u32,
    pub base: Duration,
    /// tenant namespace declared on every (re)connect; `""` = the v1
    /// default namespace, declared by not sending `Hello` at all
    pub tenant: String,
    /// ship intervals as `FitBatch` frames instead of per-job `Fit`
    pub batch: bool,
    /// max `FitBatch` frames in flight per interval flush (>= 1)
    pub inflight: usize,
    /// requested fit-tensor wire format (`offload_wire`). bf16 is
    /// negotiated via the Hello capability byte; a daemon that doesn't
    /// speak it makes the link fall back to f32 with a warning.
    pub wire: WireFormat,
}

impl Default for TcpLinkOpts {
    fn default() -> Self {
        TcpLinkOpts {
            attempts: CONNECT_ATTEMPTS,
            base: BASE_BACKOFF,
            tenant: String::new(),
            batch: false,
            inflight: 1,
            wire: WireFormat::F32,
        }
    }
}

/// Connect with exponential backoff — `attempts` tries, sleeping
/// `base * 2^k` (capped at 2 s) between them. Lets a server start
/// before its worker daemons finish binding.
pub fn connect_with_backoff(addr: &str, attempts: u32, base: Duration) -> Result<TcpStream> {
    let mut delay = base;
    let mut last_err: Option<std::io::Error> = None;
    for attempt in 0..attempts.max(1) {
        if attempt > 0 {
            std::thread::sleep(delay);
            delay = (delay * 2).min(Duration::from_secs(2));
        }
        match TcpStream::connect(addr) {
            Ok(s) => {
                // small frames dominate the handshake traffic; don't let
                // Nagle hold them back
                let _ = s.set_nodelay(true);
                return Ok(s);
            }
            Err(e) => last_err = Some(e),
        }
    }
    let detail = match last_err {
        Some(e) => e.to_string(),
        None => "no connection attempt ran".to_string(),
    };
    Err(anyhow!(
        "connect to worker at {addr} failed after {} attempts: {detail}",
        attempts.max(1)
    ))
}

// ---------------------------------------------------------------------
// client side (held by the server's WorkerPool)
// ---------------------------------------------------------------------

enum ClientCmd {
    Register { user: usize, site: String, adapter: SiteAdapter, reply: Sender<Result<()>> },
    Fit(FitJob, Sender<Result<FitResult>>),
    /// one interval's jobs, shipped as pipelined `FitBatch` frames
    FitBatch(Vec<(FitJob, Sender<Result<FitResult>>)>),
    Snapshot { user: usize, site: String, reply: Sender<Result<AdapterParams>> },
    StateBytes(Sender<Result<usize>>),
    ExportState { user: usize, site: String, reply: Sender<Result<Vec<u8>>> },
    ImportState { blob: Vec<u8>, reply: Sender<Result<()>> },
    EvictState { user: usize, site: String, reply: Sender<Result<()>> },
    PutReplica { blob: Vec<u8>, reply: Sender<Result<()>> },
    PromoteReplica { user: usize, site: String, reply: Sender<Result<()>> },
    DropReplica { user: usize, site: String, reply: Sender<Result<()>> },
    Disconnect,
}

/// Client proxy for one remote worker daemon — the `Tcp` implementation
/// of [`Transport`]. A dedicated I/O thread owns the socket; handles
/// are cheap to use from the coordinator thread.
pub struct TcpWorker {
    tx: Sender<ClientCmd>,
    id: usize,
    addr: String,
    batch: bool,
    inflight: usize,
    /// request bytes this proxy has put on the wire (headers included),
    /// shared with the I/O thread; drained by [`Transport::take_wire_bytes`]
    wire_bytes: Arc<AtomicU64>,
}

impl TcpWorker {
    /// Connect with the default options (v1-compatible: no tenant, no
    /// batching).
    pub fn connect(id: usize, addr: &str) -> Result<TcpWorker> {
        Self::connect_with_link_opts(id, addr, &TcpLinkOpts::default())
    }

    /// Connect with an explicit backoff schedule (tests use tight
    /// ones). The same schedule governs mid-run reconnects.
    pub fn connect_with_opts(
        id: usize,
        addr: &str,
        attempts: u32,
        base: Duration,
    ) -> Result<TcpWorker> {
        Self::connect_with_link_opts(
            id,
            addr,
            &TcpLinkOpts { attempts, base, ..TcpLinkOpts::default() },
        )
    }

    /// Connect with full link options.
    ///
    /// After connecting, a `StateBytes` probe (bounded by
    /// [`PROBE_TIMEOUT`]) confirms the daemon is actually *serving*
    /// this link — a wedged daemon fails loudly here instead of hanging
    /// the first fit. A non-empty tenant — or a bf16 wire request — is
    /// then declared with the `Hello` handshake (and re-declared on
    /// every reconnect).
    pub fn connect_with_link_opts(
        id: usize,
        addr: &str,
        opts: &TcpLinkOpts,
    ) -> Result<TcpWorker> {
        if opts.inflight == 0 {
            bail!("worker {id}: offload_inflight must be >= 1");
        }
        let mut stream = connect_with_backoff(addr, opts.attempts, opts.base)
            .with_context(|| format!("worker {id}"))?;
        stream.set_read_timeout(Some(PROBE_TIMEOUT))?;
        wire::send(&mut stream, &Msg::StateBytes)
            .and_then(|_| wire::recv(&mut stream))
            .and_then(|m| match m {
                Msg::StateBytesOk(_) => Ok(()),
                other => unexpected(other),
            })
            .with_context(|| {
                format!(
                    "worker {id} @ {addr}: connected but the daemon is not \
                     serving this link (wedged?)"
                )
            })?;
        let mut active = WireFormat::F32;
        if !opts.tenant.is_empty() || opts.wire == WireFormat::Bf16 {
            active = hello(&mut stream, &opts.tenant, opts.wire)
                .with_context(|| format!("worker {id} @ {addr}: tenant handshake"))?;
        }
        stream.set_read_timeout(None)?;
        let (tx, rx) = channel();
        let wire_bytes = Arc::new(AtomicU64::new(0));
        let link = Link {
            id,
            addr: addr.to_string(),
            conn: Some(stream),
            attempts: opts.attempts,
            base: opts.base,
            tenant: opts.tenant.clone(),
            inflight: opts.inflight,
            seq: 0,
            wire: opts.wire,
            active,
            wire_bytes: wire_bytes.clone(),
        };
        std::thread::Builder::new()
            .name(format!("tcp-worker-{id}"))
            .spawn(move || client_main(link, rx))?;
        Ok(TcpWorker {
            tx,
            id,
            addr: addr.to_string(),
            batch: opts.batch,
            inflight: opts.inflight,
            wire_bytes,
        })
    }

    fn send_cmd(&self, cmd: ClientCmd) -> Result<()> {
        self.tx
            .send(cmd)
            .map_err(|_| anyhow!("worker {} @ {}: client thread gone", self.id, self.addr))
    }
}

impl Transport for TcpWorker {
    fn id(&self) -> usize {
        self.id
    }

    fn describe(&self) -> String {
        format!("tcp://{}", self.addr)
    }

    fn register(&self, user: usize, site: &str, adapter: SiteAdapter) -> Result<()> {
        let (tx, rx) = channel();
        self.send_cmd(ClientCmd::Register {
            user,
            site: site.to_string(),
            adapter,
            reply: tx,
        })?;
        rx.recv()?
    }

    fn fit(&self, job: FitJob) -> Result<Receiver<Result<FitResult>>> {
        let (tx, rx) = channel();
        self.send_cmd(ClientCmd::Fit(job, tx))?;
        Ok(rx)
    }

    fn fit_many(&self, jobs: Vec<FitJob>) -> Result<Vec<Receiver<Result<FitResult>>>> {
        if !self.batch || jobs.len() <= 1 {
            // the v1 shape: one Fit frame per job
            return jobs.into_iter().map(|j| self.fit(j)).collect();
        }
        let mut rxs = Vec::with_capacity(jobs.len());
        let mut pairs = Vec::with_capacity(jobs.len());
        for job in jobs {
            let (tx, rx) = channel();
            pairs.push((job, tx));
            rxs.push(rx);
        }
        self.send_cmd(ClientCmd::FitBatch(pairs))?;
        Ok(rxs)
    }

    fn fit_frames(&self, n_jobs: usize) -> u64 {
        if self.batch && n_jobs > 1 {
            // mirror run_batch's chunking exactly: w windows of per jobs
            // gives ceil(n / per) frames, which is < w when w does not
            // divide n (e.g. 4 jobs, window 3 -> 2 frames, not 3)
            let per = n_jobs.div_ceil(self.inflight.min(n_jobs));
            n_jobs.div_ceil(per) as u64
        } else {
            n_jobs as u64
        }
    }

    fn snapshot(&self, user: usize, site: &str) -> Result<AdapterParams> {
        let (tx, rx) = channel();
        self.send_cmd(ClientCmd::Snapshot { user, site: site.to_string(), reply: tx })?;
        rx.recv()?
    }

    fn state_bytes(&self) -> Result<usize> {
        let (tx, rx) = channel();
        self.send_cmd(ClientCmd::StateBytes(tx))?;
        rx.recv()?
    }

    /// Liveness ping on a dedicated short-deadline connection.
    /// Deliberately NOT routed through the client I/O thread: that
    /// thread serializes commands, so a ping queued behind an in-flight
    /// `FitBatch` would wait out the whole fit — and a hung daemon
    /// would stall the heartbeat sweep indefinitely. A busy-but-alive
    /// daemon answers from a fresh connection thread within
    /// [`PING_DEADLINE`]; a dead or wedged one fails fast.
    fn ping(&self) -> Result<u64> {
        let r = (|| -> Result<u64> {
            // single connect attempt: a dead daemon must be *detected*,
            // not patiently retried into looking alive
            let mut stream = connect_with_backoff(&self.addr, 1, BASE_BACKOFF)?;
            stream.set_read_timeout(Some(PING_DEADLINE))?;
            let n = wire::send(&mut stream, &Msg::Ping)?;
            self.wire_bytes.fetch_add(n as u64, Ordering::Relaxed);
            match wire::recv(&mut stream)? {
                Msg::Pong { load } => Ok(load),
                other => unexpected(other),
            }
        })();
        r.map_err(|e| anyhow!("worker {} @ {}: ping: {e:#}", self.id, self.addr))
    }

    fn export_state(&self, user: usize, site: &str) -> Result<Vec<u8>> {
        let (tx, rx) = channel();
        self.send_cmd(ClientCmd::ExportState { user, site: site.to_string(), reply: tx })?;
        rx.recv()?
    }

    fn import_state(&self, blob: Vec<u8>) -> Result<()> {
        let (tx, rx) = channel();
        self.send_cmd(ClientCmd::ImportState { blob, reply: tx })?;
        rx.recv()?
    }

    fn evict_state(&self, user: usize, site: &str) -> Result<()> {
        let (tx, rx) = channel();
        self.send_cmd(ClientCmd::EvictState { user, site: site.to_string(), reply: tx })?;
        rx.recv()?
    }

    fn put_replica(&self, blob: Vec<u8>) -> Result<()> {
        let (tx, rx) = channel();
        self.send_cmd(ClientCmd::PutReplica { blob, reply: tx })?;
        rx.recv()?
    }

    fn promote_replica(&self, user: usize, site: &str) -> Result<()> {
        let (tx, rx) = channel();
        self.send_cmd(ClientCmd::PromoteReplica { user, site: site.to_string(), reply: tx })?;
        rx.recv()?
    }

    fn drop_replica(&self, user: usize, site: &str) -> Result<()> {
        let (tx, rx) = channel();
        self.send_cmd(ClientCmd::DropReplica { user, site: site.to_string(), reply: tx })?;
        rx.recv()?
    }

    fn shutdown(&self) {
        // disconnect only — daemon state survives for the next server
        let _ = self.tx.send(ClientCmd::Disconnect);
    }

    fn take_wire_bytes(&self) -> u64 {
        self.wire_bytes.swap(0, Ordering::Relaxed)
    }
}

/// The tenant + wire-format handshake on a fresh stream. Returns the
/// format the link actually speaks: `want` when the daemon acks, or
/// f32 when a pre-bf16 daemon rejects the capability byte (it replies
/// `Error` for the trailing byte; the legacy Hello is then re-sent so
/// the tenant still binds). Degradation is loud — the run keeps its
/// determinism, it just ships uncompressed.
fn hello(stream: &mut TcpStream, tenant: &str, want: WireFormat) -> Result<WireFormat> {
    wire::send(stream, &Msg::Hello { tenant: tenant.to_string(), wire: want })?;
    match wire::recv(stream)? {
        Msg::Ack => Ok(want),
        Msg::Error(e) if want == WireFormat::Bf16 => {
            eprintln!(
                "cola: worker at the other end of this link does not speak \
                 bf16 ({e}); falling back to f32 fit tensors"
            );
            wire::send(
                stream,
                &Msg::Hello { tenant: tenant.to_string(), wire: WireFormat::F32 },
            )?;
            match wire::recv(stream)? {
                Msg::Ack => Ok(WireFormat::F32),
                other => unexpected(other),
            }
        }
        other => unexpected(other),
    }
}

/// Client-thread state: the socket plus the reconnect schedule and
/// batching window the worker was built with.
struct Link {
    id: usize,
    addr: String,
    conn: Option<TcpStream>,
    attempts: u32,
    base: Duration,
    tenant: String,
    inflight: usize,
    /// FitBatch frame sequence numbers (monotone per link)
    seq: u64,
    /// requested fit-tensor format (what every reconnect re-negotiates)
    wire: WireFormat,
    /// format the current connection actually speaks (f32 after a
    /// fallback against a pre-bf16 daemon)
    active: WireFormat,
    /// request-byte ledger shared with the owning [`TcpWorker`]
    wire_bytes: Arc<AtomicU64>,
}

impl Link {
    /// (Re)connect if needed, re-declaring the tenant namespace and
    /// re-negotiating the wire format — daemon state is keyed by tenant
    /// and a fresh connection starts in the default namespace at f32.
    fn ensure_conn(&mut self) -> Result<()> {
        if self.conn.is_some() {
            return Ok(());
        }
        let mut stream = connect_with_backoff(&self.addr, self.attempts, self.base)?;
        self.active = WireFormat::F32;
        if !self.tenant.is_empty() || self.wire == WireFormat::Bf16 {
            self.active = hello(&mut stream, &self.tenant, self.wire)
                .context("tenant handshake on reconnect")?;
        }
        self.conn = Some(stream);
        Ok(())
    }

    /// One request/reply exchange. Returns the reply and the wall time
    /// spent on the wire exchange itself — reconnect backoff is
    /// excluded, so it never pollutes the measured-transfer ledger. On
    /// link failure the connection is torn down so the next request
    /// reconnects; the failed request itself is NOT replayed (see
    /// module docs).
    fn request(&mut self, msg: &Msg) -> Result<(Msg, Duration)> {
        self.ensure_conn()?;
        let fmt = self.active;
        let ledger = self.wire_bytes.clone();
        let stream = self.conn.as_mut().ok_or_else(|| {
            anyhow!("worker link lost before the request could be sent")
        })?;
        let t0 = Instant::now();
        let r = wire::send_with(stream, msg, fmt).and_then(|n| {
            ledger.fetch_add(n as u64, Ordering::Relaxed);
            wire::recv(stream)
        });
        let wire_time = t0.elapsed();
        match r {
            Ok(Msg::Error(e)) => Err(anyhow!("remote error: {e}")),
            Ok(m) => Ok((m, wire_time)),
            Err(e) => {
                self.conn = None;
                Err(e.context(
                    "worker link failed mid-request (next dispatch will reconnect)",
                ))
            }
        }
    }

    /// One interval's jobs as pipelined `FitBatch` frames: the jobs are
    /// split into `inflight` chunks, every chunk is written before the
    /// first reply is read (so a later chunk rides the wire while the
    /// daemon computes an earlier one), and replies are read back in
    /// sequence order. If the link dies anywhere in the exchange, every
    /// job not yet answered gets its own error naming its (user, site),
    /// and nothing is ever replayed — the daemon may have stepped those
    /// optimizers already.
    // while-let keeps the iterators nameable so the failure paths can
    // drain "everything not yet answered" — a for-loop would consume them
    #[allow(clippy::while_let_on_iterator)]
    fn run_batch(&mut self, pairs: Vec<(FitJob, Sender<Result<FitResult>>)>) {
        let (id, addr) = (self.id, self.addr.clone());
        let n = pairs.len();
        if n == 0 {
            return;
        }
        let w = self.inflight.max(1).min(n);
        let per = n.div_ceil(w);

        type Repliers = Vec<(usize, String, Sender<Result<FitResult>>)>;
        let fail_all = |chunks: &mut dyn Iterator<Item = Repliers>, e: &anyhow::Error| {
            for repliers in chunks {
                for (user, site, sender) in repliers {
                    let _ = sender.send(Err(anyhow!(
                        "worker {id} @ {addr}: batched fit (user {user}, site \
                         {site}) lost in flight (not replayed — the daemon may \
                         already have stepped it): {e:#}"
                    )));
                }
            }
        };

        // split into <= inflight contiguous chunks, keeping job order
        let mut chunks: Vec<(Vec<FitJob>, Repliers)> = Vec::with_capacity(w);
        let mut pending = pairs;
        while !pending.is_empty() {
            let rest = pending.split_off(per.min(pending.len()));
            let mut jobs = Vec::with_capacity(pending.len());
            let mut repliers = Vec::with_capacity(pending.len());
            for (job, sender) in pending {
                repliers.push((job.user, job.site.clone(), sender));
                jobs.push(job);
            }
            chunks.push((jobs, repliers));
            pending = rest;
        }

        if let Err(e) = self.ensure_conn() {
            fail_all(&mut chunks.into_iter().map(|(_, r)| r), &e);
            return;
        }

        // send phase: put the whole window on the wire
        let mut sent: Vec<(u64, Repliers, Instant)> = Vec::with_capacity(chunks.len());
        let mut chunk_iter = chunks.into_iter();
        let fmt = self.active;
        while let Some((jobs, repliers)) = chunk_iter.next() {
            let seq = self.seq;
            self.seq += 1;
            let Some(stream) = self.conn.as_mut() else {
                // ensure_conn succeeded above, so this means the link
                // object was torn down mid-batch: fail every job not
                // yet answered, naming its (user, site)
                let e = anyhow!("worker link lost during the batch send window");
                let mut rest = std::iter::once(repliers)
                    .chain(sent.drain(..).map(|(_, r, _)| r))
                    .chain(chunk_iter.map(|(_, r)| r));
                fail_all(&mut rest, &e);
                return;
            };
            let t_send = Instant::now();
            match wire::send_with(stream, &Msg::FitBatch { seq, jobs }, fmt) {
                Ok(n) => {
                    self.wire_bytes.fetch_add(n as u64, Ordering::Relaxed);
                }
                Err(e) => {
                    self.conn = None;
                    let mut rest = std::iter::once(repliers)
                        .chain(sent.drain(..).map(|(_, r, _)| r))
                        .chain(chunk_iter.map(|(_, r)| r));
                    fail_all(&mut rest, &e);
                    return;
                }
            }
            sent.push((seq, repliers, t_send));
        }

        // receive phase: replies come back in sequence order
        let mut sent_iter = sent.into_iter();
        // end of the previous chunk's reply — each chunk's wall segment
        // starts there (or at its own send, for the first chunk), so
        // summed segments cover the exchange exactly once and an earlier
        // chunk's compute never double-counts into a later chunk's
        // transfer when the window is > 1
        let mut mark: Option<Instant> = None;
        while let Some((seq, repliers, t_send)) = sent_iter.next() {
            let Some(stream) = self.conn.as_mut() else {
                let e = anyhow!("worker link lost before the batch replies arrived");
                let mut rest =
                    std::iter::once(repliers).chain(sent_iter.map(|(_, r, _)| r));
                fail_all(&mut rest, &e);
                return;
            };
            let reply = wire::recv(stream);
            let done = Instant::now();
            let wire_time = done.saturating_duration_since(mark.unwrap_or(t_send));
            mark = Some(done);
            match reply {
                Ok(Msg::FitBatchOk { seq: rseq, results })
                    if rseq == seq && results.len() == repliers.len() =>
                {
                    // the daemon reports pure compute per job; what's left
                    // of the chunk's wall segment is wire + queueing,
                    // charged to the chunk's first successful job (split
                    // finer is guesswork)
                    let computed: Duration = results
                        .iter()
                        .filter_map(|i| match i {
                            BatchItem::Ok(r) => Some(r.compute),
                            BatchItem::Err { .. } => None,
                        })
                        .sum();
                    let mut extra = Some(wire_time.saturating_sub(computed));
                    for (item, (user, site, sender)) in
                        results.into_iter().zip(repliers)
                    {
                        match item {
                            BatchItem::Ok(mut res) => {
                                res.transfer = extra.take().unwrap_or(Duration::ZERO);
                                let _ = sender.send(Ok(res));
                            }
                            BatchItem::Err { error, .. } => {
                                let _ = sender.send(Err(anyhow!(
                                    "worker {id} @ {addr}: batched fit (user \
                                     {user}, site {site}): remote error: {error}"
                                )));
                            }
                        }
                    }
                }
                Ok(Msg::Error(e)) => {
                    // the daemon rejected this frame (e.g. decode error)
                    // but the connection is intact: fail this chunk only
                    let e = anyhow!("remote error: {e}");
                    fail_all(&mut std::iter::once(repliers), &e);
                }
                Ok(other) => {
                    self.conn = None;
                    let e = anyhow!("protocol error: unexpected reply {other:?}");
                    let mut rest =
                        std::iter::once(repliers).chain(sent_iter.map(|(_, r, _)| r));
                    fail_all(&mut rest, &e);
                    return;
                }
                Err(e) => {
                    self.conn = None;
                    let e = e.context(
                        "worker link failed mid-batch (next dispatch will reconnect)",
                    );
                    let mut rest =
                        std::iter::once(repliers).chain(sent_iter.map(|(_, r, _)| r));
                    fail_all(&mut rest, &e);
                    return;
                }
            }
        }
    }
}

fn unexpected<T>(m: Msg) -> Result<T> {
    Err(anyhow!("protocol error: unexpected reply {m:?}"))
}

fn client_main(mut link: Link, rx: Receiver<ClientCmd>) {
    let (id, addr) = (link.id, link.addr.clone());
    let wrap = |e: anyhow::Error| anyhow!("worker {id} @ {addr}: {e:#}");
    while let Ok(cmd) = rx.recv() {
        match cmd {
            ClientCmd::Register { user, site, adapter, reply } => {
                let r = link
                    .request(&Msg::Register { user, site, adapter })
                    .and_then(|(m, _)| match m {
                        Msg::Ack => Ok(()),
                        other => unexpected(other),
                    });
                let _ = reply.send(r.map_err(wrap));
            }
            ClientCmd::Fit(job, reply) => {
                let (user, site) = (job.user, job.site.clone());
                let r = link.request(&Msg::Fit(job)).and_then(|(m, wire_time)| match m {
                    Msg::FitOk(mut res) => {
                        // the daemon reports pure compute; the rest of
                        // the wire exchange is real transfer
                        res.transfer = wire_time.saturating_sub(res.compute);
                        Ok(res)
                    }
                    other => unexpected(other),
                });
                let _ = reply.send(r.map_err(|e| {
                    anyhow!("worker {id} @ {addr}: fit(user {user}, site {site}): {e:#}")
                }));
            }
            ClientCmd::FitBatch(pairs) => {
                link.run_batch(pairs);
            }
            ClientCmd::Snapshot { user, site, reply } => {
                let r = link
                    .request(&Msg::Snapshot { user, site })
                    .and_then(|(m, _)| match m {
                        Msg::SnapshotOk(p) => Ok(p),
                        other => unexpected(other),
                    });
                let _ = reply.send(r.map_err(wrap));
            }
            ClientCmd::StateBytes(reply) => {
                let r = link.request(&Msg::StateBytes).and_then(|(m, _)| match m {
                    Msg::StateBytesOk(n) => Ok(n as usize),
                    other => unexpected(other),
                });
                let _ = reply.send(r.map_err(wrap));
            }
            ClientCmd::ExportState { user, site, reply } => {
                let r = link
                    .request(&Msg::StateExport { user, site })
                    .and_then(|(m, _)| match m {
                        Msg::StateExportOk(blob) => Ok(blob),
                        other => unexpected(other),
                    });
                let _ = reply.send(r.map_err(wrap));
            }
            ClientCmd::ImportState { blob, reply } => {
                let r = link
                    .request(&Msg::StateImport(blob))
                    .and_then(|(m, _)| match m {
                        Msg::Ack => Ok(()),
                        other => unexpected(other),
                    });
                let _ = reply.send(r.map_err(wrap));
            }
            ClientCmd::EvictState { user, site, reply } => {
                let r = link
                    .request(&Msg::StateEvict { user, site })
                    .and_then(|(m, _)| match m {
                        Msg::Ack => Ok(()),
                        other => unexpected(other),
                    });
                let _ = reply.send(r.map_err(wrap));
            }
            ClientCmd::PutReplica { blob, reply } => {
                let r = link
                    .request(&Msg::ReplicaPut(blob))
                    .and_then(|(m, _)| match m {
                        Msg::Ack => Ok(()),
                        other => unexpected(other),
                    });
                let _ = reply.send(r.map_err(wrap));
            }
            ClientCmd::PromoteReplica { user, site, reply } => {
                let r = link
                    .request(&Msg::ReplicaPromote { user, site })
                    .and_then(|(m, _)| match m {
                        Msg::Ack => Ok(()),
                        other => unexpected(other),
                    });
                let _ = reply.send(r.map_err(wrap));
            }
            ClientCmd::DropReplica { user, site, reply } => {
                let r = link
                    .request(&Msg::ReplicaDrop { user, site })
                    .and_then(|(m, _)| match m {
                        Msg::Ack => Ok(()),
                        other => unexpected(other),
                    });
                let _ = reply.send(r.map_err(wrap));
            }
            ClientCmd::Disconnect => break,
        }
    }
    // dropping the stream closes the connection; the daemon goes back
    // to accepting
}

// ---------------------------------------------------------------------
// worker side (the daemon behind `cola worker --listen`)
// ---------------------------------------------------------------------

/// The worker daemon: a TCP listener bridging the wire protocol onto a
/// shared [`WorkerCore`]. Serves any number of concurrent connections
/// (one thread each); adapter + optimizer state persist across
/// connections AND across tenants (reconnect safety, multi-tenant
/// FTaaS). Exits on the [`Msg::Shutdown`] handshake — or abruptly via
/// [`WorkerDaemon::kill`], the chaos-testing stand-in for `kill -9`.
pub struct WorkerDaemon {
    addr: SocketAddr,
    handle: Option<JoinHandle<()>>,
    shared: Arc<DaemonShared>,
}

/// State shared by the accept loop and every connection thread.
struct DaemonShared {
    core: WorkerCore,
    addr: SocketAddr,
    stop: AtomicBool,
    /// live connection handles (id, cloned stream) so [`WorkerDaemon::kill`]
    /// can sever in-flight links, not just stop accepting
    conns: std::sync::Mutex<Vec<(usize, TcpStream)>>,
}

fn lock_conns(shared: &DaemonShared) -> std::sync::MutexGuard<'_, Vec<(usize, TcpStream)>> {
    // a connection thread that died mid-registration must not wedge the
    // accept loop; poison recovery is centralized in util::lock_recover
    crate::util::lock_recover(&shared.conns)
}

impl WorkerDaemon {
    /// Bind `listen` (e.g. `127.0.0.1:0` for an ephemeral port) and
    /// start serving. `transfer` injects a simulated link on top of the
    /// real wire (for calibration sweeps); pass `None` for honest
    /// measured-transfer numbers.
    pub fn bind(
        listen: &str,
        target: OffloadTarget,
        manifest: Arc<Manifest>,
        transfer: Option<TransferModel>,
    ) -> Result<WorkerDaemon> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("worker daemon: binding {listen}"))?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(DaemonShared {
            core: WorkerCore::new(0, target, manifest, transfer),
            addr,
            stop: AtomicBool::new(false),
            conns: std::sync::Mutex::new(Vec::new()),
        });
        let shared2 = shared.clone();
        let handle = std::thread::Builder::new()
            .name("cola-worker-daemon".into())
            .spawn(move || daemon_main(listener, shared2))?;
        Ok(WorkerDaemon { addr, handle: Some(handle), shared })
    }

    /// The actually-bound address (resolves `:0` to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Arm a one-shot injected panic in the shared core: the next fit
    /// for `(tenant, user, site)` panics while holding the adapter
    /// table lock. The chaos-testing stand-in for a kernel assert
    /// inside a serving thread — the poisoned-mutex regression test
    /// uses it to prove the daemon keeps serving every other tenant
    /// (see [`WorkerCore::inject_fit_panic`]).
    pub fn inject_fit_panic(&self, tenant: &str, user: usize, site: &str) {
        self.shared.core.inject_fit_panic(tenant, user, site);
    }

    /// Block until a client completes the shutdown handshake.
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    /// Abrupt termination — the chaos-testing stand-in for `kill -9`:
    /// stops accepting, severs every live connection mid-whatever (peers
    /// see a dead link, not a clean shutdown handshake), and returns
    /// once the accept thread has exited and the listening port is
    /// closed. Resident adapter/optimizer state is NOT exported first —
    /// exactly the failure `failover = "migrate"` exists to survive.
    pub fn kill(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        for (_, conn) in lock_conns(&self.shared).drain(..) {
            let _ = conn.shutdown(std::net::Shutdown::Both);
        }
        // unblock the accept loop; dropping the listener then refuses
        // further connects on this port
        let _ = TcpStream::connect(wake_addr(self.addr));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        // the accept thread is the only registrar, so after the join no
        // new entries can appear — sever anything it registered between
        // the first drain and its exit (a connection accepted at the
        // exact kill moment must not survive as a live link to a
        // "dead" daemon)
        for (_, conn) in lock_conns(&self.shared).drain(..) {
            let _ = conn.shutdown(std::net::Shutdown::Both);
        }
    }
}

fn daemon_main(listener: TcpListener, shared: Arc<DaemonShared>) {
    let mut conn_id = 0usize;
    loop {
        let (stream, peer) = match listener.accept() {
            Ok(x) => x,
            Err(e) => {
                if shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                eprintln!("cola worker: accept failed: {e}");
                // persistent accept errors (fd exhaustion etc.) must not
                // become a 100%-CPU spin; retry on a human timescale
                std::thread::sleep(Duration::from_millis(200));
                continue;
            }
        };
        if shared.stop.load(Ordering::SeqCst) {
            // the shutdown wake-up connection (or a late client)
            break;
        }
        let _ = stream.set_nodelay(true);
        conn_id += 1;
        let id = conn_id;
        if let Ok(clone) = stream.try_clone() {
            lock_conns(&shared).push((id, clone));
        }
        let sh = shared.clone();
        let spawned = std::thread::Builder::new()
            .name(format!("cola-conn-{id}"))
            .spawn(move || {
                if let Err(e) = serve_conn(stream, &sh) {
                    eprintln!("cola worker: connection from {peer} failed: {e:#}");
                }
                // drop the kill handle so the registry can't grow
                // unboundedly over a long-lived daemon's lifetime
                lock_conns(&sh).retain(|(cid, _)| *cid != id);
            });
        if let Err(e) = spawned {
            eprintln!("cola worker: spawning connection thread failed: {e}");
            lock_conns(&shared).retain(|(cid, _)| *cid != id);
        }
    }
    // connection threads drain on their own as peers disconnect; the
    // core (and its adapter state) lives until the last Arc drops
}

/// The loopback address that reaches our own listener — used to wake a
/// blocking `accept()` after the stop flag is set.
fn wake_addr(addr: SocketAddr) -> SocketAddr {
    if addr.ip().is_unspecified() {
        let ip = if addr.is_ipv4() {
            std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
        } else {
            std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
        };
        SocketAddr::new(ip, addr.port())
    } else {
        addr
    }
}

fn serve_conn(mut stream: TcpStream, shared: &DaemonShared) -> Result<()> {
    // per-connection tenant namespace; a wire-v2 Hello rebinds it
    let mut tenant = String::new();
    loop {
        let frame = match wire::read_frame(&mut stream) {
            Ok(f) => f,
            // peer went away; daemon state persists for a reconnect
            Err(e) if is_disconnect(&e) => return Ok(()),
            Err(e) => return Err(e),
        };
        match wire::decode(&frame) {
            Ok(Msg::Shutdown) => {
                shared.stop.store(true, Ordering::SeqCst);
                // ack BEFORE waking the accept loop: the moment accept()
                // wakes, join() can return and the process exit — the ack
                // must already be on the wire by then or `--stop` reads
                // EOF instead of ShutdownOk
                let acked = wire::send(&mut stream, &Msg::ShutdownOk);
                // unblock the accept loop so the daemon thread exits
                let _ = TcpStream::connect(wake_addr(shared.addr));
                return acked.map(|_| ());
            }
            Ok(Msg::Hello { tenant: t, wire: _ }) => {
                // acking a bf16 Hello IS the capability grant: this build
                // decodes dtype-2 fit tensors statelessly (each frame
                // declares its own dtype), and replies are always f32,
                // so no per-connection format state is needed
                tenant = t;
                wire::send(&mut stream, &Msg::Ack)?;
            }
            Ok(msg) => {
                let reply = dispatch(msg, &tenant, &shared.core);
                wire::send(&mut stream, &reply)?;
            }
            Err(e) => {
                // decodable framing but corrupt body: report and keep
                // the connection — the peer sees exactly what broke
                wire::send(&mut stream, &Msg::Error(format!("{e:#}")))?;
            }
        }
    }
}

fn dispatch(msg: Msg, tenant: &str, core: &WorkerCore) -> Msg {
    let r: Result<Msg> = (|| match msg {
        Msg::Register { user, site, adapter } => {
            core.register(tenant, user, &site, adapter)?;
            Ok(Msg::Ack)
        }
        Msg::Fit(job) => Ok(Msg::FitOk(core.fit(tenant, job)?)),
        Msg::FitBatch { seq, jobs } => {
            let meta: Vec<(usize, String)> =
                jobs.iter().map(|j| (j.user, j.site.clone())).collect();
            let results = core.fit_batch(tenant, jobs);
            let items = meta
                .into_iter()
                .zip(results)
                .map(|((user, site), r)| match r {
                    Ok(res) => BatchItem::Ok(res),
                    Err(e) => BatchItem::Err { user, site, error: format!("{e:#}") },
                })
                .collect();
            Ok(Msg::FitBatchOk { seq, results: items })
        }
        Msg::Snapshot { user, site } => {
            Ok(Msg::SnapshotOk(core.snapshot(tenant, user, &site)?))
        }
        Msg::StateBytes => Ok(Msg::StateBytesOk(core.state_bytes() as u64)),
        Msg::Ping => Ok(Msg::Pong { load: core.load() }),
        Msg::StateExport { user, site } => {
            Ok(Msg::StateExportOk(core.export_state(tenant, user, &site)?))
        }
        Msg::StateImport(blob) => {
            core.import_state(tenant, &blob)?;
            Ok(Msg::Ack)
        }
        Msg::StateEvict { user, site } => {
            core.evict_state(tenant, user, &site)?;
            Ok(Msg::Ack)
        }
        Msg::ReplicaPut(blob) => {
            core.put_replica(tenant, &blob)?;
            Ok(Msg::Ack)
        }
        Msg::ReplicaPromote { user, site } => {
            core.promote_replica(tenant, user, &site)?;
            Ok(Msg::Ack)
        }
        Msg::ReplicaDrop { user, site } => {
            core.drop_replica(tenant, user, &site);
            Ok(Msg::Ack)
        }
        // Join is a registry-listener message; a worker daemon receiving
        // it falls through to the loud rejection below, which is exactly
        // what a mis-pointed `--join` should see
        other => bail!("unexpected message on worker side: {other:?}"),
    })();
    r.unwrap_or_else(|e| Msg::Error(format!("{e:#}")))
}

/// True when the error chain bottoms out in a peer-went-away IO error.
fn is_disconnect(e: &anyhow::Error) -> bool {
    use std::io::ErrorKind::*;
    e.chain().any(|c| {
        c.downcast_ref::<std::io::Error>()
            .map(|io| {
                matches!(
                    io.kind(),
                    UnexpectedEof | ConnectionReset | ConnectionAborted | BrokenPipe
                )
            })
            .unwrap_or(false)
    })
}

/// The clean shutdown handshake: connect, send [`Msg::Shutdown`], wait
/// for the ack. After this returns `Ok`, the daemon has stopped
/// accepting and its accept thread is exiting.
pub fn request_daemon_shutdown(addr: &str) -> Result<()> {
    let mut stream = connect_with_backoff(addr, 3, Duration::from_millis(50))?;
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    wire::send(&mut stream, &Msg::Shutdown)?;
    match wire::recv(&mut stream)? {
        Msg::ShutdownOk => Ok(()),
        other => bail!("unexpected reply to shutdown handshake: {other:?}"),
    }
}
