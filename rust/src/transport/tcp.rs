//! TCP offload: the client proxy the server pool holds, and the worker
//! daemon (`cola worker --listen <addr>`) that owns adapters behind a
//! socket.
//!
//! Topology: each [`TcpWorker`] owns one connection to one daemon and
//! serializes requests over it (mirroring the one-command-at-a-time
//! local worker thread). The daemon hosts a single long-lived local
//! [`Worker`] — adapter and optimizer state live for the daemon's
//! lifetime, *not* the connection's, so a dropped link is survivable:
//! the client reconnects with exponential backoff and the registered
//! state is still there.
//!
//! Failure semantics: a request that dies mid-flight is **not**
//! replayed — a `Fit` may already have stepped the remote optimizer,
//! and replaying would double-apply it, silently breaking the
//! determinism guarantee. The error surfaces (naming the worker and,
//! for fits, the user/site), and the *next* request reconnects.
//!
//! Shutdown: closing a connection leaves the daemon running; the clean
//! shutdown handshake ([`request_daemon_shutdown`], or `cola worker
//! --stop <addr>`) makes it ack with `ShutdownOk` and exit. The daemon
//! serves one connection at a time, so finish (or drop) the training
//! run before requesting shutdown.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::wire::{self, Msg};
use super::Transport;
use crate::adapters::{AdapterParams, SiteAdapter};
use crate::config::OffloadTarget;
use crate::coordinator::offload::{FitJob, FitResult, TransferModel, Worker};
use crate::runtime::Manifest;

/// Default connection attempts before giving up (first contact).
pub const CONNECT_ATTEMPTS: u32 = 8;
/// Base backoff delay; doubles per attempt, capped at 2 s.
pub const BASE_BACKOFF: Duration = Duration::from_millis(50);
/// How long the connect-time liveness probe waits for the daemon to
/// answer before declaring the link dead-on-arrival.
pub const PROBE_TIMEOUT: Duration = Duration::from_secs(10);

/// Connect with exponential backoff — `attempts` tries, sleeping
/// `base * 2^k` (capped at 2 s) between them. Lets a server start
/// before its worker daemons finish binding.
pub fn connect_with_backoff(addr: &str, attempts: u32, base: Duration) -> Result<TcpStream> {
    let mut delay = base;
    let mut last_err: Option<std::io::Error> = None;
    for attempt in 0..attempts.max(1) {
        if attempt > 0 {
            std::thread::sleep(delay);
            delay = (delay * 2).min(Duration::from_secs(2));
        }
        match TcpStream::connect(addr) {
            Ok(s) => {
                // small frames dominate the handshake traffic; don't let
                // Nagle hold them back
                let _ = s.set_nodelay(true);
                return Ok(s);
            }
            Err(e) => last_err = Some(e),
        }
    }
    Err(anyhow!(
        "connect to worker at {addr} failed after {} attempts: {}",
        attempts.max(1),
        last_err.expect("at least one attempt ran")
    ))
}

// ---------------------------------------------------------------------
// client side (held by the server's WorkerPool)
// ---------------------------------------------------------------------

enum ClientCmd {
    Register { user: usize, site: String, adapter: SiteAdapter, reply: Sender<Result<()>> },
    Fit(FitJob, Sender<Result<FitResult>>),
    Snapshot { user: usize, site: String, reply: Sender<Result<AdapterParams>> },
    StateBytes(Sender<Result<usize>>),
    Disconnect,
}

/// Client proxy for one remote worker daemon — the `Tcp` implementation
/// of [`Transport`]. A dedicated I/O thread owns the socket; handles
/// are cheap to use from the coordinator thread.
pub struct TcpWorker {
    tx: Sender<ClientCmd>,
    id: usize,
    addr: String,
}

impl TcpWorker {
    /// Connect with the default backoff schedule.
    pub fn connect(id: usize, addr: &str) -> Result<TcpWorker> {
        Self::connect_with_opts(id, addr, CONNECT_ATTEMPTS, BASE_BACKOFF)
    }

    /// Connect with an explicit backoff schedule (tests use tight
    /// ones). The same schedule governs mid-run reconnects.
    ///
    /// After connecting, a `StateBytes` probe (bounded by
    /// [`PROBE_TIMEOUT`]) confirms the daemon is actually *serving*
    /// this link. A daemon serves one connection at a time, and the OS
    /// accept backlog happily queues a second one — without the probe,
    /// pointing two links at one daemon (e.g. `localhost:7701` and
    /// `127.0.0.1:7701` sneaking past the literal-string dedup) would
    /// hang the first request forever instead of failing loudly here.
    pub fn connect_with_opts(
        id: usize,
        addr: &str,
        attempts: u32,
        base: Duration,
    ) -> Result<TcpWorker> {
        let mut stream = connect_with_backoff(addr, attempts, base)
            .with_context(|| format!("worker {id}"))?;
        stream.set_read_timeout(Some(PROBE_TIMEOUT))?;
        wire::send(&mut stream, &Msg::StateBytes)
            .and_then(|()| wire::recv(&mut stream))
            .and_then(|m| match m {
                Msg::StateBytesOk(_) => Ok(()),
                other => unexpected(other),
            })
            .with_context(|| {
                format!(
                    "worker {id} @ {addr}: connected but the daemon is not \
                     serving this link (already serving another server, or \
                     wedged?)"
                )
            })?;
        stream.set_read_timeout(None)?;
        let (tx, rx) = channel();
        let link = Link {
            id,
            addr: addr.to_string(),
            conn: Some(stream),
            attempts,
            base,
        };
        std::thread::Builder::new()
            .name(format!("tcp-worker-{id}"))
            .spawn(move || client_main(link, rx))?;
        Ok(TcpWorker { tx, id, addr: addr.to_string() })
    }

    fn send_cmd(&self, cmd: ClientCmd) -> Result<()> {
        self.tx
            .send(cmd)
            .map_err(|_| anyhow!("worker {} @ {}: client thread gone", self.id, self.addr))
    }
}

impl Transport for TcpWorker {
    fn id(&self) -> usize {
        self.id
    }

    fn describe(&self) -> String {
        format!("tcp://{}", self.addr)
    }

    fn register(&self, user: usize, site: &str, adapter: SiteAdapter) -> Result<()> {
        let (tx, rx) = channel();
        self.send_cmd(ClientCmd::Register {
            user,
            site: site.to_string(),
            adapter,
            reply: tx,
        })?;
        rx.recv()?
    }

    fn fit(&self, job: FitJob) -> Result<Receiver<Result<FitResult>>> {
        let (tx, rx) = channel();
        self.send_cmd(ClientCmd::Fit(job, tx))?;
        Ok(rx)
    }

    fn snapshot(&self, user: usize, site: &str) -> Result<AdapterParams> {
        let (tx, rx) = channel();
        self.send_cmd(ClientCmd::Snapshot { user, site: site.to_string(), reply: tx })?;
        rx.recv()?
    }

    fn state_bytes(&self) -> Result<usize> {
        let (tx, rx) = channel();
        self.send_cmd(ClientCmd::StateBytes(tx))?;
        rx.recv()?
    }

    fn shutdown(&self) {
        // disconnect only — daemon state survives for the next server
        let _ = self.tx.send(ClientCmd::Disconnect);
    }
}

/// Client-thread state: the socket plus the reconnect schedule the
/// worker was built with.
struct Link {
    id: usize,
    addr: String,
    conn: Option<TcpStream>,
    attempts: u32,
    base: Duration,
}

impl Link {
    /// One request/reply exchange. Returns the reply and the wall time
    /// spent on the wire exchange itself — reconnect backoff is
    /// excluded, so it never pollutes the measured-transfer ledger. On
    /// link failure the connection is torn down so the next request
    /// reconnects; the failed request itself is NOT replayed (see
    /// module docs).
    fn request(&mut self, msg: &Msg) -> Result<(Msg, Duration)> {
        if self.conn.is_none() {
            self.conn = Some(connect_with_backoff(&self.addr, self.attempts, self.base)?);
        }
        let stream = self.conn.as_mut().expect("connected above");
        let t0 = Instant::now();
        let r = wire::send(stream, msg).and_then(|()| wire::recv(stream));
        let wire_time = t0.elapsed();
        match r {
            Ok(Msg::Error(e)) => Err(anyhow!("remote error: {e}")),
            Ok(m) => Ok((m, wire_time)),
            Err(e) => {
                self.conn = None;
                Err(e.context(
                    "worker link failed mid-request (next dispatch will reconnect)",
                ))
            }
        }
    }
}

fn unexpected<T>(m: Msg) -> Result<T> {
    Err(anyhow!("protocol error: unexpected reply {m:?}"))
}

fn client_main(mut link: Link, rx: Receiver<ClientCmd>) {
    let (id, addr) = (link.id, link.addr.clone());
    let wrap = |e: anyhow::Error| anyhow!("worker {id} @ {addr}: {e:#}");
    while let Ok(cmd) = rx.recv() {
        match cmd {
            ClientCmd::Register { user, site, adapter, reply } => {
                let r = link
                    .request(&Msg::Register { user, site, adapter })
                    .and_then(|(m, _)| match m {
                        Msg::Ack => Ok(()),
                        other => unexpected(other),
                    });
                let _ = reply.send(r.map_err(wrap));
            }
            ClientCmd::Fit(job, reply) => {
                let (user, site) = (job.user, job.site.clone());
                let r = link.request(&Msg::Fit(job)).and_then(|(m, wire_time)| match m {
                    Msg::FitOk(mut res) => {
                        // the daemon reports pure compute; the rest of
                        // the wire exchange is real transfer
                        res.transfer = wire_time.saturating_sub(res.compute);
                        Ok(res)
                    }
                    other => unexpected(other),
                });
                let _ = reply.send(r.map_err(|e| {
                    anyhow!("worker {id} @ {addr}: fit(user {user}, site {site}): {e:#}")
                }));
            }
            ClientCmd::Snapshot { user, site, reply } => {
                let r = link
                    .request(&Msg::Snapshot { user, site })
                    .and_then(|(m, _)| match m {
                        Msg::SnapshotOk(p) => Ok(p),
                        other => unexpected(other),
                    });
                let _ = reply.send(r.map_err(wrap));
            }
            ClientCmd::StateBytes(reply) => {
                let r = link.request(&Msg::StateBytes).and_then(|(m, _)| match m {
                    Msg::StateBytesOk(n) => Ok(n as usize),
                    other => unexpected(other),
                });
                let _ = reply.send(r.map_err(wrap));
            }
            ClientCmd::Disconnect => break,
        }
    }
    // dropping the stream closes the connection; the daemon goes back
    // to accepting
}

// ---------------------------------------------------------------------
// worker side (the daemon behind `cola worker --listen`)
// ---------------------------------------------------------------------

/// The worker daemon: a TCP listener bridging the wire protocol onto a
/// long-lived local [`Worker`]. Serves one connection at a time;
/// adapter + optimizer state persist across connections (reconnect
/// safety). Exits on the [`Msg::Shutdown`] handshake.
pub struct WorkerDaemon {
    addr: SocketAddr,
    handle: Option<JoinHandle<()>>,
}

impl WorkerDaemon {
    /// Bind `listen` (e.g. `127.0.0.1:0` for an ephemeral port) and
    /// start serving. `transfer` injects a simulated link on top of the
    /// real wire (for calibration sweeps); pass `None` for honest
    /// measured-transfer numbers.
    pub fn bind(
        listen: &str,
        target: OffloadTarget,
        manifest: Arc<Manifest>,
        transfer: Option<TransferModel>,
    ) -> Result<WorkerDaemon> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("worker daemon: binding {listen}"))?;
        let addr = listener.local_addr()?;
        let worker = Worker::spawn_local(0, target, manifest, transfer)?;
        let handle = std::thread::Builder::new()
            .name("cola-worker-daemon".into())
            .spawn(move || daemon_main(listener, worker))?;
        Ok(WorkerDaemon { addr, handle: Some(handle) })
    }

    /// The actually-bound address (resolves `:0` to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until a client completes the shutdown handshake.
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

enum ConnEnd {
    /// peer asked the daemon to exit (handshake acked)
    Shutdown,
    /// peer went away; state persists, wait for a reconnect
    Disconnect,
}

fn daemon_main(listener: TcpListener, worker: Worker) {
    loop {
        let (stream, peer) = match listener.accept() {
            Ok(x) => x,
            Err(e) => {
                eprintln!("cola worker: accept failed: {e}");
                // persistent accept errors (fd exhaustion etc.) must not
                // become a 100%-CPU spin; retry on a human timescale
                std::thread::sleep(Duration::from_millis(200));
                continue;
            }
        };
        let _ = stream.set_nodelay(true);
        match serve_conn(stream, &worker) {
            Ok(ConnEnd::Shutdown) => break,
            Ok(ConnEnd::Disconnect) => {}
            Err(e) => eprintln!("cola worker: connection from {peer} failed: {e:#}"),
        }
    }
    worker.shutdown();
}

fn serve_conn(mut stream: TcpStream, worker: &Worker) -> Result<ConnEnd> {
    loop {
        let frame = match wire::read_frame(&mut stream) {
            Ok(f) => f,
            Err(e) if is_disconnect(&e) => return Ok(ConnEnd::Disconnect),
            Err(e) => return Err(e),
        };
        match wire::decode(&frame) {
            Ok(Msg::Shutdown) => {
                wire::send(&mut stream, &Msg::ShutdownOk)?;
                return Ok(ConnEnd::Shutdown);
            }
            Ok(msg) => {
                let reply = dispatch(msg, worker);
                wire::send(&mut stream, &reply)?;
            }
            Err(e) => {
                // decodable framing but corrupt body: report and keep
                // the connection — the peer sees exactly what broke
                wire::send(&mut stream, &Msg::Error(format!("{e:#}")))?;
            }
        }
    }
}

fn dispatch(msg: Msg, worker: &Worker) -> Msg {
    let r: Result<Msg> = (|| match msg {
        Msg::Register { user, site, adapter } => {
            Worker::register(worker, user, &site, adapter)?;
            Ok(Msg::Ack)
        }
        Msg::Fit(job) => {
            let rx = Worker::fit(worker, job)?;
            Ok(Msg::FitOk(rx.recv()??))
        }
        Msg::Snapshot { user, site } => {
            Ok(Msg::SnapshotOk(Worker::snapshot(worker, user, &site)?))
        }
        Msg::StateBytes => Ok(Msg::StateBytesOk(Worker::state_bytes(worker)? as u64)),
        other => bail!("unexpected message on worker side: {other:?}"),
    })();
    r.unwrap_or_else(|e| Msg::Error(format!("{e:#}")))
}

/// True when the error chain bottoms out in a peer-went-away IO error.
fn is_disconnect(e: &anyhow::Error) -> bool {
    use std::io::ErrorKind::*;
    e.chain().any(|c| {
        c.downcast_ref::<std::io::Error>()
            .map(|io| {
                matches!(
                    io.kind(),
                    UnexpectedEof | ConnectionReset | ConnectionAborted | BrokenPipe
                )
            })
            .unwrap_or(false)
    })
}

/// The clean shutdown handshake: connect, send [`Msg::Shutdown`], wait
/// for the ack. After this returns `Ok`, the daemon process is exiting.
pub fn request_daemon_shutdown(addr: &str) -> Result<()> {
    let mut stream = connect_with_backoff(addr, 3, Duration::from_millis(50))?;
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    wire::send(&mut stream, &Msg::Shutdown)?;
    match wire::recv(&mut stream)? {
        Msg::ShutdownOk => Ok(()),
        other => bail!("unexpected reply to shutdown handshake: {other:?}"),
    }
}
