//! Synthetic image classification (MNIST / CIFAR10 substitutes,
//! Appendix C.3 learning-from-scratch study).
//!
//! Ten class templates: a class-specific 2-D Gaussian blob plus a
//! class-specific spatial frequency grating, plus iid pixel noise.
//! `smnist` uses low noise (high ceiling, like MNIST); `scifar` uses
//! strong noise + distractor blobs (lower ceiling, like CIFAR10) — the
//! relative difficulty that drives Table 9's MNIST-vs-CIFAR10 gap.

use super::{ImgBatch, Split};
use crate::rng::Rng;
use crate::runtime::value::IntTensor;
use crate::tensor::Tensor;

pub const IMG: usize = 28;
pub const N_CLASSES: usize = 10;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ImageSet {
    /// MNIST-like: clean
    Smnist,
    /// CIFAR10-like: noisy with distractors
    Scifar,
}

impl ImageSet {
    pub fn parse(s: &str) -> Option<ImageSet> {
        match s {
            "smnist" | "mnist" => Some(ImageSet::Smnist),
            "scifar" | "cifar10" => Some(ImageSet::Scifar),
            _ => None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct ImgTaskGen {
    pub set: ImageSet,
    pub seed: u64,
}

impl ImgTaskGen {
    pub fn new(set: ImageSet, seed: u64) -> Self {
        ImgTaskGen { set, seed }
    }

    fn render(&self, class: usize, rng: &mut Rng, out: &mut [f32]) {
        let (noise, distract) = match self.set {
            ImageSet::Smnist => (0.5, 0.0),
            ImageSet::Scifar => (1.1, 1.6),
        };
        // class blob center on a ring
        let ang = class as f32 / N_CLASSES as f32 * std::f32::consts::TAU;
        let (cy, cx) = (14.0 + 7.0 * ang.sin(), 14.0 + 7.0 * ang.cos());
        // spatial jitter (larger on the hard set)
        let amp = if self.set == ImageSet::Scifar { 6.0 } else { 2.0 };
        let jy = (rng.next_f32() - 0.5) * amp;
        let jx = (rng.next_f32() - 0.5) * amp;
        let freq = 0.3 + 0.15 * (class % 5) as f32;
        let phase = if class < 5 { 0.0 } else { 1.2 };
        for y in 0..IMG {
            for x in 0..IMG {
                let dy = y as f32 - cy - jy;
                let dx = x as f32 - cx - jx;
                let blob = (-(dy * dy + dx * dx) / 10.0).exp();
                let grating = 0.4 * ((x as f32 * freq + phase).sin()
                                     * (y as f32 * freq).cos());
                out[y * IMG + x] = blob + grating + noise * rng.normal();
            }
        }
        if distract > 0.0 {
            // distractor blob at a random location
            let ry = rng.below(IMG) as f32;
            let rx = rng.below(IMG) as f32;
            for y in 0..IMG {
                for x in 0..IMG {
                    let dy = y as f32 - ry;
                    let dx = x as f32 - rx;
                    out[y * IMG + x] += distract * (-(dy * dy + dx * dx) / 10.0).exp();
                }
            }
        }
    }

    pub fn batch(&self, batch: usize, split: Split, step: u64) -> ImgBatch {
        let mut rng = Rng::new(self.seed ^ split.salt() ^ step.wrapping_mul(0x9E37));
        let mut images = vec![0.0f32; batch * IMG * IMG];
        let mut labels = Vec::with_capacity(batch);
        for b in 0..batch {
            let class = rng.below(N_CLASSES);
            labels.push(class as i32);
            self.render(class, &mut rng,
                        &mut images[b * IMG * IMG..(b + 1) * IMG * IMG]);
        }
        ImgBatch {
            images: Tensor::new(vec![batch, IMG, IMG, 1], images),
            labels: IntTensor::new(vec![batch], labels),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let g = ImgTaskGen::new(ImageSet::Smnist, 1);
        let a = g.batch(4, Split::Train, 2);
        let b = g.batch(4, Split::Train, 2);
        assert_eq!(a.images, b.images);
    }

    #[test]
    fn shapes() {
        let g = ImgTaskGen::new(ImageSet::Scifar, 1);
        let b = g.batch(3, Split::Eval, 0);
        assert_eq!(b.images.shape(), &[3, 28, 28, 1]);
        assert_eq!(b.labels.shape(), &[3]);
    }

    #[test]
    fn classes_visually_distinct() {
        // template means for different classes must differ markedly
        let g = ImgTaskGen::new(ImageSet::Smnist, 3);
        let mut per_class = vec![vec![0.0f32; IMG * IMG]; 2];
        let mut counts = [0usize; 2];
        for step in 0..40 {
            let b = g.batch(8, Split::Train, step);
            for (i, &l) in b.labels.data().iter().enumerate() {
                if l < 2 {
                    counts[l as usize] += 1;
                    for p in 0..IMG * IMG {
                        per_class[l as usize][p] += b.images.data()[i * IMG * IMG + p];
                    }
                }
            }
        }
        let diff: f32 = per_class[0]
            .iter()
            .zip(&per_class[1])
            .map(|(a, b)| (a / counts[0] as f32 - b / counts[1] as f32).abs())
            .sum::<f32>()
            / (IMG * IMG) as f32;
        assert!(diff > 0.02, "class templates too similar: {diff}");
    }

    #[test]
    fn scifar_noisier_than_smnist() {
        let gm = ImgTaskGen::new(ImageSet::Smnist, 5).batch(8, Split::Train, 0);
        let gc = ImgTaskGen::new(ImageSet::Scifar, 5).batch(8, Split::Train, 0);
        let var = |t: &Tensor| {
            let m: f32 = t.data().iter().sum::<f32>() / t.len() as f32;
            t.data().iter().map(|x| (x - m) * (x - m)).sum::<f32>() / t.len() as f32
        };
        assert!(var(&gc.images) > var(&gm.images));
    }
}
