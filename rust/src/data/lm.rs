//! Causal-LM / seq2seq synthetic tasks.
//!
//! Two families:
//!
//! 1. **Instruction mix** (Dolly substitute, Tables 4/6/7/8): 8
//!    categories, each a deterministic prompt->response rule of distinct
//!    difficulty. Layout: `[BOS, cat, prompt..., SEP, response..., EOS,
//!    PAD...]`, loss masked to response positions (instruction tuning).
//!
//! 2. **S2S tasks** (Table 3 substitute): six prompt->response
//!    transforms of graded difficulty evaluated with teacher-forced
//!    token accuracy (the ROUGE stand-in).
//!
//! 3. **Corpus** (pretraining / e2e): an order-1 Markov chain with
//!    Zipf-ish marginals and a periodic syntax skeleton, so a small
//!    transformer has real structure to learn.

use super::{LmBatch, Split, BOS, CAT0, CONTENT0, EOS, PAD, SEP};
use crate::rng::Rng;
use crate::runtime::value::IntTensor;
use crate::tensor::Tensor;

/// The eight instruction-mix categories (paper Table 4 columns).
pub const CATEGORIES: [&str; 8] = [
    "classification",
    "information_extraction",
    "summarization",
    "brainstorming",
    "creative_writing",
    "open_qa",
    "closed_qa",
    "general_qa",
];

/// The six S2S tasks (paper Table 3 columns, graded difficulty).
pub const S2S_TASKS: [&str; 6] = ["fpb", "wikisql", "samsum", "e2e_nlg", "webnlg", "dart"];

#[derive(Clone, Debug)]
pub struct LmTaskGen {
    pub vocab: usize,
    pub seq: usize,
    pub seed: u64,
}

impl LmTaskGen {
    pub fn new(vocab: usize, seq: usize, seed: u64) -> Self {
        assert!(vocab > CONTENT0 as usize + 16, "vocab too small");
        LmTaskGen { vocab, seq, seed }
    }

    fn content(&self, rng: &mut Rng) -> i32 {
        CONTENT0 + rng.zipf(self.vocab - CONTENT0 as usize) as i32
    }

    /// Generate one instruction-mix example for `category`.
    /// Returns (full sequence, response byte range).
    fn instruct_example(&self, category: usize, rng: &mut Rng) -> (Vec<i32>, usize, usize) {
        let plen = 8 + rng.below(8); // prompt length
        let prompt: Vec<i32> = (0..plen).map(|_| self.content(rng)).collect();
        let response: Vec<i32> = match category {
            // classification: 1 token = bucketized prompt sum (learnable)
            0 => {
                let s: i64 = prompt.iter().map(|&t| t as i64).sum();
                vec![CONTENT0 + (s % 8) as i32]
            }
            // information extraction: tokens at even positions
            1 => prompt.iter().step_by(2).copied().collect(),
            // summarization: first 4 tokens
            2 => prompt[..4.min(prompt.len())].to_vec(),
            // brainstorming: tokens shifted by +1 in content space
            3 => prompt
                .iter()
                .map(|&t| {
                    CONTENT0 + ((t - CONTENT0 + 1) % (self.vocab as i32 - CONTENT0))
                })
                .collect(),
            // creative writing: high-entropy (hard; bounds achievable score)
            4 => {
                let mut r2 = Rng::new(rng.next_u64());
                (0..6).map(|_| self.content(&mut r2)).collect()
            }
            // open qa: reverse of the prompt tail
            5 => prompt.iter().rev().take(5).copied().collect(),
            // closed qa: the middle third
            6 => prompt[plen / 3..2 * plen / 3].to_vec(),
            // general qa: first and last
            // lint:allow(panic-safety): prompt always holds plen >= 1 tokens by construction — the `prompt[0]` beside it leans on the same invariant
            _ => vec![prompt[0], *prompt.last().unwrap()],
        };
        let mut seq = Vec::with_capacity(self.seq);
        seq.push(BOS);
        seq.push(CAT0 + category as i32);
        seq.extend_from_slice(&prompt);
        seq.push(SEP);
        let resp_start = seq.len();
        seq.extend_from_slice(&response);
        seq.push(EOS);
        let resp_end = seq.len(); // include EOS in the supervised region
        seq.truncate(self.seq);
        while seq.len() < self.seq {
            seq.push(PAD);
        }
        (seq, resp_start.min(self.seq), resp_end.min(self.seq))
    }

    /// Batch of instruction-mix data. `category = None` mixes all 8.
    pub fn instruct_batch(&self, batch: usize, category: Option<usize>,
                          split: Split, step: u64) -> LmBatch {
        let mut rng = Rng::new(self.seed ^ split.salt() ^ step.wrapping_mul(0x9E37));
        self.emit(batch, |rng| {
            let cat = category.unwrap_or_else(|| rng.below(8));
            self.instruct_example(cat, rng)
        }, &mut rng)
    }

    /// One S2S task (prompt -> transform(prompt)).
    fn s2s_example(&self, task: usize, rng: &mut Rng) -> (Vec<i32>, usize, usize) {
        let plen = 10 + rng.below(6);
        let prompt: Vec<i32> = (0..plen).map(|_| self.content(rng)).collect();
        let v = self.vocab as i32 - CONTENT0;
        let response: Vec<i32> = match task {
            0 => prompt.clone(),                                    // fpb: copy
            1 => prompt.iter().rev().copied().collect(),            // wikisql: reverse
            2 => prompt[..5].to_vec(),                              // samsum: prefix
            3 => prompt.iter().map(|&t| CONTENT0 + ((t - CONTENT0 + 3) % v)).collect(), // e2e: shift
            4 => {
                // webnlg: sorted prefix (harder: global structure)
                let mut r = prompt[..6].to_vec();
                r.sort();
                r
            }
            _ => {
                // dart: interleave halves
                let half = plen / 2;
                let mut r = Vec::new();
                for i in 0..half {
                    r.push(prompt[i]);
                    if half + i < plen {
                        r.push(prompt[half + i]);
                    }
                }
                r.truncate(8);
                r
            }
        };
        let mut seq = Vec::with_capacity(self.seq);
        seq.push(BOS);
        seq.extend_from_slice(&prompt);
        seq.push(SEP);
        let rs = seq.len();
        seq.extend_from_slice(&response);
        seq.push(EOS);
        let re = seq.len();
        seq.truncate(self.seq);
        while seq.len() < self.seq {
            seq.push(PAD);
        }
        (seq, rs.min(self.seq), re.min(self.seq))
    }

    pub fn s2s_batch(&self, batch: usize, task: usize, split: Split, step: u64) -> LmBatch {
        let mut rng = Rng::new(self.seed ^ split.salt()
                               ^ (task as u64) << 32
                               ^ step.wrapping_mul(0x9E37));
        self.emit(batch, |rng| self.s2s_example(task, rng), &mut rng)
    }

    /// Markov-chain pretraining corpus (full-sequence loss).
    pub fn corpus_batch(&self, batch: usize, split: Split, step: u64) -> LmBatch {
        let mut rng = Rng::new(self.seed ^ split.salt() ^ step.wrapping_mul(0x9E37));
        let v = self.vocab as i32 - CONTENT0;
        let mut toks = Vec::with_capacity(batch * self.seq);
        for _ in 0..batch {
            let mut t = self.content(&mut rng);
            for pos in 0..self.seq {
                toks.push(t);
                // order-1 chain with a period-4 syntax skeleton
                let step_size = match pos % 4 {
                    0 => 1,
                    1 => 7,
                    2 => 3,
                    _ => rng.below(5) as i32,
                };
                t = CONTENT0 + ((t - CONTENT0 + step_size) % v).abs();
            }
        }
        // next-token prediction: targets are tokens shifted left
        let mut targets = Vec::with_capacity(batch * self.seq);
        for b in 0..batch {
            let row = &toks[b * self.seq..(b + 1) * self.seq];
            targets.extend_from_slice(&row[1..]);
            targets.push(EOS);
        }
        LmBatch {
            tokens: IntTensor::new(vec![batch, self.seq], toks),
            targets: IntTensor::new(vec![batch, self.seq], targets),
            mask: Tensor::from_fn(&[batch, self.seq], |i| {
                if (i % self.seq) + 1 < self.seq { 1.0 } else { 0.0 }
            }),
        }
    }

    fn emit(&self, batch: usize,
            mut gen: impl FnMut(&mut Rng) -> (Vec<i32>, usize, usize),
            rng: &mut Rng) -> LmBatch {
        let mut toks = Vec::with_capacity(batch * self.seq);
        let mut targets = vec![PAD; batch * self.seq];
        let mut mask = vec![0.0f32; batch * self.seq];
        for b in 0..batch {
            let (seq, rs, re) = gen(rng);
            // next-token prediction within the response region:
            // position p predicts seq[p+1]; supervised for p in [rs-1, re-1)
            for p in rs.saturating_sub(1)..re.saturating_sub(1) {
                if p + 1 < self.seq {
                    targets[b * self.seq + p] = seq[p + 1];
                    mask[b * self.seq + p] = 1.0;
                }
            }
            toks.extend_from_slice(&seq);
        }
        LmBatch {
            tokens: IntTensor::new(vec![batch, self.seq], toks),
            targets: IntTensor::new(vec![batch, self.seq], targets),
            mask: Tensor::new(vec![batch, self.seq], mask),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen() -> LmTaskGen {
        LmTaskGen::new(512, 64, 7)
    }

    #[test]
    fn deterministic_batches() {
        let g = gen();
        let a = g.instruct_batch(4, Some(0), Split::Train, 3);
        let b = g.instruct_batch(4, Some(0), Split::Train, 3);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.targets, b.targets);
    }

    #[test]
    fn splits_disjoint() {
        let g = gen();
        let a = g.instruct_batch(4, Some(1), Split::Train, 0);
        let b = g.instruct_batch(4, Some(1), Split::Eval, 0);
        assert_ne!(a.tokens, b.tokens);
    }

    #[test]
    fn mask_covers_response_only() {
        let g = gen();
        let b = g.instruct_batch(2, Some(2), Split::Train, 0);
        let mask_sum: f32 = b.mask.data().iter().sum();
        assert!(mask_sum > 0.0);
        // masked positions must have non-PAD targets
        for (i, &m) in b.mask.data().iter().enumerate() {
            if m > 0.0 {
                assert_ne!(b.targets.data()[i], PAD, "pos {i}");
            }
        }
    }

    #[test]
    fn all_categories_and_tasks_emit() {
        let g = gen();
        for c in 0..8 {
            let b = g.instruct_batch(2, Some(c), Split::Train, 1);
            assert!(b.mask.data().iter().sum::<f32>() > 0.0, "cat {c}");
        }
        for t in 0..6 {
            let b = g.s2s_batch(2, t, Split::Train, 1);
            assert!(b.mask.data().iter().sum::<f32>() > 0.0, "task {t}");
        }
    }

    #[test]
    fn tokens_in_vocab() {
        let g = gen();
        let b = g.corpus_batch(4, Split::Train, 9);
        for &t in b.tokens.data() {
            assert!((0..512).contains(&t));
        }
    }

    #[test]
    fn corpus_is_learnable_structure() {
        // the Markov skeleton means next token is often determined
        let g = gen();
        let b = g.corpus_batch(1, Split::Train, 0);
        let toks = b.tokens.data();
        // period-4 positions with fixed step: verify t[1]-t[0] == 1 in content space
        let d = toks[1] - toks[0];
        assert!(d == 1 || d < 0); // wrapped or +1
    }
}
