//! Synthetic dataset substrates (the no-network substitutes for GLUE /
//! the S2S suite / Dolly / MNIST+CIFAR10 — see DESIGN.md §2).
//!
//! Every generator is a pure function of (task id, seed, index), so any
//! batch is reproducible and train/eval splits are disjoint by index
//! range. Tasks are *graded in difficulty and noise* so that the method
//! ordering the paper's quality tables measure (FT ≈ ColA(Linear/MLP) ≥
//! LoRA ≈ ColA(LowRank) > IA3 > prompt-class) has room to show.

pub mod images;
pub mod lm;
pub mod seqcls;

use crate::runtime::value::IntTensor;
use crate::tensor::Tensor;

/// Special token ids (content tokens start at [`CONTENT0`]).
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const SEP: i32 = 2;
pub const EOS: i32 = 3;
/// category tokens for the instruction mix occupy [4, 12)
pub const CAT0: i32 = 4;
pub const CONTENT0: i32 = 16;

/// A causal-LM / seq2seq batch (loss on mask=1 positions).
#[derive(Clone, Debug)]
pub struct LmBatch {
    pub tokens: IntTensor,
    pub targets: IntTensor,
    pub mask: Tensor,
}

impl LmBatch {
    pub fn batch_size(&self) -> usize {
        self.tokens.shape()[0]
    }
}

/// A sequence-classification batch.
#[derive(Clone, Debug)]
pub struct ClsBatch {
    pub tokens: IntTensor,
    pub labels: IntTensor,
    pub mask: Tensor,
}

/// An image-classification batch.
#[derive(Clone, Debug)]
pub struct ImgBatch {
    pub images: Tensor,
    pub labels: IntTensor,
}

/// Train/eval split by index range: eval indices are negative offsets
/// into a disjoint stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Eval,
}

impl Split {
    /// Mixes the split into the per-example seed so streams are disjoint.
    pub fn salt(&self) -> u64 {
        match self {
            Split::Train => 0x7261696e,
            Split::Eval => 0x6576616c,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_salts_differ() {
        assert_ne!(Split::Train.salt(), Split::Eval.salt());
    }

    #[test]
    fn token_regions_disjoint() {
        assert!(PAD < BOS && BOS < SEP && SEP < EOS && EOS < CAT0);
        assert!(CAT0 + 8 <= CONTENT0);
    }
}
