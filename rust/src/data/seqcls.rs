//! Sequence-classification tasks (the 8-dataset GLUE substitute).
//!
//! Each task is a deterministic labeling rule over a random token
//! sequence plus a task-specific label-noise rate, giving the graded
//! headroom the paper's Table 2 shows across GLUE datasets. All tasks
//! use 4 classes (STS-B regression is substituted by 4-way bucketing,
//! noted in DESIGN.md).

use super::{ClsBatch, Split, CONTENT0};
use crate::rng::Rng;
use crate::runtime::value::IntTensor;
use crate::tensor::Tensor;

pub const N_CLASSES: usize = 4;

/// GLUE-substitute task names in Table 2 column order.
pub const TASKS: [&str; 8] = [
    "mnli", "sst2", "mrpc", "cola", "qnli", "qqp", "rte", "stsb",
];

#[derive(Clone, Debug)]
pub struct ClsTaskGen {
    pub vocab: usize,
    pub seq: usize,
    pub seed: u64,
}

impl ClsTaskGen {
    pub fn new(vocab: usize, seq: usize, seed: u64) -> Self {
        ClsTaskGen { vocab, seq, seed }
    }

    /// Per-task label-noise rate (controls achievable ceiling).
    fn noise(task: usize) -> f32 {
        [0.05, 0.02, 0.08, 0.15, 0.04, 0.06, 0.20, 0.05][task % 8]
    }

    fn label_rule(&self, task: usize, toks: &[i32], rng: &mut Rng) -> usize {
        let v = self.vocab as i64 - CONTENT0 as i64;
        let content: Vec<i64> = toks.iter().map(|&t| (t - CONTENT0) as i64).collect();
        let n = content.len() as i64;
        let raw = match task % 8 {
            // mnli: bucket of the mean token value
            0 => (content.iter().sum::<i64>() / n) * 4 / v,
            // sst2: count of "positive-region" tokens vs threshold
            1 => {
                let pos = content.iter().filter(|&&c| c < v / 4).count() as i64;
                pos * 4 / (n / 2 + 1)
            }
            // mrpc: first-half/second-half similarity bucket
            2 => {
                let h = content.len() / 2;
                let a: i64 = content[..h].iter().sum();
                let b: i64 = content[h..].iter().sum();
                ((a - b).abs() * 4) / (v * n / 3 + 1)
            }
            // cola: parity-pair rule (hard for shallow nets)
            3 => {
                let odd = content.iter().filter(|&&c| c % 2 == 1).count() as i64;
                let asc = content.windows(2).filter(|w| w[1] > w[0]).count() as i64;
                (odd % 2) * 2 + (asc % 2)
            }
            // qnli: position of the max token, bucketed
            4 => {
                let arg = content
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, &c)| c)
                    .map(|(i, _)| i as i64)
                    .unwrap_or(0);
                arg * 4 / n
            }
            // qqp: sum mod 4
            5 => content.iter().sum::<i64>() % 4,
            // rte: noisy xor of two buckets (low ceiling, like paper's RTE)
            6 => ((content[0] * 2 / v) % 2) * 2 + ((content[n as usize - 1] * 2 / v) % 2),
            // stsb: bucketed "similarity score"
            _ => {
                let h = content.len() / 2;
                let dot: i64 = content[..h]
                    .iter()
                    .zip(&content[h..])
                    .map(|(a, b)| (a - b).abs())
                    .sum();
                3 - (dot * 4 / (v * h as i64 + 1)).min(3)
            }
        };
        let mut label = raw.rem_euclid(N_CLASSES as i64) as usize;
        if rng.next_f32() < Self::noise(task) {
            label = rng.below(N_CLASSES);
        }
        label
    }

    pub fn batch(&self, batch: usize, task: usize, split: Split, step: u64) -> ClsBatch {
        let mut rng = Rng::new(self.seed ^ split.salt()
                               ^ (task as u64) << 40
                               ^ step.wrapping_mul(0x9E37));
        let len = self.seq; // full-length sequences, mask all ones
        let mut toks = Vec::with_capacity(batch * self.seq);
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            let row: Vec<i32> = (0..len)
                .map(|_| CONTENT0 + rng.below(self.vocab - CONTENT0 as usize) as i32)
                .collect();
            labels.push(self.label_rule(task, &row, &mut rng) as i32);
            toks.extend_from_slice(&row);
        }
        ClsBatch {
            tokens: IntTensor::new(vec![batch, self.seq], toks),
            labels: IntTensor::new(vec![batch], labels),
            mask: Tensor::from_fn(&[batch, self.seq], |_| 1.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen() -> ClsTaskGen {
        ClsTaskGen::new(512, 64, 11)
    }

    #[test]
    fn deterministic() {
        let g = gen();
        let a = g.batch(8, 0, Split::Train, 5);
        let b = g.batch(8, 0, Split::Train, 5);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn labels_in_range_all_tasks() {
        let g = gen();
        for task in 0..8 {
            let b = g.batch(16, task, Split::Train, 0);
            for &l in b.labels.data() {
                assert!((0..N_CLASSES as i32).contains(&l), "task {task}");
            }
        }
    }

    #[test]
    fn labels_nontrivially_distributed() {
        // every task must use at least 2 classes over a large sample
        let g = gen();
        for task in 0..8 {
            let mut seen = [false; N_CLASSES];
            for step in 0..8 {
                let b = g.batch(16, task, Split::Train, step);
                for &l in b.labels.data() {
                    seen[l as usize] = true;
                }
            }
            assert!(seen.iter().filter(|&&s| s).count() >= 2, "task {task}");
        }
    }

    #[test]
    fn tasks_differ() {
        let g = gen();
        let a = g.batch(16, 0, Split::Train, 0);
        let b = g.batch(16, 1, Split::Train, 0);
        assert_ne!(a.labels, b.labels);
    }
}
