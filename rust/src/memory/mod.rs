//! Memory accountant — the byte-exact ledger behind Table 1 and the
//! computation-evaluation Tables 10–18.
//!
//! The paper measures GPU memory on an A6000; our substitute is an
//! analytic per-device ledger derived from tensor shapes, with the
//! activation model documented below, validated against the real
//! resident-buffer sizes of the tiny/small runs in integration tests,
//! and evaluated on paper-scale model profiles (RoBERTa/BART/GPT-2/
//! Llama-2) to regenerate the tables' *shape* (who fits, who OOMs,
//! what grows with K and adapter size).
//!
//! Activation model (floats, per fwd+bwd, batch B, seq S, d_model d,
//! d_ff f, heads H, vocab V, L layers):
//!   embeddings + logits:  B*S*d + B*S*V
//!   per layer:            B*S*(7d + f) + B*H*S^2   (ln1, q,k,v, att-out,
//!                         ln2, ffn-out rows + ffn mid + attention probs)
//! Backward roughly doubles the live set; we charge 2x activations for
//! learning rows, matching the paper's observed FT-vs-inference gap.

use std::fmt;

use crate::config::AdapterKind;

pub const GB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Paper-scale (and local) model shape profiles.
///
/// Calibrated against the paper's A6000 measurements: half-precision
/// weights/activations for the LLM profiles (`dtype_bytes = 2`),
/// SwiGLU FFNs for Llama (`ffn_mats = 3`), memory-efficient attention
/// (no materialized S^2 probability tensor), and a fixed CUDA-context
/// overhead on the hosting device.
#[derive(Clone, Debug)]
pub struct ModelProfile {
    pub name: String,
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub dff: usize,
    pub vocab: usize,
    pub seq: usize,
    /// adapter sites (paper: q,v per layer unless "all")
    pub n_sites: usize,
    /// bytes per element on the hosting device (2 = bf16, 4 = f32)
    pub dtype_bytes: usize,
    /// FFN weight matrices per layer (3 = gated/SwiGLU, 2 = classic)
    pub ffn_mats: usize,
}

/// CUDA context + allocator overhead on the paper's testbed.
pub const FRAMEWORK_OVERHEAD: usize = 700 << 20;

impl ModelProfile {
    pub fn params(&self) -> usize {
        // embeddings + per-layer (4 attn mats + ffn mats + norms/bias)
        self.vocab * self.d
            + self.seq * self.d
            + self.layers * (4 * self.d * self.d
                             + self.ffn_mats * self.d * self.dff
                             + 4 * self.d + self.dff + self.d)
            + 2 * self.d
    }

    /// Retained fwd+bwd activations in elements (memory-efficient
    /// attention: no S^2 tensor).
    pub fn activations(&self, batch: usize) -> usize {
        let (b, s, d, f) = (batch, self.seq, self.d, self.dff);
        b * s * d + b * s * self.vocab
            + self.layers * b * s * (7 * d + f)
    }

    /// Known profiles: paper models + our local sizes.
    pub fn by_name(name: &str) -> Option<ModelProfile> {
        let p = |name: &str, d, layers, heads, dff, vocab, seq, n_sites,
                 dtype_bytes, ffn_mats| ModelProfile {
            name: name.into(), d, layers, heads, dff, vocab, seq, n_sites,
            dtype_bytes, ffn_mats,
        };
        Some(match name {
            // paper hardware-scale profiles (Tables 10-14); seq for the
            // llama profiles reflects Dolly's realized average length
            "roberta-base" => p("roberta-base", 768, 12, 12, 3072, 50265, 128, 26, 4, 2),
            "bart-base" => p("bart-base", 768, 12, 12, 3072, 50265, 128, 36, 4, 2),
            "gpt2" => p("gpt2", 768, 12, 12, 3072, 50257, 512, 12, 4, 2),
            "llama2-qv" => p("llama2-qv", 4096, 32, 32, 11008, 32000, 384, 64, 2, 3),
            "llama2-all" => p("llama2-all", 4096, 32, 32, 11008, 32000, 384, 228, 2, 3),
            // local testbed profiles (f32 end to end, like our runtime)
            "tiny" => p("tiny", 128, 2, 4, 512, 512, 64, 4, 4, 2),
            "small" => p("small", 256, 4, 8, 1024, 2048, 128, 8, 4, 2),
            "base" => p("base", 384, 8, 8, 1536, 4096, 128, 16, 4, 2),
            _ => return None,
        })
    }

    /// Adapter parameter count per site.
    pub fn adapter_params_per_site(&self, kind: AdapterKind, rank: usize,
                                   mlp_hidden: usize) -> usize {
        match kind {
            AdapterKind::LowRank => 2 * self.d * rank,
            AdapterKind::Linear => self.d * self.d,
            AdapterKind::Mlp => self.d * mlp_hidden + mlp_hidden
                + mlp_hidden * self.d + self.d,
        }
    }
}

/// The training arrangement being accounted.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arrangement {
    /// full fine-tuning: params + grads + opt state all on server
    FullFt,
    /// coupled PEFT (LoRA-class): tunables + their grads on server
    Peft { kind: AdapterKind, users: usize },
    /// ColA: adaptation data shipped; adapter compute on workers
    Cola { kind: AdapterKind, merged: bool, users: usize },
}

/// Byte ledger per device class for one training step.
#[derive(Clone, Copy, Debug, Default)]
pub struct Footprint {
    /// server: frozen/merged base parameters
    pub server_params: usize,
    /// server: live adapter parameters (PEFT / ColA unmerged)
    pub server_adapter_params: usize,
    /// server: forward+backward activations incl. adapter activations
    pub server_acts: usize,
    /// server: parameter gradients (FT / coupled PEFT)
    pub server_param_grads: usize,
    /// server: optimizer state (FT / coupled PEFT, Adam moments)
    pub server_opt: usize,
    /// worker: adapter params + grads + opt state
    pub worker_state: usize,
    /// worker: buffered adaptation data (x, grad_hhat) x interval
    pub worker_buffer: usize,
    /// bytes transferred server->worker per training step
    pub transfer_per_step: usize,
}

impl Footprint {
    pub fn server_total(&self) -> usize {
        self.server_params + self.server_adapter_params + self.server_acts
            + self.server_param_grads + self.server_opt
    }

    pub fn worker_total(&self) -> usize {
        self.worker_state + self.worker_buffer
    }
}

impl fmt::Display for Footprint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "server {:.2} GB (params {:.2} + adapters {:.2} + acts {:.2} + grads {:.2} + opt {:.2}), worker {:.2} GB, transfer {:.3} GB/step",
            self.server_total() as f64 / GB,
            self.server_params as f64 / GB,
            self.server_adapter_params as f64 / GB,
            self.server_acts as f64 / GB,
            self.server_param_grads as f64 / GB,
            self.server_opt as f64 / GB,
            self.worker_total() as f64 / GB,
            self.transfer_per_step as f64 / GB,
        )
    }
}

/// Compute the ledger. `rank`/`mlp_hidden` parameterize adapter sizes;
/// `interval` is the adaptation interval I (buffer depth).
pub fn footprint(profile: &ModelProfile, arr: Arrangement, batch: usize,
                 interval: usize, rank: usize, mlp_hidden: usize) -> Footprint {
    let f32b = profile.dtype_bytes;
    let base_params = profile.params() * f32b;
    let acts = profile.activations(batch) * f32b + FRAMEWORK_OVERHEAD;
    // per-site adaptation data: x (B*S*d) + grad_hhat (B*S*d)
    let site_rows = batch * profile.seq * profile.d * f32b;
    let adaptation_per_step = profile.n_sites * 2 * site_rows;

    match arr {
        Arrangement::FullFt => Footprint {
            server_params: base_params,
            server_acts: acts,
            server_param_grads: base_params,
            // Adam m+v kept in f32 regardless of model dtype
            server_opt: 2 * profile.params() * 4,
            ..Default::default()
        },
        Arrangement::Peft { kind, users } => {
            let aparams = profile.n_sites
                * profile.adapter_params_per_site(kind, rank, mlp_hidden)
                * f32b
                * users;
            // adapter activations: delta h per site (+ rank intermediate)
            let extra = match kind {
                AdapterKind::LowRank => batch * profile.seq * rank * f32b,
                _ => batch * profile.seq * mlp_hidden * f32b,
            };
            let adapter_acts =
                users * profile.n_sites * (site_rows + extra) * 2;
            Footprint {
                server_params: base_params,
                server_adapter_params: aparams,
                server_acts: acts + adapter_acts,
                server_param_grads: aparams,
                server_opt: 2 * aparams,
                ..Default::default()
            }
        }
        Arrangement::Cola { kind, merged, users } => {
            let aparams_one = profile.n_sites
                * profile.adapter_params_per_site(kind, rank, mlp_hidden)
                * f32b;
            let aparams = aparams_one * users;
            let extra = match kind {
                AdapterKind::LowRank => batch * profile.seq * rank * f32b,
                _ => batch * profile.seq * mlp_hidden * f32b,
            };
            let adapter_acts =
                users * profile.n_sites * (site_rows + extra) * 2;
            let (srv_aparams, srv_aacts) = if merged {
                // adapters folded into base weights; server sees nothing
                (0, 0)
            } else {
                (aparams, adapter_acts)
            };
            Footprint {
                server_params: base_params,
                server_adapter_params: srv_aparams,
                server_acts: acts + srv_aacts,
                server_param_grads: 0, // Gradient Decoupling: never on server
                server_opt: 0,
                worker_state: aparams + aparams + 2 * aparams, // w + grads + m,v
                worker_buffer: adaptation_per_step * interval,
                transfer_per_step: adaptation_per_step,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn llama() -> ModelProfile {
        ModelProfile::by_name("llama2-qv").unwrap()
    }

    #[test]
    fn llama_params_about_7b() {
        let p = ModelProfile::by_name("llama2-qv").unwrap().params();
        assert!((5e9..9e9).contains(&(p as f64)), "params {p}");
    }

    #[test]
    fn ft_exceeds_48gb_on_llama() {
        // Paper Table 13: FT does not fit on the 48 GB A6000.
        let fp = footprint(&llama(), Arrangement::FullFt, 1, 1, 8, 64);
        assert!(fp.server_total() as f64 / GB > 48.0);
    }

    #[test]
    fn cola_merged_server_independent_of_users_and_kind() {
        // The headline claim of Table 1 / Tables 16-18.
        let p = llama();
        let base = footprint(&p, Arrangement::Cola {
            kind: AdapterKind::LowRank, merged: true, users: 1 }, 8, 1, 8, 64);
        for users in [1, 8, 64] {
            for kind in [AdapterKind::LowRank, AdapterKind::Linear] {
                let fp = footprint(&p, Arrangement::Cola {
                    kind, merged: true, users }, 8, 1, 8, 64);
                assert_eq!(fp.server_total(), base.server_total(),
                           "{kind:?} x{users}");
            }
        }
    }

    #[test]
    fn peft_grows_with_users() {
        let p = llama();
        let one = footprint(&p, Arrangement::Peft {
            kind: AdapterKind::LowRank, users: 1 }, 8, 1, 8, 64);
        let eight = footprint(&p, Arrangement::Peft {
            kind: AdapterKind::LowRank, users: 8 }, 8, 1, 8, 64);
        assert!(eight.server_total() > one.server_total());
    }

    #[test]
    fn cola_unmerged_server_below_peft() {
        // ColA unmerged drops param grads + opt state from the server.
        let p = llama();
        let peft = footprint(&p, Arrangement::Peft {
            kind: AdapterKind::Linear, users: 1 }, 8, 1, 8, 64);
        let cola = footprint(&p, Arrangement::Cola {
            kind: AdapterKind::Linear, merged: false, users: 1 }, 8, 1, 8, 64);
        assert!(cola.server_total() < peft.server_total());
    }

    #[test]
    fn buffer_scales_with_interval() {
        let p = ModelProfile::by_name("tiny").unwrap();
        let f1 = footprint(&p, Arrangement::Cola {
            kind: AdapterKind::LowRank, merged: true, users: 1 }, 8, 1, 8, 64);
        let f8 = footprint(&p, Arrangement::Cola {
            kind: AdapterKind::LowRank, merged: true, users: 1 }, 8, 8, 8, 64);
        assert_eq!(f8.worker_buffer, 8 * f1.worker_buffer);
    }

    #[test]
    fn cola_merged_beats_full_ft_even_with_linear(){
        // ColA(Linear, merged) trains full-rank while using less server
        // memory than FT (the "reduce the cost of full fine-tuning" claim).
        let p = llama();
        let ft = footprint(&p, Arrangement::FullFt, 8, 1, 8, 64);
        let cola = footprint(&p, Arrangement::Cola {
            kind: AdapterKind::Linear, merged: true, users: 1 }, 8, 1, 8, 64);
        // FT additionally carries param grads + Adam moments (3x params);
        // ColA merged drops all of it.
        assert!(cola.server_total() < ft.server_total() * 2 / 3);
        assert!(ft.server_total() - cola.server_total()
                > 2 * llama().params() * 4);
    }
}
