//! Parameter merging (Prop. 2) — folding adapters into base weights.
//!
//! Only adapters linear in their input merge: `W_hat = W + s * D` where
//! `D = A@B` (low-rank) or the full matrix. The coordinator's merged
//! mode keeps the server's weights always-merged; after a worker updates
//! its adapter it ships only the *delta difference*
//! `s * (D_new - D_old)` and the server adds it in place — the server
//! never stores adapter parameters at all (Table 1, ColA merged row).
//!
//! Multi-user collaboration is merge composition: all K users' deltas
//! sum into the same base weight (Table 4 'Collaboration').

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::adapters::{AdapterParams, SCALE};
use crate::tensor::{self, Tensor};

/// Which base weight a site's adapter folds into.
///
/// LM sites: `l{i}.q` -> `l{i}.wq`, `l{i}.v` -> `l{i}.wv`.
/// Seq-cls head: `head` -> the dedicated `head.W` input.
/// IC models: site `s` -> `s.W`.
pub fn site_weight_name(site: &str) -> String {
    if let Some(layer) = site.strip_suffix(".q") {
        format!("{layer}.wq")
    } else if let Some(layer) = site.strip_suffix(".v") {
        format!("{layer}.wv")
    } else {
        format!("{site}.W")
    }
}

/// Merge an adapter into a weight map in place: W += s * D.
pub fn merge_into(weights: &mut BTreeMap<String, Tensor>, site: &str,
                  params: &AdapterParams) -> Result<()> {
    let wname = site_weight_name(site);
    let delta = params.delta_matrix()?;
    let w = weights
        .get_mut(&wname)
        .ok_or_else(|| anyhow!("merge: no base weight '{wname}' for site '{site}'"))?;
    tensor::axpy(w, SCALE, &delta);
    Ok(())
}

/// Unmerge: W -= s * D.
pub fn unmerge_from(weights: &mut BTreeMap<String, Tensor>, site: &str,
                    params: &AdapterParams) -> Result<()> {
    let wname = site_weight_name(site);
    let delta = params.delta_matrix()?;
    let w = weights
        .get_mut(&wname)
        .ok_or_else(|| anyhow!("unmerge: no base weight '{wname}'"))?;
    tensor::axpy(w, -SCALE, &delta);
    Ok(())
}

/// The incremental merged-mode update a worker ships after an optimizer
/// step: `s * (D_new - D_old)`, to be added to the merged server weight.
pub fn delta_diff(old: &AdapterParams, new: &AdapterParams) -> Result<Tensor> {
    let d_old = old.delta_matrix()?;
    let d_new = new.delta_matrix()?;
    Ok(tensor::scale(&tensor::sub(&d_new, &d_old), SCALE))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn lowrank(rng: &mut Rng) -> AdapterParams {
        AdapterParams::LowRank {
            a: Tensor::randn(&[8, 4], 0.3, rng),
            b: Tensor::randn(&[4, 8], 0.3, rng),
        }
    }

    #[test]
    fn site_names() {
        assert_eq!(site_weight_name("l3.q"), "l3.wq");
        assert_eq!(site_weight_name("l0.v"), "l0.wv");
        assert_eq!(site_weight_name("head"), "head.W");
        assert_eq!(site_weight_name("conv1"), "conv1.W");
    }

    #[test]
    fn merge_unmerge_roundtrip() {
        let mut rng = Rng::new(1);
        let base = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let mut ws = BTreeMap::from([("l0.wq".to_string(), base.clone())]);
        let p = lowrank(&mut rng);
        merge_into(&mut ws, "l0.q", &p).unwrap();
        assert!(!ws["l0.wq"].allclose(&base, 1e-6, 1e-6));
        unmerge_from(&mut ws, "l0.q", &p).unwrap();
        assert!(ws["l0.wq"].allclose(&base, 1e-5, 1e-5));
    }

    #[test]
    fn merged_forward_equals_live_adapter() {
        let mut rng = Rng::new(2);
        let base = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let p = lowrank(&mut rng);
        let x = Tensor::randn(&[5, 8], 1.0, &mut rng);
        let live = tensor::add(&tensor::matmul(&x, &base), &p.apply(&x));
        let mut ws = BTreeMap::from([("l0.wq".to_string(), base)]);
        merge_into(&mut ws, "l0.q", &p).unwrap();
        let merged = tensor::matmul(&x, &ws["l0.wq"]);
        assert!(live.allclose(&merged, 1e-4, 1e-4));
    }

    #[test]
    fn delta_diff_applies_update() {
        let mut rng = Rng::new(3);
        let old = lowrank(&mut rng);
        let new = lowrank(&mut rng);
        let base = Tensor::randn(&[8, 8], 1.0, &mut rng);
        // merged with old, then apply diff == merged with new
        let mut ws1 = BTreeMap::from([("s.W".to_string(), base.clone())]);
        merge_into(&mut ws1, "s", &old).unwrap();
        let diff = delta_diff(&old, &new).unwrap();
        tensor::axpy(ws1.get_mut("s.W").unwrap(), 1.0, &diff);
        let mut ws2 = BTreeMap::from([("s.W".to_string(), base)]);
        merge_into(&mut ws2, "s", &new).unwrap();
        assert!(ws1["s.W"].allclose(&ws2["s.W"], 1e-4, 1e-4));
    }

    #[test]
    fn multi_user_composition() {
        let mut rng = Rng::new(4);
        let base = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let users: Vec<_> = (0..3).map(|_| lowrank(&mut rng)).collect();
        let mut ws = BTreeMap::from([("s.W".to_string(), base.clone())]);
        for u in &users {
            merge_into(&mut ws, "s", u).unwrap();
        }
        let mut expect = base;
        for u in &users {
            tensor::axpy(&mut expect, SCALE, &u.delta_matrix().unwrap());
        }
        assert!(ws["s.W"].allclose(&expect, 1e-4, 1e-4));
        // unmerge one user leaves the other two
        unmerge_from(&mut ws, "s", &users[1]).unwrap();
        let mut expect2 = expect;
        tensor::axpy(&mut expect2, -SCALE, &users[1].delta_matrix().unwrap());
        assert!(ws["s.W"].allclose(&expect2, 1e-4, 1e-4));
    }
}
