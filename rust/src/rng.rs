//! Deterministic PRNG substrate (no `rand` crate available offline).
//!
//! SplitMix64 for seeding + xoshiro256++ for the stream — the standard
//! pairing. Everything downstream (datasets, schedulers, benches) takes a
//! `Rng` so every experiment is reproducible from a single `u64` seed.

/// xoshiro256++ seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        let s = [
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-user / per-task seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, n) via Lemire's widening-multiply reduction:
    /// `(u64 * n) >> 64` on the 128-bit product. The old `next_u64() % n`
    /// had modulo bias (low ranks slightly over-sampled — visible exactly
    /// at the small adapter ranks this repo samples); the residual bias
    /// here is < n / 2^64, far below anything observable, and the
    /// reduction is division-free. NOTE: this changes every sampled
    /// stream (shuffles, synthetic corpora) relative to earlier commits.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        // hard assert: the old `% n` panicked on n = 0 in every build
        // profile; the multiply would silently return 0 forever
        assert!(n > 0, "Rng::below(0)");
        (((self.next_u64() as u128) * (n as u128)) >> 64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-9);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fill a vec with iid N(0, std^2).
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Zipf-ish rank sample over [0, n): p(r) ~ 1/(r+2), cheap inverse-ish
    /// sampler (used by the synthetic corpus for a natural token
    /// frequency profile).
    pub fn zipf(&mut self, n: usize) -> usize {
        // rejection-free approximation: u^k concentrates mass at low ranks
        let u = self.next_f32();
        let r = (u * u * u * n as f32) as usize;
        r.min(n - 1)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let xs: Vec<f32> = (0..20000).map(|_| r.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        // the multiply-shift reduction must not skew buckets the way the
        // old modulo reduction skewed small ranges
        let mut r = Rng::new(13);
        let mut counts = [0usize; 8];
        for _ in 0..8000 {
            counts[r.below(8)] += 1;
        }
        for (i, c) in counts.iter().enumerate() {
            assert!((700..1300).contains(c), "bucket {i}: {c}");
        }
    }

    #[test]
    fn fork_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..32).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..32).collect::<Vec<_>>());
        assert_ne!(v, (0..32).collect::<Vec<_>>());
    }
}
