//! Adapter (auxiliary-model) state and the native worker update path.
//!
//! ColA is model-agnostic (§3.2): a site's auxiliary model can be any
//! function of the hidden input. We implement the paper's three:
//! LowRank (LoRA-shaped), Linear (full matrix, Prop.2-mergeable), and a
//! 2-layer ReLU MLP (not mergeable).
//!
//! `fit_grads` is the native-CPU twin of the Pallas `fit_step` kernels:
//! the surrogate residual at w = w^t collapses to grad_hhat (Eq. 6 /
//! Prop. 1), so the gradients are plain contractions of (x, grad_hhat).
//! Integration tests assert the native path matches the PJRT artifact
//! path to fp tolerance.

pub mod optimizer;

use anyhow::{bail, Result};

pub use optimizer::{OptState, OptimizerCfg};

use crate::config::AdapterKind;
use crate::rng::Rng;
use crate::tensor::{self, Tensor};

/// GL requires alpha = 1 (Sec. 3.2); kept symbolic for clarity.
pub const SCALE: f32 = 1.0;

/// Parameters of one site's auxiliary model.
#[derive(Clone, Debug)]
pub enum AdapterParams {
    LowRank { a: Tensor, b: Tensor },
    Linear { w: Tensor },
    Mlp { w1: Tensor, b1: Tensor, w2: Tensor, b2: Tensor },
}

impl AdapterParams {
    /// Paper init: adapter output starts at zero (A/W1 random, rest 0).
    pub fn init(kind: AdapterKind, d_in: usize, d_out: usize, rank: usize,
                hidden: usize, rng: &mut Rng) -> AdapterParams {
        let std = (1.0 / d_in as f32).sqrt();
        match kind {
            AdapterKind::LowRank => {
                let r = rank.min(d_in).min(d_out);
                AdapterParams::LowRank {
                    a: Tensor::randn(&[d_in, r], std, rng),
                    b: Tensor::zeros(&[r, d_out]),
                }
            }
            AdapterKind::Linear => AdapterParams::Linear {
                w: Tensor::zeros(&[d_in, d_out]),
            },
            AdapterKind::Mlp => AdapterParams::Mlp {
                w1: Tensor::randn(&[d_in, hidden], std, rng),
                b1: Tensor::zeros(&[hidden]),
                w2: Tensor::zeros(&[hidden, d_out]),
                b2: Tensor::zeros(&[d_out]),
            },
        }
    }

    pub fn kind(&self) -> AdapterKind {
        match self {
            AdapterParams::LowRank { .. } => AdapterKind::LowRank,
            AdapterParams::Linear { .. } => AdapterKind::Linear,
            AdapterParams::Mlp { .. } => AdapterKind::Mlp,
        }
    }

    pub fn n_params(&self) -> usize {
        self.tensors().iter().map(|t| t.len()).sum()
    }

    pub fn bytes(&self) -> usize {
        self.n_params() * 4
    }

    pub fn tensors(&self) -> Vec<&Tensor> {
        match self {
            AdapterParams::LowRank { a, b } => vec![a, b],
            AdapterParams::Linear { w } => vec![w],
            AdapterParams::Mlp { w1, b1, w2, b2 } => vec![w1, b1, w2, b2],
        }
    }

    pub fn tensors_mut(&mut self) -> Vec<&mut Tensor> {
        match self {
            AdapterParams::LowRank { a, b } => vec![a, b],
            AdapterParams::Linear { w } => vec![w],
            AdapterParams::Mlp { w1, b1, w2, b2 } => vec![w1, b1, w2, b2],
        }
    }

    /// Canonical tensor names (match the artifact manifest suffixes).
    pub fn tensor_names(&self) -> Vec<&'static str> {
        match self {
            AdapterParams::LowRank { .. } => vec!["A", "B"],
            AdapterParams::Linear { .. } => vec!["W"],
            AdapterParams::Mlp { .. } => vec!["W1", "b1", "W2", "b2"],
        }
    }

    /// delta = scale * g(x); x: (n, d_in) -> (n, d_out).
    pub fn apply(&self, x: &Tensor) -> Tensor {
        match self {
            AdapterParams::LowRank { a, b } => {
                let xa = tensor::matmul(x, a);
                tensor::scale(&tensor::matmul(&xa, b), SCALE)
            }
            AdapterParams::Linear { w } => tensor::scale(&tensor::matmul(x, w), SCALE),
            AdapterParams::Mlp { w1, b1, w2, b2 } => {
                let z = tensor::add_row(&tensor::matmul(x, w1), b1);
                let h = tensor::relu(&z);
                tensor::scale(&tensor::add_row(&tensor::matmul(&h, w2), b2), SCALE)
            }
        }
    }

    /// The Prop.2 merge delta: the (d_in, d_out) matrix W such that
    /// g(x) = x @ W — only for linear-in-input adapters.
    pub fn delta_matrix(&self) -> Result<Tensor> {
        match self {
            AdapterParams::LowRank { a, b } => Ok(tensor::matmul(a, b)),
            AdapterParams::Linear { w } => Ok(w.clone()),
            AdapterParams::Mlp { .. } => {
                bail!("Prop. 2: MLP adapters are not linear in their input \
                       and cannot be merged")
            }
        }
    }

    /// Surrogate-loss gradients from shipped adaptation data.
    ///
    /// The worker recomputes delta = g_w(x) itself (Algorithm 1 line 13),
    /// the residual at w^t collapses to grad_hhat, and the gradients are
    /// (Prop. 1) exactly the coupled parameter gradients. Mirrors
    /// `python/compile/kernels/fit_step.py`.
    pub fn fit_grads(&self, x: &Tensor, ghat: &Tensor) -> Vec<Tensor> {
        match self {
            AdapterParams::LowRank { a, b } => {
                // da = s * x^T (ghat B^T); db = s * (xA)^T ghat
                let gbt = tensor::matmul_nt(ghat, b);
                let da = tensor::scale(&tensor::matmul_tn(x, &gbt), SCALE);
                let xa = tensor::matmul(x, a);
                let db = tensor::scale(&tensor::matmul_tn(&xa, ghat), SCALE);
                vec![da, db]
            }
            AdapterParams::Linear { .. } => {
                vec![tensor::scale(&tensor::matmul_tn(x, ghat), SCALE)]
            }
            AdapterParams::Mlp { w1, b1, w2, .. } => {
                // z = xW1+b1; hmid = relu(z); res = ghat (scale=1)
                let z = tensor::add_row(&tensor::matmul(x, w1), b1);
                let hmid = tensor::relu(&z);
                let dw2 = tensor::matmul_tn(&hmid, ghat);
                let db2 = tensor::col_sum(ghat);
                let mut dmid = tensor::matmul_nt(ghat, w2);
                for (m, zv) in dmid.data_mut().iter_mut().zip(z.data()) {
                    if *zv <= 0.0 {
                        *m = 0.0;
                    }
                }
                let dw1 = tensor::matmul_tn(x, &dmid);
                let db1 = tensor::col_sum(&dmid);
                vec![dw1, db1, dw2, db2]
            }
        }
    }
}

/// One adapter site with its optimizer state (optimizer state lives on
/// the worker device — the ZeRO-Offload-style saving of §3.2).
#[derive(Clone, Debug)]
pub struct SiteAdapter {
    pub site: String,
    pub params: AdapterParams,
    pub opt: OptState,
}

impl SiteAdapter {
    pub fn new(site: &str, params: AdapterParams, opt_cfg: &OptimizerCfg) -> Self {
        let opt = OptState::new(opt_cfg, &params.tensors().iter().map(|t| t.len())
                                               .collect::<Vec<_>>());
        SiteAdapter { site: site.to_string(), params, opt }
    }

    /// One optimizer step from (already accumulated & scaled) gradients.
    pub fn step(&mut self, grads: &[Tensor]) {
        self.opt.apply(&mut self.params.tensors_mut(), grads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(42)
    }

    #[test]
    fn init_outputs_zero() {
        let mut r = rng();
        for kind in [AdapterKind::LowRank, AdapterKind::Linear, AdapterKind::Mlp] {
            let p = AdapterParams::init(kind, 16, 12, 8, 8, &mut r);
            let x = Tensor::randn(&[5, 16], 1.0, &mut r);
            assert_eq!(tensor::norm(&p.apply(&x)), 0.0, "{kind:?}");
        }
    }

    #[test]
    fn lowrank_fit_grads_match_finite_difference() {
        // d/dA of L(w) where the "task loss" is <g(x), ghat> has gradient
        // equal to fit_grads by Prop.1 (res == ghat identically).
        let mut r = rng();
        let a = Tensor::randn(&[6, 3], 0.5, &mut r);
        let b = Tensor::randn(&[3, 4], 0.5, &mut r);
        let p = AdapterParams::LowRank { a: a.clone(), b: b.clone() };
        let x = Tensor::randn(&[9, 6], 1.0, &mut r);
        let ghat = Tensor::randn(&[9, 4], 1.0, &mut r);
        let grads = p.fit_grads(&x, &ghat);

        let loss = |aa: &Tensor, bb: &Tensor| -> f32 {
            let d = tensor::matmul(&tensor::matmul(&x, aa), bb);
            d.data().iter().zip(ghat.data()).map(|(u, v)| u * v).sum()
        };
        let eps = 1e-3;
        for idx in [0usize, 5, 17] {
            let mut ap = a.clone();
            ap.data_mut()[idx] += eps;
            let mut am = a.clone();
            am.data_mut()[idx] -= eps;
            let fd = (loss(&ap, &b) - loss(&am, &b)) / (2.0 * eps);
            let an = grads[0].data()[idx];
            assert!((fd - an).abs() < 2e-2, "idx {idx}: fd {fd} vs {an}");
        }
    }

    #[test]
    fn mlp_fit_grads_shapes() {
        let mut r = rng();
        let p = AdapterParams::init(AdapterKind::Mlp, 10, 6, 8, 4, &mut r);
        let x = Tensor::randn(&[7, 10], 1.0, &mut r);
        let g = Tensor::randn(&[7, 6], 1.0, &mut r);
        let grads = p.fit_grads(&x, &g);
        assert_eq!(grads[0].shape(), &[10, 4]);
        assert_eq!(grads[1].shape(), &[4]);
        assert_eq!(grads[2].shape(), &[4, 6]);
        assert_eq!(grads[3].shape(), &[6]);
    }

    #[test]
    fn delta_matrix_matches_apply() {
        let mut r = rng();
        let mut p = AdapterParams::init(AdapterKind::LowRank, 8, 8, 4, 4, &mut r);
        if let AdapterParams::LowRank { b, .. } = &mut p {
            *b = Tensor::randn(&[4, 8], 0.3, &mut r);
        }
        let x = Tensor::randn(&[5, 8], 1.0, &mut r);
        let via_delta = tensor::matmul(&x, &p.delta_matrix().unwrap());
        assert!(p.apply(&x).allclose(&via_delta, 1e-5, 1e-5));
    }

    #[test]
    fn mlp_merge_rejected() {
        let mut r = rng();
        let p = AdapterParams::init(AdapterKind::Mlp, 8, 8, 4, 4, &mut r);
        assert!(p.delta_matrix().is_err());
    }
}
