//! SGD / AdamW — bit-for-bit twins of the lowered reference graphs
//! (`adamw_n*` / `sgd_n*` artifacts), so the native worker path and the
//! PJRT worker path produce identical parameter trajectories.
//!
//! Optimizer state lives with the worker that owns the adapter — the
//! paper's ZeRO-Offload-style placement (§3.2): the server never holds
//! m/v moments.

use crate::config::Optimizer;
use crate::tensor::{simd, Tensor};

#[derive(Clone, Copy, Debug)]
pub struct OptimizerCfg {
    pub kind: Optimizer,
    pub lr: f32,
    pub weight_decay: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl OptimizerCfg {
    pub fn sgd(lr: f32, weight_decay: f32) -> Self {
        OptimizerCfg { kind: Optimizer::Sgd, lr, weight_decay,
                       beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }

    pub fn adamw(lr: f32, weight_decay: f32) -> Self {
        OptimizerCfg { kind: Optimizer::AdamW, lr, weight_decay,
                       beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

/// Per-adapter optimizer state (one m/v pair per tensor for AdamW).
#[derive(Clone, Debug)]
pub struct OptState {
    pub cfg: OptimizerCfg,
    /// 1-based step counter (bias correction)
    pub t: u32,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl OptState {
    pub fn new(cfg: &OptimizerCfg, sizes: &[usize]) -> OptState {
        let (m, v) = match cfg.kind {
            Optimizer::Sgd => (vec![], vec![]),
            Optimizer::AdamW => (
                sizes.iter().map(|&n| vec![0.0; n]).collect(),
                sizes.iter().map(|&n| vec![0.0; n]).collect(),
            ),
        };
        OptState { cfg: *cfg, t: 0, m, v }
    }

    /// The raw (m, v) moment vectors — exposed so `transport::wire` can
    /// ship optimizer state to a worker daemon byte-exactly.
    pub fn moments(&self) -> (&[Vec<f32>], &[Vec<f32>]) {
        (&self.m, &self.v)
    }

    /// Rebuild state from wire parts; the inverse of [`OptState::moments`].
    /// The caller is responsible for m/v matching the adapter's tensor
    /// sizes (the fit path indexes them positionally).
    pub fn from_parts(cfg: OptimizerCfg, t: u32, m: Vec<Vec<f32>>, v: Vec<Vec<f32>>) -> OptState {
        OptState { cfg, t, m, v }
    }

    /// Bytes of optimizer state (memory accountant: lives on the worker).
    pub fn bytes(&self) -> usize {
        (self.m.iter().map(|x| x.len()).sum::<usize>()
            + self.v.iter().map(|x| x.len()).sum::<usize>())
            * 4
    }

    /// Apply one step. `params[i]` and `grads[i]` must correspond.
    pub fn apply(&mut self, params: &mut [&mut Tensor], grads: &[Tensor]) {
        assert_eq!(params.len(), grads.len());
        self.t += 1;
        let c = self.cfg;
        // element updates run through tensor::simd — runtime-dispatched
        // scalar/AVX2 kernels that are bit-identical to the pinned scalar
        // loops (the reference-graph twin contract survives SIMD)
        match c.kind {
            Optimizer::Sgd => {
                for (p, g) in params.iter_mut().zip(grads) {
                    simd::sgd_update(p.data_mut(), g.data(), c.lr, c.weight_decay);
                }
            }
            Optimizer::AdamW => {
                let step = simd::AdamwStep {
                    lr: c.lr,
                    beta1: c.beta1,
                    beta2: c.beta2,
                    eps: c.eps,
                    weight_decay: c.weight_decay,
                    bc1: 1.0 - c.beta1.powi(self.t as i32),
                    bc2: 1.0 - c.beta2.powi(self.t as i32),
                };
                for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
                    simd::adamw_update(
                        p.data_mut(),
                        g.data(),
                        &mut self.m[i],
                        &mut self.v[i],
                        &step,
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn sgd_matches_formula() {
        let cfg = OptimizerCfg::sgd(0.1, 0.01);
        let mut st = OptState::new(&cfg, &[3]);
        let mut w = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        let g = Tensor::new(vec![3], vec![0.5, 0.5, 0.5]);
        st.apply(&mut [&mut w], &[g]);
        // w - lr*(g + wd*w) = 1 - 0.1*(0.5 + 0.01*1) = 0.949
        assert!((w.data()[0] - 0.949).abs() < 1e-6);
    }

    #[test]
    fn adamw_first_step_is_sign_scaled() {
        // On step 1, mhat/(sqrt(vhat)+eps) ~= sign(g).
        let cfg = OptimizerCfg::adamw(0.001, 0.0);
        let mut st = OptState::new(&cfg, &[2]);
        let mut w = Tensor::new(vec![2], vec![0.0, 0.0]);
        let g = Tensor::new(vec![2], vec![10.0, -0.01]);
        st.apply(&mut [&mut w], &[g]);
        assert!((w.data()[0] + 0.001).abs() < 1e-5);
        assert!((w.data()[1] - 0.001).abs() < 1e-5);
    }

    #[test]
    fn adamw_state_accumulates() {
        let cfg = OptimizerCfg::adamw(0.01, 0.0);
        let mut st = OptState::new(&cfg, &[1]);
        let mut w = Tensor::new(vec![1], vec![1.0]);
        for _ in 0..10 {
            let g = Tensor::new(vec![1], vec![1.0]);
            st.apply(&mut [&mut w], &[g]);
        }
        assert_eq!(st.t, 10);
        assert!(w.data()[0] < 0.95); // moved downhill consistently
    }

    #[test]
    fn deterministic_across_clones() {
        let cfg = OptimizerCfg::adamw(0.01, 0.001);
        let mut rng = Rng::new(0);
        let mut s1 = OptState::new(&cfg, &[8]);
        let mut s2 = s1.clone();
        let mut w1 = Tensor::randn(&[8], 1.0, &mut rng);
        let mut w2 = w1.clone();
        for i in 0..5 {
            let g = Tensor::randn(&[8], 1.0, &mut Rng::new(i));
            s1.apply(&mut [&mut w1], std::slice::from_ref(&g));
            s2.apply(&mut [&mut w2], std::slice::from_ref(&g));
        }
        assert_eq!(w1, w2);
    }
}
