//! Fire-and-forget usage ledger: one JSONL line per (tenant, user) per
//! adaptation interval, appended off the hot path.
//!
//! The training loop must never block on accounting, so
//! [`UsageLedger::record`] is a bounded-channel `try_send`: a full
//! channel (writer stalled on disk) DROPS the entry and bumps a
//! counter instead of applying backpressure. That loss tolerance is a
//! deliberate trade — billing samples, curves don't — and is written
//! up in `docs/decisions/003-fire-and-forget-usage-ledger.md`. Dropped
//! counts are surfaced via [`UsageLedger::dropped`] and the gateway's
//! `/healthz` body, so silent loss is still visible loss.
//!
//! Timestamps come from `SystemTime` — the only wall-clock read in the
//! gateway. They annotate ledger lines for operators and never feed
//! curve math, so the determinism contract is untouched.

use std::collections::BTreeMap;
use std::fs::OpenOptions;
use std::io::{BufWriter, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Channel capacity: at one line per (tenant, user, interval) this
/// absorbs seconds of burst before sampling kicks in.
const CHANNEL_DEPTH: usize = 1024;

/// One accounting record.
#[derive(Clone, Debug)]
pub struct UsageEntry {
    pub tenant: String,
    pub job: u64,
    pub user: usize,
    /// 1-based interval ordinal within the job.
    pub interval: u64,
    /// Training step the interval ended on.
    pub step: u64,
    /// Adaptation-pair bytes offloaded to this user's worker during the
    /// interval.
    pub bytes_offloaded: u64,
    /// Fit-reply bytes returned by this user's worker during the interval.
    pub bytes_returned: u64,
    /// Milliseconds since the Unix epoch, stamped at record time.
    pub unix_ms: u64,
}

impl UsageEntry {
    /// Serialize as one JSON object (sorted keys, no whitespace — the
    /// house `Json` serializer, so lines are byte-stable given equal
    /// fields).
    pub fn to_json(&self) -> String {
        let mut obj = BTreeMap::new();
        obj.insert("tenant".to_string(), Json::Str(self.tenant.clone()));
        obj.insert("job".to_string(), Json::Num(self.job as f64));
        obj.insert("user".to_string(), Json::Num(self.user as f64));
        obj.insert("interval".to_string(), Json::Num(self.interval as f64));
        obj.insert("step".to_string(), Json::Num(self.step as f64));
        obj.insert(
            "bytes_offloaded".to_string(),
            Json::Num(self.bytes_offloaded as f64),
        );
        obj.insert(
            "bytes_returned".to_string(),
            Json::Num(self.bytes_returned as f64),
        );
        obj.insert("unix_ms".to_string(), Json::Num(self.unix_ms as f64));
        Json::Obj(obj).to_string()
    }
}

/// Milliseconds since the Unix epoch for ledger annotation.
pub fn now_unix_ms() -> u64 {
    // lint:allow(determinism): operator-facing ledger timestamp — never feeds curve math
    match std::time::SystemTime::now().duration_since(std::time::UNIX_EPOCH) {
        Ok(d) => d.as_millis() as u64,
        Err(_) => 0,
    }
}

/// Appending JSONL writer with a dedicated flush thread.
pub struct UsageLedger {
    tx: Option<SyncSender<String>>,
    dropped: Arc<AtomicU64>,
    writer: Option<JoinHandle<()>>,
}

impl UsageLedger {
    /// Open (create-or-append) the ledger file and start the writer.
    pub fn open(path: &str) -> Result<UsageLedger> {
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening usage ledger {path}"))?;
        let (tx, rx) = mpsc::sync_channel::<String>(CHANNEL_DEPTH);
        let writer = std::thread::Builder::new()
            .name("cola-ledger".into())
            .spawn(move || {
                let mut w = BufWriter::new(file);
                while let Ok(line) = rx.recv() {
                    // best-effort by design: an I/O error here must not
                    // take the gateway down, and there is no one to
                    // propagate it to off-thread
                    let _ = w.write_all(line.as_bytes());
                    let _ = w.write_all(b"\n");
                    // drain the burst before flushing, so disk syncs
                    // amortize across however many lines queued up
                    while let Ok(next) = rx.try_recv() {
                        let _ = w.write_all(next.as_bytes());
                        let _ = w.write_all(b"\n");
                    }
                    let _ = w.flush();
                }
                let _ = w.flush();
            })
            .context("spawning the ledger writer thread")?;
        Ok(UsageLedger {
            tx: Some(tx),
            dropped: Arc::new(AtomicU64::new(0)),
            writer: Some(writer),
        })
    }

    /// Enqueue one entry without blocking. Only a FULL channel (writer
    /// stalled on disk) drops the entry and bumps the counter — that is
    /// the sampling trade `ledger_dropped` exists to surface. A closed
    /// channel means the ledger is shutting down; a record racing that
    /// close is not a capacity drop and must not inflate the counter
    /// (the gateway joins the runner before closing the ledger, so by
    /// then every job's rows are already enqueued).
    pub fn record(&self, entry: &UsageEntry) {
        let Some(tx) = &self.tx else {
            return;
        };
        match tx.try_send(entry.to_json()) {
            Ok(()) | Err(TrySendError::Disconnected(_)) => {}
            Err(TrySendError::Full(_)) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Entries dropped so far (full channel only).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// A handle on the drop counter that outlives the ledger — the
    /// gateway keeps one so `/healthz` can keep reporting
    /// `ledger_dropped` after shutdown closed the ledger itself.
    pub fn drop_counter(&self) -> Arc<AtomicU64> {
        self.dropped.clone()
    }

    /// Close the channel, let the writer drain everything still
    /// buffered, and join it — after this returns, every recorded line
    /// is flushed to disk. Idempotent; `Drop` calls it too, but the
    /// gateway closes explicitly on `/v1/shutdown` so buffered rows
    /// can never be lost to process exit racing a lingering
    /// connection thread's `Arc` clone.
    pub fn close(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.writer.take() {
            let _ = h.join();
        }
    }
}

impl Drop for UsageLedger {
    fn drop(&mut self) {
        // closing the channel lets the writer drain and exit; join so
        // buffered lines hit disk before the gateway reports "exited"
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_serializes_with_sorted_keys() {
        let e = UsageEntry {
            tenant: "alice".into(),
            job: 7,
            user: 1,
            interval: 3,
            step: 5,
            bytes_offloaded: 4096,
            bytes_returned: 1024,
            unix_ms: 1700000000000,
        };
        assert_eq!(
            e.to_json(),
            "{\"bytes_offloaded\":4096,\"bytes_returned\":1024,\
             \"interval\":3,\"job\":7,\"step\":5,\"tenant\":\"alice\",\
             \"unix_ms\":1700000000000,\"user\":1}"
        );
    }

    #[test]
    fn writes_lines_and_counts_drops() {
        let path = std::env::temp_dir().join(format!(
            "cola_ledger_test_{}.jsonl",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let ledger = UsageLedger::open(path.to_str().unwrap()).unwrap();
        let e = UsageEntry {
            tenant: "t".into(),
            job: 1,
            user: 0,
            interval: 1,
            step: 1,
            bytes_offloaded: 1,
            bytes_returned: 2,
            unix_ms: now_unix_ms(),
        };
        ledger.record(&e);
        ledger.record(&e);
        assert_eq!(ledger.dropped(), 0);
        drop(ledger); // joins the writer -> file is complete
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        for line in text.lines() {
            let v = Json::parse(line).unwrap();
            assert_eq!(v.get("tenant").and_then(Json::as_str), Some("t"));
        }
        let _ = std::fs::remove_file(&path);
    }

    /// The shutdown race must not masquerade as capacity loss: records
    /// racing (or following) `close()` are discarded silently, and the
    /// drop counter stays a pure try_send-Full count. The counter
    /// handle also survives the ledger for post-shutdown `/healthz`.
    #[test]
    fn close_drains_and_shutdown_races_do_not_count_as_drops() {
        let path = std::env::temp_dir().join(format!(
            "cola_ledger_close_test_{}.jsonl",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let mut ledger = UsageLedger::open(path.to_str().unwrap()).unwrap();
        let counter = ledger.drop_counter();
        let e = UsageEntry {
            tenant: "t".into(),
            job: 1,
            user: 0,
            interval: 1,
            step: 1,
            bytes_offloaded: 1,
            bytes_returned: 2,
            unix_ms: now_unix_ms(),
        };
        for _ in 0..5 {
            ledger.record(&e);
        }
        ledger.close();
        // every buffered row is on disk once close() returns
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 5);
        // a record after close is a shutdown race, not a capacity drop
        ledger.record(&e);
        assert_eq!(ledger.dropped(), 0);
        assert_eq!(counter.load(Ordering::Relaxed), 0);
        // close is idempotent (Drop will call it again)
        ledger.close();
        drop(ledger);
        let _ = std::fs::remove_file(&path);
    }
}
