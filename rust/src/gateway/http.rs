//! Minimal HTTP/1.1 framing over `std::net`, hand-rolled like the rest
//! of the repo's wire code (see [`crate::transport::wire`] and
//! `docs/decisions/001-http-over-std-net.md` for why no HTTP crate).
//!
//! Scope is deliberately tiny: one request per connection
//! (`Connection: close`), `Content-Length` request bodies only, and
//! chunked transfer-encoding on the *response* side for progress
//! streaming. Everything is bounded — request-line length, header
//! count, header length, body size — and every malformed input maps to
//! an [`HttpError`] status, never a panic: the malformed-request fuzz
//! in `tests/gateway_http.rs` pins that down.

use std::io::{BufRead, Read, Write};
use std::net::TcpStream;

/// Max request-line / header-line length in bytes.
pub const MAX_LINE: usize = 8 * 1024;
/// Max number of request headers.
pub const MAX_HEADERS: usize = 64;
/// Max request body size (a `[train]` config TOML is a few hundred
/// bytes; 1 MiB leaves room without letting a client balloon memory).
pub const MAX_BODY: usize = 1024 * 1024;

/// A parsed request. Header names keep their wire spelling; use
/// [`Request::header`] for case-insensitive lookup.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup (first match wins).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// A request-level failure carrying the HTTP status to answer with.
#[derive(Debug)]
pub struct HttpError {
    pub status: u16,
    pub message: String,
    /// Seconds to advertise in a 429's `Retry-After` header. `None`
    /// falls back to the 1-second floor — a flat hint was always wrong
    /// for deep backlogs, so admission-control sites derive this from
    /// backlog depth x smoothed per-job runtime (see
    /// `Gateway::retry_after_hint`).
    pub retry_after: Option<u64>,
}

impl HttpError {
    pub fn new(status: u16, message: impl Into<String>) -> HttpError {
        HttpError { status, message: message.into(), retry_after: None }
    }

    /// Attach a derived `Retry-After` hint (seconds) to a 429.
    pub fn with_retry_after(mut self, secs: u64) -> HttpError {
        self.retry_after = Some(secs);
        self
    }
}

/// Canonical reason phrase for the statuses the gateway emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        401 => "Unauthorized",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// Read one CRLF/LF-terminated line with a hard length cap, without
/// over-reading past the terminator. Returns `Ok(None)` on clean EOF
/// before any byte (client connected and went away — not an error).
fn read_line(r: &mut impl BufRead, cap: usize) -> Result<Option<String>, HttpError> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let chunk = r
            .fill_buf()
            .map_err(|e| HttpError::new(400, format!("read failed: {e}")))?;
        if chunk.is_empty() {
            if buf.is_empty() {
                return Ok(None);
            }
            // EOF mid-line: treat what we have as the line
            break;
        }
        let nl = chunk.iter().position(|&b| b == b'\n');
        let take = match nl {
            Some(i) => i + 1,
            None => chunk.len(),
        };
        if buf.len() + take > cap {
            return Err(HttpError::new(431, "request line or header too long"));
        }
        buf.extend_from_slice(&chunk[..take]);
        r.consume(take);
        if nl.is_some() {
            break;
        }
    }
    while matches!(buf.last(), Some(b'\n') | Some(b'\r')) {
        buf.pop();
    }
    String::from_utf8(buf)
        .map(Some)
        .map_err(|_| HttpError::new(400, "non-UTF-8 bytes in request head"))
}

/// Parse one request off the connection. `Ok(None)` = the peer closed
/// before sending anything (drop silently, as the daemon accept loop
/// does for its wake connection).
pub fn read_request(r: &mut impl BufRead) -> Result<Option<Request>, HttpError> {
    let Some(line) = read_line(r, MAX_LINE)? else {
        return Ok(None);
    };
    let mut parts = line.split_whitespace();
    let (Some(method), Some(path), Some(version), None) =
        (parts.next(), parts.next(), parts.next(), parts.next())
    else {
        return Err(HttpError::new(400, format!("malformed request line {line:?}")));
    };
    if !method.bytes().all(|b| b.is_ascii_uppercase()) || method.is_empty() {
        return Err(HttpError::new(400, format!("malformed method {method:?}")));
    }
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::new(505, format!("unsupported version {version:?}")));
    }
    if !path.starts_with('/') {
        return Err(HttpError::new(400, format!("malformed path {path:?}")));
    }
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let Some(h) = read_line(r, MAX_LINE)? else {
            return Err(HttpError::new(400, "EOF inside request headers"));
        };
        if h.is_empty() {
            break;
        }
        if headers.len() == MAX_HEADERS {
            return Err(HttpError::new(431, "too many request headers"));
        }
        let Some(colon) = h.find(':') else {
            return Err(HttpError::new(400, format!("malformed header {h:?}")));
        };
        let (name, value) = h.split_at(colon);
        headers.push((name.trim().to_string(), value[1..].trim().to_string()));
    }
    let req = Request {
        method: method.to_string(),
        path: path.to_string(),
        headers,
        body: Vec::new(),
    };
    if req.header("transfer-encoding").is_some() {
        return Err(HttpError::new(501, "chunked request bodies are not supported"));
    }
    let body_len = match req.header("content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| HttpError::new(400, format!("bad Content-Length {v:?}")))?,
    };
    if body_len > MAX_BODY {
        return Err(HttpError::new(
            413,
            format!("body of {body_len} bytes exceeds the {MAX_BODY}-byte cap"),
        ));
    }
    let mut body = vec![0u8; body_len];
    r.read_exact(&mut body)
        .map_err(|e| HttpError::new(400, format!("short body: {e}")))?;
    Ok(Some(Request { body, ..req }))
}

/// Write a complete response with a known body. Extra headers ride
/// along for e.g. `WWW-Authenticate` and `Retry-After`.
pub fn respond(
    w: &mut TcpStream,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n",
        reason(status),
        body.len()
    );
    for (k, v) in extra_headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Answer an [`HttpError`] with a small JSON body. A 401 advertises the
/// Bearer challenge so plain HTTP clients know what to send.
pub fn respond_error(w: &mut TcpStream, e: &HttpError) -> std::io::Result<()> {
    let body = format!(
        "{}\n",
        crate::util::json::Json::Obj(
            [("error".to_string(), crate::util::json::Json::Str(e.message.clone()))]
                .into_iter()
                .collect()
        )
    );
    // formatted into an owned string declared before `extra` so the
    // borrow lives across the respond() call
    let retry_after = e.retry_after.unwrap_or(1).max(1).to_string();
    let mut extra: Vec<(&str, &str)> = Vec::new();
    if e.status == 401 {
        extra.push(("WWW-Authenticate", "Bearer realm=\"cola\""));
    } else if e.status == 429 {
        extra.push(("Retry-After", retry_after.as_str()));
    }
    respond(w, e.status, "application/json", &extra, body.as_bytes())
}

/// Open a chunked-transfer response (the progress stream).
pub fn start_chunked(
    w: &mut TcpStream,
    status: u16,
    content_type: &str,
) -> std::io::Result<()> {
    w.write_all(
        format!(
            "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\n\
             Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
            reason(status)
        )
        .as_bytes(),
    )?;
    w.flush()
}

/// Write one chunk. Empty payloads are skipped — a zero-length chunk is
/// the stream terminator, which only [`finish_chunked`] may send.
pub fn write_chunk(w: &mut TcpStream, data: &[u8]) -> std::io::Result<()> {
    if data.is_empty() {
        return Ok(());
    }
    w.write_all(format!("{:x}\r\n", data.len()).as_bytes())?;
    w.write_all(data)?;
    w.write_all(b"\r\n")?;
    w.flush()
}

/// Terminate a chunked response.
pub fn finish_chunked(w: &mut TcpStream) -> std::io::Result<()> {
    w.write_all(b"0\r\n\r\n")?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(raw: &[u8]) -> Result<Option<Request>, HttpError> {
        read_request(&mut BufReader::new(raw))
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse(
            b"POST /v1/fit HTTP/1.1\r\nAuthorization: Bearer t\r\n\
              Content-Length: 4\r\n\r\nabcd",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/fit");
        assert_eq!(req.header("authorization"), Some("Bearer t"));
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn clean_eof_is_none() {
        assert!(parse(b"").unwrap().is_none());
    }

    #[test]
    fn rejects_malformed_inputs_with_statuses() {
        assert_eq!(parse(b"GET\r\n\r\n").unwrap_err().status, 400);
        assert_eq!(parse(b"GET / SMTP/1.0\r\n\r\n").unwrap_err().status, 505);
        assert_eq!(parse(b"GET relative HTTP/1.1\r\n\r\n").unwrap_err().status, 400);
        assert_eq!(parse(b"G E T / HTTP/1.1\r\n\r\n").unwrap_err().status, 400);
        assert_eq!(
            parse(b"GET / HTTP/1.1\r\nbroken header\r\n\r\n").unwrap_err().status,
            400
        );
        assert_eq!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: zebra\r\n\r\n")
                .unwrap_err()
                .status,
            400
        );
        assert_eq!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n")
                .unwrap_err()
                .status,
            413
        );
        assert_eq!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc")
                .unwrap_err()
                .status,
            400
        );
        assert_eq!(
            parse(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n")
                .unwrap_err()
                .status,
            501
        );
        assert_eq!(parse(b"\xff\xfe\x00garbage\r\n\r\n").unwrap_err().status, 400);
    }

    #[test]
    fn caps_line_length_and_header_count() {
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_LINE));
        assert_eq!(parse(long.as_bytes()).unwrap_err().status, 431);
        let mut many = String::from("GET / HTTP/1.1\r\n");
        for i in 0..(MAX_HEADERS + 1) {
            many.push_str(&format!("X-H{i}: v\r\n"));
        }
        many.push_str("\r\n");
        assert_eq!(parse(many.as_bytes()).unwrap_err().status, 431);
    }
}
