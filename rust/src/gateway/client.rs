//! Stdlib-only HTTP/1.1 client for driving a gateway — `cola http` and
//! the smoke scripts use this instead of depending on `curl`.
//!
//! Mirrors the server's framing subset ([`super::http`]): one request
//! per connection, `Content-Length` request bodies, and response
//! bodies framed by `Content-Length`, chunked transfer-encoding, or
//! connection close. Strictly a test/ops convenience — nothing in the
//! training path calls it.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

use anyhow::{anyhow, bail, Context, Result};

/// A complete response.
#[derive(Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    /// Case-insensitive header lookup (first match wins).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// Split an `http://host:port/path` URL. Only plain `http` — the
/// gateway speaks nothing else.
fn split_url(url: &str) -> Result<(String, String)> {
    let rest = url
        .strip_prefix("http://")
        .ok_or_else(|| anyhow!("only http:// URLs are supported, got {url:?}"))?;
    let (hostport, path) = match rest.find('/') {
        Some(i) => (&rest[..i], &rest[i..]),
        None => (rest, "/"),
    };
    if hostport.is_empty() {
        bail!("empty host in {url:?}");
    }
    Ok((hostport.to_string(), path.to_string()))
}

/// Issue one request. `body` is `(content_type, bytes)`; `token`
/// becomes a `Bearer` Authorization header. Blocks until the full
/// response (including a chunked progress stream) has arrived.
pub fn request(
    method: &str,
    url: &str,
    token: Option<&str>,
    body: Option<(&str, &[u8])>,
) -> Result<HttpResponse> {
    let (hostport, path) = split_url(url)?;
    let mut stream = TcpStream::connect(&hostport)
        .with_context(|| format!("connecting to {hostport}"))?;
    stream.set_nodelay(true).ok();

    let mut head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {hostport}\r\nConnection: close\r\n"
    );
    if let Some(t) = token {
        head.push_str(&format!("Authorization: Bearer {t}\r\n"));
    }
    match body {
        Some((ctype, bytes)) => {
            head.push_str(&format!(
                "Content-Type: {ctype}\r\nContent-Length: {}\r\n\r\n",
                bytes.len()
            ));
            stream.write_all(head.as_bytes())?;
            stream.write_all(bytes)?;
        }
        None => {
            head.push_str("\r\n");
            stream.write_all(head.as_bytes())?;
        }
    }
    stream.flush()?;

    let mut r = BufReader::new(stream);
    let mut status_line = String::new();
    r.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed status line {status_line:?}"))?;

    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let mut line = String::new();
        r.read_line(&mut line)?;
        let line = line.trim_end_matches(['\r', '\n']);
        if line.is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }

    let chunked = headers.iter().any(|(k, v)| {
        k.eq_ignore_ascii_case("transfer-encoding")
            && v.eq_ignore_ascii_case("chunked")
    });
    let content_length = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.parse::<usize>().ok());

    let mut body = Vec::new();
    if chunked {
        loop {
            let mut size_line = String::new();
            r.read_line(&mut size_line)?;
            let size = usize::from_str_radix(
                size_line.trim_end_matches(['\r', '\n']).trim(),
                16,
            )
            .map_err(|_| anyhow!("malformed chunk size {size_line:?}"))?;
            if size == 0 {
                // trailing CRLF after the terminator (may be absent on
                // a server that closes right away)
                let mut rest = String::new();
                let _ = r.read_line(&mut rest);
                break;
            }
            let mut chunk = vec![0u8; size];
            r.read_exact(&mut chunk)?;
            body.extend_from_slice(&chunk);
            let mut crlf = [0u8; 2];
            r.read_exact(&mut crlf)?;
        }
    } else if let Some(n) = content_length {
        let mut buf = vec![0u8; n];
        r.read_exact(&mut buf)?;
        body = buf;
    } else {
        // Connection: close framing
        r.read_to_end(&mut body)?;
    }
    Ok(HttpResponse { status, headers, body })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_urls() {
        assert_eq!(
            split_url("http://127.0.0.1:7780/v1/fit").unwrap(),
            ("127.0.0.1:7780".to_string(), "/v1/fit".to_string())
        );
        assert_eq!(
            split_url("http://localhost:1").unwrap(),
            ("localhost:1".to_string(), "/".to_string())
        );
        assert!(split_url("https://x/").is_err());
        assert!(split_url("ftp://x/").is_err());
        assert!(split_url("http:///path").is_err());
    }
}
