//! Job registry: every submitted fine-tuning job's lifecycle, progress
//! lines, and result artifacts, behind one mutex + condvar.
//!
//! Jobs are tenant-owned: every accessor takes the authenticated
//! tenant and answers `None`/`NotFound` for another tenant's job id —
//! the gateway maps that to 404, so ids don't leak existence across
//! tenants. Progress consumers block on [`JobRegistry::wait_progress`]
//! (condvar with a short timeout so streams can also notice server
//! shutdown); the runner publishes with the lock held briefly and
//! notifies after every append.
//!
//! Locking goes through [`crate::util::lock_recover`] /
//! [`crate::util::wait_timeout_recover`]: a panicking job is caught by
//! the runner, but the registry must stay serviceable even if a panic
//! ever unwinds through a lock holder.

use std::collections::BTreeMap;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::util::{lock_recover, wait_timeout_recover};

/// Lifecycle of one job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
}

impl JobState {
    pub fn as_str(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
        }
    }

    fn terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Failed)
    }
}

/// Owner-visible view of a job (everything but the bulk artifacts).
#[derive(Clone, Debug)]
pub struct JobSnapshot {
    pub id: u64,
    pub state: JobState,
    /// Global start ordinal (1-based) — the fairness tests assert the
    /// exact service order through this.
    pub started_seq: Option<u64>,
    pub error: Option<String>,
    pub progress_lines: usize,
}

/// Outcome of fetching a result artifact (curves or adapter bundle).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Fetch<T> {
    /// No such job for this tenant (gateway answers 404).
    NotFound,
    /// Job exists but has not finished (409).
    NotReady,
    /// Job failed; the message explains why (409).
    Failed(String),
    /// Job finished but never produced this artifact — e.g. a coupled
    /// baseline has no exportable adapter (409).
    Missing,
    Ready(T),
}

struct JobRecord {
    tenant: String,
    config: String,
    state: JobState,
    started_seq: Option<u64>,
    /// Wall-clock start stamp feeding the runtime EMA. Never surfaces
    /// in any curve or artifact — it only tunes the 429 Retry-After
    /// hint, which is advisory by spec.
    started_at: Option<std::time::Instant>,
    error: Option<String>,
    progress: Vec<String>,
    curves: Option<String>,
    adapter: Option<Vec<u8>>,
}

struct Inner {
    next_id: u64,
    next_seq: u64,
    jobs: BTreeMap<u64, JobRecord>,
    /// Smoothed per-job runtime in ms (`ema = ema*3/4 + sample/4`),
    /// seeded by the first completed job. Shared across tenants: the
    /// runner is single-threaded, so fleet-wide runtime is the right
    /// estimate for how long a queue slot takes to drain.
    runtime_ema_ms: Option<u64>,
}

impl Inner {
    /// Fold one completed job's elapsed runtime into the EMA.
    fn observe_runtime(&mut self, id: u64) {
        let Some(started) = self.jobs.get(&id).and_then(|j| j.started_at) else {
            return;
        };
        let sample = started.elapsed().as_millis().min(u64::MAX as u128) as u64;
        self.runtime_ema_ms = Some(match self.runtime_ema_ms {
            None => sample,
            Some(ema) => ema - ema / 4 + sample / 4,
        });
    }
}

/// The registry. One per gateway; shared between connection threads
/// and the job runner.
pub struct JobRegistry {
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl Default for JobRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl JobRegistry {
    pub fn new() -> JobRegistry {
        JobRegistry {
            inner: Mutex::new(Inner {
                next_id: 1,
                next_seq: 1,
                jobs: BTreeMap::new(),
                runtime_ema_ms: None,
            }),
            cv: Condvar::new(),
        }
    }

    /// Register a new queued job; returns its id.
    pub fn create(&self, tenant: &str, config: String) -> u64 {
        let mut g = lock_recover(&self.inner);
        let id = g.next_id;
        g.next_id += 1;
        g.jobs.insert(
            id,
            JobRecord {
                tenant: tenant.to_string(),
                config,
                state: JobState::Queued,
                started_seq: None,
                started_at: None,
                error: None,
                progress: Vec::new(),
                curves: None,
                adapter: None,
            },
        );
        id
    }

    /// Drop a job record (admission rollback when the queue is full).
    pub fn remove(&self, id: u64) {
        lock_recover(&self.inner).jobs.remove(&id);
    }

    /// The runner fetches the config text it should train from.
    pub fn config(&self, id: u64) -> Option<String> {
        lock_recover(&self.inner).jobs.get(&id).map(|j| j.config.clone())
    }

    /// Transition to Running, stamping the global start ordinal.
    pub fn mark_running(&self, id: u64) {
        let mut g = lock_recover(&self.inner);
        // single deref so the borrow checker can split the field borrows
        let inner = &mut *g;
        if let Some(j) = inner.jobs.get_mut(&id) {
            j.state = JobState::Running;
            j.started_seq = Some(inner.next_seq);
            // lint:allow(determinism): feeds only the advisory Retry-After hint, never a curve
            j.started_at = Some(std::time::Instant::now());
            inner.next_seq += 1;
        }
        drop(g);
        self.cv.notify_all();
    }

    /// Append one progress line (already-serialized JSON).
    pub fn push_progress(&self, id: u64, line: String) {
        if let Some(j) = lock_recover(&self.inner).jobs.get_mut(&id) {
            j.progress.push(line);
        }
        self.cv.notify_all();
    }

    /// Transition to Done with the result artifacts. `adapter` is
    /// `None` for methods with nothing exportable (coupled baselines).
    pub fn finish(&self, id: u64, curves: String, adapter: Option<Vec<u8>>) {
        let mut g = lock_recover(&self.inner);
        g.observe_runtime(id);
        if let Some(j) = g.jobs.get_mut(&id) {
            j.state = JobState::Done;
            j.curves = Some(curves);
            j.adapter = adapter;
        }
        drop(g);
        self.cv.notify_all();
    }

    /// Transition to Failed with an error message.
    pub fn fail(&self, id: u64, error: String) {
        let mut g = lock_recover(&self.inner);
        // failed jobs still held a runner slot for their whole runtime,
        // so they are real samples for the backlog-drain estimate
        g.observe_runtime(id);
        if let Some(j) = g.jobs.get_mut(&id) {
            j.state = JobState::Failed;
            j.error = Some(error);
        }
        drop(g);
        self.cv.notify_all();
    }

    /// Smoothed per-job runtime in ms, if any job has completed yet.
    /// Admission control turns this into the 429 `Retry-After` hint.
    pub fn runtime_ema_ms(&self) -> Option<u64> {
        lock_recover(&self.inner).runtime_ema_ms
    }

    /// Owner-checked status view; `None` = not this tenant's job.
    pub fn snapshot(&self, tenant: &str, id: u64) -> Option<JobSnapshot> {
        let g = lock_recover(&self.inner);
        let j = g.jobs.get(&id).filter(|j| j.tenant == tenant)?;
        Some(JobSnapshot {
            id,
            state: j.state,
            started_seq: j.started_seq,
            error: j.error.clone(),
            progress_lines: j.progress.len(),
        })
    }

    /// Owner-checked curves fetch.
    pub fn curves(&self, tenant: &str, id: u64) -> Fetch<String> {
        let g = lock_recover(&self.inner);
        let Some(j) = g.jobs.get(&id).filter(|j| j.tenant == tenant) else {
            return Fetch::NotFound;
        };
        match (&j.state, &j.curves) {
            (JobState::Failed, _) => {
                Fetch::Failed(j.error.clone().unwrap_or_else(|| "job failed".into()))
            }
            (JobState::Done, Some(c)) => Fetch::Ready(c.clone()),
            (JobState::Done, None) => Fetch::Missing,
            _ => Fetch::NotReady,
        }
    }

    /// Owner-checked adapter-bundle fetch.
    pub fn adapter(&self, tenant: &str, id: u64) -> Fetch<Vec<u8>> {
        let g = lock_recover(&self.inner);
        let Some(j) = g.jobs.get(&id).filter(|j| j.tenant == tenant) else {
            return Fetch::NotFound;
        };
        match (&j.state, &j.adapter) {
            (JobState::Failed, _) => {
                Fetch::Failed(j.error.clone().unwrap_or_else(|| "job failed".into()))
            }
            (JobState::Done, Some(b)) => Fetch::Ready(b.clone()),
            (JobState::Done, None) => Fetch::Missing,
            _ => Fetch::NotReady,
        }
    }

    /// Block (up to `timeout`) for progress lines past index `from`, or
    /// for the job to reach a terminal state. Returns the new lines and
    /// whether the job is terminal; `None` = not this tenant's job. A
    /// timeout returns `Some((vec![], false))` so streaming loops can
    /// interleave shutdown checks.
    pub fn wait_progress(
        &self,
        tenant: &str,
        id: u64,
        from: usize,
        timeout: Duration,
    ) -> Option<(Vec<String>, bool)> {
        let mut g = lock_recover(&self.inner);
        loop {
            let Some(j) = g.jobs.get(&id) else {
                return None;
            };
            if j.tenant != tenant {
                return None;
            }
            if j.progress.len() > from || j.state.terminal() {
                let lines = j.progress.get(from..).unwrap_or(&[]).to_vec();
                return Some((lines, j.state.terminal()));
            }
            let before = j.progress.len();
            g = wait_timeout_recover(&self.cv, g, timeout);
            let still = g
                .jobs
                .get(&id)
                .map(|j| j.progress.len() == before && !j.state.terminal())
                .unwrap_or(false);
            if still {
                // spurious wake or timeout with no news: hand control
                // back so the caller can check its stop flag
                return Some((Vec::new(), false));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_and_tenant_isolation() {
        let r = JobRegistry::new();
        let id = r.create("alice", "[train]\n".into());
        assert_eq!(r.snapshot("alice", id).unwrap().state, JobState::Queued);
        // another tenant can't even observe the job
        assert!(r.snapshot("bob", id).is_none());
        assert_eq!(r.curves("bob", id), Fetch::NotFound);
        assert!(r.wait_progress("bob", id, 0, Duration::from_millis(1)).is_none());

        r.mark_running(id);
        assert_eq!(r.snapshot("alice", id).unwrap().started_seq, Some(1));
        assert_eq!(r.curves("alice", id), Fetch::NotReady);

        r.push_progress(id, "{\"step\":0}".into());
        let (lines, done) =
            r.wait_progress("alice", id, 0, Duration::from_millis(1)).unwrap();
        assert_eq!(lines, vec!["{\"step\":0}".to_string()]);
        assert!(!done);

        r.finish(id, "{}\n".into(), Some(vec![1, 2, 3]));
        assert_eq!(r.curves("alice", id), Fetch::Ready("{}\n".into()));
        assert_eq!(r.adapter("alice", id), Fetch::Ready(vec![1, 2, 3]));
        let (rest, done) =
            r.wait_progress("alice", id, 1, Duration::from_millis(1)).unwrap();
        assert!(rest.is_empty());
        assert!(done);
    }

    #[test]
    fn failure_and_missing_artifacts() {
        let r = JobRegistry::new();
        let a = r.create("t", String::new());
        r.fail(a, "boom".into());
        assert_eq!(r.curves("t", a), Fetch::Failed("boom".into()));
        assert_eq!(r.adapter("t", a), Fetch::Failed("boom".into()));

        let b = r.create("t", String::new());
        r.finish(b, "{}\n".into(), None);
        assert_eq!(r.adapter("t", b), Fetch::Missing);

        r.remove(b);
        assert!(r.snapshot("t", b).is_none());
    }

    #[test]
    fn start_seq_is_global_service_order() {
        let r = JobRegistry::new();
        let a = r.create("x", String::new());
        let b = r.create("y", String::new());
        r.mark_running(b);
        r.mark_running(a);
        assert_eq!(r.snapshot("y", b).unwrap().started_seq, Some(1));
        assert_eq!(r.snapshot("x", a).unwrap().started_seq, Some(2));
    }
}
