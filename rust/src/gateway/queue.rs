//! Fair-share admission queue: round-robin across tenants with a
//! bounded per-tenant backlog.
//!
//! Two properties, both load-bearing for the FTaaS story (and spelled
//! out in `docs/decisions/002-fair-share-admission.md`):
//!
//! 1. **No starvation.** [`AdmissionQueue::pop`] serves tenants
//!    round-robin in sorted-name order; a tenant that floods its own
//!    backlog only delays its own later jobs, never another tenant's
//!    next job — the fairness regression in `tests/gateway_http.rs`
//!    pins the exact interleaving.
//! 2. **Bounded memory.** Each tenant holds at most `cap` slots,
//!    counting queued jobs AND the job currently running ([`
//!    AdmissionQueue::pop`] moves a job from queued to running;
//!    [`AdmissionQueue::finish`] frees the slot). The gateway answers
//!    an overflowing submit with `429` instead of buffering without
//!    limit. Counting only the queue let a tenant hold `cap + 1` slots
//!    (cap queued + one in flight) — fixed by including the running
//!    job in the depth the admission check sees.
//!
//! The structure is deliberately deterministic (`BTreeMap`, sorted
//! iteration): given the same admission order, the service order is a
//! pure function — which is what lets the fairness test assert exact
//! start sequence numbers.

use std::collections::{BTreeMap, VecDeque};

/// FIFO per tenant, round-robin across tenants.
#[derive(Debug)]
pub struct AdmissionQueue {
    cap: usize,
    backlog: BTreeMap<String, VecDeque<u64>>,
    /// Jobs popped but not yet finished, per tenant. A running job
    /// still occupies one of its tenant's `cap` slots — otherwise a
    /// tenant with one job in flight could keep `cap` more queued,
    /// holding `cap + 1` slots total.
    running: BTreeMap<String, usize>,
    /// Last tenant served; the next pop starts strictly after it in
    /// sorted order, wrapping.
    cursor: Option<String>,
}

impl AdmissionQueue {
    /// `cap` = max in-flight + queued jobs per tenant (>= 1).
    pub fn new(cap: usize) -> AdmissionQueue {
        AdmissionQueue {
            cap: cap.max(1),
            backlog: BTreeMap::new(),
            running: BTreeMap::new(),
            cursor: None,
        }
    }

    /// Enqueue a job. `Ok(depth)` = admitted at that depth (queued +
    /// running); `Err(cap)` = the tenant already holds `cap` slots
    /// (caller answers 429).
    pub fn push(&mut self, tenant: &str, job: u64) -> Result<usize, usize> {
        if self.depth(tenant) >= self.cap {
            return Err(self.cap);
        }
        let q = self.backlog.entry(tenant.to_string()).or_default();
        q.push_back(job);
        Ok(q.len() + self.running.get(tenant).copied().unwrap_or(0))
    }

    /// Dequeue the next job round-robin: the first tenant in sorted
    /// order strictly after the last-served one (wrapping) that has
    /// work, FIFO within the tenant.
    pub fn pop(&mut self) -> Option<(String, u64)> {
        let live: Vec<String> = self
            .backlog
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .map(|(k, _)| k.clone())
            .collect();
        let first = live.first()?.clone();
        let pick = match &self.cursor {
            Some(c) => live.iter().find(|k| k.as_str() > c.as_str())
                .cloned()
                .unwrap_or(first),
            None => first,
        };
        let job = {
            let q = self.backlog.get_mut(&pick)?;
            q.pop_front()?
        };
        if self.backlog.get(&pick).is_some_and(VecDeque::is_empty) {
            self.backlog.remove(&pick);
        }
        *self.running.entry(pick.clone()).or_insert(0) += 1;
        self.cursor = Some(pick.clone());
        Some((pick, job))
    }

    /// Release a popped job's slot once it finished (or failed). The
    /// runner calls this after the job returns; finishing a tenant
    /// with nothing running is a no-op, so a crash-recovered runner
    /// can over-call safely.
    pub fn finish(&mut self, tenant: &str) {
        if let Some(n) = self.running.get_mut(tenant) {
            *n = n.saturating_sub(1);
            if *n == 0 {
                self.running.remove(tenant);
            }
        }
    }

    /// Total queued jobs across tenants (running jobs excluded — this
    /// feeds the runner's "is there work" predicate).
    pub fn len(&self) -> usize {
        self.backlog.values().map(VecDeque::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Slots one tenant currently holds: queued jobs plus the running
    /// one, which is the figure the `cap` admission check compares
    /// against.
    pub fn depth(&self, tenant: &str) -> usize {
        self.backlog.get(tenant).map_or(0, VecDeque::len)
            + self.running.get(tenant).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_interleaves_tenants() {
        let mut q = AdmissionQueue::new(8);
        for j in [1, 2, 3] {
            q.push("alice", j).unwrap();
        }
        q.push("bob", 10).unwrap();
        q.push("carol", 20).unwrap();
        let order: Vec<(String, u64)> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(
            order,
            vec![
                ("alice".to_string(), 1),
                ("bob".to_string(), 10),
                ("carol".to_string(), 20),
                ("alice".to_string(), 2),
                ("alice".to_string(), 3),
            ]
        );
    }

    #[test]
    fn flooding_tenant_cannot_starve_another() {
        let mut q = AdmissionQueue::new(64);
        for j in 0..50 {
            q.push("flooder", j).unwrap();
        }
        q.push("starved", 99).unwrap();
        // the starved tenant's job is served 2nd, not 51st
        assert_eq!(q.pop(), Some(("flooder".to_string(), 0)));
        assert_eq!(q.pop(), Some(("starved".to_string(), 99)));
        assert_eq!(q.pop(), Some(("flooder".to_string(), 1)));
    }

    #[test]
    fn late_arrival_joins_the_rotation() {
        let mut q = AdmissionQueue::new(8);
        q.push("zed", 1).unwrap();
        q.push("zed", 2).unwrap();
        assert_eq!(q.pop(), Some(("zed".to_string(), 1)));
        // cursor sits at "zed"; "anna" sorts before it and must still
        // be served next via wraparound
        q.push("anna", 10).unwrap();
        assert_eq!(q.pop(), Some(("anna".to_string(), 10)));
        assert_eq!(q.pop(), Some(("zed".to_string(), 2)));
        assert_eq!(q.pop(), None);
    }

    /// Churn property: random enqueue/pop interleavings — tenants
    /// drain to empty, leave the backlog map, and rejoin later — never
    /// break strict wrap-around rotation. In particular the cursor may
    /// keep naming a tenant that has since been removed; the next pop
    /// must still pick the first live tenant strictly after it in
    /// sorted order, wrapping. The expectation is recomputed here from
    /// an independently-maintained shadow backlog, so a cursor-reset or
    /// stale-cursor regression in `pop` shows up as a mismatch.
    #[test]
    fn churn_keeps_wraparound_rotation_fair() {
        let mut rng = crate::rng::Rng::new(0xC01A_FA12);
        let tenants = ["anna", "bob", "carol", "dave", "erin"];
        let cap = 4;
        let mut q = AdmissionQueue::new(cap);
        let mut shadow: BTreeMap<String, VecDeque<u64>> = BTreeMap::new();
        let mut cursor: Option<String> = None;
        let mut next_job = 0u64;
        for _ in 0..4000 {
            if rng.below(5) < 3 {
                let t = tenants[rng.below(tenants.len())];
                next_job += 1;
                match q.push(t, next_job) {
                    Ok(depth) => {
                        let sq = shadow.entry(t.to_string()).or_default();
                        sq.push_back(next_job);
                        assert_eq!(depth, sq.len());
                    }
                    Err(reported) => {
                        assert_eq!(reported, cap);
                        assert_eq!(shadow.get(t).map_or(0, VecDeque::len), cap);
                    }
                }
            } else {
                let live: Vec<String> = shadow
                    .iter()
                    .filter(|(_, v)| !v.is_empty())
                    .map(|(k, _)| k.clone())
                    .collect();
                let expect = live.first().map(|first| match &cursor {
                    None => first.clone(),
                    Some(c) => live
                        .iter()
                        .find(|k| k.as_str() > c.as_str())
                        .unwrap_or(first)
                        .clone(),
                });
                match (q.pop(), expect) {
                    (None, None) => {}
                    (Some((t, j)), Some(want)) => {
                        assert_eq!(t, want);
                        let sq = shadow.get_mut(&t).unwrap();
                        assert_eq!(sq.pop_front(), Some(j));
                        if sq.is_empty() {
                            shadow.remove(&t);
                        }
                        // settle the job immediately so the shadow's
                        // queued-only depth keeps matching `depth()`
                        q.finish(&t);
                        cursor = Some(t);
                    }
                    (got, want) => panic!("pop mismatch: got {got:?}, want {want:?}"),
                }
            }
        }
    }

    /// With every tenant fully backlogged, per-tenant service counts
    /// differ by at most 1 at every prefix of the pop sequence: strict
    /// round robin never gives one tenant two turns before another
    /// gets its first.
    #[test]
    fn service_counts_spread_at_most_one_when_all_backlogged() {
        let cap = 8;
        let mut q = AdmissionQueue::new(cap);
        let tenants = ["a", "b", "c", "d"];
        for t in tenants {
            for j in 0..cap as u64 {
                q.push(t, j).unwrap();
            }
        }
        let mut served: BTreeMap<&str, usize> = tenants.iter().map(|t| (*t, 0)).collect();
        for _ in 0..tenants.len() * cap {
            let (t, _) = q.pop().unwrap();
            *served.get_mut(t.as_str()).unwrap() += 1;
            let lo = *served.values().min().unwrap();
            let hi = *served.values().max().unwrap();
            assert!(hi - lo <= 1, "unfair prefix: {served:?}");
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn per_tenant_backlog_is_bounded() {
        let mut q = AdmissionQueue::new(2);
        assert_eq!(q.push("a", 1), Ok(1));
        assert_eq!(q.push("a", 2), Ok(2));
        assert_eq!(q.push("a", 3), Err(2));
        // another tenant is unaffected
        assert_eq!(q.push("b", 9), Ok(1));
        assert_eq!(q.depth("a"), 2);
        assert_eq!(q.len(), 3);
        // popping alone does NOT free capacity — the job is running now
        q.pop().unwrap();
        assert_eq!(q.push("a", 3), Err(2));
        // finishing it does
        q.finish("a");
        assert_eq!(q.push("a", 3), Ok(2));
    }

    /// The cap+1 regression: a tenant's in-flight job must keep holding
    /// one of its slots until the runner finishes it, or cap queued +
    /// one running = cap+1 slots.
    #[test]
    fn running_job_counts_against_the_cap() {
        let mut q = AdmissionQueue::new(2);
        assert_eq!(q.push("a", 1), Ok(1));
        assert_eq!(q.push("a", 2), Ok(2));
        let (t, j) = q.pop().unwrap();
        assert_eq!((t.as_str(), j), ("a", 1));
        // one queued + one running == cap: still full
        assert_eq!(q.depth("a"), 2);
        assert_eq!(q.push("a", 3), Err(2));
        // the running job does not block OTHER tenants
        assert_eq!(q.push("b", 9), Ok(1));
        q.finish("a");
        assert_eq!(q.depth("a"), 1);
        assert_eq!(q.push("a", 3), Ok(2));
        // finishing an idle or unknown tenant is a no-op
        q.finish("a");
        q.finish("a");
        q.finish("nobody");
        assert_eq!(q.depth("a"), 2);
    }
}
