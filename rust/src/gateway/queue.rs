//! Fair-share admission queue: round-robin across tenants with a
//! bounded per-tenant backlog.
//!
//! Two properties, both load-bearing for the FTaaS story (and spelled
//! out in `docs/decisions/002-fair-share-admission.md`):
//!
//! 1. **No starvation.** [`AdmissionQueue::pop`] serves tenants
//!    round-robin in sorted-name order; a tenant that floods its own
//!    backlog only delays its own later jobs, never another tenant's
//!    next job — the fairness regression in `tests/gateway_http.rs`
//!    pins the exact interleaving.
//! 2. **Bounded memory.** Each tenant holds at most `cap` queued jobs;
//!    the gateway answers an overflowing submit with `429` instead of
//!    buffering without limit.
//!
//! The structure is deliberately deterministic (`BTreeMap`, sorted
//! iteration): given the same admission order, the service order is a
//! pure function — which is what lets the fairness test assert exact
//! start sequence numbers.

use std::collections::{BTreeMap, VecDeque};

/// FIFO per tenant, round-robin across tenants.
#[derive(Debug)]
pub struct AdmissionQueue {
    cap: usize,
    backlog: BTreeMap<String, VecDeque<u64>>,
    /// Last tenant served; the next pop starts strictly after it in
    /// sorted order, wrapping.
    cursor: Option<String>,
}

impl AdmissionQueue {
    /// `cap` = max queued jobs per tenant (>= 1).
    pub fn new(cap: usize) -> AdmissionQueue {
        AdmissionQueue { cap: cap.max(1), backlog: BTreeMap::new(), cursor: None }
    }

    /// Enqueue a job. `Ok(depth)` = queued at that backlog depth;
    /// `Err(cap)` = the tenant's backlog is full (caller answers 429).
    pub fn push(&mut self, tenant: &str, job: u64) -> Result<usize, usize> {
        let q = self.backlog.entry(tenant.to_string()).or_default();
        if q.len() >= self.cap {
            return Err(self.cap);
        }
        q.push_back(job);
        Ok(q.len())
    }

    /// Dequeue the next job round-robin: the first tenant in sorted
    /// order strictly after the last-served one (wrapping) that has
    /// work, FIFO within the tenant.
    pub fn pop(&mut self) -> Option<(String, u64)> {
        let live: Vec<String> = self
            .backlog
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .map(|(k, _)| k.clone())
            .collect();
        let first = live.first()?.clone();
        let pick = match &self.cursor {
            Some(c) => live.iter().find(|k| k.as_str() > c.as_str())
                .cloned()
                .unwrap_or(first),
            None => first,
        };
        let job = {
            let q = self.backlog.get_mut(&pick)?;
            q.pop_front()?
        };
        if self.backlog.get(&pick).is_some_and(VecDeque::is_empty) {
            self.backlog.remove(&pick);
        }
        self.cursor = Some(pick.clone());
        Some((pick, job))
    }

    /// Total queued jobs across tenants.
    pub fn len(&self) -> usize {
        self.backlog.values().map(VecDeque::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current backlog depth for one tenant.
    pub fn depth(&self, tenant: &str) -> usize {
        self.backlog.get(tenant).map_or(0, VecDeque::len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_interleaves_tenants() {
        let mut q = AdmissionQueue::new(8);
        for j in [1, 2, 3] {
            q.push("alice", j).unwrap();
        }
        q.push("bob", 10).unwrap();
        q.push("carol", 20).unwrap();
        let order: Vec<(String, u64)> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(
            order,
            vec![
                ("alice".to_string(), 1),
                ("bob".to_string(), 10),
                ("carol".to_string(), 20),
                ("alice".to_string(), 2),
                ("alice".to_string(), 3),
            ]
        );
    }

    #[test]
    fn flooding_tenant_cannot_starve_another() {
        let mut q = AdmissionQueue::new(64);
        for j in 0..50 {
            q.push("flooder", j).unwrap();
        }
        q.push("starved", 99).unwrap();
        // the starved tenant's job is served 2nd, not 51st
        assert_eq!(q.pop(), Some(("flooder".to_string(), 0)));
        assert_eq!(q.pop(), Some(("starved".to_string(), 99)));
        assert_eq!(q.pop(), Some(("flooder".to_string(), 1)));
    }

    #[test]
    fn late_arrival_joins_the_rotation() {
        let mut q = AdmissionQueue::new(8);
        q.push("zed", 1).unwrap();
        q.push("zed", 2).unwrap();
        assert_eq!(q.pop(), Some(("zed".to_string(), 1)));
        // cursor sits at "zed"; "anna" sorts before it and must still
        // be served next via wraparound
        q.push("anna", 10).unwrap();
        assert_eq!(q.pop(), Some(("anna".to_string(), 10)));
        assert_eq!(q.pop(), Some(("zed".to_string(), 2)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn per_tenant_backlog_is_bounded() {
        let mut q = AdmissionQueue::new(2);
        assert_eq!(q.push("a", 1), Ok(1));
        assert_eq!(q.push("a", 2), Ok(2));
        assert_eq!(q.push("a", 3), Err(2));
        // another tenant is unaffected
        assert_eq!(q.push("b", 9), Ok(1));
        assert_eq!(q.depth("a"), 2);
        assert_eq!(q.len(), 3);
        // popping frees capacity
        q.pop().unwrap();
        assert_eq!(q.push("a", 3), Ok(2));
    }
}
