//! FTaaS serving gateway — `cola serve` (L3's front door).
//!
//! The paper's headline deployment is Fine-Tuning as a Service:
//! *numerous* users offload gradient learning to a shared coordinator.
//! This module is that front door: a long-running, zero-dependency
//! HTTP/1.1 service over `std::net` TCP (same hand-rolled house style
//! as [`crate::transport::wire`]; rationale in
//! `docs/decisions/001-http-over-std-net.md`) that accepts fine-tuning
//! jobs, streams their progress, and serves the trained adapters back.
//!
//! # Endpoints
//!
//! | endpoint | auth | semantics |
//! |---|---|---|
//! | `GET /healthz` | none | liveness + ledger drop counter |
//! | `POST /v1/fit` | Bearer | submit a `[train]` TOML config; `202 {"job":id}`, `400` invalid config, `429` backlog full |
//! | `POST /v1/shutdown` | Bearer | clean shutdown after the current job |
//! | `GET /v1/jobs/{id}` | Bearer | status JSON (`queued`/`running`/`done`/`failed`) |
//! | `GET /v1/jobs/{id}/progress` | Bearer | chunked JSONL stream, one line per adaptation interval |
//! | `GET /v1/jobs/{id}/curves` | Bearer | the run's loss curves — byte-identical to `cola train --loss_out` |
//! | `GET /v1/jobs/{id}/adapter` | Bearer | deterministic adapter bundle ([`crate::coordinator::Trainer::export_adapter_bundle`]) |
//!
//! Jobs are tenant-scoped: tokens map to tenants
//! ([`auth::TokenTable`]), another tenant's job id answers `404`, and
//! admission is fair-share round-robin with a bounded per-tenant
//! backlog ([`queue::AdmissionQueue`]). Jobs execute **sequentially**
//! on one runner thread: a [`crate::coordinator::Trainer`] pins
//! process-global engine state (thread pool width, SIMD policy) at
//! construction, so serial execution is what keeps every gateway job
//! byte-identical to the same config run via `cola train` — the
//! determinism contract `tests/gateway_http.rs` and the
//! `gateway-smoke` CI job enforce.
//!
//! # Worked example
//!
//! Write a token file (`tenant:token` per line), bind, and serve:
//!
//! ```no_run
//! use cola::gateway::{Gateway, ServeConfig};
//!
//! fn main() -> anyhow::Result<()> {
//!     std::fs::write("tokens.txt", "alice:s3cr3t\n")?;
//!     let mut cfg = ServeConfig::default();
//!     cfg.listen = "127.0.0.1:0".to_string(); // port 0 = ephemeral
//!     cfg.token_file = "tokens.txt".to_string();
//!     cfg.ledger = "usage.jsonl".to_string();
//!     let gateway = Gateway::bind(&cfg)?;
//!     println!("cola gateway listening on {}", gateway.local_addr());
//!     gateway.join(); // blocks until POST /v1/shutdown
//!     Ok(())
//! }
//! ```
//!
//! then drive it with the stdlib-only client (`cola http`):
//!
//! ```text
//! cola http post http://$ADDR/v1/fit --token s3cr3t --body job.toml
//! cola http get  http://$ADDR/v1/jobs/1/progress --token s3cr3t
//! cola http get  http://$ADDR/v1/jobs/1/adapter  --token s3cr3t --out a.bin
//! ```

pub mod auth;
pub mod client;
pub mod http;
pub mod jobs;
pub mod ledger;
pub mod queue;

use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::config::{Method, TomlDoc, TrainConfig};
use crate::coordinator::{Progress, Trainer};
use crate::util::json::Json;
use crate::util::{lock_recover, panic_message, wait_timeout_recover};

use auth::TokenTable;
use http::{HttpError, Request};
use jobs::{Fetch, JobRegistry};
use ledger::{now_unix_ms, UsageEntry, UsageLedger};
use queue::AdmissionQueue;

/// Condvar/stream poll period: how quickly idle threads notice stop.
const TICK: Duration = Duration::from_millis(50);

/// `[serve]` section of a config file + CLI overrides.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Listen address; port 0 binds an ephemeral port (scraped from
    /// the "cola gateway listening on ..." stdout line, same contract
    /// as the worker daemon).
    pub listen: String,
    /// Path to the `tenant:token` file ([`auth::TokenTable`]); required.
    pub token_file: String,
    /// Max queued jobs per tenant before `429` (>= 1).
    pub backlog: usize,
    /// Usage-ledger JSONL path; empty disables the ledger.
    pub ledger: String,
    /// Test-only: start with the job runner paused so tests can stage
    /// a deterministic admission order, then [`Gateway::resume`]. Not
    /// reachable from config keys or CLI flags.
    pub start_paused: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            listen: "127.0.0.1:7780".to_string(),
            token_file: String::new(),
            backlog: 8,
            ledger: String::new(),
            start_paused: false,
        }
    }
}

impl ServeConfig {
    /// Set one key (`listen`, `token_file`, `backlog`, `ledger`) from
    /// its string form. Unknown keys are hard errors — same loud-typo
    /// contract as [`TrainConfig::set`].
    pub fn set(&mut self, key: &str, val: &str) -> Result<()> {
        match key {
            "listen" => self.listen = val.to_string(),
            "token_file" => self.token_file = val.to_string(),
            "backlog" => {
                self.backlog = val
                    .parse()
                    .with_context(|| format!("backlog must be an integer, got {val:?}"))?
            }
            "ledger" => self.ledger = val.to_string(),
            other => bail!("unknown [serve] key {other:?} \
                            (listen|token_file|backlog|ledger)"),
        }
        Ok(())
    }

    /// Apply the `serve.*` keys of a parsed config file over `self`.
    /// Other sections (e.g. `[train]`) are ignored so one file can
    /// describe both a gateway and the jobs submitted to it.
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        for (k, v) in doc.flat() {
            if let Some(key) = k.strip_prefix("serve.") {
                self.set(key, &v).with_context(|| format!("config key {k}"))?;
            }
        }
        Ok(())
    }

    /// Cross-field checks, applied by [`Gateway::bind`].
    pub fn validate(&self) -> Result<()> {
        if self.token_file.is_empty() {
            bail!("serve.token_file is required — the gateway refuses to run \
                   unauthenticated");
        }
        if self.backlog == 0 {
            bail!("serve.backlog must be >= 1");
        }
        Ok(())
    }
}

/// State shared by the accept loop, connection threads, and the runner.
struct Shared {
    auth: TokenTable,
    jobs: JobRegistry,
    queue: Mutex<AdmissionQueue>,
    queue_cv: Condvar,
    /// Behind a mutex so [`Gateway::join`] can TAKE and close it after
    /// the runner exits. `Shared` itself sits in an `Arc` whose last
    /// clone may be held by a lingering connection thread (the one
    /// serving `POST /v1/shutdown`, typically) — relying on `Drop` to
    /// drain the writer meant process exit could race it and lose the
    /// final interval's buffered rows.
    ledger: Mutex<Option<UsageLedger>>,
    /// Counter handle that outlives the ledger, so `/healthz` keeps
    /// reporting `ledger_dropped` (try_send-Full drops only) even
    /// after shutdown closed the ledger. `None` = no ledger configured.
    ledger_drops: Option<std::sync::Arc<std::sync::atomic::AtomicU64>>,
    stop: AtomicBool,
    paused: AtomicBool,
    /// Resolved listen address (for the shutdown self-connect wake).
    addr: String,
}

/// The running gateway: accept loop + sequential job runner.
pub struct Gateway {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    runner: Option<JoinHandle<()>>,
}

impl Gateway {
    /// Validate config, load tokens, bind the listener, and start the
    /// accept + runner threads.
    pub fn bind(cfg: &ServeConfig) -> Result<Gateway> {
        cfg.validate()?;
        let auth = TokenTable::load(&cfg.token_file)?;
        if auth.is_empty() {
            bail!("token file {} has no tenant:token entries", cfg.token_file);
        }
        let ledger = if cfg.ledger.is_empty() {
            None
        } else {
            Some(UsageLedger::open(&cfg.ledger)?)
        };
        let ledger_drops = ledger.as_ref().map(UsageLedger::drop_counter);
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("binding gateway listener on {}", cfg.listen))?;
        let addr = listener.local_addr()?.to_string();
        let shared = Arc::new(Shared {
            auth,
            jobs: JobRegistry::new(),
            queue: Mutex::new(AdmissionQueue::new(cfg.backlog)),
            queue_cv: Condvar::new(),
            ledger: Mutex::new(ledger),
            ledger_drops,
            stop: AtomicBool::new(false),
            paused: AtomicBool::new(cfg.start_paused),
            addr,
        });
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("cola-gw-accept".into())
                .spawn(move || accept_main(&shared, listener))
                .context("spawning the gateway accept thread")?
        };
        let runner = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("cola-gw-runner".into())
                .spawn(move || runner_main(&shared))
                .context("spawning the gateway job runner")?
        };
        Ok(Gateway { shared, accept: Some(accept), runner: Some(runner) })
    }

    /// Resolved listen address (`host:port`, port concrete).
    pub fn local_addr(&self) -> &str {
        &self.shared.addr
    }

    /// Un-pause a gateway built with [`ServeConfig::start_paused`].
    pub fn resume(&self) {
        self.shared.paused.store(false, Ordering::SeqCst);
        self.queue_notify();
    }

    fn queue_notify(&self) {
        // grab-and-drop the lock so a runner between check and wait
        // can't miss the notification
        drop(lock_recover(&self.shared.queue));
        self.shared.queue_cv.notify_all();
    }

    /// Ask the gateway to stop (same effect as `POST /v1/shutdown`).
    pub fn request_stop(&self) {
        stop_shared(&self.shared);
    }

    /// Block until the accept loop and runner exit (i.e. until someone
    /// calls [`Gateway::request_stop`] or `POST /v1/shutdown` arrives),
    /// then flush the ledger: the writer channel is closed, drained,
    /// and joined HERE — not left to the `Arc<Shared>` drop, which a
    /// lingering connection thread (the `/v1/shutdown` one included)
    /// could keep alive past process exit, losing the final interval's
    /// buffered rows.
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.runner.take() {
            let _ = h.join();
        }
        // runner is down, so every job's records are enqueued; drain
        // them to disk before reporting "exited"
        if let Some(mut ledger) = lock_recover(&self.shared.ledger).take() {
            ledger.close();
        }
    }

    /// Ledger entries dropped so far (0 when no ledger is configured).
    /// Counts try_send-Full drops only — shutdown races never inflate
    /// it — and stays readable after `join` closed the ledger.
    pub fn ledger_dropped(&self) -> u64 {
        self.shared
            .ledger_drops
            .as_ref()
            .map_or(0, |c| c.load(Ordering::Relaxed))
    }
}

fn stop_shared(shared: &Shared) {
    shared.stop.store(true, Ordering::SeqCst);
    drop(lock_recover(&shared.queue));
    shared.queue_cv.notify_all();
    // wake the blocking accept() the way the worker daemon does
    let _ = TcpStream::connect(&shared.addr);
}

// ----------------------------------------------------------------------
// accept loop + connection handling
// ----------------------------------------------------------------------

fn accept_main(shared: &Arc<Shared>, listener: TcpListener) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                let shared = Arc::clone(shared);
                let _ = std::thread::Builder::new()
                    .name("cola-gw-conn".into())
                    .spawn(move || serve_conn(&shared, stream));
            }
            Err(_) => {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                std::thread::sleep(TICK);
            }
        }
    }
}

fn serve_conn(shared: &Arc<Shared>, stream: TcpStream) {
    // a stalled or malicious peer must not pin the thread forever
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    match http::read_request(&mut reader) {
        Ok(Some(req)) => route(shared, &mut writer, &req),
        Ok(None) => {} // peer connected and left (e.g. the stop wake)
        Err(e) => {
            let _ = http::respond_error(&mut writer, &e);
        }
    }
}

/// Serialize an f64 the way curve files do: numeric when finite, a
/// string otherwise (JSON has no NaN/inf tokens).
fn json_f64(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Str(v.to_string())
    }
}

fn json_body(w: &mut TcpStream, status: u16, obj: BTreeMap<String, Json>) {
    let body = format!("{}\n", Json::Obj(obj));
    let _ = http::respond(w, status, "application/json", &[], body.as_bytes());
}

fn route(shared: &Arc<Shared>, w: &mut TcpStream, req: &Request) {
    // none of the endpoints take query parameters; strip them so a
    // `?x=y` suffix can't dodge the route match
    let path = req.path.split('?').next().unwrap_or("");
    let segs: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();

    if req.method == "GET" && segs == ["healthz"] {
        let mut obj = BTreeMap::new();
        obj.insert("ok".to_string(), Json::Bool(true));
        obj.insert(
            "ledger_dropped".to_string(),
            Json::Num(
                shared
                    .ledger_drops
                    .as_ref()
                    .map_or(0, |c| c.load(Ordering::Relaxed)) as f64,
            ),
        );
        json_body(w, 200, obj);
        return;
    }

    let Some(tenant) = shared.auth.tenant_for(req.header("authorization")) else {
        let _ = http::respond_error(
            w,
            &HttpError::new(401, "missing or invalid bearer token"),
        );
        return;
    };
    let tenant = tenant.to_string();

    match (req.method.as_str(), segs.as_slice()) {
        ("POST", ["v1", "fit"]) => handle_fit(shared, w, &tenant, &req.body),
        ("POST", ["v1", "shutdown"]) => {
            let mut obj = BTreeMap::new();
            obj.insert("stopping".to_string(), Json::Bool(true));
            json_body(w, 200, obj);
            stop_shared(shared);
        }
        ("GET", ["v1", "jobs", id]) => match id.parse::<u64>() {
            Ok(id) => handle_status(shared, w, &tenant, id),
            Err(_) => not_found(w),
        },
        ("GET", ["v1", "jobs", id, sub @ ("progress" | "curves" | "adapter")]) => {
            match id.parse::<u64>() {
                Ok(id) => match *sub {
                    "progress" => handle_progress(shared, w, &tenant, id),
                    "curves" => handle_curves(shared, w, &tenant, id),
                    _ => handle_adapter(shared, w, &tenant, id),
                },
                Err(_) => not_found(w),
            }
        }
        (_, ["healthz"]) | (_, ["v1", "fit"]) | (_, ["v1", "shutdown"]) => {
            let _ = http::respond_error(
                w,
                &HttpError::new(405, format!("method {} not allowed here", req.method)),
            );
        }
        _ => not_found(w),
    }
}

fn not_found(w: &mut TcpStream) {
    let _ = http::respond_error(&mut *w, &HttpError::new(404, "no such resource"));
}

/// Parse + validate a job's `[train]` config TOML, exactly the way
/// `cola train --config` does (same key namespace, same defaults), so
/// gateway-submitted configs mean the same thing as CLI ones.
fn parse_train_config(src: &str) -> Result<TrainConfig> {
    let doc = TomlDoc::parse(src)?;
    let cfg = TrainConfig::from_toml(&doc)?;
    cfg.validate()?;
    Ok(cfg)
}

fn handle_fit(shared: &Shared, w: &mut TcpStream, tenant: &str, body: &[u8]) {
    let Ok(src) = std::str::from_utf8(body) else {
        let _ = http::respond_error(
            w,
            &HttpError::new(400, "config body must be UTF-8 TOML"),
        );
        return;
    };
    if let Err(e) = parse_train_config(src) {
        let _ = http::respond_error(
            w,
            &HttpError::new(400, format!("invalid config: {e:#}")),
        );
        return;
    }
    let id = shared.jobs.create(tenant, src.to_string());
    let pushed = lock_recover(&shared.queue).push(tenant, id);
    match pushed {
        Ok(depth) => {
            shared.queue_cv.notify_all();
            let mut obj = BTreeMap::new();
            obj.insert("job".to_string(), Json::Num(id as f64));
            obj.insert("backlog".to_string(), Json::Num(depth as f64));
            json_body(w, 202, obj);
        }
        Err(cap) => {
            shared.jobs.remove(id);
            let _ = http::respond_error(
                w,
                &HttpError::new(
                    429,
                    format!("tenant backlog is full ({cap} queued jobs)"),
                )
                .with_retry_after(retry_after_secs(cap, shared.jobs.runtime_ema_ms())),
            );
        }
    }
}

/// Derive a 429 `Retry-After` hint (seconds) from how many jobs the
/// tenant has queued and the smoothed per-job runtime: the earliest a
/// retry can possibly be admitted is once one backlog slot drains.
/// Deterministic given registry state: before any job has completed,
/// the estimate is a flat 1 s/job, so the value equals the backlog cap.
/// Clamped to [1, 60] — an advisory hint, not a reservation.
fn retry_after_secs(backlog: usize, runtime_ema_ms: Option<u64>) -> u64 {
    let est_ms = runtime_ema_ms.unwrap_or(1000).max(1);
    (backlog as u64).saturating_mul(est_ms).div_ceil(1000).clamp(1, 60)
}

fn handle_status(shared: &Shared, w: &mut TcpStream, tenant: &str, id: u64) {
    let Some(s) = shared.jobs.snapshot(tenant, id) else {
        not_found(w);
        return;
    };
    let mut obj = BTreeMap::new();
    obj.insert("job".to_string(), Json::Num(s.id as f64));
    obj.insert("state".to_string(), Json::Str(s.state.as_str().to_string()));
    obj.insert(
        "progress_lines".to_string(),
        Json::Num(s.progress_lines as f64),
    );
    if let Some(seq) = s.started_seq {
        obj.insert("started_seq".to_string(), Json::Num(seq as f64));
    }
    if let Some(e) = s.error {
        obj.insert("error".to_string(), Json::Str(e));
    }
    json_body(w, 200, obj);
}

fn handle_progress(shared: &Shared, w: &mut TcpStream, tenant: &str, id: u64) {
    let Some(snap) = shared.jobs.snapshot(tenant, id) else {
        not_found(w);
        return;
    };
    if http::start_chunked(w, 200, "application/x-ndjson").is_err() {
        return;
    }
    let mut from = 0usize;
    let done = loop {
        let Some((lines, done)) = shared.jobs.wait_progress(tenant, id, from, TICK)
        else {
            break false; // record vanished mid-stream
        };
        from += lines.len();
        for line in lines {
            if http::write_chunk(w, format!("{line}\n").as_bytes()).is_err() {
                return; // client went away
            }
        }
        if done {
            break true;
        }
        if shared.stop.load(Ordering::SeqCst) {
            break false;
        }
    };
    if done {
        // terminal summary line so stream consumers need no second call
        let mut obj = BTreeMap::new();
        obj.insert("done".to_string(), Json::Bool(true));
        let state = shared
            .jobs
            .snapshot(tenant, id)
            .map_or(snap.state, |s| s.state);
        obj.insert("state".to_string(), Json::Str(state.as_str().to_string()));
        let _ = http::write_chunk(w, format!("{}\n", Json::Obj(obj)).as_bytes());
    }
    let _ = http::finish_chunked(w);
}

fn handle_curves(shared: &Shared, w: &mut TcpStream, tenant: &str, id: u64) {
    match shared.jobs.curves(tenant, id) {
        Fetch::NotFound => not_found(w),
        Fetch::NotReady => {
            let _ = http::respond_error(
                w,
                &HttpError::new(409, "job has not finished yet"),
            );
        }
        Fetch::Failed(e) => {
            let _ = http::respond_error(
                w,
                &HttpError::new(409, format!("job failed: {e}")),
            );
        }
        Fetch::Missing => {
            let _ = http::respond_error(
                w,
                &HttpError::new(409, "job produced no curves"),
            );
        }
        Fetch::Ready(curves) => {
            let _ = http::respond(w, 200, "application/json", &[], curves.as_bytes());
        }
    }
}

fn handle_adapter(shared: &Shared, w: &mut TcpStream, tenant: &str, id: u64) {
    match shared.jobs.adapter(tenant, id) {
        Fetch::NotFound => not_found(w),
        Fetch::NotReady => {
            let _ = http::respond_error(
                w,
                &HttpError::new(409, "job has not finished yet"),
            );
        }
        Fetch::Failed(e) => {
            let _ = http::respond_error(
                w,
                &HttpError::new(409, format!("job failed: {e}")),
            );
        }
        Fetch::Missing => {
            let _ = http::respond_error(
                w,
                &HttpError::new(
                    409,
                    "job has no exportable adapter (coupled baseline — its \
                     tunables live on the server)",
                ),
            );
        }
        Fetch::Ready(bundle) => {
            let _ = http::respond(w, 200, "application/octet-stream", &[], &bundle);
        }
    }
}

// ----------------------------------------------------------------------
// the job runner
// ----------------------------------------------------------------------

fn runner_main(shared: &Arc<Shared>) {
    loop {
        let next = {
            let mut q = lock_recover(&shared.queue);
            loop {
                if shared.stop.load(Ordering::SeqCst) {
                    break None;
                }
                if !shared.paused.load(Ordering::SeqCst) {
                    if let Some(x) = q.pop() {
                        break Some(x);
                    }
                }
                q = wait_timeout_recover(&shared.queue_cv, q, TICK);
            }
        };
        let Some((tenant, id)) = next else {
            return;
        };
        run_job(shared, &tenant, id);
        // the job reached a terminal state either way — release its
        // admission slot so the tenant's cap counts only live work
        lock_recover(&shared.queue).finish(&tenant);
    }
}

/// Run one job to a terminal state. Panics unwind into a `Failed`
/// record instead of killing the runner — one poisoned config must not
/// wedge every later tenant.
fn run_job(shared: &Shared, tenant: &str, id: u64) {
    let Some(src) = shared.jobs.config(id) else {
        shared.jobs.fail(id, "job record vanished before it ran".to_string());
        return;
    };
    shared.jobs.mark_running(id);
    match catch_unwind(AssertUnwindSafe(|| execute_job(shared, tenant, id, &src))) {
        Ok(Ok(())) => {}
        Ok(Err(e)) => shared.jobs.fail(id, format!("{e:#}")),
        Err(payload) => shared.jobs.fail(
            id,
            format!("job panicked: {}", panic_message(payload.as_ref())),
        ),
    }
}

fn execute_job(shared: &Shared, tenant: &str, id: u64, src: &str) -> Result<()> {
    let cfg = parse_train_config(src)?;
    let users = cfg.users.max(1);
    let is_cola = matches!(cfg.method, Method::Cola(_));
    let mut trainer = Trainer::new(cfg).context("building trainer")?;
    let mut interval_no = 0u64;
    let mut last_off = 0u64;
    let mut last_ret = 0u64;
    let report = trainer.run_with_progress(|p| {
        if !p.interval_boundary {
            return Ok(());
        }
        interval_no += 1;
        shared.jobs.push_progress(id, progress_line(p, interval_no));
        if let Some(ledger) = lock_recover(&shared.ledger).as_ref() {
            // per-interval deltas, attributed evenly per user (the
            // joint batch divides evenly across users by construction)
            let d_off = p.bytes_offloaded.saturating_sub(last_off);
            let d_ret = p.bytes_returned.saturating_sub(last_ret);
            last_off = p.bytes_offloaded;
            last_ret = p.bytes_returned;
            for user in 0..users {
                ledger.record(&UsageEntry {
                    tenant: tenant.to_string(),
                    job: id,
                    user,
                    interval: interval_no,
                    step: p.step,
                    bytes_offloaded: d_off / users as u64,
                    bytes_returned: d_ret / users as u64,
                    unix_ms: now_unix_ms(),
                });
            }
        }
        Ok(())
    })?;
    let curves = report.curves_json();
    let adapter = if is_cola {
        Some(trainer.export_adapter_bundle()?)
    } else {
        None
    };
    shared.jobs.finish(id, curves, adapter);
    Ok(())
}

/// One progress-stream line per adaptation interval.
fn progress_line(p: &Progress, interval: u64) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("step".to_string(), Json::Num(p.step as f64));
    obj.insert("interval".to_string(), Json::Num(interval as f64));
    obj.insert("train_loss".to_string(), json_f64(p.train_loss as f64));
    if let Some(a) = p.train_acc {
        obj.insert("train_acc".to_string(), json_f64(a as f64));
    }
    if let Some(e) = p.eval_loss {
        obj.insert("eval_loss".to_string(), json_f64(e));
    }
    if let Some(a) = p.eval_acc {
        obj.insert("eval_acc".to_string(), json_f64(a));
    }
    obj.insert(
        "bytes_offloaded".to_string(),
        Json::Num(p.bytes_offloaded as f64),
    );
    obj.insert(
        "bytes_returned".to_string(),
        Json::Num(p.bytes_returned as f64),
    );
    Json::Obj(obj).to_string()
}

#[cfg(test)]
mod tests {
    use super::retry_after_secs;

    #[test]
    fn retry_after_scales_with_backlog_and_runtime() {
        // no completed job yet: 1 s/job default, value = backlog depth
        assert_eq!(retry_after_secs(4, None), 4);
        // fast jobs round up to whole seconds, floored at 1
        assert_eq!(retry_after_secs(4, Some(100)), 1);
        assert_eq!(retry_after_secs(8, Some(300)), 3);
        // slow jobs: depth x runtime, capped at the 60 s ceiling
        assert_eq!(retry_after_secs(8, Some(2000)), 16);
        assert_eq!(retry_after_secs(64, Some(30_000)), 60);
        // degenerate inputs stay in-range
        assert_eq!(retry_after_secs(0, Some(5000)), 1);
        assert_eq!(retry_after_secs(usize::MAX, Some(u64::MAX)), 60);
    }
}
