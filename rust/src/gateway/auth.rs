//! Per-tenant API tokens for the gateway.
//!
//! The token file is trivially auditable: one `tenant:token` pair per
//! line, `#` comments and blank lines ignored (the format and its
//! rationale live in `docs/decisions/004-per-tenant-api-tokens.md`).
//! Lookup walks the WHOLE table and compares every candidate with
//! [`constant_time_eq`], so neither the match position nor the first
//! differing byte leaks through response timing.

use anyhow::{bail, Context, Result};

/// The parsed `tenant:token` table.
#[derive(Debug)]
pub struct TokenTable {
    /// (tenant, token) pairs in file order.
    entries: Vec<(String, String)>,
}

impl TokenTable {
    /// Parse token-file text. Duplicate tenants, empty names, empty
    /// tokens, and `:` in a tenant name are all hard errors — a typo in
    /// an auth file must fail loudly at startup, not at request time.
    pub fn parse(src: &str) -> Result<TokenTable> {
        let mut entries: Vec<(String, String)> = Vec::new();
        for (i, raw) in src.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((tenant, token)) = line.split_once(':') else {
                bail!("token file line {}: expected tenant:token, got {line:?}",
                      i + 1);
            };
            let (tenant, token) = (tenant.trim(), token.trim());
            if tenant.is_empty() || token.is_empty() {
                bail!("token file line {}: empty tenant or token", i + 1);
            }
            if entries.iter().any(|(t, _)| t == tenant) {
                bail!("token file line {}: duplicate tenant {tenant:?}", i + 1);
            }
            entries.push((tenant.to_string(), token.to_string()));
        }
        Ok(TokenTable { entries })
    }

    /// Load and parse a token file.
    pub fn load(path: &str) -> Result<TokenTable> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading token file {path}"))?;
        Self::parse(&src).with_context(|| format!("parsing token file {path}"))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Resolve an `Authorization` header to a tenant name, or `None`
    /// (missing header, wrong scheme, unknown token). Always scans the
    /// full table — no early exit on match.
    pub fn tenant_for(&self, authorization: Option<&str>) -> Option<&str> {
        let token = authorization?.strip_prefix("Bearer ")?.trim();
        let mut found: Option<&str> = None;
        for (tenant, secret) in &self.entries {
            let hit = constant_time_eq(secret.as_bytes(), token.as_bytes());
            if hit && found.is_none() {
                found = Some(tenant);
            }
        }
        found
    }
}

/// Compare two byte strings without data-dependent early exit: the
/// loop always runs `max(len_a, len_b)` iterations and folds every
/// byte XOR (plus the length difference) into one accumulator. A
/// mismatched length or byte therefore costs the same time as a match.
pub fn constant_time_eq(a: &[u8], b: &[u8]) -> bool {
    let mut diff = a.len() ^ b.len();
    for i in 0..a.len().max(b.len()) {
        let x = a.get(i).copied().unwrap_or(0);
        let y = b.get(i).copied().unwrap_or(0);
        diff |= (x ^ y) as usize;
    }
    diff == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_and_resolves() {
        let t = TokenTable::parse(
            "# comment\n\nalice:tok-a\nbob: tok-b \n",
        )
        .unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t.tenant_for(Some("Bearer tok-a")), Some("alice"));
        assert_eq!(t.tenant_for(Some("Bearer tok-b")), Some("bob"));
        assert_eq!(t.tenant_for(Some("Bearer nope")), None);
        assert_eq!(t.tenant_for(Some("Basic tok-a")), None);
        assert_eq!(t.tenant_for(None), None);
    }

    #[test]
    fn rejects_malformed_files() {
        assert!(TokenTable::parse("no-colon-here\n").is_err());
        assert!(TokenTable::parse("alice:\n").is_err());
        assert!(TokenTable::parse(":tok\n").is_err());
        assert!(TokenTable::parse("alice:a\nalice:b\n").is_err());
    }

    #[test]
    fn constant_time_eq_semantics() {
        assert!(constant_time_eq(b"secret", b"secret"));
        assert!(!constant_time_eq(b"secret", b"secreT"));
        assert!(!constant_time_eq(b"secret", b"secre"));
        assert!(!constant_time_eq(b"", b"x"));
        assert!(constant_time_eq(b"", b""));
    }
}
