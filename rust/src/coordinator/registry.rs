//! Worker registry — the self-assembling fleet (ROADMAP open item #1).
//!
//! `worker_addrs` used to be the source of truth for pool membership:
//! operators hand-wired every daemon address into the config, and a
//! fleet could only change shape by restarting the trainer. This module
//! inverts that: daemons announce themselves (`cola worker --join
//! <coordinator>`), the coordinator tracks them through an explicit
//! member lifecycle, and `worker_addrs` degrades to a static bootstrap
//! fallback (its members are registered as already-active, which is
//! also how pre-registry v1/v2 daemons interop).
//!
//! # Lifecycle
//!
//! ```text
//!             Join frame             admitted at a
//!             arrives                sweep boundary
//!   (absent) ──────────► joining ─────────────────► active
//!                           ▲                        │   │
//!                      re-join OK              drain │   │ missed
//!                           │                        ▼   │ heartbeat
//!                         dead ◄──────────────── draining│
//!                           ▲    (or dropped            ▼
//!                           └──── when empty)          dead
//! ```
//!
//! - **joining** — announced but not yet admitted. Receives no
//!   placements; the supervisor admits joiners only at heartbeat-sweep
//!   boundaries, the same deterministic points where failures are
//!   detected, so membership changes never land mid-interval.
//! - **active** — a full member: owns shards, receives new users.
//! - **draining** — scheduled for removal: receives no *new* users but
//!   finishes (and then migrates away) the shards it owns.
//! - **dead** — failed a heartbeat (or was killed). Its shards were
//!   re-homed by `fail_over`; the address may re-join later.
//!
//! The registry itself is pure bookkeeping — [`WorkerRegistry`] never
//! touches the network. The network half is [`RegistryServer`] (the
//! coordinator-side listener that turns wire-v3 [`Msg::Join`] frames
//! into `joining` entries) and [`join_coordinator`] (the daemon-side
//! announce call). Capability negotiation is NOT duplicated here: after
//! admission the coordinator dials the daemon back through the normal
//! [`TcpWorker`](crate::transport::tcp::TcpWorker) connect path, whose
//! `Hello` handshake carries tenant + wire-format capabilities exactly
//! as it does for static members.

use std::collections::{BTreeMap, BTreeSet};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::transport::tcp::{connect_with_backoff, BASE_BACKOFF, CONNECT_ATTEMPTS};
use crate::transport::wire::{self, Msg};

/// Where a member sits in the `joining → active → draining → dead`
/// lifecycle (see the module diagram).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum MemberState {
    Joining,
    Active,
    Draining,
    Dead,
}

impl std::fmt::Display for MemberState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            MemberState::Joining => "joining",
            MemberState::Active => "active",
            MemberState::Draining => "draining",
            MemberState::Dead => "dead",
        })
    }
}

#[derive(Clone, Debug)]
struct Member {
    state: MemberState,
    /// came from `worker_addrs` (the static bootstrap fallback) rather
    /// than a `Join` announce — how v1/v2 daemons without the registry
    /// capability participate
    is_static: bool,
}

/// Coordinator-side membership book: daemon address → lifecycle state.
/// Keyed by address (`BTreeMap` for deterministic iteration — placement
/// decisions derive from registry scans). Shared between the trainer
/// thread and the [`RegistryServer`] accept loop behind a mutex; all
/// lock traffic goes through [`crate::util::lock_recover`].
#[derive(Default)]
pub struct WorkerRegistry {
    members: BTreeMap<String, Member>,
}

impl WorkerRegistry {
    pub fn new() -> WorkerRegistry {
        WorkerRegistry::default()
    }

    /// Register a `worker_addrs` bootstrap member: enters `active`
    /// directly (the trainer connects to it before training starts, so
    /// there is no join/admit window to wait out).
    pub fn register_static(&mut self, addr: &str) {
        self.members
            .insert(addr.to_string(), Member { state: MemberState::Active, is_static: true });
    }

    /// A `Join` announce arrived for `addr`. New addresses enter
    /// `joining`; a `dead` address re-enters `joining` (daemon restart
    /// on the same endpoint); announces for members already in flight
    /// (`joining`/`active`/`draining`) are idempotent no-ops so a
    /// re-sent Join frame cannot demote a live member.
    pub fn join(&mut self, addr: &str) -> MemberState {
        match self.members.get_mut(addr) {
            Some(m) if m.state == MemberState::Dead => {
                m.state = MemberState::Joining;
                m.is_static = false;
                MemberState::Joining
            }
            Some(m) => m.state,
            None => {
                self.members.insert(
                    addr.to_string(),
                    Member { state: MemberState::Joining, is_static: false },
                );
                MemberState::Joining
            }
        }
    }

    /// Promote a joiner to full membership — called by the supervisor
    /// once the member's `TcpWorker` link is up and its shards can be
    /// placed. Only `joining` members promote; anything else is left
    /// alone (a drain must not be cancelled by a stale admit).
    pub fn activate(&mut self, addr: &str) {
        if let Some(m) = self.members.get_mut(addr) {
            if m.state == MemberState::Joining {
                m.state = MemberState::Active;
            }
        }
    }

    /// Begin draining `addr`: it stops receiving new users immediately
    /// (it leaves the placement-eligible set) while its owned shards
    /// are finished and migrated away by the supervisor.
    pub fn begin_drain(&mut self, addr: &str) {
        if let Some(m) = self.members.get_mut(addr) {
            m.state = MemberState::Draining;
        }
    }

    /// A heartbeat sweep declared `addr` unreachable.
    pub fn mark_dead(&mut self, addr: &str) {
        if let Some(m) = self.members.get_mut(addr) {
            m.state = MemberState::Dead;
        }
    }

    /// Forget `addr` entirely (a completed drain). A later `Join` from
    /// the same address starts the lifecycle over.
    pub fn remove(&mut self, addr: &str) {
        self.members.remove(addr);
    }

    /// Addresses waiting in `joining`, in deterministic (sorted) order
    /// — what the supervisor admits at the next sweep boundary.
    pub fn pending_joins(&self) -> Vec<String> {
        self.members
            .iter()
            .filter(|(_, m)| m.state == MemberState::Joining)
            .map(|(a, _)| a.clone())
            .collect()
    }

    /// Addresses excluded from *new-user* placement: everything not
    /// `active`. Draining members keep serving the shards they already
    /// own — exclusion only steers where new users land.
    pub fn non_placeable_addrs(&self) -> BTreeSet<String> {
        self.members
            .iter()
            .filter(|(_, m)| m.state != MemberState::Active)
            .map(|(a, _)| a.clone())
            .collect()
    }

    pub fn state(&self, addr: &str) -> Option<MemberState> {
        self.members.get(addr).map(|m| m.state)
    }

    /// Whether `addr` is a static (`worker_addrs`) bootstrap member.
    pub fn is_static(&self, addr: &str) -> bool {
        self.members.get(addr).map_or(false, |m| m.is_static)
    }

    /// (address, state, is_static) rows for status output, sorted.
    pub fn snapshot(&self) -> Vec<(String, MemberState, bool)> {
        self.members
            .iter()
            .map(|(a, m)| (a.clone(), m.state, m.is_static))
            .collect()
    }
}

/// How long the registry listener waits on a connection before giving
/// up on it — announces are a single tiny frame, so anything slower is
/// a stuck peer that must not pin an accept-loop thread.
const REGISTRY_READ_TIMEOUT: Duration = Duration::from_secs(5);

/// The coordinator-side announce listener: accepts connections from
/// `cola worker --join` daemons and records them in the shared
/// [`WorkerRegistry`] as `joining`. Admission (dialing the daemon back,
/// placing users on it) happens on the trainer thread at sweep
/// boundaries — the listener only books the announce, so a burst of
/// joins can never race the training loop's placement decisions.
pub struct RegistryServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl RegistryServer {
    /// Bind `listen` (e.g. `127.0.0.1:0` for an ephemeral port) and
    /// start accepting announces into `registry`.
    pub fn bind(listen: &str, registry: Arc<Mutex<WorkerRegistry>>) -> Result<RegistryServer> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("worker registry: binding {listen}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("cola-registry".into())
            .spawn(move || registry_main(listener, registry, stop2))?;
        Ok(RegistryServer { addr, stop, handle: Some(handle) })
    }

    /// The actually-bound address (resolves `:0` to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting announces and join the listener thread.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the accept loop the same way WorkerDaemon::kill does
        let _ = TcpStream::connect(wake_addr(self.addr));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RegistryServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// The loopback address that reaches our own listener — used to wake a
/// blocking `accept()` after the stop flag is set.
fn wake_addr(addr: SocketAddr) -> SocketAddr {
    if addr.ip().is_unspecified() {
        let ip = if addr.is_ipv4() {
            std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
        } else {
            std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
        };
        SocketAddr::new(ip, addr.port())
    } else {
        addr
    }
}

fn registry_main(
    listener: TcpListener,
    registry: Arc<Mutex<WorkerRegistry>>,
    stop: Arc<AtomicBool>,
) {
    loop {
        let (stream, peer) = match listener.accept() {
            Ok(x) => x,
            Err(e) => {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                eprintln!("cola registry: accept failed: {e}");
                // fd exhaustion etc. must not become a busy spin
                std::thread::sleep(Duration::from_millis(200));
                continue;
            }
        };
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let reg = registry.clone();
        // one short-lived thread per announce: a stuck peer times out on
        // its own connection instead of blocking the accept loop
        let spawned = std::thread::Builder::new()
            .name("cola-registry-conn".into())
            .spawn(move || {
                if let Err(e) = serve_announce(stream, &reg) {
                    eprintln!("cola registry: announce from {peer} failed: {e:#}");
                }
            });
        if let Err(e) = spawned {
            eprintln!("cola registry: spawning announce thread failed: {e}");
        }
    }
}

/// Serve one announce connection: `Join` frames register the sender,
/// `Hello` is acked (a capability-probing joiner may lead with it),
/// `Ping` answers with a zero-load `Pong` so fleet tooling can probe
/// the listener, and anything else is rejected loudly.
fn serve_announce(mut stream: TcpStream, registry: &Arc<Mutex<WorkerRegistry>>) -> Result<()> {
    stream.set_read_timeout(Some(REGISTRY_READ_TIMEOUT))?;
    let _ = stream.set_nodelay(true);
    loop {
        let msg = match wire::recv(&mut stream) {
            Ok(m) => m,
            // announce done; peer went away
            Err(_) => return Ok(()),
        };
        match msg {
            Msg::Join { addr } => {
                if addr.is_empty() {
                    wire::send(
                        &mut stream,
                        &Msg::Error("join announce carried an empty address".into()),
                    )?;
                    continue;
                }
                let state = crate::util::lock_recover(registry).join(&addr);
                println!("cola: worker {addr} announced itself (now {state})");
                wire::send(&mut stream, &Msg::Ack)?;
            }
            Msg::Hello { .. } => {
                wire::send(&mut stream, &Msg::Ack)?;
            }
            Msg::Ping => {
                wire::send(&mut stream, &Msg::Pong { load: 0 })?;
            }
            other => {
                wire::send(
                    &mut stream,
                    &Msg::Error(format!(
                        "unexpected message on registry side: {other:?}"
                    )),
                )?;
            }
        }
    }
}

/// Daemon-side announce: tell the coordinator's registry listener that
/// a worker is serving on `own_addr`. Retries the connect with the
/// standard backoff schedule (the daemon may come up before the
/// coordinator), then fails loudly — a mis-pointed `--join` (e.g. at a
/// worker daemon, or at a pre-registry coordinator) gets the remote's
/// "unexpected message" rejection verbatim instead of a silent no-op.
pub fn join_coordinator(coordinator: &str, own_addr: &str) -> Result<()> {
    let mut stream = connect_with_backoff(coordinator, CONNECT_ATTEMPTS, BASE_BACKOFF)
        .with_context(|| format!("joining coordinator at {coordinator}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    wire::send(&mut stream, &Msg::Join { addr: own_addr.to_string() })?;
    match wire::recv(&mut stream)? {
        Msg::Ack => Ok(()),
        Msg::Error(e) => bail!(
            "coordinator at {coordinator} rejected the join announce: {e} \
             (is --join pointed at the registry listener printed by the \
             coordinator, not at a worker or a pre-registry build?)"
        ),
        other => bail!("unexpected reply to join announce: {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_walks_joining_active_draining_dead() {
        let mut reg = WorkerRegistry::new();
        assert_eq!(reg.join("a:1"), MemberState::Joining);
        assert_eq!(reg.state("a:1"), Some(MemberState::Joining));
        assert_eq!(reg.pending_joins(), vec!["a:1".to_string()]);
        assert!(reg.non_placeable_addrs().contains("a:1"));

        reg.activate("a:1");
        assert_eq!(reg.state("a:1"), Some(MemberState::Active));
        assert!(reg.pending_joins().is_empty());
        assert!(reg.non_placeable_addrs().is_empty());

        reg.begin_drain("a:1");
        assert_eq!(reg.state("a:1"), Some(MemberState::Draining));
        assert!(reg.non_placeable_addrs().contains("a:1"));

        reg.mark_dead("a:1");
        assert_eq!(reg.state("a:1"), Some(MemberState::Dead));
    }

    #[test]
    fn dead_member_may_rejoin_but_live_states_are_sticky() {
        let mut reg = WorkerRegistry::new();
        reg.join("a:1");
        reg.activate("a:1");
        // a re-sent Join must not demote a live member
        assert_eq!(reg.join("a:1"), MemberState::Active);
        reg.begin_drain("a:1");
        assert_eq!(reg.join("a:1"), MemberState::Draining);
        // a stale admit must not cancel a drain
        reg.activate("a:1");
        assert_eq!(reg.state("a:1"), Some(MemberState::Draining));
        // but a daemon restart on a dead endpoint starts over
        reg.mark_dead("a:1");
        assert_eq!(reg.join("a:1"), MemberState::Joining);
        assert!(!reg.is_static("a:1"));
    }

    #[test]
    fn static_members_enter_active_and_are_flagged() {
        let mut reg = WorkerRegistry::new();
        reg.register_static("b:2");
        assert_eq!(reg.state("b:2"), Some(MemberState::Active));
        assert!(reg.is_static("b:2"));
        assert!(reg.non_placeable_addrs().is_empty());
    }

    #[test]
    fn removed_member_restarts_the_lifecycle() {
        let mut reg = WorkerRegistry::new();
        reg.join("c:3");
        reg.activate("c:3");
        reg.begin_drain("c:3");
        reg.remove("c:3");
        assert_eq!(reg.state("c:3"), None);
        assert_eq!(reg.join("c:3"), MemberState::Joining);
    }

    #[test]
    fn announce_listener_registers_joiners_over_the_wire() {
        let reg = Arc::new(Mutex::new(WorkerRegistry::new()));
        let mut srv = RegistryServer::bind("127.0.0.1:0", reg.clone()).unwrap();
        let addr = srv.local_addr().to_string();
        join_coordinator(&addr, "10.1.2.3:7701").unwrap();
        assert_eq!(
            crate::util::lock_recover(&reg).state("10.1.2.3:7701"),
            Some(MemberState::Joining)
        );
        // idempotent re-announce
        join_coordinator(&addr, "10.1.2.3:7701").unwrap();
        assert_eq!(crate::util::lock_recover(&reg).pending_joins().len(), 1);
        srv.stop();
    }

    #[test]
    fn empty_announce_is_rejected_loudly() {
        let reg = Arc::new(Mutex::new(WorkerRegistry::new()));
        let mut srv = RegistryServer::bind("127.0.0.1:0", reg.clone()).unwrap();
        let addr = srv.local_addr().to_string();
        let err = join_coordinator(&addr, "").unwrap_err();
        assert!(err.to_string().contains("rejected"), "got: {err:#}");
        srv.stop();
    }
}
