//! Adaptation-data buffers (Algorithm 1 lines 10-16).
//!
//! The server pushes one `(x_m, grad_hhat_m)` pair per site per step;
//! every `I` steps (the adaptation interval) the buffer drains into one
//! concatenated `FitJob` whose gradients average over the effective
//! batch B*I. The invariant that concatenated fitting equals summed
//! per-batch gradients is tested at the JAX level
//! (python/tests/test_prop1.py::test_interval_buffering_sums_per_batch_grads)
//! and again here against the native path.

use std::collections::BTreeMap;

use crate::tensor::Tensor;

/// Buffered rows for one (user, site).
#[derive(Clone, Debug, Default)]
pub struct SiteBuffer {
    xs: Vec<Tensor>,
    ghats: Vec<Tensor>,
}

impl SiteBuffer {
    pub fn push(&mut self, x: Tensor, ghat: Tensor) {
        assert_eq!(x.dims2().0, ghat.dims2().0, "row mismatch");
        self.xs.push(x);
        self.ghats.push(ghat);
    }

    pub fn batches(&self) -> usize {
        self.xs.len()
    }

    pub fn bytes(&self) -> usize {
        self.xs.iter().map(Tensor::bytes).sum::<usize>()
            + self.ghats.iter().map(Tensor::bytes).sum::<usize>()
    }

    /// Drain into (x_cat, ghat_cat, grad_scale).
    pub fn drain(&mut self) -> Option<(Tensor, Tensor, f32)> {
        if self.xs.is_empty() {
            return None;
        }
        let n = self.xs.len() as f32;
        let x = Tensor::cat_rows(&self.xs.iter().collect::<Vec<_>>());
        let g = Tensor::cat_rows(&self.ghats.iter().collect::<Vec<_>>());
        self.xs.clear();
        self.ghats.clear();
        Some((x, g, 1.0 / n))
    }
}

/// All buffers, keyed by (user, site).
#[derive(Debug, Default)]
pub struct AdaptationBuffers {
    map: BTreeMap<(usize, String), SiteBuffer>,
}

impl AdaptationBuffers {
    pub fn push(&mut self, user: usize, site: &str, x: Tensor, ghat: Tensor) {
        self.map
            .entry((user, site.to_string()))
            .or_default()
            .push(x, ghat);
    }

    /// Total buffered bytes (the worker_buffer line of the accountant).
    pub fn bytes(&self) -> usize {
        self.map.values().map(SiteBuffer::bytes).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.map.values().all(|b| b.batches() == 0)
    }

    /// Drain every non-empty buffer into (user, site, x, ghat, scale).
    pub fn drain_all(&mut self) -> Vec<(usize, String, Tensor, Tensor, f32)> {
        let mut out = Vec::new();
        for ((user, site), buf) in self.map.iter_mut() {
            if let Some((x, g, scale)) = buf.drain() {
                out.push((*user, site.clone(), x, g, scale));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(rows: usize, val: f32) -> Tensor {
        Tensor::from_fn(&[rows, 3], |_| val)
    }

    #[test]
    fn push_drain_concatenates() {
        let mut b = SiteBuffer::default();
        b.push(t(2, 1.0), t(2, 10.0));
        b.push(t(3, 2.0), t(3, 20.0));
        let (x, g, scale) = b.drain().expect("buffer seeded above is non-empty");
        assert_eq!(x.dims2(), (5, 3));
        assert_eq!(g.dims2(), (5, 3));
        assert_eq!(scale, 0.5);
        assert_eq!(x.data()[0], 1.0);
        assert_eq!(x.data()[14], 2.0);
        assert!(b.drain().is_none());
    }

    #[test]
    fn bytes_track_contents() {
        let mut bufs = AdaptationBuffers::default();
        assert_eq!(bufs.bytes(), 0);
        bufs.push(0, "l0.q", t(4, 0.0), t(4, 0.0));
        assert_eq!(bufs.bytes(), 2 * 4 * 3 * 4);
        bufs.drain_all();
        assert_eq!(bufs.bytes(), 0);
        assert!(bufs.is_empty());
    }

    #[test]
    fn drain_all_keyed_per_user_site() {
        let mut bufs = AdaptationBuffers::default();
        bufs.push(0, "a", t(1, 0.0), t(1, 0.0));
        bufs.push(1, "a", t(1, 0.0), t(1, 0.0));
        bufs.push(0, "b", t(1, 0.0), t(1, 0.0));
        let jobs = bufs.drain_all();
        assert_eq!(jobs.len(), 3);
    }

    #[test]
    #[should_panic]
    fn row_mismatch_panics() {
        let mut b = SiteBuffer::default();
        b.push(t(2, 0.0), t(3, 0.0));
    }
}
