//! Gradient Offloading — the worker ("low-cost device") pool.
//!
//! Each worker is a thread that *owns* the adapters (and optimizer
//! state) of the users assigned to it — the server never holds adapter
//! gradients or moments (Table 1). A worker serves `FitJob`s: buffered
//! adaptation data `(x, grad_hhat)` comes in, the surrogate gradients
//! are computed (natively, or on the worker's own PJRT device = the
//! paper's "offload to GPU" arm), the optimizer steps, and the reply
//! carries either the new adapter tensors (unmerged) or the merged-mode
//! delta difference.
//!
//! An optional `TransferModel` injects link latency/bandwidth so the
//! CPU-vs-GPU offload gap of Tables 10-18 can be swept on one testbed.
//!
//! The pool dispatches through the [`Transport`] trait: [`Worker`] is
//! the in-process (`Local`) implementation, and
//! [`TcpWorker`](crate::transport::tcp::TcpWorker) proxies the same
//! operations to a `cola worker` daemon over a real socket
//! (`offload_transport = "tcp"`).

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::adapters::{AdapterParams, SiteAdapter};
use crate::config::OffloadTarget;
use crate::merge;
use crate::runtime::{Device, Input, Manifest, OutputPlan, Value};
use crate::tensor::{self, Tensor};
use crate::transport::{tcp::TcpWorker, Transport};

/// Simulated interconnect: delay = latency + bytes / bandwidth.
#[derive(Clone, Copy, Debug)]
pub struct TransferModel {
    pub latency: Duration,
    pub bytes_per_sec: f64,
}

impl TransferModel {
    /// Calibrated stand-ins for the paper's links (A6000 testbed):
    /// pcie-gpu ~ 12 GB/s, cpu link ~ 2 GB/s with higher latency.
    pub fn gpu_link() -> Self {
        TransferModel { latency: Duration::from_micros(30), bytes_per_sec: 12e9 }
    }

    pub fn cpu_link() -> Self {
        TransferModel { latency: Duration::from_micros(120), bytes_per_sec: 2e9 }
    }

    pub fn delay_for(&self, bytes: usize) -> Duration {
        self.latency + Duration::from_secs_f64(bytes as f64 / self.bytes_per_sec)
    }

    pub fn apply(&self, bytes: usize) {
        std::thread::sleep(self.delay_for(bytes));
    }
}

/// A buffered-interval update job for one (user, site).
#[derive(Debug)]
pub struct FitJob {
    pub user: usize,
    pub site: String,
    /// concatenated hidden inputs over the interval (n, d_in)
    pub x: Tensor,
    /// concatenated grad_hhat over the interval (n, d_out)
    pub ghat: Tensor,
    /// 1 / number-of-batches in the buffer (grad averaging)
    pub grad_scale: f32,
    /// if true, reply carries the merged-mode delta difference
    pub merged: bool,
}

/// Worker reply for one job.
#[derive(Debug)]
pub struct FitResult {
    pub user: usize,
    pub site: String,
    /// unmerged mode: fresh copies of the adapter tensors (to refresh
    /// the server-resident copies)
    pub new_params: Option<Vec<Tensor>>,
    /// merged mode: s * (D_new - D_old) to add to the merged weight
    pub delta_diff: Option<Tensor>,
    /// pure compute time on the worker
    pub compute: Duration,
    /// simulated/measured transfer time for this job's payload
    pub transfer: Duration,
    pub bytes_in: usize,
    pub bytes_out: usize,
}

enum WorkerCmd {
    Register { user: usize, site: String, adapter: SiteAdapter },
    Fit(FitJob, Sender<Result<FitResult>>),
    /// fetch a snapshot of an adapter's parameters
    Snapshot { user: usize, site: String, reply: Sender<Result<AdapterParams>> },
    /// bytes of adapter + optimizer state held by this worker
    StateBytes(Sender<usize>),
    Shutdown,
}

/// Handle to one worker thread — the in-process (`Local`)
/// [`Transport`] implementation. The same compute core backs the TCP
/// daemon: `cola worker` spawns one of these behind its listener.
#[derive(Clone)]
pub struct Worker {
    tx: Sender<WorkerCmd>,
    pub id: usize,
}

impl Worker {
    /// Spawn one worker thread owning its own adapter/optimizer state.
    pub fn spawn_local(
        id: usize,
        target: OffloadTarget,
        manifest: Arc<Manifest>,
        transfer: Option<TransferModel>,
    ) -> Result<Worker> {
        let (tx, rx) = channel();
        std::thread::Builder::new()
            .name(format!("worker-{id}"))
            .spawn(move || worker_main(id, rx, target, manifest, transfer))?;
        Ok(Worker { tx, id })
    }

    pub fn register(&self, user: usize, site: &str, adapter: SiteAdapter) -> Result<()> {
        self.tx
            .send(WorkerCmd::Register { user, site: site.to_string(), adapter })
            .map_err(|_| anyhow!("worker {} gone", self.id))
    }

    pub fn fit(&self, job: FitJob) -> Result<Receiver<Result<FitResult>>> {
        let (tx, rx) = channel();
        self.tx
            .send(WorkerCmd::Fit(job, tx))
            .map_err(|_| anyhow!("worker {} gone", self.id))?;
        Ok(rx)
    }

    pub fn snapshot(&self, user: usize, site: &str) -> Result<AdapterParams> {
        let (tx, rx) = channel();
        self.tx
            .send(WorkerCmd::Snapshot { user, site: site.to_string(), reply: tx })
            .map_err(|_| anyhow!("worker {} gone", self.id))?;
        rx.recv()?
    }

    pub fn state_bytes(&self) -> Result<usize> {
        let (tx, rx) = channel();
        self.tx
            .send(WorkerCmd::StateBytes(tx))
            .map_err(|_| anyhow!("worker {} gone", self.id))?;
        Ok(rx.recv()?)
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(WorkerCmd::Shutdown);
    }
}

impl Transport for Worker {
    fn id(&self) -> usize {
        self.id
    }

    fn describe(&self) -> String {
        format!("local://worker-{}", self.id)
    }

    fn register(&self, user: usize, site: &str, adapter: SiteAdapter) -> Result<()> {
        Worker::register(self, user, site, adapter)
    }

    fn fit(&self, job: FitJob) -> Result<Receiver<Result<FitResult>>> {
        Worker::fit(self, job)
    }

    fn snapshot(&self, user: usize, site: &str) -> Result<AdapterParams> {
        Worker::snapshot(self, user, site)
    }

    fn state_bytes(&self) -> Result<usize> {
        Worker::state_bytes(self)
    }

    fn shutdown(&self) {
        Worker::shutdown(self)
    }
}

/// The pool: users are sharded across workers (user k -> worker k % N),
/// mirroring "multiple low-cost devices ... in parallel" (§3.2).
/// Dispatch goes through [`Transport`], so the fleet can be in-process
/// threads ([`WorkerPool::spawn`]) or remote `cola worker` daemons
/// ([`WorkerPool::connect_tcp`]) — the training loop can't tell the
/// difference, and by the bit-exact wire format + deterministic kernels
/// it trains to identical loss curves either way.
///
/// Each local worker's surrogate-fit contractions
/// (`AdapterParams::fit_grads`) run on the shared `tensor::pool` core
/// budget, so FitJobs for different users genuinely overlap without
/// oversubscribing the host: a worker that can't lease extra cores just
/// computes serially.
pub struct WorkerPool {
    workers: Vec<Box<dyn Transport>>,
}

impl WorkerPool {
    /// Spawn `n` in-process worker threads (`offload_transport = "local"`).
    pub fn spawn(
        n: usize,
        target: OffloadTarget,
        manifest: Arc<Manifest>,
        transfer: Option<TransferModel>,
    ) -> Result<WorkerPool> {
        if n == 0 {
            // for_user shards by `user % n`; n = 0 would panic on the
            // first dispatch with a bare divide-by-zero
            bail!("WorkerPool::spawn: need at least one worker (got n = 0)");
        }
        let mut workers: Vec<Box<dyn Transport>> = Vec::with_capacity(n);
        for id in 0..n {
            workers.push(Box::new(Worker::spawn_local(
                id,
                target,
                manifest.clone(),
                transfer,
            )?));
        }
        Ok(WorkerPool { workers })
    }

    /// Connect to remote worker daemons (`offload_transport = "tcp"`) —
    /// one [`TcpWorker`] per address, with connect backoff so daemons
    /// may still be binding when the server starts.
    pub fn connect_tcp(addrs: &[String]) -> Result<WorkerPool> {
        if addrs.is_empty() {
            bail!(
                "offload_transport = \"tcp\" needs at least one worker \
                 address (set worker_addrs)"
            );
        }
        let mut workers: Vec<Box<dyn Transport>> = Vec::with_capacity(addrs.len());
        for (id, addr) in addrs.iter().enumerate() {
            workers.push(Box::new(TcpWorker::connect(id, addr)?));
        }
        Ok(WorkerPool { workers })
    }

    pub fn for_user(&self, user: usize) -> &dyn Transport {
        self.workers[user % self.workers.len()].as_ref()
    }

    pub fn workers(&self) -> &[Box<dyn Transport>] {
        &self.workers
    }

    /// Total adapter + optimizer bytes across the fleet. Accounting is
    /// best-effort: a dead link counts as 0, but loudly — silent
    /// miscounts would make the Table-1 memory claims look better than
    /// they are.
    pub fn total_state_bytes(&self) -> usize {
        self.workers
            .iter()
            .map(|w| {
                w.state_bytes().unwrap_or_else(|e| {
                    eprintln!(
                        "warning: state-bytes query to {} failed ({e:#}); \
                         counting 0 for this worker",
                        w.describe()
                    );
                    0
                })
            })
            .sum()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for w in &self.workers {
            w.shutdown();
        }
    }
}

struct WorkerState {
    adapters: BTreeMap<(usize, String), SiteAdapter>,
    target: OffloadTarget,
    pjrt: Option<Device>,
    manifest: Arc<Manifest>,
    transfer: Option<TransferModel>,
}

fn worker_main(
    id: usize,
    rx: Receiver<WorkerCmd>,
    target: OffloadTarget,
    manifest: Arc<Manifest>,
    transfer: Option<TransferModel>,
) {
    // the PJRT "low-end GPU" device is spawned lazily on first use
    let mut st = WorkerState {
        adapters: BTreeMap::new(),
        target,
        pjrt: None,
        manifest,
        transfer,
    };
    while let Ok(cmd) = rx.recv() {
        match cmd {
            WorkerCmd::Register { user, site, adapter } => {
                st.adapters.insert((user, site), adapter);
            }
            WorkerCmd::Fit(job, reply) => {
                let _ = reply.send(run_fit(&mut st, id, job));
            }
            WorkerCmd::Snapshot { user, site, reply } => {
                let r = st
                    .adapters
                    .get(&(user, site.clone()))
                    .map(|a| a.params.clone())
                    .ok_or_else(|| anyhow!("worker {id}: no adapter ({user}, {site})"));
                let _ = reply.send(r);
            }
            WorkerCmd::StateBytes(reply) => {
                let bytes = st
                    .adapters
                    .values()
                    .map(|a| a.params.bytes() + a.opt.bytes())
                    .sum();
                let _ = reply.send(bytes);
            }
            WorkerCmd::Shutdown => break,
        }
    }
}

fn run_fit(st: &mut WorkerState, id: usize, job: FitJob) -> Result<FitResult> {
    let bytes_in = job.x.bytes() + job.ghat.bytes();
    let t_transfer = Instant::now();
    if let Some(tm) = &st.transfer {
        tm.apply(bytes_in);
    }
    let transfer_in = t_transfer.elapsed();

    let key = (job.user, job.site.clone());
    // take ownership for the duration of the fit (avoids double borrows
    // of st when the PJRT path needs &mut st.pjrt)
    let mut adapter = st
        .adapters
        .remove(&key)
        .ok_or_else(|| anyhow!("worker {id}: no adapter for ({}, {})", job.user, job.site))?;

    let old = if job.merged { Some(adapter.params.clone()) } else { None };

    let t0 = Instant::now();
    let mut grads = match st.target {
        OffloadTarget::NativeCpu => adapter.params.fit_grads(&job.x, &job.ghat),
        OffloadTarget::PjrtDevice => pjrt_fit_grads(st, &adapter.params, &job)?,
    };
    for g in &mut grads {
        tensor::scale_mut(g, job.grad_scale);
    }
    adapter.step(&grads);
    let compute = t0.elapsed();

    let (new_params, delta_diff, bytes_out) = if job.merged {
        let old = old.as_ref().ok_or_else(|| {
            anyhow!("worker {id}: merged fit for (user {}, site {}) lost its \
                     pre-step snapshot", job.user, job.site)
        })?;
        let diff = merge::delta_diff(old, &adapter.params)?;
        let b = diff.bytes();
        (None, Some(diff), b)
    } else {
        let ps: Vec<Tensor> = adapter.params.tensors().iter().map(|t| (*t).clone()).collect();
        let b: usize = ps.iter().map(|t| t.bytes()).sum();
        (Some(ps), None, b)
    };

    let t1 = Instant::now();
    if let Some(tm) = &st.transfer {
        tm.apply(bytes_out);
    }
    let transfer = transfer_in + t1.elapsed();

    st.adapters.insert(key, adapter);
    Ok(FitResult {
        user: job.user,
        site: job.site,
        new_params,
        delta_diff,
        compute,
        transfer,
        bytes_in,
        bytes_out,
    })
}

/// The "offload to low-end GPU" arm: run the fit artifact on the
/// worker's own execution device (PJRT under `--features xla`, the
/// native executor otherwise — the two are asserted equivalent in
/// `rust/tests/`). Artifact name encodes (kind, dims, rows); the buffer
/// is padded with zero rows up to the lowered row count (zero rows are
/// gradient-neutral — tested in python/tests).
fn pjrt_fit_grads(st: &mut WorkerState, params: &AdapterParams, job: &FitJob)
                  -> Result<Vec<Tensor>> {
    if st.pjrt.is_none() {
        st.pjrt = Some(Device::spawn("worker-pjrt", st.manifest.clone())?);
    }
    let dev = st.pjrt.as_ref().ok_or_else(|| {
        anyhow!("worker pjrt device unavailable for (user {}, site {})",
                job.user, job.site)
    })?;
    let (n, d_in) = job.x.dims2();
    let d_out = job.ghat.dims2().1;
    let kind = params.kind().name();
    // find a lowered fit artifact with enough rows
    let best = st
        .manifest
        .artifacts
        .keys()
        .filter_map(|name| {
            let prefix = format!("fit_{kind}_{d_in}x{d_out}_n");
            name.strip_prefix(&prefix)
                .and_then(|s| s.parse::<usize>().ok())
                .filter(|&rows| rows >= n)
                .map(|rows| (rows, name.clone()))
        })
        .min()
        .ok_or_else(|| anyhow!("no fit artifact fit_{kind}_{d_in}x{d_out}_n>={n}"))?;
    let (rows, artifact) = best;

    let pad = |t: &Tensor| -> Tensor {
        let (tn, td) = t.dims2();
        let mut data = t.data().to_vec();
        data.resize(rows * td, 0.0);
        let _ = tn;
        Tensor::new(vec![rows, td], data)
    };
    let mut inputs = vec![Input::Val(pad(&job.x).into()), Input::Val(pad(&job.ghat).into())];
    for t in params.tensors() {
        inputs.push(Input::Val(t.clone().into()));
    }
    let n_out = params.tensors().len();
    let plan = OutputPlan { keep: vec![], fetch: (0..n_out).collect() };
    let res = dev.execute(&artifact, inputs, plan)?;
    let mut grads = Vec::with_capacity(n_out);
    for (_, v) in res.fetched {
        let t = match v {
            Value::F32(t) => t,
            _ => anyhow::bail!("fit artifact returned non-f32"),
        };
        grads.push(t);
    }
    // bias grads come back as (1, d) from the kernels; flatten to (d,)
    for (g, p) in grads.iter_mut().zip(params.tensors()) {
        if g.shape().len() == 2 && p.shape().len() == 1 {
            *g = g.clone().reshape(&[p.shape()[0]]);
        }
    }
    Ok(grads)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_model_delay_monotone() {
        let tm = TransferModel::cpu_link();
        assert!(tm.delay_for(1 << 20) < tm.delay_for(1 << 24));
        assert!(tm.delay_for(0) >= tm.latency);
    }

    #[test]
    fn gpu_link_faster() {
        let bytes = 8 << 20;
        assert!(TransferModel::gpu_link().delay_for(bytes)
                < TransferModel::cpu_link().delay_for(bytes));
    }

    #[test]
    fn spawn_zero_workers_is_error() {
        let m = Arc::new(crate::runtime::native::builtin::builtin_manifest(
            std::path::Path::new("artifacts"),
        ));
        let err = WorkerPool::spawn(0, OffloadTarget::NativeCpu, m, None).unwrap_err();
        assert!(format!("{err}").contains("at least one worker"), "{err}");
    }
}
