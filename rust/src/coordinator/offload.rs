//! Gradient Offloading — the worker ("low-cost device") pool.
//!
//! Each worker is a thread that *owns* the adapters (and optimizer
//! state) of the users assigned to it — the server never holds adapter
//! gradients or moments (Table 1). A worker serves `FitJob`s: buffered
//! adaptation data `(x, grad_hhat)` comes in, the surrogate gradients
//! are computed (natively, or on the worker's own PJRT device = the
//! paper's "offload to GPU" arm), the optimizer steps, and the reply
//! carries either the new adapter tensors (unmerged) or the merged-mode
//! delta difference.
//!
//! An optional `TransferModel` injects link latency/bandwidth so the
//! CPU-vs-GPU offload gap of Tables 10-18 can be swept on one testbed.
//!
//! The pool dispatches through the [`Transport`] trait: [`Worker`] is
//! the in-process (`Local`) implementation, and
//! [`TcpWorker`](crate::transport::tcp::TcpWorker) proxies the same
//! operations to a `cola worker` daemon over a real socket
//! (`offload_transport = "tcp"`).
//!
//! Both implementations share one compute core: [`WorkerCore`], a
//! mutex-protected adapter table plus the fit/step math. The local
//! worker thread drives a core through its command channel; the TCP
//! daemon shares ONE core across every live connection (multi-tenant
//! FTaaS: adapters are keyed by `(tenant, user, site)`, so several
//! `cola train` processes can lease the same low-cost device without
//! clobbering each other's optimizer state).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::adapters::{AdapterParams, SiteAdapter};
use crate::config::OffloadTarget;
use crate::merge;
use crate::runtime::{Device, Input, Manifest, OutputPlan, Value};
use crate::tensor::{self, Tensor};
use crate::transport::tcp::{TcpLinkOpts, TcpWorker};
use crate::transport::Transport;

/// Simulated interconnect: delay = latency + bytes / bandwidth.
#[derive(Clone, Copy, Debug)]
pub struct TransferModel {
    pub latency: Duration,
    pub bytes_per_sec: f64,
}

impl TransferModel {
    /// Calibrated stand-ins for the paper's links (A6000 testbed):
    /// pcie-gpu ~ 12 GB/s, cpu link ~ 2 GB/s with higher latency.
    pub fn gpu_link() -> Self {
        TransferModel { latency: Duration::from_micros(30), bytes_per_sec: 12e9 }
    }

    pub fn cpu_link() -> Self {
        TransferModel { latency: Duration::from_micros(120), bytes_per_sec: 2e9 }
    }

    pub fn delay_for(&self, bytes: usize) -> Duration {
        self.latency + Duration::from_secs_f64(bytes as f64 / self.bytes_per_sec)
    }

    pub fn apply(&self, bytes: usize) {
        std::thread::sleep(self.delay_for(bytes));
    }
}

/// A buffered-interval update job for one (user, site).
#[derive(Debug)]
pub struct FitJob {
    pub user: usize,
    pub site: String,
    /// concatenated hidden inputs over the interval (n, d_in)
    pub x: Tensor,
    /// concatenated grad_hhat over the interval (n, d_out)
    pub ghat: Tensor,
    /// 1 / number-of-batches in the buffer (grad averaging)
    pub grad_scale: f32,
    /// if true, reply carries the merged-mode delta difference
    pub merged: bool,
}

/// Worker reply for one job.
#[derive(Debug)]
pub struct FitResult {
    pub user: usize,
    pub site: String,
    /// unmerged mode: fresh copies of the adapter tensors (to refresh
    /// the server-resident copies)
    pub new_params: Option<Vec<Tensor>>,
    /// merged mode: s * (D_new - D_old) to add to the merged weight
    pub delta_diff: Option<Tensor>,
    /// pure compute time on the worker
    pub compute: Duration,
    /// simulated/measured transfer time for this job's payload
    pub transfer: Duration,
    pub bytes_in: usize,
    pub bytes_out: usize,
}

enum WorkerCmd {
    Register { user: usize, site: String, adapter: SiteAdapter },
    Fit(FitJob, Sender<Result<FitResult>>),
    /// fetch a snapshot of an adapter's parameters
    Snapshot { user: usize, site: String, reply: Sender<Result<AdapterParams>> },
    /// bytes of adapter + optimizer state held by this worker
    StateBytes(Sender<usize>),
    Shutdown,
}

/// Handle to one worker thread — the in-process (`Local`)
/// [`Transport`] implementation. The same compute core backs the TCP
/// daemon: `cola worker` spawns one of these behind its listener.
#[derive(Clone)]
pub struct Worker {
    tx: Sender<WorkerCmd>,
    pub id: usize,
}

impl Worker {
    /// Spawn one worker thread owning its own adapter/optimizer state.
    pub fn spawn_local(
        id: usize,
        target: OffloadTarget,
        manifest: Arc<Manifest>,
        transfer: Option<TransferModel>,
    ) -> Result<Worker> {
        let (tx, rx) = channel();
        std::thread::Builder::new()
            .name(format!("worker-{id}"))
            .spawn(move || worker_main(id, rx, target, manifest, transfer))?;
        Ok(Worker { tx, id })
    }

    pub fn register(&self, user: usize, site: &str, adapter: SiteAdapter) -> Result<()> {
        self.tx
            .send(WorkerCmd::Register { user, site: site.to_string(), adapter })
            .map_err(|_| anyhow!("worker {} gone", self.id))
    }

    pub fn fit(&self, job: FitJob) -> Result<Receiver<Result<FitResult>>> {
        let (tx, rx) = channel();
        self.tx
            .send(WorkerCmd::Fit(job, tx))
            .map_err(|_| anyhow!("worker {} gone", self.id))?;
        Ok(rx)
    }

    pub fn snapshot(&self, user: usize, site: &str) -> Result<AdapterParams> {
        let (tx, rx) = channel();
        self.tx
            .send(WorkerCmd::Snapshot { user, site: site.to_string(), reply: tx })
            .map_err(|_| anyhow!("worker {} gone", self.id))?;
        rx.recv()?
    }

    pub fn state_bytes(&self) -> Result<usize> {
        let (tx, rx) = channel();
        self.tx
            .send(WorkerCmd::StateBytes(tx))
            .map_err(|_| anyhow!("worker {} gone", self.id))?;
        Ok(rx.recv()?)
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(WorkerCmd::Shutdown);
    }
}

impl Transport for Worker {
    fn id(&self) -> usize {
        self.id
    }

    fn describe(&self) -> String {
        format!("local://worker-{}", self.id)
    }

    fn register(&self, user: usize, site: &str, adapter: SiteAdapter) -> Result<()> {
        Worker::register(self, user, site, adapter)
    }

    fn fit(&self, job: FitJob) -> Result<Receiver<Result<FitResult>>> {
        Worker::fit(self, job)
    }

    fn snapshot(&self, user: usize, site: &str) -> Result<AdapterParams> {
        Worker::snapshot(self, user, site)
    }

    fn state_bytes(&self) -> Result<usize> {
        Worker::state_bytes(self)
    }

    fn shutdown(&self) {
        Worker::shutdown(self)
    }
}

/// The pool: users are sharded across workers, mirroring "multiple
/// low-cost devices ... in parallel" (§3.2). Dispatch goes through
/// [`Transport`], so the fleet can be in-process threads
/// ([`WorkerPool::spawn`]) or remote `cola worker` daemons
/// ([`WorkerPool::connect_tcp`]) — the training loop can't tell the
/// difference, and by the bit-exact wire format + deterministic kernels
/// it trains to identical loss curves either way.
///
/// # Sharding contract
///
/// User `u` is permanently assigned worker `u % len` ([`Self::shard_of`]),
/// and that worker *owns* the user's adapters and optimizer moments for
/// the life of the state. The worker count is therefore part of a run's
/// identity: growing or shrinking the pool remaps users onto workers
/// that never saw their moments, which would silently restart every
/// optimizer mid-run. Today every `Trainer` run registers fresh
/// adapters at init, so the contract holds by construction; any future
/// resume/checkpoint path that attaches to existing worker state (e.g.
/// TCP daemons, whose state outlives connections) must gate on
/// [`Self::verify_shard_count`] with the pool size the state was
/// registered under, and treat a mismatch as fatal (pinned by the
/// `pool_size_change_rejected_against_existing_state` test).
///
/// Each local worker's surrogate-fit contractions
/// (`AdapterParams::fit_grads`) run on the shared `tensor::pool` core
/// budget, so FitJobs for different users genuinely overlap without
/// oversubscribing the host: a worker that can't lease extra cores just
/// computes serially.
pub struct WorkerPool {
    workers: Vec<Box<dyn Transport>>,
}

impl WorkerPool {
    /// Spawn `n` in-process worker threads (`offload_transport = "local"`).
    pub fn spawn(
        n: usize,
        target: OffloadTarget,
        manifest: Arc<Manifest>,
        transfer: Option<TransferModel>,
    ) -> Result<WorkerPool> {
        if n == 0 {
            // for_user shards by `user % n`; n = 0 would panic on the
            // first dispatch with a bare divide-by-zero
            bail!("WorkerPool::spawn: need at least one worker (got n = 0)");
        }
        let mut workers: Vec<Box<dyn Transport>> = Vec::with_capacity(n);
        for id in 0..n {
            workers.push(Box::new(Worker::spawn_local(
                id,
                target,
                manifest.clone(),
                transfer,
            )?));
        }
        Ok(WorkerPool { workers })
    }

    /// Connect to remote worker daemons (`offload_transport = "tcp"`) —
    /// one [`TcpWorker`] per address, with connect backoff so daemons
    /// may still be binding when the server starts. The same address may
    /// appear more than once: a daemon serves any number of concurrent
    /// links, so one low-cost device can back several pool slots.
    /// `link` carries the tenant namespace and the FitBatch/pipelining
    /// knobs every link is built with.
    pub fn connect_tcp(addrs: &[String], link: &TcpLinkOpts) -> Result<WorkerPool> {
        if addrs.is_empty() {
            bail!(
                "offload_transport = \"tcp\" needs at least one worker \
                 address (set worker_addrs)"
            );
        }
        let mut workers: Vec<Box<dyn Transport>> = Vec::with_capacity(addrs.len());
        for (id, addr) in addrs.iter().enumerate() {
            workers.push(Box::new(TcpWorker::connect_with_link_opts(id, addr, link)?));
        }
        Ok(WorkerPool { workers })
    }

    /// The permanent worker index for a user — see the sharding
    /// contract in the type docs.
    pub fn shard_of(&self, user: usize) -> usize {
        user % self.workers.len()
    }

    pub fn for_user(&self, user: usize) -> &dyn Transport {
        self.workers[self.shard_of(user)].as_ref()
    }

    /// Worker by pool index (callers that already grouped jobs by
    /// [`Self::shard_of`]).
    pub fn worker(&self, idx: usize) -> &dyn Transport {
        self.workers[idx].as_ref()
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn workers(&self) -> &[Box<dyn Transport>] {
        &self.workers
    }

    /// Enforce the sharding contract against pre-existing worker state:
    /// `registered_with` is the pool size the state (adapters, optimizer
    /// moments, or an on-disk snapshot of either) was created under.
    /// A mismatch is rejected — `user % len` would silently reshuffle
    /// every user's moments onto a worker that never saw them.
    pub fn verify_shard_count(&self, registered_with: usize) -> Result<()> {
        if registered_with != self.workers.len() {
            bail!(
                "worker pool has {} workers but the existing adapter state was \
                 registered with {}: user -> worker sharding is `user % workers` \
                 and is part of a run's identity, so changing the pool size \
                 against live state would silently reshuffle optimizer moments \
                 — finish the run with the original pool size or start fresh",
                self.workers.len(),
                registered_with
            );
        }
        Ok(())
    }

    /// Total adapter + optimizer bytes across the fleet. Accounting is
    /// best-effort: a dead link counts as 0, but loudly — silent
    /// miscounts would make the Table-1 memory claims look better than
    /// they are. Several pool slots may share one daemon (duplicate
    /// `worker_addrs`), and a daemon reports its whole resident state,
    /// so each distinct endpoint is queried exactly once — summing per
    /// link would double-count. On a multi-tenant daemon the figure
    /// still spans ALL tenants (it is the device's footprint, not this
    /// run's share).
    pub fn total_state_bytes(&self) -> usize {
        let mut seen = BTreeSet::new();
        self.workers
            .iter()
            .filter(|w| seen.insert(w.describe()))
            .map(|w| {
                w.state_bytes().unwrap_or_else(|e| {
                    eprintln!(
                        "warning: state-bytes query to {} failed ({e:#}); \
                         counting 0 for this worker",
                        w.describe()
                    );
                    0
                })
            })
            .sum()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for w in &self.workers {
            w.shutdown();
        }
    }
}

/// Fully-qualified adapter key. The tenant is `""` for in-process pools
/// and for v1 wire clients; TCP connections that declared a tenant
/// (wire-v2 `Hello`) get their own namespace, so several trainers can
/// share one daemon without clobbering each other's adapters.
pub type TenantKey = (String, usize, String);

fn key_label(key: &TenantKey) -> String {
    if key.0.is_empty() {
        format!("({}, {})", key.1, key.2)
    } else {
        format!("(tenant {}, user {}, site {})", key.0, key.1, key.2)
    }
}

/// Lock that survives a poisoned mutex: a panicking connection thread
/// must not take the whole daemon down with cascading lock panics.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[derive(Default)]
struct AdapterTable {
    map: BTreeMap<TenantKey, SiteAdapter>,
    /// keys currently checked out by an in-flight fit
    busy: BTreeSet<TenantKey>,
}

/// The shared compute core behind every transport: the adapter +
/// optimizer state of the users assigned to one "low-cost device", and
/// the fit/step math that serves a `FitJob`.
///
/// The table is mutex-protected but fits do NOT hold the lock while
/// computing: an adapter is *checked out* (removed, marked busy),
/// fitted lock-free, then checked back in. Fits for different
/// `(tenant, user, site)` keys therefore run genuinely concurrently —
/// across daemon connections and inside one [`WorkerCore::fit_batch`]
/// fan-out — while a concurrent fit for the *same* key surfaces as a
/// "busy" error instead of a deadlock or a silent double-step.
pub struct WorkerCore {
    id: usize,
    target: OffloadTarget,
    manifest: Arc<Manifest>,
    transfer: Option<TransferModel>,
    adapters: Mutex<AdapterTable>,
    /// the PJRT "low-end GPU" device, spawned lazily on first use
    pjrt: Mutex<Option<Device>>,
}

impl WorkerCore {
    pub fn new(
        id: usize,
        target: OffloadTarget,
        manifest: Arc<Manifest>,
        transfer: Option<TransferModel>,
    ) -> WorkerCore {
        WorkerCore {
            id,
            target,
            manifest,
            transfer,
            adapters: Mutex::new(AdapterTable::default()),
            pjrt: Mutex::new(None),
        }
    }

    pub fn id(&self) -> usize {
        self.id
    }

    /// Install (or replace) the adapter for a key. Rejected while a fit
    /// for the same key is in flight — the fit's check-in would clobber
    /// the fresh registration.
    pub fn register(
        &self,
        tenant: &str,
        user: usize,
        site: &str,
        adapter: SiteAdapter,
    ) -> Result<()> {
        let key = (tenant.to_string(), user, site.to_string());
        let mut tab = lock(&self.adapters);
        if tab.busy.contains(&key) {
            bail!(
                "worker {}: cannot register {} while a fit for it is in flight",
                self.id,
                key_label(&key)
            );
        }
        tab.map.insert(key, adapter);
        Ok(())
    }

    pub fn snapshot(&self, tenant: &str, user: usize, site: &str) -> Result<AdapterParams> {
        let key = (tenant.to_string(), user, site.to_string());
        let tab = lock(&self.adapters);
        if tab.busy.contains(&key) {
            bail!("worker {}: adapter {} is busy (fit in flight)", self.id, key_label(&key));
        }
        tab.map
            .get(&key)
            .map(|a| a.params.clone())
            .ok_or_else(|| anyhow!("worker {}: no adapter {}", self.id, key_label(&key)))
    }

    /// Bytes of resident adapter + optimizer state, across all tenants.
    /// Best-effort during concurrent fits: a checked-out adapter is not
    /// counted until it checks back in.
    pub fn state_bytes(&self) -> usize {
        lock(&self.adapters)
            .map
            .values()
            .map(|a| a.params.bytes() + a.opt.bytes())
            .sum()
    }

    fn checkout(&self, key: &TenantKey) -> Result<SiteAdapter> {
        let mut tab = lock(&self.adapters);
        match tab.map.remove(key) {
            Some(a) => {
                tab.busy.insert(key.clone());
                Ok(a)
            }
            None if tab.busy.contains(key) => Err(anyhow!(
                "worker {}: adapter {} is busy (another fit for the same \
                 (user, site) is in flight)",
                self.id,
                key_label(key)
            )),
            None => Err(anyhow!("worker {}: no adapter {}", self.id, key_label(key))),
        }
    }

    fn checkin(&self, key: TenantKey, adapter: SiteAdapter) {
        let mut tab = lock(&self.adapters);
        tab.busy.remove(&key);
        tab.map.insert(key, adapter);
    }

    /// Serve one buffered-interval fit.
    pub fn fit(&self, tenant: &str, job: FitJob) -> Result<FitResult> {
        let key = (tenant.to_string(), job.user, job.site.clone());
        let mut adapter = self.checkout(&key)?;
        let r = self.fit_checked_out(&mut adapter, &job);
        // check back in on BOTH paths: an error reply must not eat the
        // adapter (the old code dropped it, turning one failed fit into
        // "no adapter" for the rest of the run)
        self.checkin(key, adapter);
        r
    }

    /// Serve a whole batch, fanning independent jobs out across the
    /// shared tensor-pool core budget. Results come back in job order
    /// and each job's numerics are identical to a serial [`Self::fit`]
    /// call, so batching can never move a loss curve. One failing job
    /// is that job's `Err` — it does not poison the rest of the batch.
    pub fn fit_batch(&self, tenant: &str, jobs: Vec<FitJob>) -> Vec<Result<FitResult>> {
        if jobs.len() <= 1 || self.target == OffloadTarget::PjrtDevice {
            // one job, or one PJRT device behind every fit: serial
            return jobs.into_iter().map(|j| self.fit(tenant, j)).collect();
        }
        let n = jobs.len();
        // Check every adapter out up front so a duplicate (user, site)
        // inside one batch becomes that job's error instead of a
        // deadlock, then compute lock-free in parallel.
        let cells: Vec<Mutex<Option<(TenantKey, Result<(FitJob, SiteAdapter)>)>>> = jobs
            .into_iter()
            .map(|job| {
                let key = (tenant.to_string(), job.user, job.site.clone());
                let r = self.checkout(&key).map(|a| (job, a));
                Mutex::new(Some((key, r)))
            })
            .collect();
        let fitted = tensor::pool::parallel_map(n, |i| {
            let (key, taken) = lock(&cells[i]).take().expect("each cell is taken once");
            match taken {
                Err(e) => (Err(e), None),
                Ok((job, mut adapter)) => {
                    let r = self.fit_checked_out(&mut adapter, &job);
                    (r, Some((key, adapter)))
                }
            }
        });
        let mut results = Vec::with_capacity(n);
        for (r, checked_out) in fitted {
            if let Some((key, adapter)) = checked_out {
                self.checkin(key, adapter);
            }
            results.push(r);
        }
        results
    }

    /// Everything between checkout and checkin: transfer simulation,
    /// shape validation, gradient compute, optimizer step, and reply
    /// assembly.
    fn fit_checked_out(&self, adapter: &mut SiteAdapter, job: &FitJob) -> Result<FitResult> {
        let bytes_in = job.x.bytes() + job.ghat.bytes();
        let t_transfer = Instant::now();
        if let Some(tm) = &self.transfer {
            tm.apply(bytes_in);
        }
        let transfer_in = t_transfer.elapsed();

        // a malformed job (wire corruption, mismatched registration) must
        // surface as this job's error, not a kernel assert that kills the
        // serving thread
        check_job_shapes(&adapter.params, job)?;

        let old = if job.merged { Some(adapter.params.clone()) } else { None };

        let t0 = Instant::now();
        let mut grads = match self.target {
            OffloadTarget::NativeCpu => adapter.params.fit_grads(&job.x, &job.ghat),
            OffloadTarget::PjrtDevice => self.pjrt_fit_grads(&adapter.params, job)?,
        };
        for g in &mut grads {
            tensor::scale_mut(g, job.grad_scale);
        }
        adapter.step(&grads);
        let compute = t0.elapsed();

        let (new_params, delta_diff, bytes_out) = if job.merged {
            let old = old.as_ref().ok_or_else(|| {
                anyhow!("worker {}: merged fit for (user {}, site {}) lost its \
                         pre-step snapshot", self.id, job.user, job.site)
            })?;
            let diff = merge::delta_diff(old, &adapter.params)?;
            let b = diff.bytes();
            (None, Some(diff), b)
        } else {
            let ps: Vec<Tensor> =
                adapter.params.tensors().iter().map(|t| (*t).clone()).collect();
            let b: usize = ps.iter().map(|t| t.bytes()).sum();
            (Some(ps), None, b)
        };

        let t1 = Instant::now();
        if let Some(tm) = &self.transfer {
            tm.apply(bytes_out);
        }
        let transfer = transfer_in + t1.elapsed();

        Ok(FitResult {
            user: job.user,
            site: job.site.clone(),
            new_params,
            delta_diff,
            compute,
            transfer,
            bytes_in,
            bytes_out,
        })
    }

    /// The "offload to low-end GPU" arm: run the fit artifact on the
    /// worker's own execution device (PJRT under `--features xla`, the
    /// native executor otherwise — the two are asserted equivalent in
    /// `rust/tests/`). Artifact name encodes (kind, dims, rows); the
    /// buffer is padded with zero rows up to the lowered row count (zero
    /// rows are gradient-neutral — tested in python/tests).
    fn pjrt_fit_grads(&self, params: &AdapterParams, job: &FitJob) -> Result<Vec<Tensor>> {
        let mut dev_guard = lock(&self.pjrt);
        if dev_guard.is_none() {
            *dev_guard = Some(Device::spawn("worker-pjrt", self.manifest.clone())?);
        }
        let dev = dev_guard.as_ref().ok_or_else(|| {
            anyhow!("worker pjrt device unavailable for (user {}, site {})",
                    job.user, job.site)
        })?;
        let (n, d_in) = job.x.dims2();
        let d_out = job.ghat.dims2().1;
        let kind = params.kind().name();
        // find a lowered fit artifact with enough rows
        let best = self
            .manifest
            .artifacts
            .keys()
            .filter_map(|name| {
                let prefix = format!("fit_{kind}_{d_in}x{d_out}_n");
                name.strip_prefix(&prefix)
                    .and_then(|s| s.parse::<usize>().ok())
                    .filter(|&rows| rows >= n)
                    .map(|rows| (rows, name.clone()))
            })
            .min()
            .ok_or_else(|| anyhow!("no fit artifact fit_{kind}_{d_in}x{d_out}_n>={n}"))?;
        let (rows, artifact) = best;

        let pad = |t: &Tensor| -> Tensor {
            let (tn, td) = t.dims2();
            let mut data = t.data().to_vec();
            data.resize(rows * td, 0.0);
            let _ = tn;
            Tensor::new(vec![rows, td], data)
        };
        let mut inputs = vec![Input::Val(pad(&job.x).into()), Input::Val(pad(&job.ghat).into())];
        for t in params.tensors() {
            inputs.push(Input::Val(t.clone().into()));
        }
        let n_out = params.tensors().len();
        let plan = OutputPlan { keep: vec![], fetch: (0..n_out).collect() };
        let res = dev.execute(&artifact, inputs, plan)?;
        let mut grads = Vec::with_capacity(n_out);
        for (_, v) in res.fetched {
            let t = match v {
                Value::F32(t) => t,
                _ => anyhow::bail!("fit artifact returned non-f32"),
            };
            grads.push(t);
        }
        // bias grads come back as (1, d) from the kernels; flatten to (d,)
        for (g, p) in grads.iter_mut().zip(params.tensors()) {
            if g.shape().len() == 2 && p.shape().len() == 1 {
                *g = g.clone().reshape(&[p.shape()[0]]);
            }
        }
        Ok(grads)
    }
}

/// Reject a job whose buffers cannot feed this adapter's contractions —
/// the kernels `assert!` on shape mismatch, and a panic on a serving
/// thread is the one failure mode the multi-connection daemon must not
/// have.
fn check_job_shapes(params: &AdapterParams, job: &FitJob) -> Result<()> {
    if job.x.shape().len() != 2 || job.ghat.shape().len() != 2 {
        bail!(
            "fit job for (user {}, site {}): x rank {} / ghat rank {} (want 2)",
            job.user, job.site, job.x.shape().len(), job.ghat.shape().len()
        );
    }
    let (xn, xd) = job.x.dims2();
    let (gn, gd) = job.ghat.dims2();
    let (d_in, d_out) = match params {
        AdapterParams::LowRank { a, b } => (a.shape()[0], b.shape()[1]),
        AdapterParams::Linear { w } => (w.shape()[0], w.shape()[1]),
        AdapterParams::Mlp { w1, w2, .. } => (w1.shape()[0], w2.shape()[1]),
    };
    if xn != gn || xd != d_in || gd != d_out {
        bail!(
            "fit job for (user {}, site {}): x ({xn}, {xd}) / ghat ({gn}, {gd}) \
             do not match adapter dims ({d_in} -> {d_out})",
            job.user, job.site
        );
    }
    Ok(())
}

fn worker_main(
    id: usize,
    rx: Receiver<WorkerCmd>,
    target: OffloadTarget,
    manifest: Arc<Manifest>,
    transfer: Option<TransferModel>,
) {
    // a local pool is single-tenant: every key lives under tenant ""
    let core = WorkerCore::new(id, target, manifest, transfer);
    while let Ok(cmd) = rx.recv() {
        match cmd {
            WorkerCmd::Register { user, site, adapter } => {
                // the one-command-at-a-time channel protocol rules out the
                // only register failure mode (a concurrent fit on the key)
                let _ = core.register("", user, &site, adapter);
            }
            WorkerCmd::Fit(job, reply) => {
                let _ = reply.send(core.fit("", job));
            }
            WorkerCmd::Snapshot { user, site, reply } => {
                let _ = reply.send(core.snapshot("", user, &site));
            }
            WorkerCmd::StateBytes(reply) => {
                let _ = reply.send(core.state_bytes());
            }
            WorkerCmd::Shutdown => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_model_delay_monotone() {
        let tm = TransferModel::cpu_link();
        assert!(tm.delay_for(1 << 20) < tm.delay_for(1 << 24));
        assert!(tm.delay_for(0) >= tm.latency);
    }

    #[test]
    fn gpu_link_faster() {
        let bytes = 8 << 20;
        assert!(TransferModel::gpu_link().delay_for(bytes)
                < TransferModel::cpu_link().delay_for(bytes));
    }

    #[test]
    fn spawn_zero_workers_is_error() {
        let m = Arc::new(crate::runtime::native::builtin::builtin_manifest(
            std::path::Path::new("artifacts"),
        ));
        let err = WorkerPool::spawn(0, OffloadTarget::NativeCpu, m, None).unwrap_err();
        assert!(format!("{err}").contains("at least one worker"), "{err}");
    }

    fn manifest() -> Arc<crate::runtime::Manifest> {
        Arc::new(crate::runtime::native::builtin::builtin_manifest(
            std::path::Path::new("artifacts"),
        ))
    }

    fn lowrank_adapter(seed: u64) -> SiteAdapter {
        use crate::adapters::OptimizerCfg;
        let mut rng = crate::rng::Rng::new(seed);
        let params =
            AdapterParams::init(crate::config::AdapterKind::LowRank, 6, 4, 3, 5, &mut rng);
        SiteAdapter::new("s", params, &OptimizerCfg::sgd(0.1, 0.0))
    }

    fn job_for(user: usize, site: &str, rows: usize) -> FitJob {
        FitJob {
            user,
            site: site.to_string(),
            x: Tensor::from_fn(&[rows, 6], |i| (i as f32).sin()),
            ghat: Tensor::from_fn(&[rows, 4], |i| (i as f32).cos()),
            grad_scale: 1.0,
            merged: false,
        }
    }

    /// Pin the sharding contract: user u maps to worker u % len, and the
    /// mapping is what `for_user` dispatches on.
    #[test]
    fn for_user_sharding_is_user_mod_len() {
        let pool = WorkerPool::spawn(3, OffloadTarget::NativeCpu, manifest(), None).unwrap();
        assert_eq!(pool.len(), 3);
        for user in 0..9 {
            assert_eq!(pool.shard_of(user), user % 3);
            assert_eq!(pool.for_user(user).id(), user % 3);
            assert_eq!(pool.worker(user % 3).id(), user % 3);
        }
    }

    #[test]
    fn pool_size_change_rejected_against_existing_state() {
        let pool = WorkerPool::spawn(2, OffloadTarget::NativeCpu, manifest(), None).unwrap();
        pool.verify_shard_count(2).unwrap();
        for wrong in [1, 3] {
            let err = pool.verify_shard_count(wrong).unwrap_err();
            assert!(format!("{err}").contains("reshuffle"), "{err}");
        }
    }

    #[test]
    fn core_batch_matches_serial_fits_bitwise() {
        let core = WorkerCore::new(0, OffloadTarget::NativeCpu, manifest(), None);
        let serial = WorkerCore::new(0, OffloadTarget::NativeCpu, manifest(), None);
        for user in 0..4 {
            core.register("", user, "s", lowrank_adapter(7 + user as u64)).unwrap();
            serial.register("", user, "s", lowrank_adapter(7 + user as u64)).unwrap();
        }
        let batch: Vec<FitJob> = (0..4).map(|u| job_for(u, "s", 5)).collect();
        let rs = core.fit_batch("", batch);
        for (u, r) in rs.into_iter().enumerate() {
            let r = r.unwrap();
            assert_eq!(r.user, u);
            let s = serial.fit("", job_for(u, "s", 5)).unwrap();
            let a = r.new_params.unwrap();
            let b = s.new_params.unwrap();
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x, y, "batched fit diverged from serial fit for user {u}");
            }
        }
    }

    #[test]
    fn core_duplicate_key_in_batch_is_per_job_error_not_deadlock() {
        let core = WorkerCore::new(0, OffloadTarget::NativeCpu, manifest(), None);
        core.register("", 0, "s", lowrank_adapter(1)).unwrap();
        let rs = core.fit_batch("", vec![job_for(0, "s", 3), job_for(0, "s", 3)]);
        assert_eq!(rs.len(), 2);
        assert!(rs[0].is_ok());
        let err = format!("{:#}", rs[1].as_ref().unwrap_err());
        assert!(err.contains("busy"), "{err}");
        // the adapter checked back in: a later fit works again
        core.fit("", job_for(0, "s", 3)).unwrap();
    }

    #[test]
    fn core_tenants_are_isolated() {
        let core = WorkerCore::new(0, OffloadTarget::NativeCpu, manifest(), None);
        core.register("a", 0, "s", lowrank_adapter(1)).unwrap();
        core.register("b", 0, "s", lowrank_adapter(2)).unwrap();
        // fitting tenant a's adapter must not move tenant b's
        let before_b = core.snapshot("b", 0, "s").unwrap();
        core.fit("a", job_for(0, "s", 4)).unwrap();
        let after_b = core.snapshot("b", 0, "s").unwrap();
        for (x, y) in before_b.tensors().into_iter().zip(after_b.tensors()) {
            assert_eq!(x, y, "tenant b's adapter moved when tenant a trained");
        }
        // and the default tenant has no such adapter at all
        let err = core.snapshot("", 0, "s").unwrap_err();
        assert!(format!("{err}").contains("no adapter"), "{err}");
    }

    #[test]
    fn core_shape_mismatch_is_error_not_panic() {
        let core = WorkerCore::new(0, OffloadTarget::NativeCpu, manifest(), None);
        core.register("", 0, "s", lowrank_adapter(1)).unwrap();
        let mut bad = job_for(0, "s", 3);
        bad.ghat = Tensor::zeros(&[3, 9]); // adapter d_out is 4
        let err = core.fit("", bad).unwrap_err();
        assert!(format!("{err}").contains("do not match adapter dims"), "{err}");
        // the adapter survived the rejected job
        core.fit("", job_for(0, "s", 3)).unwrap();
    }
}
