//! Gradient Offloading — the worker ("low-cost device") pool.
//!
//! Each worker is a thread that *owns* the adapters (and optimizer
//! state) of the users assigned to it — the server never holds adapter
//! gradients or moments (Table 1). A worker serves `FitJob`s: buffered
//! adaptation data `(x, grad_hhat)` comes in, the surrogate gradients
//! are computed (natively, or on the worker's own PJRT device = the
//! paper's "offload to GPU" arm), the optimizer steps, and the reply
//! carries either the new adapter tensors (unmerged) or the merged-mode
//! delta difference.
//!
//! An optional `TransferModel` injects link latency/bandwidth so the
//! CPU-vs-GPU offload gap of Tables 10-18 can be swept on one testbed.
//!
//! The pool dispatches through the [`Transport`] trait: [`Worker`] is
//! the in-process (`Local`) implementation, and
//! [`TcpWorker`](crate::transport::tcp::TcpWorker) proxies the same
//! operations to a `cola worker` daemon over a real socket
//! (`offload_transport = "tcp"`).
//!
//! Both implementations share one compute core: [`WorkerCore`], a
//! mutex-protected adapter table plus the fit/step math. The local
//! worker thread drives a core through its command channel; the TCP
//! daemon shares ONE core across every live connection (multi-tenant
//! FTaaS: adapters are keyed by `(tenant, user, site)`, so several
//! `cola train` processes can lease the same low-cost device without
//! clobbering each other's optimizer state).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::adapters::{AdapterParams, SiteAdapter};
use crate::config::OffloadTarget;
use crate::merge;
use crate::runtime::{Device, Input, Manifest, OutputPlan, Value};
use crate::scale::store::{KeyedStateStore, PageStats, PagerCfg};
use crate::tensor::{self, Tensor};
use crate::transport::tcp::{TcpLinkOpts, TcpWorker};
use crate::transport::Transport;

/// Simulated interconnect: delay = latency + bytes / bandwidth.
#[derive(Clone, Copy, Debug)]
pub struct TransferModel {
    pub latency: Duration,
    pub bytes_per_sec: f64,
}

impl TransferModel {
    /// Calibrated stand-ins for the paper's links (A6000 testbed):
    /// pcie-gpu ~ 12 GB/s, cpu link ~ 2 GB/s with higher latency.
    pub fn gpu_link() -> Self {
        TransferModel { latency: Duration::from_micros(30), bytes_per_sec: 12e9 }
    }

    pub fn cpu_link() -> Self {
        TransferModel { latency: Duration::from_micros(120), bytes_per_sec: 2e9 }
    }

    pub fn delay_for(&self, bytes: usize) -> Duration {
        self.latency + Duration::from_secs_f64(bytes as f64 / self.bytes_per_sec)
    }

    pub fn apply(&self, bytes: usize) {
        std::thread::sleep(self.delay_for(bytes));
    }
}

/// A buffered-interval update job for one (user, site). `Clone` exists
/// for `failover = "migrate"`: the coordinator keeps a copy of every
/// dispatched job until its reply is applied, so a job lost to a dying
/// daemon can be re-dispatched against the restored checkpoint.
#[derive(Clone, Debug)]
pub struct FitJob {
    pub user: usize,
    pub site: String,
    /// concatenated hidden inputs over the interval (n, d_in)
    pub x: Tensor,
    /// concatenated grad_hhat over the interval (n, d_out)
    pub ghat: Tensor,
    /// 1 / number-of-batches in the buffer (grad averaging)
    pub grad_scale: f32,
    /// if true, reply carries the merged-mode delta difference
    pub merged: bool,
}

/// Worker reply for one job.
#[derive(Debug)]
pub struct FitResult {
    pub user: usize,
    pub site: String,
    /// unmerged mode: fresh copies of the adapter tensors (to refresh
    /// the server-resident copies)
    pub new_params: Option<Vec<Tensor>>,
    /// merged mode: s * (D_new - D_old) to add to the merged weight
    pub delta_diff: Option<Tensor>,
    /// pure compute time on the worker
    pub compute: Duration,
    /// simulated/measured transfer time for this job's payload
    pub transfer: Duration,
    pub bytes_in: usize,
    pub bytes_out: usize,
}

enum WorkerCmd {
    Register { user: usize, site: String, adapter: SiteAdapter },
    Fit(FitJob, Sender<Result<FitResult>>),
    /// fetch a snapshot of an adapter's parameters
    Snapshot { user: usize, site: String, reply: Sender<Result<AdapterParams>> },
    /// bytes of adapter + optimizer state held by this worker
    StateBytes(Sender<usize>),
    /// bit-exact migration blob for one (user, site)
    Export { user: usize, site: String, reply: Sender<Result<Vec<u8>>> },
    /// install a migration blob (replacing any existing key state)
    Import { blob: Vec<u8>, reply: Sender<Result<()>> },
    /// drop a migrated-away shard
    Evict { user: usize, site: String, reply: Sender<Result<()>> },
    /// paging counters (faults/evictions/page writes/errors)
    PageStats(Sender<PageStats>),
    Shutdown,
}

/// Handle to one worker thread — the in-process (`Local`)
/// [`Transport`] implementation. The same compute core backs the TCP
/// daemon: `cola worker` spawns one of these behind its listener.
#[derive(Clone)]
pub struct Worker {
    tx: Sender<WorkerCmd>,
    pub id: usize,
}

impl Worker {
    /// Spawn one worker thread owning its own adapter/optimizer state.
    pub fn spawn_local(
        id: usize,
        target: OffloadTarget,
        manifest: Arc<Manifest>,
        transfer: Option<TransferModel>,
    ) -> Result<Worker> {
        Self::spawn_local_paged(id, target, manifest, transfer, None)
    }

    /// [`Self::spawn_local`] with an optional LRU pager: cold
    /// `(user, site)` state spills to `pager.dir` once more than
    /// `pager.capacity` adapters are resident. The core (and so any
    /// page-dir error) is built on the CALLING thread, before the
    /// worker thread exists — a bad directory fails the spawn, not the
    /// first fit.
    pub fn spawn_local_paged(
        id: usize,
        target: OffloadTarget,
        manifest: Arc<Manifest>,
        transfer: Option<TransferModel>,
        pager: Option<PagerCfg>,
    ) -> Result<Worker> {
        let core = WorkerCore::new_paged(id, target, manifest, transfer, pager)?;
        let (tx, rx) = channel();
        std::thread::Builder::new()
            .name(format!("worker-{id}"))
            .spawn(move || worker_main(core, rx))?;
        Ok(Worker { tx, id })
    }

    /// Paging counters for this worker's state store.
    pub fn page_stats(&self) -> Result<PageStats> {
        let (tx, rx) = channel();
        self.tx
            .send(WorkerCmd::PageStats(tx))
            .map_err(|_| anyhow!("worker {} gone", self.id))?;
        Ok(rx.recv()?)
    }

    pub fn register(&self, user: usize, site: &str, adapter: SiteAdapter) -> Result<()> {
        self.tx
            .send(WorkerCmd::Register { user, site: site.to_string(), adapter })
            .map_err(|_| anyhow!("worker {} gone", self.id))
    }

    pub fn fit(&self, job: FitJob) -> Result<Receiver<Result<FitResult>>> {
        let (tx, rx) = channel();
        self.tx
            .send(WorkerCmd::Fit(job, tx))
            .map_err(|_| anyhow!("worker {} gone", self.id))?;
        Ok(rx)
    }

    pub fn snapshot(&self, user: usize, site: &str) -> Result<AdapterParams> {
        let (tx, rx) = channel();
        self.tx
            .send(WorkerCmd::Snapshot { user, site: site.to_string(), reply: tx })
            .map_err(|_| anyhow!("worker {} gone", self.id))?;
        rx.recv()?
    }

    pub fn state_bytes(&self) -> Result<usize> {
        let (tx, rx) = channel();
        self.tx
            .send(WorkerCmd::StateBytes(tx))
            .map_err(|_| anyhow!("worker {} gone", self.id))?;
        Ok(rx.recv()?)
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(WorkerCmd::Shutdown);
    }
}

impl Transport for Worker {
    fn id(&self) -> usize {
        self.id
    }

    fn describe(&self) -> String {
        format!("local://worker-{}", self.id)
    }

    fn register(&self, user: usize, site: &str, adapter: SiteAdapter) -> Result<()> {
        Worker::register(self, user, site, adapter)
    }

    fn fit(&self, job: FitJob) -> Result<Receiver<Result<FitResult>>> {
        Worker::fit(self, job)
    }

    fn snapshot(&self, user: usize, site: &str) -> Result<AdapterParams> {
        Worker::snapshot(self, user, site)
    }

    fn state_bytes(&self) -> Result<usize> {
        Worker::state_bytes(self)
    }

    fn export_state(&self, user: usize, site: &str) -> Result<Vec<u8>> {
        let (tx, rx) = channel();
        self.tx
            .send(WorkerCmd::Export { user, site: site.to_string(), reply: tx })
            .map_err(|_| anyhow!("worker {} gone", self.id))?;
        rx.recv()?
    }

    fn import_state(&self, blob: Vec<u8>) -> Result<()> {
        let (tx, rx) = channel();
        self.tx
            .send(WorkerCmd::Import { blob, reply: tx })
            .map_err(|_| anyhow!("worker {} gone", self.id))?;
        rx.recv()?
    }

    fn evict_state(&self, user: usize, site: &str) -> Result<()> {
        let (tx, rx) = channel();
        self.tx
            .send(WorkerCmd::Evict { user, site: site.to_string(), reply: tx })
            .map_err(|_| anyhow!("worker {} gone", self.id))?;
        rx.recv()?
    }

    fn page_stats(&self) -> Result<PageStats> {
        Worker::page_stats(self)
    }

    fn shutdown(&self) {
        Worker::shutdown(self)
    }
}

// ---------------------------------------------------------------------
// deterministic rendezvous sharding
// ---------------------------------------------------------------------

/// SplitMix64 finisher — a stable, dependency-free bit mixer. The
/// std `DefaultHasher` is seeded per-process, which would make the
/// user -> worker mapping differ between the trainer and an offline
/// `cola pool` invocation; this one is identical everywhere, forever.
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// FNV-1a over a member key's bytes (stable across platforms).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The one HRW weight function — every sharding decision (live pool,
/// offline rebalancer, buddy selection, load-aware placement) MUST
/// derive its per-(key, user) weight from this single body, or two
/// copies could drift and silently disagree about ownership. `user_mix`
/// is `splitmix64(user as u64)`, hoisted so a loop over keys mixes the
/// user exactly once.
fn rendezvous_weight(key: &str, user_mix: u64) -> u64 {
    splitmix64(fnv1a64(key.as_bytes()) ^ user_mix)
}

/// HRW winner *and* runner-up of `user` among `keys`. The runner-up is
/// the member that would win if the winner vanished — which is exactly
/// why it doubles as the **buddy** for shard replication: when the
/// owner dies, the survivor rendezvous re-homes its users onto the very
/// member already holding their replicas. `None` runner-up on
/// single-member pools; `None` overall on an EMPTY key set — the old
/// code silently answered `(0, None)` there, which downstream callers
/// turned into a `members[0]` index panic the first time a pool lost
/// its last member before a placement.
fn rendezvous_rank<'a>(
    keys: impl Iterator<Item = &'a str>,
    user: usize,
) -> Option<(usize, Option<usize>)> {
    let u = splitmix64(user as u64);
    let mut best: Option<usize> = None;
    let mut best_w = 0u64;
    let mut second: Option<usize> = None;
    let mut second_w = 0u64;
    for (i, k) in keys.enumerate() {
        let w = rendezvous_weight(k, u);
        if best.is_none() || w > best_w {
            if let Some(b) = best {
                second = Some(b);
                second_w = best_w;
            }
            best = Some(i);
            best_w = w;
        } else if second.is_none() || w > second_w {
            second = Some(i);
            second_w = w;
        }
    }
    best.map(|b| (b, second))
}

/// The HRW winner alone — the common case. `None` on an empty key set.
fn rendezvous_best<'a>(keys: impl Iterator<Item = &'a str>, user: usize) -> Option<usize> {
    rendezvous_rank(keys, user).map(|(b, _)| b)
}

/// The named error every empty-member-set placement surfaces: callers
/// removed or failed over the pool's last member and then asked who
/// owns a user. An error beats the old `assert!`/index panic — the
/// supervisor and `cola pool` can report WHICH user was orphaned and
/// die cleanly (or refuse the resize) instead of unwinding.
fn empty_member_set_error(user: usize) -> anyhow::Error {
    anyhow!(
        "rendezvous over an empty member set: no live pool member remains \
         to own user {user} (the last member was removed or marked dead \
         before placement)"
    )
}

/// Rendezvous (highest-random-weight) owner of `user` among `keys`:
/// every (key, user) pair gets a deterministic weight and the max wins.
/// Adding a member can only steal users *to* the new member, and
/// removing one only re-homes the users it owned — the minimal-movement
/// property that makes elastic resizes cheap. Keys must be unique
/// ([`member_keys`] guarantees that); an empty key set is a named
/// error, never a panic.
pub fn rendezvous_owner(keys: &[String], user: usize) -> Result<usize> {
    rendezvous_best(keys.iter().map(String::as_str), user)
        .ok_or_else(|| empty_member_set_error(user))
}

/// A key not yet in `existing`: `base` itself, else `base#2`, `base#3`,
/// ... (duplicate `worker_addrs` are legal — one daemon backing several
/// pool slots — but rendezvous needs distinct identities per slot).
fn unique_key(existing: &[String], base: &str) -> String {
    if !existing.iter().any(|k| k == base) {
        return base.to_string();
    }
    for n in 2.. {
        let cand = format!("{base}#{n}");
        if !existing.iter().any(|k| k == &cand) {
            return cand;
        }
    }
    // lint:allow(panic-safety): the `2..` suffix loop can only exit by returning
    unreachable!("unbounded suffix search")
}

/// The member-key list an address list resolves to — shared by the live
/// pool and the offline `cola pool` rebalancer so both always compute
/// the same user -> worker mapping.
pub fn member_keys(addrs: &[String]) -> Vec<String> {
    let mut keys: Vec<String> = Vec::with_capacity(addrs.len());
    for a in addrs {
        let k = unique_key(&keys, a);
        keys.push(k);
    }
    keys
}

/// The daemon address behind a member key (strips the `#k` duplicate
/// suffix, if any).
pub fn key_addr(key: &str) -> &str {
    match key.rsplit_once('#') {
        Some((addr, n)) if n.parse::<usize>().is_ok() => addr,
        _ => key,
    }
}

// ---------------------------------------------------------------------
// load-aware placement
// ---------------------------------------------------------------------

/// Tier at (and above) which a member **sheds new users**: it is
/// excluded from placement entirely, not merely down-weighted, so a
/// pathologically hot daemon provably receives no new placements while
/// any cooler member exists.
pub const SHED_TIER: u8 = 3;

/// Per-tier right-shift applied to a member's HRW weight — a power-of-
/// two penalty keeps the scoring pure integer arithmetic (no float
/// rounding to drift across platforms).
const TIER_SHIFT: u32 = 8;

/// The load-quantization determinism rule (ADR 005): raw `Pong{load}`
/// figures are snapshotted **once per liveness sweep** (never
/// mid-interval) and quantized to power-of-two tiers relative to the
/// fleet median:
///
/// | load vs `max(median, 1)` | tier | effect on HRW weight        |
/// |--------------------------|------|-----------------------------|
/// | `< 2x`                   | 0    | unchanged                   |
/// | `< 4x`                   | 1    | `>> 8`                      |
/// | `< 8x`                   | 2    | `>> 16`                     |
/// | `>= 8x`                  | 3    | excluded (sheds new users)  |
///
/// Placement is then a pure function of (member keys, tier map, user):
/// the same snapshot always places identically, and because WHERE a
/// shard lives never moves a loss curve (sharding contract), live load
/// can steer placement without touching the "same config ⇒
/// byte-identical curves" guarantee.
///
/// The median uses the upper-median element of the sorted snapshot and
/// is clamped to >= 1 so an idle fleet (median 0) still tiers sanely:
/// a member 10x above the fleet median always lands in [`SHED_TIER`].
pub fn quantize_loads(loads: &BTreeMap<String, u64>) -> BTreeMap<String, u8> {
    let mut vals: Vec<u64> = loads.values().copied().collect();
    vals.sort_unstable();
    let median = vals.get(vals.len() / 2).copied().unwrap_or(0).max(1);
    loads
        .iter()
        .map(|(k, &l)| {
            let tier = if l < 2 * median {
                0
            } else if l < 4 * median {
                1
            } else if l < 8 * median {
                2
            } else {
                SHED_TIER
            };
            (k.clone(), tier)
        })
        .collect()
}

/// One pool slot: a stable identity for the rendezvous hash plus the
/// transport that reaches it.
pub struct PoolMember {
    /// rendezvous identity — the daemon address (possibly `#k`-suffixed
    /// for duplicate addresses), or `local-<i>` for in-process workers
    pub key: String,
    /// endpoint address (`""` for in-process members)
    pub addr: String,
    transport: Box<dyn Transport>,
}

impl PoolMember {
    pub fn transport(&self) -> &dyn Transport {
        self.transport.as_ref()
    }
}

/// The pool: users are sharded across workers, mirroring "multiple
/// low-cost devices ... in parallel" (§3.2). Dispatch goes through
/// [`Transport`], so the fleet can be in-process threads
/// ([`WorkerPool::spawn`]) or remote `cola worker` daemons
/// ([`WorkerPool::connect_tcp`]) — the training loop can't tell the
/// difference, and by the bit-exact wire format + deterministic kernels
/// it trains to identical loss curves either way.
///
/// # Sharding contract
///
/// User `u` is owned by the member that wins the rendezvous hash over
/// the current member keys ([`rendezvous_owner`]) — that member holds
/// the user's adapters and optimizer moments. Unlike the old `u % len`
/// rule, membership is **elastic**: adding a member moves only the
/// users it wins, and removing one re-homes only the users it owned.
/// The invariant that replaces the old pool-size check is *state
/// follows ownership*: every membership change must migrate the moved
/// users' state (bit-exact export/import — [`PoolSupervisor`], `cola
/// pool`) before the next fit dispatch, or those optimizers silently
/// restart. All workers compute bit-identically and replies apply in
/// buffer-drain order, so WHERE a user's shard lives never moves a
/// loss curve — which is exactly what lets the pool change under a
/// live run with byte-identical results.
///
/// Each local worker's surrogate-fit contractions
/// (`AdapterParams::fit_grads`) run on the shared `tensor::pool` core
/// budget, so FitJobs for different users genuinely overlap without
/// oversubscribing the host: a worker that can't lease extra cores just
/// computes serially.
pub struct WorkerPool {
    members: Vec<PoolMember>,
    /// transport ids are labels for logs/errors; monotone so a promoted
    /// standby never reuses a dead member's id
    next_id: usize,
    /// Sticky placement diversions (user -> member key): recorded when
    /// load-aware placement ([`WorkerPool::place_user`]) steers a user
    /// away from its plain-HRW home, consulted by
    /// [`WorkerPool::shard_of`] ever after. Overrides are only ever
    /// written at (re)placement points — membership changes — never by
    /// a load snapshot alone, which is what keeps existing shards put
    /// while hot members shed *new* users. An override whose target key
    /// left the pool is ignored (the user falls back to plain HRW until
    /// the next placement).
    overrides: BTreeMap<usize, String>,
}

impl WorkerPool {
    /// Spawn `n` in-process worker threads (`offload_transport = "local"`).
    pub fn spawn(
        n: usize,
        target: OffloadTarget,
        manifest: Arc<Manifest>,
        transfer: Option<TransferModel>,
    ) -> Result<WorkerPool> {
        Self::spawn_paged(n, target, manifest, transfer, None)
    }

    /// [`Self::spawn`] with adapter-state paging: each worker gets its
    /// OWN page subdirectory (`<dir>/w<id>`) and an LRU working set of
    /// `capacity` resident adapters — the memory-bounded configuration
    /// the `cola scale` harness drives 10^5+ users through.
    pub fn spawn_paged(
        n: usize,
        target: OffloadTarget,
        manifest: Arc<Manifest>,
        transfer: Option<TransferModel>,
        pager: Option<PagerCfg>,
    ) -> Result<WorkerPool> {
        if n == 0 {
            // rendezvous over an empty member set has no winner; fail at
            // construction, not on the first dispatch
            bail!("WorkerPool::spawn: need at least one worker (got n = 0)");
        }
        let mut members = Vec::with_capacity(n);
        for id in 0..n {
            let worker_pager = pager.as_ref().map(|p| PagerCfg {
                dir: p.dir.join(format!("w{id}")),
                capacity: p.capacity,
            });
            members.push(PoolMember {
                key: format!("local-{id}"),
                addr: String::new(),
                transport: Box::new(Worker::spawn_local_paged(
                    id,
                    target,
                    manifest.clone(),
                    transfer,
                    worker_pager,
                )?),
            });
        }
        Ok(WorkerPool { members, next_id: n, overrides: BTreeMap::new() })
    }

    /// Connect to remote worker daemons (`offload_transport = "tcp"`) —
    /// one [`TcpWorker`] per address, with connect backoff so daemons
    /// may still be binding when the server starts. The same address may
    /// appear more than once: a daemon serves any number of concurrent
    /// links, so one low-cost device can back several pool slots.
    /// `link` carries the tenant namespace and the FitBatch/pipelining
    /// knobs every link is built with.
    pub fn connect_tcp(addrs: &[String], link: &TcpLinkOpts) -> Result<WorkerPool> {
        Ok(Self::connect_tcp_with_standbys(addrs, &[], link)?.0)
    }

    /// [`Self::connect_tcp`] with cold-standby substitution: when a
    /// primary address refuses to connect, the next standby takes its
    /// slot (loudly) instead of aborting the whole pool — a fleet
    /// launcher with one dead daemon degrades instead of failing.
    /// Returns the pool plus the standbys that remain unused (the
    /// [`PoolSupervisor`]'s mid-run promotion reserve).
    pub fn connect_tcp_with_standbys(
        addrs: &[String],
        standbys: &[String],
        link: &TcpLinkOpts,
    ) -> Result<(WorkerPool, Vec<String>)> {
        if addrs.is_empty() {
            bail!(
                "offload_transport = \"tcp\" needs at least one worker \
                 address (set worker_addrs)"
            );
        }
        let mut remaining: Vec<String> = standbys.to_vec();
        let mut pool = WorkerPool {
            members: Vec::with_capacity(addrs.len()),
            next_id: 0,
            overrides: BTreeMap::new(),
        };
        for addr in addrs {
            match pool.add_tcp_member(addr, link) {
                Ok(_) => {}
                Err(mut err) => {
                    // substitute standbys until one connects
                    let mut placed = false;
                    while !remaining.is_empty() {
                        let standby = remaining.remove(0);
                        eprintln!(
                            "warning: worker at {addr} is unreachable ({err:#}); \
                             substituting standby {standby}"
                        );
                        match pool.add_tcp_member(&standby, link) {
                            Ok(_) => {
                                placed = true;
                                break;
                            }
                            Err(e2) => err = e2,
                        }
                    }
                    if !placed {
                        return Err(err.context(format!(
                            "connecting worker pool: {addr} is unreachable and \
                             no standby could take its slot"
                        )));
                    }
                }
            }
        }
        Ok((pool, remaining))
    }

    /// Connect `addr` and add it as a new member (its rendezvous key is
    /// deduplicated against current members). Returns the member index.
    pub fn add_tcp_member(&mut self, addr: &str, link: &TcpLinkOpts) -> Result<usize> {
        let keys: Vec<String> = self.members.iter().map(|m| m.key.clone()).collect();
        let key = unique_key(&keys, addr);
        self.add_tcp_member_with_key(addr, key, link)
    }

    /// [`Self::add_tcp_member`] with an explicit key — the failover path
    /// uses it to keep a restarted daemon at a dead member's address
    /// from inheriting the dead identity (and thereby skipping the
    /// state migration it still needs).
    pub fn add_tcp_member_with_key(
        &mut self,
        addr: &str,
        key: String,
        link: &TcpLinkOpts,
    ) -> Result<usize> {
        let id = self.next_id;
        let t = TcpWorker::connect_with_link_opts(id, addr, link)?;
        self.next_id += 1;
        self.members.push(PoolMember {
            key,
            addr: addr.to_string(),
            transport: Box::new(t),
        });
        Ok(self.members.len() - 1)
    }

    /// Remove (and return) a member. The caller owns migrating the
    /// users the member's key was winning — see the sharding contract.
    pub fn remove_member(&mut self, idx: usize) -> PoolMember {
        self.members.remove(idx)
    }

    /// First member whose endpoint is `addr` (drain/remove commands
    /// address daemons, not slots).
    pub fn index_of_addr(&self, addr: &str) -> Option<usize> {
        self.members.iter().position(|m| m.addr == addr)
    }

    /// Member index holding `key`, if present.
    pub fn index_of_key(&self, key: &str) -> Option<usize> {
        self.members.iter().position(|m| m.key == key)
    }

    /// Current rendezvous keys, in member order.
    pub fn keys(&self) -> Vec<String> {
        self.members.iter().map(|m| m.key.clone()).collect()
    }

    pub fn members(&self) -> &[PoolMember] {
        &self.members
    }

    /// The worker index currently owning a user: a sticky load-aware
    /// override when one was recorded (and its member still exists),
    /// else the rendezvous winner over the live member keys (see the
    /// sharding contract). Same weight body as [`rendezvous_owner`], by
    /// construction. Errors (named, no panic) when the pool has no
    /// members left — removing the last member and then placing is an
    /// operator mistake the caller must surface, not an index crash.
    pub fn shard_of(&self, user: usize) -> Result<usize> {
        if let Some(k) = self.overrides.get(&user) {
            if let Some(i) = self.index_of_key(k) {
                return Ok(i);
            }
        }
        self.plain_shard_of(user)
    }

    /// The unweighted HRW winner, ignoring overrides — the baseline
    /// every placement decision compares against.
    fn plain_shard_of(&self, user: usize) -> Result<usize> {
        rendezvous_best(self.members.iter().map(|m| m.key.as_str()), user)
            .ok_or_else(|| empty_member_set_error(user))
    }

    /// The member key currently owning `user` (override-aware) — what
    /// the supervisor snapshots before mutating membership.
    pub fn owner_key(&self, user: usize) -> Result<String> {
        Ok(self.members[self.shard_of(user)?].key.clone())
    }

    /// Place (or re-place) a user: the load-aware HRW winner among
    /// members that are neither excluded (joining/draining per the
    /// registry) nor in [`SHED_TIER`]. Members absent from `tiers`
    /// (fresh joiners, promoted standbys) count as tier 0. If every
    /// member is excluded or shed, placement falls back to plain HRW
    /// over the full pool — a hot owner beats no owner. Records an
    /// override iff the choice diverges from plain HRW, so
    /// [`WorkerPool::shard_of`] keeps agreeing with this decision on
    /// every later dispatch. Only membership changes call this; a load
    /// snapshot alone never moves an existing shard.
    pub fn place_user(
        &mut self,
        user: usize,
        tiers: &BTreeMap<String, u8>,
        exclude: &BTreeSet<String>,
    ) -> Result<usize> {
        let u = splitmix64(user as u64);
        let tier_of = |m: &PoolMember| tiers.get(&m.key).copied().unwrap_or(0);
        let eligible = |m: &PoolMember| {
            tier_of(m) < SHED_TIER && !exclude.contains(&m.addr)
        };
        let mut best: Option<(usize, u64)> = None;
        for (i, m) in self.members.iter().enumerate() {
            if !eligible(m) {
                continue;
            }
            let score = rendezvous_weight(&m.key, u) >> (u32::from(tier_of(m)) * TIER_SHIFT);
            if best.map_or(true, |(_, bw)| score > bw) {
                best = Some((i, score));
            }
        }
        let plain = self.plain_shard_of(user)?;
        let chosen = match best {
            Some((i, _)) => i,
            // every member is hot or excluded: plain HRW over the full
            // pool (placing somewhere beats placing nowhere)
            None => plain,
        };
        if chosen == plain {
            self.overrides.remove(&user);
        } else {
            self.overrides.insert(user, self.members[chosen].key.clone());
        }
        Ok(chosen)
    }

    /// The buddy holding `user`'s shard replicas: the highest-HRW member
    /// on a daemon *distinct from the owner's* (a replica sharing the
    /// owner's failure domain is dead weight). With no overrides in play
    /// this is exactly the rendezvous runner-up — the member the
    /// survivor remap re-homes the user onto when the owner dies, which
    /// is what makes buddy promotion zero-copy. `None` when every other
    /// member shares the owner's endpoint, the pool has one member, or
    /// the pool is empty (no owner exists, so no buddy either).
    pub fn buddy_of(&self, user: usize) -> Option<usize> {
        let owner = self.shard_of(user).ok()?;
        let owner_addr = &self.members[owner].addr;
        let u = splitmix64(user as u64);
        let mut best: Option<(usize, u64)> = None;
        for (i, m) in self.members.iter().enumerate() {
            if i == owner || (!owner_addr.is_empty() && &m.addr == owner_addr) {
                continue;
            }
            let w = rendezvous_weight(&m.key, u);
            if best.map_or(true, |(_, bw)| w > bw) {
                best = Some((i, w));
            }
        }
        best.map(|(i, _)| i)
    }

    pub fn for_user(&self, user: usize) -> Result<&dyn Transport> {
        Ok(self.members[self.shard_of(user)?].transport.as_ref())
    }

    /// Worker by pool index (callers that already grouped jobs by
    /// [`Self::shard_of`]).
    pub fn worker(&self, idx: usize) -> &dyn Transport {
        self.members[idx].transport.as_ref()
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    /// Total adapter + optimizer bytes across the fleet. Accounting is
    /// best-effort: a dead link counts as 0, but loudly — silent
    /// miscounts would make the Table-1 memory claims look better than
    /// they are. Several pool slots may share one daemon (duplicate
    /// `worker_addrs`), and a daemon reports its whole resident state,
    /// so each distinct endpoint is queried exactly once — summing per
    /// link would double-count. On a multi-tenant daemon the figure
    /// still spans ALL tenants (it is the device's footprint, not this
    /// run's share).
    pub fn total_state_bytes(&self) -> usize {
        let mut seen = BTreeSet::new();
        self.members
            .iter()
            .map(|m| m.transport.as_ref())
            .filter(|w| seen.insert(w.describe()))
            .map(|w| {
                w.state_bytes().unwrap_or_else(|e| {
                    eprintln!(
                        "warning: state-bytes query to {} failed ({e:#}); \
                         counting 0 for this worker",
                        w.describe()
                    );
                    0
                })
            })
            .sum()
    }

    /// Fleet-wide paging counters, summed per distinct endpoint (same
    /// dedup rule as [`Self::total_state_bytes`]). Best-effort: a dead
    /// link contributes zeros.
    pub fn total_page_stats(&self) -> PageStats {
        let mut seen = BTreeSet::new();
        let mut total = PageStats::default();
        for w in self
            .members
            .iter()
            .map(|m| m.transport.as_ref())
            .filter(|w| seen.insert(w.describe()))
        {
            if let Ok(s) = w.page_stats() {
                total.faults += s.faults;
                total.evictions += s.evictions;
                total.page_writes += s.page_writes;
                total.page_errors += s.page_errors;
            }
        }
        total
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for m in &self.members {
            m.transport.shutdown();
        }
    }
}

// ---------------------------------------------------------------------
// elastic pool supervision
// ---------------------------------------------------------------------

/// What one membership change moved.
#[derive(Clone, Copy, Debug, Default)]
pub struct MigrationStats {
    /// users whose owner changed
    pub users_moved: usize,
    /// (user, site) shards whose state was shipped
    pub shards_moved: usize,
    /// migration blob bytes shipped (export + checkpoint imports)
    pub bytes_moved: usize,
    /// (user, site) shards recovered by promoting a buddy replica in
    /// place — these cost zero wire bytes (the blob was already resident
    /// on the new owner) and are NOT counted in `shards_moved`
    pub shards_promoted: usize,
}

/// Health + elasticity for a TCP worker pool: heartbeats at adaptation-
/// interval boundaries, cold-standby promotion when a daemon dies, and
/// deterministic state migration for every membership change
/// (rendezvous remap + bit-exact export/import), so the pool can grow,
/// shrink, and fail over under a live run without moving a loss curve.
///
/// With `failover = "migrate"` the supervisor also keeps a **shadow
/// checkpoint** per (user, site): the state blob as of the last applied
/// interval. A daemon that dies WITH unexported state is recovered from
/// the checkpoint — the lost interval's fits are re-dispatched against
/// it, which reproduces the exact update the dead daemon would have
/// made (same inputs, same pre-step state, bit-identical kernels).
pub struct PoolSupervisor {
    users: usize,
    sites: Vec<String>,
    link: TcpLinkOpts,
    standbys: Vec<String>,
    /// checkpoints + dead-member recovery enabled (failover = "migrate")
    migrate: bool,
    /// liveness sweeps every N flushes (0 = reactive detection only)
    heartbeat_interval: usize,
    flushes: usize,
    checkpoints: BTreeMap<(usize, String), Vec<u8>>,
    /// buddy replication on (`replicate = true`): post-interval blobs
    /// are pushed to each shard's buddy, and failover promotes the
    /// replica in place instead of shipping a checkpoint
    replicate: bool,
    /// which member key holds each shard's current replica — consulted
    /// at failover to decide promote-vs-restore, pruned when the buddy
    /// itself leaves the pool
    replica_homes: BTreeMap<(usize, String), String>,
    /// member lifecycle bookkeeping (`joining → active → draining →
    /// dead`), shared with the `cola worker --join` listener when one is
    /// running; `None` for supervisors predating the registry (offline
    /// tools, older tests) — lifecycle exclusions then never apply
    registry: Option<Arc<Mutex<WorkerRegistry>>>,
    /// last liveness sweep's load snapshot (member key -> in-flight
    /// fits) — the only load figure placement ever sees, refreshed at
    /// interval boundaries and never mid-flush
    last_loads: BTreeMap<String, u64>,
}

impl PoolSupervisor {
    pub fn new(
        users: usize,
        sites: Vec<String>,
        link: TcpLinkOpts,
        standbys: Vec<String>,
        migrate: bool,
        heartbeat_interval: usize,
    ) -> PoolSupervisor {
        PoolSupervisor {
            users,
            sites,
            link,
            standbys,
            migrate,
            heartbeat_interval,
            flushes: 0,
            checkpoints: BTreeMap::new(),
            replicate: false,
            replica_homes: BTreeMap::new(),
            registry: None,
            last_loads: BTreeMap::new(),
        }
    }

    /// Enable buddy replication (`replicate = true`; requires
    /// `failover = "migrate"`, enforced by config validation).
    pub fn with_replication(mut self, on: bool) -> PoolSupervisor {
        self.replicate = on;
        self
    }

    /// Attach the member-lifecycle registry (shared with the join
    /// listener when `registry_listen` is set).
    pub fn with_registry(mut self, reg: Arc<Mutex<WorkerRegistry>>) -> PoolSupervisor {
        self.registry = Some(reg);
        self
    }

    /// Checkpoints (and therefore dead-member recovery) are on.
    pub fn migrate_enabled(&self) -> bool {
        self.migrate
    }

    /// Buddy replication is on.
    pub fn replicate_enabled(&self) -> bool {
        self.replicate
    }

    /// The lifecycle registry, if one is attached.
    pub fn registry(&self) -> Option<&Arc<Mutex<WorkerRegistry>>> {
        self.registry.as_ref()
    }

    /// Load tiers from the last sweep's snapshot (empty before the
    /// first sweep — every member then places at tier 0).
    fn tiers(&self) -> BTreeMap<String, u8> {
        quantize_loads(&self.last_loads)
    }

    /// Daemon addresses placement must skip: members the registry holds
    /// in a non-`active` lifecycle state (joining daemons own nothing
    /// yet; draining ones finish what they own but take no new users).
    fn place_exclusions(&self) -> BTreeSet<String> {
        match &self.registry {
            Some(reg) => crate::util::lock_recover(reg).non_placeable_addrs(),
            None => BTreeSet::new(),
        }
    }

    /// Standby addresses not yet promoted.
    pub fn standbys(&self) -> &[String] {
        &self.standbys
    }

    /// Record the post-interval state blob for one shard (the recovery
    /// point a future failover restores).
    pub fn checkpoint(&mut self, user: usize, site: &str, blob: Vec<u8>) {
        self.checkpoints.insert((user, site.to_string()), blob);
    }

    /// Called once per flush. Returns true when this boundary is due a
    /// proactive liveness sweep (`heartbeat_interval` flushes since the
    /// last one; 0 disables sweeping).
    pub fn sweep_due(&mut self) -> bool {
        if self.heartbeat_interval == 0 {
            return false;
        }
        self.flushes += 1;
        self.flushes % self.heartbeat_interval == 0
    }

    /// Heartbeat every member: indices of the ones that cannot answer,
    /// plus a fresh load snapshot (member key -> in-flight fits) from
    /// the ones that can. The snapshot replaces [`Self::tiers`]' input
    /// wholesale — this is the ONLY point where live load enters
    /// placement, so placement inputs change at sweep boundaries and
    /// never mid-interval (the load-quantization determinism rule).
    pub fn probe(&mut self, pool: &WorkerPool) -> Vec<usize> {
        let mut dead = Vec::new();
        let mut loads = BTreeMap::new();
        for (i, m) in pool.members().iter().enumerate() {
            match m.transport().ping() {
                Ok(load) => {
                    loads.insert(m.key.clone(), load);
                }
                Err(e) => {
                    eprintln!(
                        "warning: worker {} ({}) failed its heartbeat: {e:#}",
                        m.key,
                        m.transport().describe()
                    );
                    dead.push(i);
                }
            }
        }
        self.last_loads = loads;
        dead
    }

    /// Heartbeat every member; indices of the ones that cannot answer.
    pub fn find_dead(&mut self, pool: &WorkerPool) -> Vec<usize> {
        self.probe(pool)
    }

    /// Fail dead members over: remove them, promote standbys into the
    /// freed slots, remap every user by rendezvous, and migrate state —
    /// live export from surviving members, shadow checkpoints for the
    /// dead ones. With no standby left the pool simply shrinks onto the
    /// survivors. Errors only when no live member remains or a needed
    /// checkpoint is missing (`failover = "fail"`).
    pub fn fail_over(
        &mut self,
        pool: &mut WorkerPool,
        dead: &[usize],
    ) -> Result<MigrationStats> {
        if dead.is_empty() {
            return Ok(MigrationStats::default());
        }
        // ownership snapshot BEFORE any mutation (override-aware): the
        // remap compares against where each user actually lived, not
        // just where plain HRW would have put it
        let old_owners: Vec<String> = (0..self.users)
            .map(|u| pool.owner_key(u))
            .collect::<Result<_>>()?;
        let mut dead_keys: BTreeSet<String> = BTreeSet::new();
        let mut dead_addrs: BTreeSet<String> = BTreeSet::new();
        let mut idxs: Vec<usize> = dead.to_vec();
        idxs.sort_unstable();
        for &i in idxs.iter().rev() {
            let m = pool.remove_member(i);
            eprintln!(
                "warning: failing over dead worker {} ({}); its users will be \
                 re-homed",
                m.key, m.addr
            );
            m.transport().shutdown();
            dead_keys.insert(m.key);
            dead_addrs.insert(m.addr);
        }
        if let Some(reg) = &self.registry {
            let mut reg = crate::util::lock_recover(reg);
            for addr in &dead_addrs {
                // a duplicate-addr daemon may back several slots; only
                // flip lifecycle when no surviving slot still serves it
                if pool.index_of_addr(addr).is_none() {
                    reg.mark_dead(addr);
                }
            }
        }
        // promote one standby per dead member (a restarted daemon at a
        // dead address must NOT inherit the dead key, or the remap would
        // think nothing moved and skip the state import it needs)
        for _ in 0..dead_keys.len() {
            while !self.standbys.is_empty() {
                let addr = self.standbys.remove(0);
                let mut avoid = pool.keys();
                avoid.extend(dead_keys.iter().cloned());
                let key = unique_key(&avoid, &addr);
                match pool.add_tcp_member_with_key(&addr, key.clone(), &self.link) {
                    Ok(_) => {
                        eprintln!("promoted standby {addr} into the pool as {key}");
                        break;
                    }
                    Err(e) => {
                        eprintln!(
                            "warning: standby {addr} is unreachable ({e:#}); \
                             trying the next one"
                        );
                    }
                }
            }
        }
        if pool.len() == 0 {
            bail!(
                "every worker is dead and no standby could be promoted — the \
                 pool cannot serve fits"
            );
        }
        self.remap_and_migrate(pool, &old_owners, &dead_keys)
    }

    /// Gracefully remove the DAEMON at `addr` from the pool — every
    /// slot backed by it (duplicate `worker_addrs` give one daemon
    /// several slots, all drained together): export every shard those
    /// slots own to the new rendezvous owners (bit-exact), evict the
    /// source copies, then drop the members. The daemon itself stays up
    /// (and empty) — stopping it is the operator's call.
    pub fn drain(&mut self, pool: &mut WorkerPool, addr: &str) -> Result<MigrationStats> {
        let idxs: Vec<usize> = (0..pool.len())
            .filter(|&i| pool.members()[i].addr == addr)
            .collect();
        if idxs.is_empty() {
            bail!("no pool member at {addr} to drain");
        }
        if idxs.len() == pool.len() {
            bail!("cannot drain the last worker(s) in the pool");
        }
        // lifecycle first: a draining member takes no new users even
        // while it still serves the shards it owns
        if let Some(reg) = &self.registry {
            crate::util::lock_recover(reg).begin_drain(addr);
        }
        let old_owners: Vec<String> = (0..self.users)
            .map(|u| pool.owner_key(u))
            .collect::<Result<_>>()?;
        // remove every slot of the daemon (desc order keeps indices
        // valid); all slots reach the same state table, so one handle
        // serves every export/evict
        let mut removed: Vec<PoolMember> = Vec::with_capacity(idxs.len());
        for &i in idxs.iter().rev() {
            removed.push(pool.remove_member(i));
        }
        let removed_keys: BTreeSet<&String> = removed.iter().map(|m| &m.key).collect();
        // replicas homed on the leaving daemon leave with it
        self.replica_homes.retain(|_, k| !removed_keys.contains(k));
        let daemon = removed[0].transport();
        let mut stats = MigrationStats::default();
        let sites = self.sites.clone();
        let tiers = self.tiers();
        let exclude = self.place_exclusions();
        for user in 0..self.users {
            if !removed_keys.contains(&old_owners[user]) {
                continue;
            }
            let new_idx = pool.place_user(user, &tiers, &exclude)?;
            let mut moved = false;
            for site in &sites {
                let blob = daemon.export_state(user, site)?;
                stats.shards_moved += 1;
                stats.bytes_moved += blob.len();
                if self.migrate {
                    // the blob IS the current state — checkpoint it
                    // without another export round-trip
                    self.checkpoints.insert((user, site.clone()), blob.clone());
                }
                // import BEFORE evict: until the new owner holds the
                // shard, the source copy is the only live one
                pool.worker(new_idx).import_state(blob)?;
                daemon.evict_state(user, site)?;
                moved = true;
            }
            if moved {
                stats.users_moved += 1;
            }
        }
        for m in &removed {
            m.transport().shutdown();
        }
        // drain complete: the daemon is healthy but out of the fleet; a
        // later `--join` starts a fresh lifecycle
        if let Some(reg) = &self.registry {
            crate::util::lock_recover(reg).remove(addr);
        }
        Ok(stats)
    }

    /// Grow the pool by one daemon: connect it, remap, and migrate the
    /// users the new member wins (live export from their old owners).
    pub fn add(&mut self, pool: &mut WorkerPool, addr: &str) -> Result<MigrationStats> {
        let old_owners: Vec<String> = (0..self.users)
            .map(|u| pool.owner_key(u))
            .collect::<Result<_>>()?;
        pool.add_tcp_member(addr, &self.link)?;
        self.remap_and_migrate(pool, &old_owners, &BTreeSet::new())
    }

    /// Admit every daemon currently waiting in the registry's `joining`
    /// state: connect it as a pool member, migrate the users it wins,
    /// and flip it `active`. Called at sweep boundaries only — the same
    /// cadence as failover — so membership (and therefore placement)
    /// changes at deterministic points of the run. An unreachable
    /// joiner is marked dead (it can re-join later) instead of failing
    /// the run.
    pub fn admit_joiners(&mut self, pool: &mut WorkerPool) -> Result<MigrationStats> {
        let Some(reg) = self.registry.clone() else {
            return Ok(MigrationStats::default());
        };
        let pending = crate::util::lock_recover(&reg).pending_joins();
        let mut total = MigrationStats::default();
        for addr in pending {
            match self.add(pool, &addr) {
                Ok(st) => {
                    crate::util::lock_recover(&reg).activate(&addr);
                    println!(
                        "cola: admitted worker {addr} into the pool \
                         ({} users re-homed, {} bytes migrated)",
                        st.users_moved, st.bytes_moved
                    );
                    total.users_moved += st.users_moved;
                    total.shards_moved += st.shards_moved;
                    total.bytes_moved += st.bytes_moved;
                    total.shards_promoted += st.shards_promoted;
                }
                Err(e) => {
                    eprintln!(
                        "warning: joining worker {addr} could not be admitted \
                         ({e:#}); marking it dead — it may re-join"
                    );
                    crate::util::lock_recover(&reg).mark_dead(&addr);
                }
            }
        }
        Ok(total)
    }

    /// Push one shard's post-interval state blob to its buddy (the
    /// runner-up HRW owner on a distinct daemon). Best-effort by
    /// design: a failed push degrades that shard to checkpoint-only
    /// recovery with a warning — replication must never fail a healthy
    /// run. No-op unless `replicate = true` or when the pool has no
    /// member outside the owner's failure domain.
    pub fn replicate_shard(
        &mut self,
        pool: &WorkerPool,
        user: usize,
        site: &str,
        blob: Vec<u8>,
    ) {
        if !self.replicate {
            return;
        }
        let Some(bi) = pool.buddy_of(user) else {
            return;
        };
        let bkey = pool.members()[bi].key.clone();
        let hk = (user, site.to_string());
        if let Some(old) = self.replica_homes.get(&hk) {
            if old != &bkey {
                // the buddy moved (membership changed): drop the stale
                // replica so the old buddy's memory accounting stays
                // honest; best-effort, the old buddy may be gone
                if let Some(oi) = pool.index_of_key(old) {
                    if let Err(e) = pool.worker(oi).drop_replica(user, site) {
                        eprintln!(
                            "warning: dropping stale replica (user {user}, site \
                             {site}) on {old} failed: {e:#}"
                        );
                    }
                }
            }
        }
        match pool.worker(bi).put_replica(blob) {
            Ok(()) => {
                self.replica_homes.insert(hk, bkey);
            }
            Err(e) => {
                eprintln!(
                    "warning: replica push (user {user}, site {site}) to {bkey} \
                     failed ({e:#}); this shard falls back to shadow-checkpoint \
                     recovery"
                );
                self.replica_homes.remove(&hk);
            }
        }
    }

    /// Move every user whose owner changed between the `old_owners`
    /// snapshot (one member key per user, taken before the membership
    /// mutation) and this pool's fresh placement: buddy-replica
    /// promotion in place when the old owner is dead and the new owner
    /// already holds the replica, live export + evict when the old
    /// owner is still a member, shadow checkpoint otherwise.
    fn remap_and_migrate(
        &mut self,
        pool: &mut WorkerPool,
        old_owners: &[String],
        dead_keys: &BTreeSet<String>,
    ) -> Result<MigrationStats> {
        let mut stats = MigrationStats::default();
        if old_owners.is_empty() {
            return Ok(stats);
        }
        // replicas die with the daemon holding them
        self.replica_homes.retain(|_, k| pool.index_of_key(k).is_some());
        let sites = self.sites.clone();
        let tiers = self.tiers();
        let exclude = self.place_exclusions();
        for user in 0..self.users {
            let old_key = &old_owners[user];
            let new_idx = pool.place_user(user, &tiers, &exclude)?;
            if &pool.members()[new_idx].key == old_key {
                continue;
            }
            let src_idx = pool.index_of_key(old_key);
            if let Some(si) = src_idx {
                // same daemon backing both slots (duplicate addresses):
                // the state table is shared, nothing moves on the wire
                let (sa, da) = (&pool.members()[si].addr, &pool.members()[new_idx].addr);
                if !sa.is_empty() && sa == da {
                    continue;
                }
            }
            let mut moved = false;
            for site in &sites {
                if src_idx.is_none() && self.replicate {
                    // the old owner is gone — if the new owner is this
                    // shard's buddy, its replica is already resident and
                    // bit-identical to the shadow checkpoint: promote in
                    // place, zero bytes on the wire, zero stall
                    let hk = (user, site.clone());
                    let new_key = pool.members()[new_idx].key.as_str();
                    if self.replica_homes.get(&hk).map(String::as_str) == Some(new_key) {
                        match pool.worker(new_idx).promote_replica(user, site) {
                            Ok(()) => {
                                self.replica_homes.remove(&hk);
                                stats.shards_promoted += 1;
                                moved = true;
                                continue;
                            }
                            Err(e) => {
                                eprintln!(
                                    "warning: buddy promotion of (user {user}, \
                                     site {site}) on {new_key} failed ({e:#}); \
                                     restoring from the shadow checkpoint"
                                );
                            }
                        }
                    }
                }
                let blob = match src_idx {
                    Some(si) => pool.worker(si).export_state(user, site)?,
                    None => {
                        if dead_keys.contains(old_key) && !self.migrate {
                            bail!(
                                "worker {old_key} died holding (user {user}, site \
                                 {site}) and failover = \"fail\" keeps no shadow \
                                 checkpoints — set failover = \"migrate\" to \
                                 survive daemon loss"
                            );
                        }
                        self.checkpoints
                            .get(&(user, site.clone()))
                            .cloned()
                            .ok_or_else(|| {
                                anyhow!(
                                    "worker {old_key} died holding (user {user}, \
                                     site {site}) and no shadow checkpoint exists \
                                     for it — state is unrecoverable"
                                )
                            })?
                    }
                };
                stats.shards_moved += 1;
                stats.bytes_moved += blob.len();
                if self.migrate {
                    // the blob IS the current state — checkpoint it
                    // without another export round-trip
                    self.checkpoints.insert((user, site.clone()), blob.clone());
                }
                pool.worker(new_idx).import_state(blob)?;
                // evict only AFTER the import landed: until then the
                // source copy is the only live one, and a failed import
                // must not strand the shard with zero owners
                if let Some(si) = src_idx {
                    pool.worker(si).evict_state(user, site)?;
                }
                moved = true;
            }
            if moved {
                stats.users_moved += 1;
            }
        }
        Ok(stats)
    }
}

/// Offline pool rebalance for `cola pool --add/--remove/--drain`: given
/// the old and new address lists, move every re-homed user's state
/// between daemons directly (export -> import -> evict), with no trainer
/// in the loop. Both sides must be reachable; daemon state is keyed
/// under `link.tenant`.
pub fn rebalance_daemons(
    old_addrs: &[String],
    new_addrs: &[String],
    users: usize,
    sites: &[String],
    link: &TcpLinkOpts,
) -> Result<MigrationStats> {
    if old_addrs.is_empty() {
        bail!("the old pool is empty — there is no state to rebalance");
    }
    if new_addrs.is_empty() {
        bail!("the new pool would be empty — refusing to strand every shard");
    }
    fn ensure(
        conns: &mut BTreeMap<String, TcpWorker>,
        addr: &str,
        link: &TcpLinkOpts,
    ) -> Result<()> {
        if !conns.contains_key(addr) {
            let id = conns.len();
            conns.insert(
                addr.to_string(),
                TcpWorker::connect_with_link_opts(id, addr, link)?,
            );
        }
        Ok(())
    }
    let old_keys = member_keys(old_addrs);
    let new_keys = member_keys(new_addrs);
    let mut conns: BTreeMap<String, TcpWorker> = BTreeMap::new();
    let mut stats = MigrationStats::default();
    for user in 0..users {
        let old_key = &old_keys[rendezvous_owner(&old_keys, user)?];
        let new_key = &new_keys[rendezvous_owner(&new_keys, user)?];
        if old_key == new_key {
            continue;
        }
        let (src, dst) = (key_addr(old_key), key_addr(new_key));
        if src == dst {
            // different slot, same daemon: shared state table, no move
            continue;
        }
        ensure(&mut conns, src, link)?;
        ensure(&mut conns, dst, link)?;
        let mut moved = false;
        for site in sites {
            let blob = match conns[src].export_state(user, site) {
                Ok(b) => b,
                // Resumability: a previous partially-failed rebalance may
                // already have moved this shard (export -> import -> evict
                // is not atomic across users). Absent at the source AND
                // present at the destination = already done, skip; any
                // other failure is real.
                Err(e) => {
                    if format!("{e:#}").contains("no adapter")
                        && conns[dst].snapshot(user, site).is_ok()
                    {
                        continue;
                    }
                    return Err(e.context(format!(
                        "exporting (user {user}, site {site}) from {src}"
                    )));
                }
            };
            stats.shards_moved += 1;
            stats.bytes_moved += blob.len();
            conns[dst].import_state(blob)?;
            conns[src].evict_state(user, site)?;
            moved = true;
        }
        if moved {
            stats.users_moved += 1;
        }
    }
    Ok(stats)
}

/// Fully-qualified adapter key. The tenant is `""` for in-process pools
/// and for v1 wire clients; TCP connections that declared a tenant
/// (wire-v2 `Hello`) get their own namespace, so several trainers can
/// share one daemon without clobbering each other's adapters.
pub type TenantKey = (String, usize, String);

fn key_label(key: &TenantKey) -> String {
    if key.0.is_empty() {
        format!("({}, {})", key.1, key.2)
    } else {
        format!("(tenant {}, user {}, site {})", key.0, key.1, key.2)
    }
}

/// Lock that survives a poisoned mutex: a panicking connection thread
/// must not take the whole daemon down with cascading lock panics.
/// Delegates to the audited [`crate::util::lock_recover`].
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    crate::util::lock_recover(m)
}

/// The shared compute core behind every transport: the adapter +
/// optimizer state of the users assigned to one "low-cost device", and
/// the fit/step math that serves a `FitJob`.
///
/// State lives in a [`KeyedStateStore`] — a keyed table with an
/// optional bounded LRU working set that pages cold `(tenant, user,
/// site)` adapters to disk as bit-exact `wire::encode_state` blobs
/// (ADR 006). The store is mutex-protected but fits do NOT hold the
/// lock while computing: an adapter is *checked out* (removed, marked
/// busy), fitted lock-free, then checked back in. Fits for different
/// `(tenant, user, site)` keys therefore run genuinely concurrently —
/// across daemon connections and inside one [`WorkerCore::fit_batch`]
/// fan-out — while a concurrent fit for the *same* key surfaces as a
/// "busy" error instead of a deadlock or a silent double-step. Page
/// faults DO happen under the lock: the fault is part of checkout, and
/// serializing it keeps the LRU clock a pure function of the access
/// sequence.
pub struct WorkerCore {
    id: usize,
    target: OffloadTarget,
    manifest: Arc<Manifest>,
    transfer: Option<TransferModel>,
    adapters: Mutex<KeyedStateStore>,
    /// the PJRT "low-end GPU" device, spawned lazily on first use
    pjrt: Mutex<Option<Device>>,
    /// chaos hook: keys whose next fit panics mid-checkout, while the
    /// adapter-table lock is held — the regression suite's stand-in for
    /// a kernel assert, proving poison recovery end to end
    chaos_panic_keys: Mutex<BTreeSet<TenantKey>>,
    /// passive buddy-replica store: raw `wire::encode_state` blobs for
    /// shards this worker does NOT own. Replicas never serve fits; they
    /// wait to be promoted (or dropped) by the coordinator. Kept apart
    /// from the adapter table on purpose — a replica must not collide
    /// with a live shard's busy/checkout machinery.
    replicas: Mutex<BTreeMap<TenantKey, Vec<u8>>>,
}

impl WorkerCore {
    pub fn new(
        id: usize,
        target: OffloadTarget,
        manifest: Arc<Manifest>,
        transfer: Option<TransferModel>,
    ) -> WorkerCore {
        // no pager -> KeyedStateStore::with_pager is never hit, so this
        // construction cannot fail
        WorkerCore {
            id,
            target,
            manifest,
            transfer,
            adapters: Mutex::new(KeyedStateStore::new()),
            pjrt: Mutex::new(None),
            chaos_panic_keys: Mutex::new(BTreeSet::new()),
            replicas: Mutex::new(BTreeMap::new()),
        }
    }

    /// [`Self::new`] with an optional LRU pager behind the state store.
    /// Fails only when the page directory cannot be created.
    pub fn new_paged(
        id: usize,
        target: OffloadTarget,
        manifest: Arc<Manifest>,
        transfer: Option<TransferModel>,
        pager: Option<PagerCfg>,
    ) -> Result<WorkerCore> {
        let mut core = WorkerCore::new(id, target, manifest, transfer);
        if let Some(cfg) = pager {
            core.adapters = Mutex::new(KeyedStateStore::with_pager(cfg)?);
        }
        Ok(core)
    }

    /// Paging counters of this core's state store.
    pub fn page_stats(&self) -> PageStats {
        lock(&self.adapters).stats()
    }

    pub fn id(&self) -> usize {
        self.id
    }

    /// Arm a one-shot injected panic: the next fit for
    /// `(tenant, user, site)` panics while the adapter-table lock is
    /// held, poisoning the shared mutex exactly the way a real kernel
    /// assert inside a serving thread would. Public for the same reason
    /// `WorkerDaemon::kill` is — chaos tests drive failure modes
    /// through the real code paths instead of mocks.
    pub fn inject_fit_panic(&self, tenant: &str, user: usize, site: &str) {
        lock(&self.chaos_panic_keys).insert((tenant.to_string(), user, site.to_string()));
    }

    /// Install (or replace) the adapter for a key. Rejected while a fit
    /// for the same key is in flight — the fit's check-in would clobber
    /// the fresh registration.
    pub fn register(
        &self,
        tenant: &str,
        user: usize,
        site: &str,
        adapter: SiteAdapter,
    ) -> Result<()> {
        let key = (tenant.to_string(), user, site.to_string());
        let mut store = lock(&self.adapters);
        if store.is_busy(&key) {
            bail!(
                "worker {}: cannot register {} while a fit for it is in flight",
                self.id,
                key_label(&key)
            );
        }
        store.insert(key, adapter);
        Ok(())
    }

    pub fn snapshot(&self, tenant: &str, user: usize, site: &str) -> Result<AdapterParams> {
        let key = (tenant.to_string(), user, site.to_string());
        let mut store = lock(&self.adapters);
        if store.is_busy(&key) {
            bail!("worker {}: adapter {} is busy (fit in flight)", self.id, key_label(&key));
        }
        store
            .peek_clone(&key)
            .with_context(|| format!("worker {}: snapshot failed", self.id))?
            .map(|a| a.params)
            .ok_or_else(|| anyhow!("worker {}: no adapter {}", self.id, key_label(&key)))
    }

    /// Bytes of RESIDENT adapter + optimizer state, across all tenants,
    /// plus passive buddy-replica blobs (they occupy real device memory
    /// too, so the footprint ledger stays honest). Paged-out state is
    /// deliberately excluded — it lives on disk, and bounding this
    /// figure is the point of paging. Best-effort during concurrent
    /// fits: a checked-out adapter is not counted until it checks back
    /// in.
    pub fn state_bytes(&self) -> usize {
        let live = lock(&self.adapters).resident_bytes();
        let passive: usize = lock(&self.replicas).values().map(Vec::len).sum();
        live + passive
    }

    /// Current number of in-flight fits (checked-out adapters) — the
    /// load figure a `Pong` heartbeat reply carries.
    pub fn load(&self) -> u64 {
        lock(&self.adapters).busy_len() as u64
    }

    /// Serialize one shard's full adapter + optimizer state as a
    /// bit-exact migration blob ([`crate::transport::wire::encode_state`]).
    /// Rejected while a fit for the key is in flight — a mid-step export
    /// would capture a torn snapshot. A paged-out shard serves from its
    /// page file (page files ARE migration blobs).
    pub fn export_state(&self, tenant: &str, user: usize, site: &str) -> Result<Vec<u8>> {
        let key = (tenant.to_string(), user, site.to_string());
        let mut store = lock(&self.adapters);
        if store.is_busy(&key) {
            bail!(
                "worker {}: cannot export {} while a fit for it is in flight",
                self.id,
                key_label(&key)
            );
        }
        store
            .export_blob(&key)
            .with_context(|| format!("worker {}: export failed", self.id))?
            .ok_or_else(|| anyhow!("worker {}: no adapter {}", self.id, key_label(&key)))
    }

    /// Install a migration blob under `tenant`, replacing any existing
    /// state for the blob's `(user, site)` key. Returns the key so
    /// callers can log what landed.
    pub fn import_state(&self, tenant: &str, blob: &[u8]) -> Result<(usize, String)> {
        let (user, site, adapter) = crate::transport::wire::decode_state(blob)?;
        let key = (tenant.to_string(), user, site.clone());
        let mut store = lock(&self.adapters);
        if store.is_busy(&key) {
            bail!(
                "worker {}: cannot import {} while a fit for it is in flight",
                self.id,
                key_label(&key)
            );
        }
        store.insert(key, adapter);
        Ok((user, site))
    }

    /// Drop a shard's state after it migrated away (resident AND any
    /// on-disk page). Evicting an absent key is a no-op; evicting a
    /// busy key is an error (the fit's check-in would resurrect it).
    pub fn evict_state(&self, tenant: &str, user: usize, site: &str) -> Result<()> {
        let key = (tenant.to_string(), user, site.to_string());
        let mut store = lock(&self.adapters);
        if store.is_busy(&key) {
            bail!(
                "worker {}: cannot evict {} while a fit for it is in flight",
                self.id,
                key_label(&key)
            );
        }
        store.remove(&key);
        Ok(())
    }

    /// Store a buddy-replica blob under `tenant`, replacing any earlier
    /// replica for the same `(user, site)`. The blob is validated (it
    /// must decode as a [`crate::transport::wire::encode_state`]
    /// payload) but kept as raw bytes — promotion re-decodes, so the
    /// promoted state is bit-identical to what the owner exported.
    pub fn put_replica(&self, tenant: &str, blob: &[u8]) -> Result<()> {
        let (user, site, _) = crate::transport::wire::decode_state(blob)
            .map_err(|e| anyhow!("worker {}: rejected replica blob: {e:#}", self.id))?;
        let key = (tenant.to_string(), user, site);
        lock(&self.replicas).insert(key, blob.to_vec());
        Ok(())
    }

    /// Promote a stored replica to live state — the zero-wire-cost half
    /// of buddy failover. Decodes + installs exactly like
    /// [`WorkerCore::import_state`]; the replica entry is removed only
    /// after the install succeeds, so a failed promotion (busy key)
    /// leaves the replica in place for a retry.
    pub fn promote_replica(&self, tenant: &str, user: usize, site: &str) -> Result<()> {
        let key = (tenant.to_string(), user, site.to_string());
        let blob = lock(&self.replicas)
            .get(&key)
            .cloned()
            .ok_or_else(|| {
                anyhow!("worker {}: no replica for {}", self.id, key_label(&key))
            })?;
        self.import_state(tenant, &blob)?;
        lock(&self.replicas).remove(&key);
        Ok(())
    }

    /// Discard a replica whose buddy assignment moved elsewhere.
    /// Dropping an absent key is a no-op.
    pub fn drop_replica(&self, tenant: &str, user: usize, site: &str) {
        let key = (tenant.to_string(), user, site.to_string());
        lock(&self.replicas).remove(&key);
    }

    fn checkout(&self, key: &TenantKey) -> Result<SiteAdapter> {
        let mut store = lock(&self.adapters);
        if lock(&self.chaos_panic_keys).remove(key) {
            // lint:allow(panic-safety): one-shot chaos hook; panics under the table lock on purpose
            panic!("injected fit panic for {}", key_label(key));
        }
        // take() faults paged keys in from disk; a corrupted page is
        // THIS key's error (never a panic, never another key's problem)
        match store.take(key) {
            Ok(Some(a)) => Ok(a),
            Ok(None) if store.is_busy(key) => Err(anyhow!(
                "worker {}: adapter {} is busy (another fit for the same \
                 (user, site) is in flight)",
                self.id,
                key_label(key)
            )),
            Ok(None) => Err(anyhow!("worker {}: no adapter {}", self.id, key_label(key))),
            Err(e) => Err(e.context(format!("worker {}: checkout failed", self.id))),
        }
    }

    fn checkin(&self, key: TenantKey, adapter: SiteAdapter) {
        lock(&self.adapters).checkin(key, adapter);
    }

    /// Serve one buffered-interval fit.
    ///
    /// A panic anywhere inside the fit — kernel assert, index panic in
    /// adapter math, injected chaos — is contained here: the key is
    /// released, state the unwound stack may have torn is discarded,
    /// and the caller gets an error naming the (user, site). One
    /// panicking fit therefore degrades to a per-tenant wire `Error`
    /// instead of killing the serving thread and wedging the key
    /// busy-forever for every other connection.
    pub fn fit(&self, tenant: &str, job: FitJob) -> Result<FitResult> {
        let key = (tenant.to_string(), job.user, job.site.clone());
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut adapter = self.checkout(&key)?;
            let r = self.fit_checked_out(&mut adapter, &job);
            // check back in on BOTH paths: an error reply must not eat the
            // adapter (the old code dropped it, turning one failed fit into
            // "no adapter" for the rest of the run)
            self.checkin(key.clone(), adapter);
            r
        }));
        match outcome {
            Ok(r) => r,
            Err(payload) => Err(self.release_after_panic(&key, payload.as_ref())),
        }
    }

    /// Contain a panic that unwound out of a fit: un-busy the key (its
    /// checked-out adapter, if any, died with the unwound stack) and
    /// build the per-(user, site) error the caller returns. Re-locking
    /// here goes through [`crate::util::lock_recover`] because the
    /// panicking thread may have poisoned the table mutex — this pair
    /// is exactly what keeps a multi-tenant daemon serving after one
    /// tenant's fit blows up.
    fn release_after_panic(
        &self,
        key: &TenantKey,
        payload: &(dyn std::any::Any + Send),
    ) -> anyhow::Error {
        let discarded = lock(&self.adapters).clear_busy(key);
        let what = crate::util::panic_message(payload);
        if discarded {
            anyhow!(
                "worker {}: fit for {} panicked mid-step ({what}); its adapter \
                 state was discarded — re-register before the next fit",
                self.id,
                key_label(key)
            )
        } else {
            anyhow!(
                "worker {}: fit for {} panicked before checkout ({what}); \
                 registered state is intact",
                self.id,
                key_label(key)
            )
        }
    }

    /// Serve a whole batch, fanning independent jobs out across the
    /// shared tensor-pool core budget. Results come back in job order
    /// and each job's numerics are identical to a serial [`Self::fit`]
    /// call, so batching can never move a loss curve. One failing job
    /// is that job's `Err` — it does not poison the rest of the batch.
    pub fn fit_batch(&self, tenant: &str, jobs: Vec<FitJob>) -> Vec<Result<FitResult>> {
        if jobs.len() <= 1 || self.target == OffloadTarget::PjrtDevice {
            // one job, or one PJRT device behind every fit: serial
            return jobs.into_iter().map(|j| self.fit(tenant, j)).collect();
        }
        let n = jobs.len();
        // Check every adapter out up front so a duplicate (user, site)
        // inside one batch becomes that job's error instead of a
        // deadlock, then compute lock-free in parallel.
        let cells: Vec<Mutex<Option<(TenantKey, Result<(FitJob, SiteAdapter)>)>>> = jobs
            .into_iter()
            .map(|job| {
                let key = (tenant.to_string(), job.user, job.site.clone());
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    self.checkout(&key)
                }))
                .unwrap_or_else(|p| Err(self.release_after_panic(&key, p.as_ref())))
                .map(|a| (job, a));
                Mutex::new(Some((key, r)))
            })
            .collect();
        let fitted = tensor::pool::parallel_map(n, |i| {
            let Some((key, taken)) = lock(&cells[i]).take() else {
                // each cell is taken exactly once by construction; a
                // repeat take is a pool-dispatch bug, surfaced as this
                // job's error rather than a panic
                return (
                    Err(anyhow!("worker {}: batch cell {i} was consumed twice", self.id)),
                    None,
                );
            };
            match taken {
                Err(e) => (Err(e), None),
                Ok((job, mut adapter)) => {
                    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        self.fit_checked_out(&mut adapter, &job)
                    }));
                    match outcome {
                        Ok(r) => (r, Some((key, adapter))),
                        // the torn adapter drops here instead of
                        // checking back in
                        Err(p) => (Err(self.release_after_panic(&key, p.as_ref())), None),
                    }
                }
            }
        });
        let mut results = Vec::with_capacity(n);
        for (r, checked_out) in fitted {
            if let Some((key, adapter)) = checked_out {
                self.checkin(key, adapter);
            }
            results.push(r);
        }
        results
    }

    /// Everything between checkout and checkin: transfer simulation,
    /// shape validation, gradient compute, optimizer step, and reply
    /// assembly.
    fn fit_checked_out(&self, adapter: &mut SiteAdapter, job: &FitJob) -> Result<FitResult> {
        let bytes_in = job.x.bytes() + job.ghat.bytes();
        // lint:allow(determinism): timing ledger only — durations never feed curve math
        let t_transfer = Instant::now();
        if let Some(tm) = &self.transfer {
            tm.apply(bytes_in);
        }
        let transfer_in = t_transfer.elapsed();

        // a malformed job (wire corruption, mismatched registration) must
        // surface as this job's error, not a kernel assert that kills the
        // serving thread
        check_job_shapes(&adapter.params, job)?;

        let old = if job.merged { Some(adapter.params.clone()) } else { None };

        // lint:allow(determinism): timing ledger only — durations never feed curve math
        let t0 = Instant::now();
        let mut grads = match self.target {
            OffloadTarget::NativeCpu => adapter.params.fit_grads(&job.x, &job.ghat),
            OffloadTarget::PjrtDevice => self.pjrt_fit_grads(&adapter.params, job)?,
        };
        for g in &mut grads {
            tensor::scale_mut(g, job.grad_scale);
        }
        adapter.step(&grads);
        let compute = t0.elapsed();

        let (new_params, delta_diff, bytes_out) = if job.merged {
            let old = old.as_ref().ok_or_else(|| {
                anyhow!("worker {}: merged fit for (user {}, site {}) lost its \
                         pre-step snapshot", self.id, job.user, job.site)
            })?;
            let diff = merge::delta_diff(old, &adapter.params)?;
            let b = diff.bytes();
            (None, Some(diff), b)
        } else {
            let ps: Vec<Tensor> =
                adapter.params.tensors().iter().map(|t| (*t).clone()).collect();
            let b: usize = ps.iter().map(|t| t.bytes()).sum();
            (Some(ps), None, b)
        };

        // lint:allow(determinism): timing ledger only — durations never feed curve math
        let t1 = Instant::now();
        if let Some(tm) = &self.transfer {
            tm.apply(bytes_out);
        }
        let transfer = transfer_in + t1.elapsed();

        Ok(FitResult {
            user: job.user,
            site: job.site.clone(),
            new_params,
            delta_diff,
            compute,
            transfer,
            bytes_in,
            bytes_out,
        })
    }

    /// The "offload to low-end GPU" arm: run the fit artifact on the
    /// worker's own execution device (PJRT under `--features xla`, the
    /// native executor otherwise — the two are asserted equivalent in
    /// `rust/tests/`). Artifact name encodes (kind, dims, rows); the
    /// buffer is padded with zero rows up to the lowered row count (zero
    /// rows are gradient-neutral — tested in python/tests).
    fn pjrt_fit_grads(&self, params: &AdapterParams, job: &FitJob) -> Result<Vec<Tensor>> {
        let mut dev_guard = lock(&self.pjrt);
        if dev_guard.is_none() {
            *dev_guard = Some(Device::spawn("worker-pjrt", self.manifest.clone())?);
        }
        let dev = dev_guard.as_ref().ok_or_else(|| {
            anyhow!("worker pjrt device unavailable for (user {}, site {})",
                    job.user, job.site)
        })?;
        let (n, d_in) = job.x.dims2();
        let d_out = job.ghat.dims2().1;
        let kind = params.kind().name();
        // find a lowered fit artifact with enough rows
        let best = self
            .manifest
            .artifacts
            .keys()
            .filter_map(|name| {
                let prefix = format!("fit_{kind}_{d_in}x{d_out}_n");
                name.strip_prefix(&prefix)
                    .and_then(|s| s.parse::<usize>().ok())
                    .filter(|&rows| rows >= n)
                    .map(|rows| (rows, name.clone()))
            })
            .min()
            .ok_or_else(|| anyhow!("no fit artifact fit_{kind}_{d_in}x{d_out}_n>={n}"))?;
        let (rows, artifact) = best;

        let pad = |t: &Tensor| -> Tensor {
            let (tn, td) = t.dims2();
            let mut data = t.data().to_vec();
            data.resize(rows * td, 0.0);
            let _ = tn;
            Tensor::new(vec![rows, td], data)
        };
        let mut inputs = vec![Input::Val(pad(&job.x).into()), Input::Val(pad(&job.ghat).into())];
        for t in params.tensors() {
            inputs.push(Input::Val(t.clone().into()));
        }
        let n_out = params.tensors().len();
        let plan = OutputPlan { keep: vec![], fetch: (0..n_out).collect() };
        let res = dev.execute(&artifact, inputs, plan)?;
        let mut grads = Vec::with_capacity(n_out);
        for (_, v) in res.fetched {
            let t = match v {
                Value::F32(t) => t,
                _ => anyhow::bail!("fit artifact returned non-f32"),
            };
            grads.push(t);
        }
        // bias grads come back as (1, d) from the kernels; flatten to (d,)
        for (g, p) in grads.iter_mut().zip(params.tensors()) {
            if g.shape().len() == 2 && p.shape().len() == 1 {
                *g = g.clone().reshape(&[p.shape()[0]]);
            }
        }
        Ok(grads)
    }
}

/// Reject a job whose buffers cannot feed this adapter's contractions —
/// the kernels `assert!` on shape mismatch, and a panic on a serving
/// thread is the one failure mode the multi-connection daemon must not
/// have.
fn check_job_shapes(params: &AdapterParams, job: &FitJob) -> Result<()> {
    if job.x.shape().len() != 2 || job.ghat.shape().len() != 2 {
        bail!(
            "fit job for (user {}, site {}): x rank {} / ghat rank {} (want 2)",
            job.user, job.site, job.x.shape().len(), job.ghat.shape().len()
        );
    }
    let (xn, xd) = job.x.dims2();
    let (gn, gd) = job.ghat.dims2();
    let (d_in, d_out) = match params {
        AdapterParams::LowRank { a, b } => (a.shape()[0], b.shape()[1]),
        AdapterParams::Linear { w } => (w.shape()[0], w.shape()[1]),
        AdapterParams::Mlp { w1, w2, .. } => (w1.shape()[0], w2.shape()[1]),
    };
    if xn != gn || xd != d_in || gd != d_out {
        bail!(
            "fit job for (user {}, site {}): x ({xn}, {xd}) / ghat ({gn}, {gd}) \
             do not match adapter dims ({d_in} -> {d_out})",
            job.user, job.site
        );
    }
    Ok(())
}

/// The bounded event loop behind one local worker: a SINGLE thread
/// multiplexing every user sharded onto it — which is why 10^6 users
/// never mean 10^6 threads. The core is built by the spawner (so a bad
/// page dir fails the spawn) and moved in here.
fn worker_main(core: WorkerCore, rx: Receiver<WorkerCmd>) {
    // a local pool is single-tenant: every key lives under tenant ""
    while let Ok(cmd) = rx.recv() {
        match cmd {
            WorkerCmd::Register { user, site, adapter } => {
                // the one-command-at-a-time channel protocol rules out the
                // only register failure mode (a concurrent fit on the key)
                let _ = core.register("", user, &site, adapter);
            }
            WorkerCmd::Fit(job, reply) => {
                let _ = reply.send(core.fit("", job));
            }
            WorkerCmd::Snapshot { user, site, reply } => {
                let _ = reply.send(core.snapshot("", user, &site));
            }
            WorkerCmd::StateBytes(reply) => {
                let _ = reply.send(core.state_bytes());
            }
            WorkerCmd::Export { user, site, reply } => {
                let _ = reply.send(core.export_state("", user, &site));
            }
            WorkerCmd::Import { blob, reply } => {
                let _ = reply.send(core.import_state("", &blob).map(|_| ()));
            }
            WorkerCmd::Evict { user, site, reply } => {
                let _ = reply.send(core.evict_state("", user, &site));
            }
            WorkerCmd::PageStats(reply) => {
                let _ = reply.send(core.page_stats());
            }
            WorkerCmd::Shutdown => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_model_delay_monotone() {
        let tm = TransferModel::cpu_link();
        assert!(tm.delay_for(1 << 20) < tm.delay_for(1 << 24));
        assert!(tm.delay_for(0) >= tm.latency);
    }

    #[test]
    fn gpu_link_faster() {
        let bytes = 8 << 20;
        assert!(TransferModel::gpu_link().delay_for(bytes)
                < TransferModel::cpu_link().delay_for(bytes));
    }

    #[test]
    fn spawn_zero_workers_is_error() {
        let m = Arc::new(crate::runtime::native::builtin::builtin_manifest(
            std::path::Path::new("artifacts"),
        ));
        let err = WorkerPool::spawn(0, OffloadTarget::NativeCpu, m, None).unwrap_err();
        assert!(format!("{err}").contains("at least one worker"), "{err}");
    }

    fn manifest() -> Arc<crate::runtime::Manifest> {
        Arc::new(crate::runtime::native::builtin::builtin_manifest(
            std::path::Path::new("artifacts"),
        ))
    }

    fn lowrank_adapter(seed: u64) -> SiteAdapter {
        use crate::adapters::OptimizerCfg;
        let mut rng = crate::rng::Rng::new(seed);
        let params =
            AdapterParams::init(crate::config::AdapterKind::LowRank, 6, 4, 3, 5, &mut rng);
        SiteAdapter::new("s", params, &OptimizerCfg::sgd(0.1, 0.0))
    }

    fn job_for(user: usize, site: &str, rows: usize) -> FitJob {
        FitJob {
            user,
            site: site.to_string(),
            x: Tensor::from_fn(&[rows, 6], |i| (i as f32).sin()),
            ghat: Tensor::from_fn(&[rows, 4], |i| (i as f32).cos()),
            grad_scale: 1.0,
            merged: false,
        }
    }

    /// Pin the sharding contract: `shard_of` is the rendezvous winner
    /// over the member keys, `for_user` dispatches on it, and the
    /// mapping matches the standalone [`rendezvous_owner`] (which `cola
    /// pool` uses offline — the two must never disagree).
    #[test]
    fn for_user_sharding_is_rendezvous_over_member_keys() {
        let pool = WorkerPool::spawn(3, OffloadTarget::NativeCpu, manifest(), None).unwrap();
        assert_eq!(pool.len(), 3);
        let keys = pool.keys();
        assert_eq!(keys, vec!["local-0", "local-1", "local-2"]);
        let mut seen = BTreeSet::new();
        for user in 0..64 {
            let shard = pool.shard_of(user).unwrap();
            assert_eq!(shard, rendezvous_owner(&keys, user).unwrap());
            assert_eq!(pool.for_user(user).unwrap().id(), pool.worker(shard).id());
            seen.insert(shard);
        }
        // 64 users over 3 members: every member owns someone
        assert_eq!(seen.len(), 3, "rendezvous left a member idle: {seen:?}");
    }

    /// The elasticity property the whole migration design leans on:
    /// adding a member moves users ONLY onto the new member, and
    /// removing it restores the exact original mapping.
    #[test]
    fn rendezvous_add_moves_only_the_minimal_user_set() {
        let two = member_keys(&["a:1".into(), "b:1".into()]);
        let three = member_keys(&["a:1".into(), "b:1".into(), "c:1".into()]);
        let mut moved = 0;
        for user in 0..500 {
            let before = &two[rendezvous_owner(&two, user).unwrap()];
            let after = &three[rendezvous_owner(&three, user).unwrap()];
            if before != after {
                assert_eq!(after, "c:1", "user {user} moved {before} -> {after}");
                moved += 1;
            }
        }
        // roughly a third should move; certainly not none, and far from all
        assert!(moved > 0, "adding a member stole no users");
        assert!(moved < 400, "adding one member reshuffled {moved}/500 users");
        // users NOT owned by c under the three-member set are unaffected
        // by c's removal — removal only re-homes the removed member's own
        // users (the weights of survivors never change)
        for user in 0..500 {
            let o3 = rendezvous_owner(&three, user).unwrap();
            if three[o3] != "c:1" {
                assert_eq!(two[rendezvous_owner(&two, user).unwrap()], three[o3]);
            }
        }
    }

    /// The empty-member-set regression: a pool whose last member was
    /// removed (or marked dead) before a placement must answer with a
    /// named error, never an assert/index panic. The standalone
    /// `rendezvous_owner` (used offline by `cola pool`) and the pool's
    /// own placement surface agree on this.
    #[test]
    fn empty_member_set_is_a_named_error_not_a_panic() {
        let err = rendezvous_owner(&[], 7).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("empty member set"), "{msg}");
        assert!(msg.contains("user 7"), "{msg}");

        let mut pool =
            WorkerPool::spawn(1, OffloadTarget::NativeCpu, manifest(), None).unwrap();
        // placement works while the member lives...
        assert_eq!(pool.shard_of(3).unwrap(), 0);
        // ...then the operator removes the last member before the next
        // dispatch (the exact sequence that used to panic)
        let m = pool.remove_member(0);
        m.transport().shutdown();
        assert_eq!(pool.len(), 0);
        for res in [
            pool.shard_of(3).map(|_| ()),
            pool.owner_key(3).map(|_| ()),
            pool.for_user(3).map(|_| ()),
            pool.place_user(3, &BTreeMap::new(), &BTreeSet::new()).map(|_| ()),
        ] {
            let msg = format!("{}", res.unwrap_err());
            assert!(msg.contains("empty member set"), "{msg}");
            assert!(msg.contains("user 3"), "{msg}");
        }
        // no owner -> no buddy, and still no panic
        assert_eq!(pool.buddy_of(3), None);
    }

    #[test]
    fn member_keys_deduplicate_shared_daemons() {
        let keys = member_keys(&["a:1".into(), "a:1".into(), "b:1".into(), "a:1".into()]);
        assert_eq!(keys, vec!["a:1", "a:1#2", "b:1", "a:1#3"]);
        for k in &keys {
            assert_eq!(key_addr(k), if k.starts_with('a') { "a:1" } else { "b:1" });
        }
        // a non-suffix '#' (not a number) is part of the address
        assert_eq!(key_addr("weird#host"), "weird#host");
    }

    #[test]
    fn core_state_export_import_round_trips_bitwise() {
        use crate::adapters::OptimizerCfg;
        let core = WorkerCore::new(0, OffloadTarget::NativeCpu, manifest(), None);
        // AdamW so the blob carries non-trivial moments, not just params
        let mut rng = crate::rng::Rng::new(9);
        let params =
            AdapterParams::init(crate::config::AdapterKind::LowRank, 6, 4, 3, 5, &mut rng);
        let adapter = SiteAdapter::new("s", params, &OptimizerCfg::adamw(1e-3, 1e-4));
        core.register("", 3, "s", adapter).unwrap();
        // advance past init so moments are non-trivial
        core.fit("", job_for(3, "s", 5)).unwrap();
        let blob = core.export_state("", 3, "s").unwrap();

        let fresh = WorkerCore::new(1, OffloadTarget::NativeCpu, manifest(), None);
        let (user, site) = fresh.import_state("", &blob).unwrap();
        assert_eq!((user, site.as_str()), (3, "s"));

        // bitwise-equal snapshot...
        let a = core.snapshot("", 3, "s").unwrap();
        let b = fresh.snapshot("", 3, "s").unwrap();
        for (x, y) in a.tensors().into_iter().zip(b.tensors()) {
            assert_eq!(x, y, "imported params diverged from the source");
        }
        // ...and a bitwise-equal NEXT fit (moments made the trip too)
        let r1 = core.fit("", job_for(3, "s", 4)).unwrap();
        let r2 = fresh.fit("", job_for(3, "s", 4)).unwrap();
        let (p1, p2) = (r1.new_params.unwrap(), r2.new_params.unwrap());
        assert_eq!(p1.len(), p2.len());
        for (x, y) in p1.iter().zip(&p2) {
            assert_eq!(x, y, "post-import fit diverged — moments were not bit-exact");
        }
    }

    #[test]
    fn core_import_rejects_garbage_and_evict_is_idempotent() {
        let core = WorkerCore::new(0, OffloadTarget::NativeCpu, manifest(), None);
        assert!(core.import_state("", &[]).is_err());
        assert!(core.import_state("", &[1, 2, 3, 4]).is_err());
        // exporting a missing key names it
        let err = core.export_state("", 0, "s").unwrap_err();
        assert!(format!("{err}").contains("no adapter"), "{err}");
        // evict: absent key is a no-op, present key actually frees state
        core.evict_state("", 0, "s").unwrap();
        core.register("", 0, "s", lowrank_adapter(1)).unwrap();
        assert!(core.state_bytes() > 0);
        core.evict_state("", 0, "s").unwrap();
        assert_eq!(core.state_bytes(), 0);
        core.evict_state("", 0, "s").unwrap();
    }

    #[test]
    fn core_export_respects_tenant_namespaces() {
        let core = WorkerCore::new(0, OffloadTarget::NativeCpu, manifest(), None);
        core.register("a", 0, "s", lowrank_adapter(1)).unwrap();
        assert!(core.export_state("b", 0, "s").is_err());
        let blob = core.export_state("a", 0, "s").unwrap();
        // importing under another tenant lands in THAT namespace
        core.import_state("b", &blob).unwrap();
        assert!(core.snapshot("b", 0, "s").is_ok());
    }

    #[test]
    fn core_batch_matches_serial_fits_bitwise() {
        let core = WorkerCore::new(0, OffloadTarget::NativeCpu, manifest(), None);
        let serial = WorkerCore::new(0, OffloadTarget::NativeCpu, manifest(), None);
        for user in 0..4 {
            core.register("", user, "s", lowrank_adapter(7 + user as u64)).unwrap();
            serial.register("", user, "s", lowrank_adapter(7 + user as u64)).unwrap();
        }
        let batch: Vec<FitJob> = (0..4).map(|u| job_for(u, "s", 5)).collect();
        let rs = core.fit_batch("", batch);
        for (u, r) in rs.into_iter().enumerate() {
            let r = r.unwrap();
            assert_eq!(r.user, u);
            let s = serial.fit("", job_for(u, "s", 5)).unwrap();
            let a = r.new_params.unwrap();
            let b = s.new_params.unwrap();
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x, y, "batched fit diverged from serial fit for user {u}");
            }
        }
    }

    #[test]
    fn core_duplicate_key_in_batch_is_per_job_error_not_deadlock() {
        let core = WorkerCore::new(0, OffloadTarget::NativeCpu, manifest(), None);
        core.register("", 0, "s", lowrank_adapter(1)).unwrap();
        let rs = core.fit_batch("", vec![job_for(0, "s", 3), job_for(0, "s", 3)]);
        assert_eq!(rs.len(), 2);
        assert!(rs[0].is_ok());
        let err = format!("{:#}", rs[1].as_ref().unwrap_err());
        assert!(err.contains("busy"), "{err}");
        // the adapter checked back in: a later fit works again
        core.fit("", job_for(0, "s", 3)).unwrap();
    }

    #[test]
    fn core_tenants_are_isolated() {
        let core = WorkerCore::new(0, OffloadTarget::NativeCpu, manifest(), None);
        core.register("a", 0, "s", lowrank_adapter(1)).unwrap();
        core.register("b", 0, "s", lowrank_adapter(2)).unwrap();
        // fitting tenant a's adapter must not move tenant b's
        let before_b = core.snapshot("b", 0, "s").unwrap();
        core.fit("a", job_for(0, "s", 4)).unwrap();
        let after_b = core.snapshot("b", 0, "s").unwrap();
        for (x, y) in before_b.tensors().into_iter().zip(after_b.tensors()) {
            assert_eq!(x, y, "tenant b's adapter moved when tenant a trained");
        }
        // and the default tenant has no such adapter at all
        let err = core.snapshot("", 0, "s").unwrap_err();
        assert!(format!("{err}").contains("no adapter"), "{err}");
    }

    #[test]
    fn core_shape_mismatch_is_error_not_panic() {
        let core = WorkerCore::new(0, OffloadTarget::NativeCpu, manifest(), None);
        core.register("", 0, "s", lowrank_adapter(1)).unwrap();
        let mut bad = job_for(0, "s", 3);
        bad.ghat = Tensor::zeros(&[3, 9]); // adapter d_out is 4
        let err = core.fit("", bad).unwrap_err();
        assert!(format!("{err}").contains("do not match adapter dims"), "{err}");
        // the adapter survived the rejected job
        core.fit("", job_for(0, "s", 3)).unwrap();
    }

    /// Pin the load-quantization table the ADR documents: power-of-two
    /// bands against max(upper-median, 1), with everything at >= 8x the
    /// median landing in the shed tier.
    #[test]
    fn quantize_loads_tiers_by_powers_of_two_over_the_median() {
        let loads: BTreeMap<String, u64> = [
            ("a".to_string(), 7u64),   // < 2x median(4)  -> 0
            ("b".to_string(), 9),      // < 4x            -> 1
            ("c".to_string(), 20),     // < 8x            -> 2
            ("d".to_string(), 40),     // >= 8x           -> shed
            ("e".to_string(), 4),
            ("f".to_string(), 4),
            ("g".to_string(), 4),
            ("h".to_string(), 4),
            ("i".to_string(), 4),
        ]
        .into_iter()
        .collect();
        // sorted snapshot [4,4,4,4,4,7,9,20,40]: upper median vals[4]
        // = 4, so the band edges sit at 8 / 16 / 32
        let tiers = quantize_loads(&loads);
        assert_eq!(tiers["a"], 0);
        assert_eq!(tiers["b"], 1);
        assert_eq!(tiers["c"], 2);
        assert_eq!(tiers["d"], SHED_TIER);
        // an idle fleet (all zeros) clamps the median to 1 and nobody
        // gets shed
        let idle: BTreeMap<String, u64> =
            [("x".to_string(), 0u64), ("y".to_string(), 0)].into_iter().collect();
        assert!(quantize_loads(&idle).values().all(|&t| t == 0));
        // ...but a member 10x above an idle fleet still sheds
        let one_hot: BTreeMap<String, u64> =
            [("x".to_string(), 0u64), ("y".to_string(), 0), ("z".to_string(), 10)]
                .into_iter()
                .collect();
        assert_eq!(quantize_loads(&one_hot)["z"], SHED_TIER);
    }

    /// The ISSUE acceptance scenario: a member reporting 10x the fleet
    /// median load receives no NEW users at the next placement, while
    /// every existing shard stays exactly where it was.
    #[test]
    fn hot_member_sheds_new_users_but_existing_shards_stay_put() {
        let mut pool =
            WorkerPool::spawn(3, OffloadTarget::NativeCpu, manifest(), None).unwrap();
        let keys = pool.keys();
        let before: Vec<usize> = (0..32).map(|u| pool.shard_of(u).unwrap()).collect();
        let loads: BTreeMap<String, u64> = [
            (keys[0].clone(), 4u64),
            (keys[1].clone(), 40), // 10x the fleet median
            (keys[2].clone(), 4),
        ]
        .into_iter()
        .collect();
        let tiers = quantize_loads(&loads);
        assert_eq!(tiers[&keys[1]], SHED_TIER);
        let exclude = BTreeSet::new();
        let mut diverted = 0;
        for u in 100..164 {
            let placed = pool.place_user(u, &tiers, &exclude).unwrap();
            assert_ne!(placed, 1, "hot member was handed new user {u}");
            if pool.shard_of(u).unwrap() != rendezvous_owner(&keys, u).unwrap() {
                diverted += 1;
            }
        }
        // the hot member would have won some of those users under plain
        // HRW — shedding must actually have diverted them
        assert!(diverted > 0, "shed tier never diverged from plain HRW");
        // existing users (placed before the load snapshot) never moved
        for (u, b) in before.iter().enumerate() {
            assert_eq!(pool.shard_of(u).unwrap(), *b, "existing shard {u} moved");
        }
        // once the member cools off, re-placing a diverted user sends it
        // home and clears the override (plain HRW and shard_of agree)
        for u in 100..164 {
            pool.place_user(u, &BTreeMap::new(), &exclude).unwrap();
            assert_eq!(pool.shard_of(u).unwrap(), rendezvous_owner(&keys, u).unwrap());
        }
    }

    /// When every member is shed or excluded, placement falls back to
    /// plain HRW (a hot owner beats no owner) and records no override.
    #[test]
    fn place_user_falls_back_to_plain_hrw_when_nobody_is_eligible() {
        let mut pool =
            WorkerPool::spawn(2, OffloadTarget::NativeCpu, manifest(), None).unwrap();
        let keys = pool.keys();
        // in-process members share the empty addr, so excluding "" is
        // "exclude everyone" — the degenerate case we want
        let exclude: BTreeSet<String> = [String::new()].into_iter().collect();
        for u in 0..16 {
            let placed = pool.place_user(u, &BTreeMap::new(), &exclude).unwrap();
            assert_eq!(placed, rendezvous_owner(&keys, u).unwrap());
            assert_eq!(pool.shard_of(u).unwrap(), placed);
        }
    }

    /// The buddy is the rendezvous runner-up: the HRW winner among the
    /// non-owner members — exactly where the survivor remap re-homes
    /// the user when the owner dies, which is what makes promotion
    /// zero-copy. Never the owner; `None` for a one-member pool.
    #[test]
    fn buddy_is_the_rendezvous_runner_up_and_never_the_owner() {
        let pool = WorkerPool::spawn(3, OffloadTarget::NativeCpu, manifest(), None).unwrap();
        let keys = pool.keys();
        for u in 0..64 {
            let owner = pool.shard_of(u).unwrap();
            let buddy = pool.buddy_of(u).expect("3-member pool must have a buddy");
            assert_ne!(buddy, owner, "buddy shares the owner's failure domain");
            let rest: Vec<String> = keys
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != owner)
                .map(|(_, k)| k.clone())
                .collect();
            assert_eq!(keys[buddy], rest[rendezvous_owner(&rest, u).unwrap()]);
        }
        let solo = WorkerPool::spawn(1, OffloadTarget::NativeCpu, manifest(), None).unwrap();
        assert!(solo.buddy_of(0).is_none());
    }

    /// Buddy promotion is bit-identical to a shadow-checkpoint restore:
    /// both paths import the same `wire::encode_state` blob, so the
    /// promoted adapter's params, moments, and next fit all match.
    #[test]
    fn replica_promotion_matches_checkpoint_restore_bitwise() {
        use crate::adapters::OptimizerCfg;
        let owner = WorkerCore::new(0, OffloadTarget::NativeCpu, manifest(), None);
        let mut rng = crate::rng::Rng::new(17);
        let params =
            AdapterParams::init(crate::config::AdapterKind::LowRank, 6, 4, 3, 5, &mut rng);
        let adapter = SiteAdapter::new("s", params, &OptimizerCfg::adamw(1e-3, 1e-4));
        owner.register("", 3, "s", adapter).unwrap();
        owner.fit("", job_for(3, "s", 5)).unwrap();
        let blob = owner.export_state("", 3, "s").unwrap();

        // the buddy holds the blob passively; a third core plays the
        // shadow-checkpoint restore path
        let buddy = WorkerCore::new(1, OffloadTarget::NativeCpu, manifest(), None);
        buddy.put_replica("", &blob).unwrap();
        // passive bytes are accounted (the replica is real memory)...
        assert!(buddy.state_bytes() >= blob.len());
        let restored = WorkerCore::new(2, OffloadTarget::NativeCpu, manifest(), None);
        restored.import_state("", &blob).unwrap();

        buddy.promote_replica("", 3, "s").unwrap();
        let a = buddy.snapshot("", 3, "s").unwrap();
        let b = restored.snapshot("", 3, "s").unwrap();
        for (x, y) in a.tensors().into_iter().zip(b.tensors()) {
            assert_eq!(x, y, "promoted replica diverged from checkpoint restore");
        }
        let r1 = buddy.fit("", job_for(3, "s", 4)).unwrap();
        let r2 = restored.fit("", job_for(3, "s", 4)).unwrap();
        let (p1, p2) = (r1.new_params.unwrap(), r2.new_params.unwrap());
        assert_eq!(p1.len(), p2.len());
        for (x, y) in p1.iter().zip(&p2) {
            assert_eq!(x, y, "post-promotion fit diverged bit-wise");
        }
        // promotion consumed the replica: a second promotion has
        // nothing to work from
        assert!(buddy.promote_replica("", 3, "s").is_err());
    }

    #[test]
    fn replica_store_rejects_garbage_and_drop_is_idempotent() {
        let core = WorkerCore::new(0, OffloadTarget::NativeCpu, manifest(), None);
        assert!(core.put_replica("", &[]).is_err());
        assert!(core.put_replica("", &[1, 2, 3, 4]).is_err());
        core.register("", 3, "s", lowrank_adapter(5)).unwrap();
        let blob = core.export_state("", 3, "s").unwrap();
        let buddy = WorkerCore::new(1, OffloadTarget::NativeCpu, manifest(), None);
        buddy.put_replica("", &blob).unwrap();
        buddy.drop_replica("", 3, "s");
        // dropped replicas are gone: promotion fails, dropping again is
        // a no-op, and the passive bytes are released
        assert!(buddy.promote_replica("", 3, "s").is_err());
        buddy.drop_replica("", 3, "s");
        assert_eq!(buddy.state_bytes(), 0);
    }
}
