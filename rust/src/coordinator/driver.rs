//! Task drivers: bind a (task, model size, dataset) triple to concrete
//! artifacts, adapter sites, and batch generators — the composable model
//! definition of the framework. The `Trainer` is generic over this.

use anyhow::{anyhow, bail, Result};

use crate::config::{AdapterKind, Method, Task, TrainConfig};
use crate::data::images::{ImageSet, ImgTaskGen, N_CLASSES as IMG_CLASSES};
use crate::data::lm::{LmTaskGen, CATEGORIES, S2S_TASKS};
use crate::data::seqcls::{ClsTaskGen, N_CLASSES as CLS_CLASSES, TASKS as CLS_TASKS};
use crate::data::Split;
use crate::runtime::{Manifest, Value};

/// One adapter site as seen by the coordinator.
#[derive(Clone, Debug)]
pub struct SiteSpec {
    /// site id, e.g. "l0.q", "head", "conv1"
    pub site: String,
    pub d_in: usize,
    pub d_out: usize,
    /// artifact output carrying the hidden input x_m
    pub x_output: String,
    /// artifact output carrying grad_hhat_m
    pub g_output: String,
    /// merged-mode base weight name this site folds into
    pub weight_name: String,
}

/// LM data variants sharing the decoupled LM graphs.
#[derive(Clone, Debug)]
pub enum LmVariant {
    /// instruction mix; None = all categories mixed (the 'Joint' setup)
    Instruct(Option<usize>),
    /// collaboration: user k trains on category k % 8 (Table 4)
    PerUserCategory,
    /// one of the six S2S transforms
    S2s(usize),
    /// pretraining corpus (full-sequence loss)
    Corpus,
}

#[derive(Clone, Debug)]
pub enum TaskData {
    Lm { generator: LmTaskGen, variant: LmVariant },
    SeqCls { generator: ClsTaskGen, task: usize },
    Ic { generator: ImgTaskGen, model: String },
}

/// Resolved driver for one run.
#[derive(Clone, Debug)]
pub struct Driver {
    pub size: String,
    pub task: Task,
    pub data: TaskData,
    pub sites: Vec<SiteSpec>,
    /// base-weight names in artifact input order (empty for IC adapters-
    /// only graphs)
    pub weight_names: Vec<String>,
    pub batch: usize,
    pub seq: usize,
    pub has_acc: bool,
}

impl Driver {
    pub fn new(cfg: &TrainConfig, manifest: &Manifest) -> Result<Driver> {
        match cfg.task {
            Task::Clm | Task::S2s => Self::new_lm(cfg, manifest),
            Task::SeqCls => Self::new_seqcls(cfg, manifest),
        }
    }

    fn lm_weight_names(layers: usize) -> Vec<String> {
        let mut names = vec!["embed".to_string(), "pos".to_string()];
        for i in 0..layers {
            for suffix in ["ln1g", "ln1b", "wq", "wk", "wv", "wo",
                           "ln2g", "ln2b", "w1", "b1", "w2", "b2"] {
                names.push(format!("l{i}.{suffix}"));
            }
        }
        names.push("lnfg".into());
        names.push("lnfb".into());
        names
    }

    fn lm_sites(layers: usize, d: usize) -> Vec<SiteSpec> {
        let mut sites = Vec::new();
        for i in 0..layers {
            for proj in ["q", "v"] {
                sites.push(SiteSpec {
                    site: format!("l{i}.{proj}"),
                    d_in: d,
                    d_out: d,
                    x_output: format!("l{i}.x"),
                    g_output: format!("l{i}.g{proj}"),
                    weight_name: format!("l{i}.w{proj}"),
                });
            }
        }
        sites
    }

    fn new_lm(cfg: &TrainConfig, manifest: &Manifest) -> Result<Driver> {
        let sz = manifest.size(&cfg.size)?;
        let generator = LmTaskGen::new(sz.vocab, sz.seq, cfg.seed);
        let variant = match (&cfg.task, cfg.dataset.as_str()) {
            (Task::S2s, name) => {
                let idx = S2S_TASKS.iter().position(|t| *t == name).ok_or_else(
                    || anyhow!("unknown s2s dataset '{name}' (have {S2S_TASKS:?})"))?;
                LmVariant::S2s(idx)
            }
            (_, "corpus") => LmVariant::Corpus,
            (_, "per-user") => LmVariant::PerUserCategory,
            (_, "default") | (_, "dolly") => LmVariant::Instruct(None),
            (_, name) => {
                let idx = CATEGORIES.iter().position(|c| *c == name).ok_or_else(
                    || anyhow!("unknown clm category '{name}' (have {CATEGORIES:?})"))?;
                LmVariant::Instruct(Some(idx))
            }
        };
        Ok(Driver {
            size: cfg.size.clone(),
            task: cfg.task,
            data: TaskData::Lm { generator, variant },
            sites: Self::lm_sites(sz.layers, sz.d),
            weight_names: Self::lm_weight_names(sz.layers),
            batch: cfg.batch,
            seq: sz.seq,
            has_acc: true,
        })
    }

    fn new_seqcls(cfg: &TrainConfig, manifest: &Manifest) -> Result<Driver> {
        let sz = manifest.size(&cfg.size)?;
        let task = CLS_TASKS
            .iter()
            .position(|t| *t == cfg.dataset)
            .or_else(|| if cfg.dataset == "default" { Some(0) } else { None })
            .ok_or_else(|| anyhow!("unknown seqcls dataset '{}'", cfg.dataset))?;
        let mut sites = Self::lm_sites(sz.layers, sz.d);
        sites.push(SiteSpec {
            site: "head".into(),
            d_in: sz.d,
            d_out: CLS_CLASSES,
            x_output: "head.x".into(),
            g_output: "head.g".into(),
            weight_name: "head.W".into(),
        });
        Ok(Driver {
            size: cfg.size.clone(),
            task: cfg.task,
            data: TaskData::SeqCls {
                generator: ClsTaskGen::new(sz.vocab, sz.seq, cfg.seed),
                task,
            },
            sites,
            weight_names: Self::lm_weight_names(sz.layers),
            batch: cfg.batch,
            seq: sz.seq,
            has_acc: true,
        })
    }

    /// IC driver (from-scratch study). `model` in {linear, mlp, cnn};
    /// `set` in {smnist, scifar}. Not reachable from `Task` — built
    /// directly by the table9 bench and the from-scratch example.
    pub fn new_ic(model: &str, set: &str, batch: usize, seed: u64) -> Result<Driver> {
        let set = ImageSet::parse(set).ok_or_else(|| anyhow!("unknown image set {set}"))?;
        let dims: Vec<(&str, usize, usize)> = match model {
            "linear" => vec![("fc", 28 * 28, IMG_CLASSES)],
            "mlp" => vec![("fc1", 28 * 28, 128), ("fc2", 128, IMG_CLASSES)],
            "cnn" => vec![("conv1", 9, 16), ("conv2", 144, 32),
                          ("fc", 32 * 7 * 7, IMG_CLASSES)],
            other => bail!("unknown ic model '{other}'"),
        };
        let sites = dims
            .iter()
            .map(|(s, din, dout)| SiteSpec {
                site: s.to_string(),
                d_in: *din,
                d_out: *dout,
                x_output: format!("{s}.x"),
                g_output: format!("{s}.g"),
                weight_name: format!("{s}.W"),
            })
            .collect();
        Ok(Driver {
            size: model.to_string(),
            task: Task::Clm, // unused for IC
            data: TaskData::Ic {
                generator: ImgTaskGen::new(set, seed),
                model: model.to_string(),
            },
            sites,
            weight_names: vec![],
            batch,
            seq: 1,
            has_acc: true,
        })
    }

    pub fn is_ic(&self) -> bool {
        matches!(self.data, TaskData::Ic { .. })
    }

    /// Artifact for the decoupled (ColA) step.
    pub fn decoupled_artifact(&self, kind: Option<AdapterKind>, batch: usize) -> String {
        let k = kind.map(|k| k.name()).unwrap_or("none");
        match &self.data {
            TaskData::Lm { .. } => {
                if batch == 8 {
                    format!("lm_fwdbwd_{}_{k}", self.size)
                } else {
                    format!("lm_fwdbwd_{}_{k}_b{batch}", self.size)
                }
            }
            TaskData::SeqCls { .. } => format!("seqcls_fwdbwd_{}_{k}", self.size),
            TaskData::Ic { model, .. } => {
                if kind.is_none() {
                    format!("ic_{model}_fwdbwd_merged")
                } else {
                    format!("ic_{model}_fwdbwd_{k}")
                }
            }
        }
    }

    /// Artifact for a coupled baseline step.
    pub fn coupled_artifact(&self, method: Method, batch: usize) -> String {
        let m = method.baseline_name();
        match &self.data {
            TaskData::Lm { .. } => {
                if batch == 8 {
                    format!("coupled_clm_{}_{m}", self.size)
                } else {
                    format!("coupled_clm_{}_{m}_b{batch}", self.size)
                }
            }
            TaskData::SeqCls { .. } => format!("coupled_seqcls_{}_{m}", self.size),
            TaskData::Ic { model, .. } => format!("ic_{model}_coupled_{m}"),
        }
    }

    /// Batch inputs by artifact input name. `user_batch` is this user's
    /// portion of the global batch.
    pub fn data_inputs(&self, user_batch: usize, user: usize, split: Split,
                       step: u64) -> Vec<(String, Value)> {
        // fold the user into the stream so users see disjoint data
        let ustep = step.wrapping_mul(64).wrapping_add(user as u64);
        match &self.data {
            TaskData::Lm { generator, variant } => {
                let b = match variant {
                    LmVariant::Instruct(cat) => {
                        generator.instruct_batch(user_batch, *cat, split, ustep)
                    }
                    LmVariant::PerUserCategory => {
                        generator.instruct_batch(user_batch, Some(user % 8), split, ustep)
                    }
                    LmVariant::S2s(t) => generator.s2s_batch(user_batch, *t, split, ustep),
                    LmVariant::Corpus => generator.corpus_batch(user_batch, split, ustep),
                };
                vec![
                    ("tokens".into(), b.tokens.into()),
                    ("targets".into(), b.targets.into()),
                    ("mask".into(), b.mask.into()),
                ]
            }
            TaskData::SeqCls { generator, task } => {
                let b = generator.batch(user_batch, *task, split, ustep);
                vec![
                    ("tokens".into(), b.tokens.into()),
                    ("labels".into(), b.labels.into()),
                    ("mask".into(), b.mask.into()),
                ]
            }
            TaskData::Ic { generator, .. } => {
                let b = generator.batch(user_batch, split, ustep);
                vec![
                    ("images".into(), b.images.into()),
                    ("labels".into(), b.labels.into()),
                ]
            }
        }
    }

    /// The init group name for base weights. IC models ship a random
    /// frozen base (`{site}.Wbase`), the learning-from-scratch setup.
    pub fn weights_init_group(&self) -> Option<String> {
        match &self.data {
            TaskData::Ic { model, .. } => Some(format!("ic_base_{model}")),
            _ => Some(format!("lm_{}", self.size)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lm_sites_shape() {
        let sites = Driver::lm_sites(2, 128);
        assert_eq!(sites.len(), 4);
        assert_eq!(sites[0].site, "l0.q");
        assert_eq!(sites[0].weight_name, "l0.wq");
        assert_eq!(sites[3].g_output, "l1.gv");
    }

    #[test]
    fn ic_driver_sites() {
        let d = Driver::new_ic("cnn", "smnist", 32, 0).unwrap();
        assert_eq!(d.sites.len(), 3);
        assert_eq!(d.sites[1].d_in, 144);
        assert_eq!(d.decoupled_artifact(Some(AdapterKind::LowRank), 32),
                   "ic_cnn_fwdbwd_lowrank");
        assert_eq!(d.decoupled_artifact(None, 32), "ic_cnn_fwdbwd_merged");
    }

    #[test]
    fn unknown_ic_model_rejected() {
        assert!(Driver::new_ic("resnet", "smnist", 8, 0).is_err());
    }

    #[test]
    fn weight_names_count() {
        // 2 + 12*L + 2
        assert_eq!(Driver::lm_weight_names(4).len(), 2 + 48 + 2);
    }
}
