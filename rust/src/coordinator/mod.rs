//! The ColA coordinator — the paper's system contribution (L3).
//!
//! - `driver`  — binds (task, size, dataset) to artifacts/sites/batches
//! - `buffer`  — adaptation-interval buffering (Algorithm 1 lines 10-16)
//! - `offload` — Gradient Offloading worker pool ("low-cost devices");
//!   dispatches through `crate::transport` (in-process or TCP daemons)
//! - `registry` — self-assembling fleet membership (`cola worker
//!   --join`): lifecycle book + announce listener + buddy replication's
//!   placement inputs
//! - `server`  — the training loop (Algorithm 1) + coupled baselines
//! - `api`     — FTaaS service facade (Figure 1)
//!
//! The [`crate::gateway`] serves this layer over HTTP: its job runner
//! drives [`Trainer::run_with_progress`] and exports adapters with
//! [`Trainer::export_adapter_bundle`].

pub mod api;
pub mod buffer;
pub mod driver;
pub mod offload;
pub mod registry;
pub mod server;

pub use api::FtaasService;
pub use buffer::AdaptationBuffers;
pub use driver::{Driver, LmVariant, SiteSpec, TaskData};
pub use offload::{
    key_addr, member_keys, quantize_loads, rebalance_daemons, rendezvous_owner, FitJob,
    FitResult, MigrationStats, PoolMember, PoolSupervisor, TransferModel, Worker,
    WorkerCore, WorkerPool,
};
pub use registry::{join_coordinator, MemberState, RegistryServer, WorkerRegistry};
pub use server::{Progress, RunReport, Trainer};
