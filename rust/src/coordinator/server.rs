//! The ColA training server — Algorithm 1 end to end.
//!
//! Per training iteration t:
//!   1. sample a batch across the K collaborating users;
//!   2. run the decoupled fwd/bwd artifact on the *server device* (the
//!      GPU of the paper): forward through base + adapters (unmerged) or
//!      merged weights, backward producing grad_hhat — and NO parameter
//!      gradients;
//!   3. ship each user's (x_m, grad_hhat_m) slices into the adaptation
//!      buffers (Gradient Offloading);
//!   4. every I steps, drain buffers into FitJobs dispatched to the
//!      worker pool; workers fit the surrogate (Prop. 1) and step their
//!      optimizers; replies refresh the server state (new adapter
//!      buffers, or merged-weight delta diffs).
//!
//! Coupled baselines (FT/LoRA/IA3/prompt/...) run through their own
//! artifacts with the optimizer on the server — the thing ColA avoids.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::buffer::AdaptationBuffers;
use super::driver::{Driver, TaskData};
use super::offload::{FitJob, FitResult, PoolSupervisor, TransferModel, WorkerPool};
use super::registry::{RegistryServer, WorkerRegistry};
use crate::adapters::{AdapterParams, OptState, OptimizerCfg, SiteAdapter};
use crate::config::{AdapterKind, FailoverPolicy, Method, Mode, Optimizer, SimdMode,
                    Task, TrainConfig, TransportKind};
use crate::data::Split;
use crate::merge;
use crate::metrics::{Curve, Timings};
use crate::runtime::{Input, Runtime, Value};
use crate::tensor::{self, Tensor};
use crate::transport::tcp::TcpLinkOpts;
use crate::transport::{wire, Transport};
use crate::util::json::Json;

/// Summary of a finished run (consumed by benches/examples).
#[derive(Clone, Debug)]
pub struct RunReport {
    pub train_loss: Curve,
    pub train_acc: Curve,
    pub eval_loss: Curve,
    pub eval_acc: Curve,
    pub timings: Timings,
    pub trainable_params: usize,
    pub server_resident_bytes: usize,
    pub worker_state_bytes: usize,
}

impl RunReport {
    /// End-of-training quality score in [0,100] (the ROUGE/GLUE stand-in:
    /// tail-mean eval accuracy x 100).
    pub fn score(&self) -> f64 {
        100.0 * self.eval_acc.tail_mean(3)
    }

    /// Loss/accuracy curves as stable JSON. f64 values print in Rust's
    /// shortest round-trip form, so two runs diff byte-equal iff their
    /// curves are bit-identical — the contract the `distributed-smoke`
    /// CI job checks across transports, and the gateway-vs-CLI contract
    /// `gateway-smoke` checks across entry points. `cola train
    /// --loss_out` and the gateway's `/v1/jobs/{id}/curves` endpoint
    /// both serialize through here, so "byte-identical" is trivially
    /// the same function on both sides.
    pub fn curves_json(&self) -> String {
        fn num(v: f64) -> Json {
            if v.is_finite() {
                Json::Num(v)
            } else {
                // JSON has no NaN/inf tokens; a diverged run must still
                // produce a parseable (and still deterministic) file
                Json::Str(v.to_string())
            }
        }
        fn curve(c: &Curve) -> Json {
            Json::Arr(
                c.points
                    .iter()
                    .map(|(s, v)| Json::Arr(vec![Json::Num(*s as f64), num(*v)]))
                    .collect(),
            )
        }
        let mut obj = BTreeMap::new();
        obj.insert("train_loss".to_string(), curve(&self.train_loss));
        obj.insert("train_acc".to_string(), curve(&self.train_acc));
        obj.insert("eval_loss".to_string(), curve(&self.eval_loss));
        obj.insert("eval_acc".to_string(), curve(&self.eval_acc));
        format!("{}\n", Json::Obj(obj))
    }
}

/// One observation of a running training loop, delivered to the
/// [`Trainer::run_with_progress`] callback after every step (and once
/// more after the final drain + eval). Values are copied out of the
/// trainer so observers never borrow it — the byte-identity contract
/// holds because observation cannot perturb the run.
#[derive(Clone, Debug)]
pub struct Progress {
    /// Step index `t` (== `cfg.steps` for the final post-drain event).
    pub step: u64,
    /// This step's training loss (NaN on the final event of a 0-step run).
    pub train_loss: f32,
    /// This step's training accuracy, for tasks that report one.
    pub train_acc: Option<f32>,
    /// Mean held-out loss, present when this step sat on an
    /// `eval_every` boundary (and always on the final event).
    pub eval_loss: Option<f64>,
    /// Mean held-out accuracy when evaluated and the task reports one.
    pub eval_acc: Option<f64>,
    /// True when this step flushed adaptation buffers to the worker
    /// pool (`(step + 1) % interval == 0`, plus the final drain). The
    /// gateway streams one progress line per boundary.
    pub interval_boundary: bool,
    /// Cumulative adaptation-pair bytes fetched off the server device.
    pub bytes_offloaded: u64,
    /// Cumulative fit-reply bytes returned by workers.
    pub bytes_returned: u64,
}

/// One dispatched-but-unapplied worker fit. Carrying (user, site) next
/// to the reply channel lets a dead worker link surface as an error
/// naming exactly whose update was lost — not a bare channel panic.
/// With `failover = "migrate"` the job itself rides along too, so a
/// fit lost to a dying daemon can be re-dispatched against the restored
/// shadow checkpoint.
struct PendingFit {
    user: usize,
    site: String,
    job: Option<FitJob>,
    rx: std::sync::mpsc::Receiver<Result<FitResult>>,
}

/// One fit of the interval being settled: its identity, the retained
/// job (migrate mode), its current outcome, and whether its shadow
/// checkpoint already reflects this interval's optimizer step. The
/// `refreshed` bit is what makes recovery exactly-once: a slot whose
/// checkpoint is still pre-step must be re-run after a restore (the
/// step died with the daemon), while a refreshed slot must NOT be (the
/// restore already carries the step — re-running would double-apply).
struct IntervalSlot {
    user: usize,
    site: String,
    job: Option<FitJob>,
    outcome: Result<FitResult>,
    refreshed: bool,
}

/// Recovery rounds per interval before giving up: each round can absorb
/// one more member death (sweep -> fail over -> re-dispatch), so this
/// bounds cascading failures, not ordinary operation.
const MAX_RECOVERY_ROUNDS: usize = 4;

/// How long an all-dynamic trainer (`worker_addrs` empty, registry
/// bound) waits for the first `cola worker --join` announce before
/// failing loudly.
const BOOTSTRAP_JOIN_WAIT: Duration = Duration::from_secs(60);

/// Move a slot's error out (leaving a tombstone) so it can be returned
/// by value with context attached.
fn take_slot_error(s: &mut IntervalSlot) -> anyhow::Error {
    std::mem::replace(&mut s.outcome, Err(anyhow!("error already reported")))
        .expect_err("take_slot_error on an Ok slot")
}

pub struct Trainer {
    pub cfg: TrainConfig,
    pub rt: Runtime,
    pub driver: Driver,
    /// authoritative host copy of base (or merged) weights
    weights: BTreeMap<String, Tensor>,
    /// coordinator-side cache of coupled-baseline tunables
    tunables: BTreeMap<String, Tensor>,
    coupled_opt: Option<OptState>,
    pool: Option<WorkerPool>,
    /// elastic-pool health + migration (tcp transport only)
    supervisor: Option<PoolSupervisor>,
    /// fleet membership book (tcp transport only); shared with the
    /// supervisor and, when `registry_listen` is set, with the announce
    /// listener thread
    registry: Option<std::sync::Arc<std::sync::Mutex<WorkerRegistry>>>,
    /// the `cola worker --join` announce listener; held so it serves
    /// for the life of the run and stops on drop
    registry_server: Option<RegistryServer>,
    /// fits transiently lost to dying daemons and recovered by
    /// re-dispatch, in loss order — each names its (user, site)
    lost: Vec<(usize, String)>,
    /// in-flight worker fits (async offload overlap)
    pending: Vec<PendingFit>,
    buffers: AdaptationBuffers,
    pub timings: Timings,
    opt_cfg: OptimizerCfg,
    trainable_params: usize,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Trainer> {
        let rt = Runtime::load(&cfg.artifacts_dir)?;
        let driver = Driver::new(&cfg, &rt.manifest)?;
        Self::with_driver(cfg, rt, driver)
    }

    /// Build with an explicit driver (the IC study constructs its own).
    pub fn with_driver(cfg: TrainConfig, rt: Runtime, driver: Driver) -> Result<Trainer> {
        cfg.validate()?;
        // apply this run's tensor-engine width (0 = back to auto).
        // Uniform semantics: every Trainer construction sets the global
        // override from its own config, so a pin from an earlier run in
        // the same process can't silently leak into this one. Results
        // are thread-count independent; this is a wall-clock knob.
        tensor::pool::set_threads(cfg.threads);
        // kernel dispatch tier, same uniform-override semantics: `auto`
        // leaves the COLA_SIMD env decision in place, anything explicit
        // pins the process-wide policy for this run
        tensor::simd::set_policy(match cfg.simd {
            SimdMode::Auto => None,
            SimdMode::Off => Some(tensor::simd::Policy::Off),
            SimdMode::On => Some(tensor::simd::Policy::Auto),
            SimdMode::Fma => Some(tensor::simd::Policy::Fma),
        });
        if cfg.users > 1 && cfg.mode != Mode::Merged {
            bail!("multi-user training in one server requires mode=merged \
                   (the 'Alone' arm of Table 4 is separate runs)");
        }
        if cfg.users > 1 && cfg.batch % cfg.users != 0 {
            bail!("batch ({}) must divide evenly across users ({})",
                  cfg.batch, cfg.users);
        }
        let opt_cfg = match cfg.optimizer {
            Optimizer::Sgd => OptimizerCfg::sgd(cfg.lr, cfg.weight_decay),
            Optimizer::AdamW => OptimizerCfg::adamw(cfg.lr, cfg.weight_decay),
        };
        let mut t = Trainer {
            cfg,
            rt,
            driver,
            weights: BTreeMap::new(),
            tunables: BTreeMap::new(),
            coupled_opt: None,
            pool: None,
            supervisor: None,
            registry: None,
            registry_server: None,
            lost: Vec::new(),
            pending: Vec::new(),
            buffers: AdaptationBuffers::default(),
            timings: Timings::default(),
            opt_cfg,
            trainable_params: 0,
        };
        t.init_weights()?;
        match t.cfg.method {
            Method::Cola(kind) => t.init_cola(kind)?,
            m => t.init_coupled(m)?,
        }
        Ok(t)
    }

    // ------------------------------------------------------------------
    // initialization
    // ------------------------------------------------------------------

    fn init_weights(&mut self) -> Result<()> {
        if let Some(group) = self.driver.weights_init_group() {
            self.weights = self.rt.manifest.load_init(&group)?;
        }
        if self.cfg.task == Task::SeqCls && self.cfg.mode == Mode::Merged {
            // merged-mode classifier head starts at zero (trained through
            // the head's linear adapter)
            let d = self.rt.manifest.size(&self.cfg.size)?.d;
            let c = self.rt.manifest.n_classes_seqcls;
            self.weights.insert("head.W".into(), Tensor::zeros(&[d, c]));
        }
        if self.driver.is_ic() && self.cfg.mode == Mode::Merged {
            // from-scratch merged: merged weights start at the random
            // base init ({site}.Wbase -> {site}.W)
            let base: Vec<(String, Tensor)> = self
                .weights
                .iter()
                .filter_map(|(k, v)| {
                    k.strip_suffix(".Wbase")
                        .map(|s| (format!("{s}.W"), v.clone()))
                })
                .collect();
            self.weights.extend(base);
        }
        for (name, t) in &self.weights {
            self.rt
                .server
                .upload(&format!("w.{name}"), Value::F32(t.clone()))?;
        }
        Ok(())
    }

    fn init_cola(&mut self, kind: AdapterKind) -> Result<()> {
        let transfer = None::<TransferModel>;
        let migrate = self.cfg.failover == FailoverPolicy::Migrate;
        let mut link = TcpLinkOpts {
            tenant: self.cfg.offload_tenant.clone(),
            batch: self.cfg.offload_batch,
            inflight: self.cfg.offload_inflight,
            wire: self.cfg.offload_wire,
            ..TcpLinkOpts::default()
        };
        if migrate {
            // recovery owns retries under migrate: a long blind
            // reconnect backoff against a dead daemon would only delay
            // the failover that actually fixes things
            link.attempts = 2;
            link.base = Duration::from_millis(30);
        }
        let (pool, mut supervisor) = match self.cfg.offload_transport {
            TransportKind::Local => (
                // state_working_set bounds resident adapters per worker;
                // cold shards page to state_page_dir as bit-exact
                // wire::encode_state blobs (curves are byte-identical
                // paging on or off — crate::scale::store)
                WorkerPool::spawn_paged(
                    self.cfg.workers,
                    self.cfg.offload,
                    self.rt.manifest.clone(),
                    transfer,
                    (self.cfg.state_working_set > 0).then(|| {
                        crate::scale::store::PagerCfg {
                            dir: std::path::PathBuf::from(
                                &self.cfg.state_page_dir,
                            ),
                            capacity: self.cfg.state_working_set,
                        }
                    }),
                )?,
                None,
            ),
            // remote daemons pick their own offload target (`cola worker
            // --offload`); determinism holds either way because both
            // targets implement the same Eq. 6 update bit-exactly
            TransportKind::Tcp => {
                // membership book: static worker_addrs enter active (the
                // bootstrap fallback, and how v1/v2 daemons without the
                // registry capability participate); --join daemons flow
                // through joining -> active at sweep boundaries
                let registry = std::sync::Arc::new(std::sync::Mutex::new(
                    WorkerRegistry::new(),
                ));
                for a in &self.cfg.worker_addrs {
                    crate::util::lock_recover(&registry).register_static(a);
                }
                if !self.cfg.registry_listen.is_empty() {
                    let srv =
                        RegistryServer::bind(&self.cfg.registry_listen, registry.clone())?;
                    // greppable by scripts/distributed_smoke.sh registry mode
                    println!("cola: worker registry listening on {}", srv.local_addr());
                    self.registry_server = Some(srv);
                }
                let boot_addrs = if self.cfg.worker_addrs.is_empty() {
                    Self::await_bootstrap_joiners(&registry)?
                } else {
                    self.cfg.worker_addrs.clone()
                };
                let (pool, standbys) = WorkerPool::connect_tcp_with_standbys(
                    &boot_addrs,
                    &self.cfg.standby_addrs,
                    &link,
                )?;
                let sites: Vec<String> =
                    self.driver.sites.iter().map(|s| s.site.clone()).collect();
                let sup = PoolSupervisor::new(
                    self.cfg.users,
                    sites,
                    link.clone(),
                    standbys,
                    migrate,
                    self.cfg.heartbeat_interval,
                )
                .with_registry(registry.clone())
                .with_replication(self.cfg.replicate);
                self.registry = Some(registry);
                (pool, Some(sup))
            }
        };
        let rank = self.rt.manifest.rank;
        let hidden = self.rt.manifest.mlp_hidden;
        let mut rng = crate::rng::Rng::new(self.cfg.seed ^ 0xADA7);
        for user in 0..self.cfg.users {
            for s in &self.driver.sites {
                // the head site is always a 'linear' adapter (§4.2)
                let k = if s.site == "head" { AdapterKind::Linear } else { kind };
                let params = AdapterParams::init(k, s.d_in, s.d_out, rank, hidden,
                                                 &mut rng.fork(user as u64));
                self.trainable_params += params.n_params();
                if self.cfg.mode == Mode::Unmerged {
                    // server-resident copies used by the forward pass
                    for (t, n) in params.tensors().iter().zip(params.tensor_names()) {
                        self.rt.server.upload(
                            &format!("u{user}.{}.{n}", s.site),
                            Value::F32((*t).clone()),
                        )?;
                    }
                }
                let adapter = SiteAdapter::new(&s.site, params, &self.opt_cfg);
                if migrate {
                    // seed the shadow checkpoint (and the buddy replica,
                    // when replication is on) from the state we are
                    // about to install — no extra round-trip needed
                    if let Some(sup) = supervisor.as_mut() {
                        let blob = wire::encode_state(user, &s.site, &adapter);
                        if sup.replicate_enabled() {
                            sup.replicate_shard(&pool, user, &s.site, blob.clone());
                        }
                        sup.checkpoint(user, &s.site, blob);
                    }
                }
                pool.for_user(user)?.register(user, &s.site, adapter)?;
            }
        }
        self.pool = Some(pool);
        self.supervisor = supervisor;
        Ok(())
    }

    /// Bootstrap a pool with no static `worker_addrs`: wait (bounded)
    /// for at least one `cola worker --join` announce, then take every
    /// joiner booked by that moment as the founding membership —
    /// activated directly, since the trainer connects to them before
    /// any training state exists to place.
    fn await_bootstrap_joiners(
        registry: &std::sync::Arc<std::sync::Mutex<WorkerRegistry>>,
    ) -> Result<Vec<String>> {
        // lint:allow(determinism): bootstrap wait only — membership settles before any curve math runs
        let t0 = Instant::now();
        loop {
            let pending = crate::util::lock_recover(registry).pending_joins();
            if !pending.is_empty() {
                let mut reg = crate::util::lock_recover(registry);
                for a in &pending {
                    reg.activate(a);
                }
                drop(reg);
                println!(
                    "cola: bootstrapping the worker pool from {} joined worker(s): {}",
                    pending.len(),
                    pending.join(", ")
                );
                return Ok(pending);
            }
            if t0.elapsed() >= BOOTSTRAP_JOIN_WAIT {
                bail!(
                    "worker_addrs is empty and no worker announced itself within \
                     {}s — start daemons with `cola worker --join <registry addr>` \
                     or set worker_addrs",
                    BOOTSTRAP_JOIN_WAIT.as_secs()
                );
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    }

    /// The fleet membership book (tcp transport only) — read by the
    /// registry integration tests and status output.
    pub fn registry(&self) -> Option<&std::sync::Arc<std::sync::Mutex<WorkerRegistry>>> {
        self.registry.as_ref()
    }

    /// Where the `--join` announce listener is bound, when
    /// `registry_listen` is set (resolves `:0` to the real port).
    pub fn registry_addr(&self) -> Option<std::net::SocketAddr> {
        self.registry_server.as_ref().map(|s| s.local_addr())
    }

    fn init_coupled(&mut self, method: Method) -> Result<()> {
        let m = method.baseline_name();
        self.tunables = match &self.driver.data {
            TaskData::Ic { model, .. } => {
                if method == Method::Ft {
                    // FT trains the site weights directly from the same
                    // random base init the ColA arms use
                    self.rt
                        .manifest
                        .load_init(&format!("ic_base_{model}"))?
                        .into_iter()
                        .map(|(k, v)| {
                            (k.replace(".Wbase", ".W"), v)
                        })
                        .collect()
                } else if method == Method::Lora {
                    self.rt.manifest.load_init(&format!("ic_{model}_lowrank"))?
                } else {
                    bail!("IC supports only ft/lora coupled baselines")
                }
            }
            TaskData::SeqCls { .. } => {
                let mut t = if method == Method::Ft {
                    let mut w = self.rt.manifest
                        .load_init(&format!("lm_{}", self.cfg.size))?;
                    let d = self.rt.manifest.size(&self.cfg.size)?.d;
                    let c = self.rt.manifest.n_classes_seqcls;
                    w.insert("head.W".into(), Tensor::zeros(&[d, c]));
                    w
                } else {
                    self.rt.manifest
                        .load_init(&format!("tunables_seqcls_{}_{m}", self.cfg.size))?
                };
                // FT init group has no head; others include it
                if !t.contains_key("head.W") {
                    let d = self.rt.manifest.size(&self.cfg.size)?.d;
                    let c = self.rt.manifest.n_classes_seqcls;
                    t.insert("head.W".into(), Tensor::zeros(&[d, c]));
                }
                t
            }
            TaskData::Lm { .. } => {
                if method == Method::Ft {
                    self.rt.manifest.load_init(&format!("lm_{}", self.cfg.size))?
                } else {
                    self.rt.manifest
                        .load_init(&format!("tunables_{}_{m}", self.cfg.size))?
                }
            }
        };
        self.trainable_params = self.tunables.values().map(Tensor::len).sum();
        let sizes: Vec<usize> = self.tunables.values().map(Tensor::len).collect();
        self.coupled_opt = Some(OptState::new(&self.opt_cfg, &sizes));
        Ok(())
    }

    // ------------------------------------------------------------------
    // the training loop
    // ------------------------------------------------------------------

    pub fn run(&mut self) -> Result<RunReport> {
        self.run_with_hook(|_, _| Ok(()))
    }

    /// [`Self::run`] with a callback invoked after every training step
    /// (and its interval flush, when the step sits on a boundary). The
    /// chaos/soak harnesses use it to kill, drain, and add pool members
    /// at deterministic points mid-run; operational tooling can use it
    /// for progress reporting.
    pub fn run_with_hook<F>(&mut self, hook: F) -> Result<RunReport>
    where
        F: FnMut(&mut Trainer, u64) -> Result<()>,
    {
        self.run_driven(hook, |_: &Progress| Ok(()))
    }

    /// [`Self::run`] with a read-only [`Progress`] observer invoked
    /// after every step and once more after the final drain + eval. The
    /// gateway's job runner uses it to stream per-interval loss lines
    /// and feed the usage ledger without touching the trainer — the
    /// observer receives copies, never `&mut Trainer`, so it cannot
    /// perturb the run and the loss curves stay byte-identical to
    /// [`Self::run`] on the same config.
    pub fn run_with_progress<P>(&mut self, progress: P) -> Result<RunReport>
    where
        P: FnMut(&Progress) -> Result<()>,
    {
        self.run_driven(|_, _| Ok(()), progress)
    }

    /// The one training-loop body behind [`Self::run`],
    /// [`Self::run_with_hook`], and [`Self::run_with_progress`]: both
    /// observers thread through every path, so combining them later
    /// cannot fork the loop's semantics.
    fn run_driven<F, P>(&mut self, mut hook: F, mut progress: P) -> Result<RunReport>
    where
        F: FnMut(&mut Trainer, u64) -> Result<()>,
        P: FnMut(&Progress) -> Result<()>,
    {
        let mut train_loss = Curve::new("train_loss");
        let mut train_acc = Curve::new("train_acc");
        let mut eval_loss = Curve::new("eval_loss");
        let mut eval_acc = Curve::new("eval_acc");
        for t in 0..self.cfg.steps as u64 {
            let (loss, acc) = self.step(t)?;
            train_loss.push(t, loss as f64);
            if let Some(a) = acc {
                train_acc.push(t, a as f64);
            }
            let mut obs = Progress {
                step: t,
                train_loss: loss,
                train_acc: acc,
                eval_loss: None,
                eval_acc: None,
                interval_boundary: (t + 1) % self.cfg.interval as u64 == 0,
                bytes_offloaded: self.timings.bytes_offloaded,
                bytes_returned: self.timings.bytes_returned,
            };
            if self.cfg.eval_every > 0
                && (t + 1) % self.cfg.eval_every as u64 == 0
            {
                self.collect_pending()?;
                let (el, ea) = self.eval(t)?;
                eval_loss.push(t + 1, el);
                if let Some(a) = ea {
                    eval_acc.push(t + 1, a);
                }
                obs.eval_loss = Some(el);
                obs.eval_acc = ea;
                obs.bytes_returned = self.timings.bytes_returned;
            }
            progress(&obs)?;
            hook(self, t)?;
        }
        // final drain so no adaptation data is dropped
        self.flush_adapters()?;
        self.collect_pending()?;
        let (el, ea) = self.eval(self.cfg.steps as u64)?;
        eval_loss.push(self.cfg.steps as u64, el);
        if let Some(a) = ea {
            eval_acc.push(self.cfg.steps as u64, a);
        }
        progress(&Progress {
            step: self.cfg.steps as u64,
            train_loss: train_loss.last().unwrap_or(f64::NAN) as f32,
            train_acc: None,
            eval_loss: Some(el),
            eval_acc: ea,
            interval_boundary: true,
            bytes_offloaded: self.timings.bytes_offloaded,
            bytes_returned: self.timings.bytes_returned,
        })?;
        // pick up bytes from registration/snapshot traffic that never
        // flowed through a fit interval (collect_pending early-returns
        // when nothing is pending)
        self.drain_wire_bytes();
        Ok(RunReport {
            train_loss,
            train_acc,
            eval_loss,
            eval_acc,
            timings: self.timings.clone(),
            trainable_params: self.trainable_params,
            server_resident_bytes: self.rt.server.resident_bytes()?,
            worker_state_bytes: self
                .pool
                .as_ref()
                .map(|p| p.total_state_bytes())
                .unwrap_or(0),
        })
    }

    /// One training iteration. Returns (loss, acc).
    pub fn step(&mut self, t: u64) -> Result<(f32, Option<f32>)> {
        self.timings.steps += 1;
        match self.cfg.method {
            Method::Cola(kind) => self.step_cola(t, kind),
            m => self.step_coupled(t, m),
        }
    }

    fn artifact_kind(&self) -> Option<AdapterKind> {
        match (self.cfg.mode, self.cfg.method) {
            (Mode::Merged, _) => None,
            (Mode::Unmerged, Method::Cola(k)) => Some(k),
            _ => None,
        }
    }

    /// Assemble + execute the decoupled artifact for one joint batch.
    /// Returns (outputs, exec, compile, host-transfer, bytes fetched).
    fn exec_decoupled(&self, split: Split, t: u64, fetch_adaptation: bool)
                      -> Result<(BTreeMap<String, Value>, std::time::Duration,
                                 std::time::Duration, std::time::Duration,
                                 usize)> {
        let artifact = self
            .driver
            .decoupled_artifact(self.artifact_kind(), self.cfg.batch);
        let per_user = self.cfg.batch / self.cfg.users;
        // joint batch: concatenate per-user sub-batches (row-contiguous)
        let mut parts: Vec<Vec<(String, Value)>> = (0..self.cfg.users)
            .map(|u| self.driver.data_inputs(per_user, u, split, t))
            .collect();
        let data = if self.cfg.users == 1 {
            parts
                .pop()
                .ok_or_else(|| anyhow!("no data batch produced for the single-user run"))?
        } else {
            concat_user_batches(parts)?
        };
        let data_map: BTreeMap<String, Value> = data.into_iter().collect();

        let inputs = self.rt.assemble(&artifact, |io| {
            if let Some(v) = data_map.get(&io.name) {
                return Ok(Input::Val(v.clone()));
            }
            if self.weights.contains_key(&io.name) {
                return Ok(Input::Ref(format!("w.{}", io.name)));
            }
            // unmerged adapter parameter (single-user only)
            Ok(Input::Ref(format!("u0.{}", io.name)))
        })?;

        let spec = self.rt.manifest.artifact(&artifact)?;
        let mut fetch: Vec<&str> = vec!["loss"];
        if self.driver.has_acc {
            fetch.push("acc");
        }
        if fetch_adaptation {
            for s in &self.driver.sites {
                if !fetch.contains(&s.x_output.as_str()) {
                    fetch.push(&s.x_output);
                }
                fetch.push(&s.g_output);
            }
        }
        let _ = spec;
        // lint:allow(determinism): timing ledger only — durations never feed curve math
        let t0 = Instant::now();
        let (outs, res) = self.rt.execute_fetch(&self.rt.server, &artifact,
                                                inputs, &fetch)?;
        let transfer = t0
            .elapsed()
            .saturating_sub(res.exec_time)
            .saturating_sub(res.compile_time);
        if std::env::var("COLA_TRACE").is_ok() {
            eprintln!("[trace] exec {:?} compile {:?} up {:?} fetch {:?} other {:?}",
                      res.exec_time, res.compile_time, res.upload_time,
                      res.fetch_time,
                      transfer.saturating_sub(res.upload_time + res.fetch_time));
        }
        Ok((outs, res.exec_time, res.compile_time, transfer, res.bytes_down))
    }

    fn step_cola(&mut self, t: u64, _kind: AdapterKind) -> Result<(f32, Option<f32>)> {
        let (outs, exec_time, compile, transfer, bytes_down) =
            self.exec_decoupled(Split::Train, t, true)?;
        self.timings.fwdbwd += exec_time;
        self.timings.compile += compile;
        self.timings.transfer += transfer;
        self.timings.bytes_offloaded += bytes_down as u64;

        let loss = outs["loss"].scalar_f32()?;
        let acc = outs.get("acc").and_then(|v| v.scalar_f32().ok());

        // route adaptation data to per-user buffers
        let per_user = self.cfg.batch / self.cfg.users;
        for s in &self.driver.sites {
            let x = outs
                .get(&s.x_output)
                .ok_or_else(|| anyhow!("missing x output {}", s.x_output))?
                .as_f32()
                .ok_or_else(|| anyhow!("x output {} is not f32", s.x_output))?
                .clone()
                .to_rows();
            let g = outs
                .get(&s.g_output)
                .ok_or_else(|| anyhow!("missing grad output {}", s.g_output))?
                .as_f32()
                .ok_or_else(|| anyhow!("grad output {} is not f32", s.g_output))?
                .clone()
                .to_rows();
            let rows = x.dims2().0;
            let rpe = rows / self.cfg.batch; // rows per example
            for u in 0..self.cfg.users {
                let (r0, r1) = (u * per_user * rpe, (u + 1) * per_user * rpe);
                self.buffers
                    .push(u, &s.site, x.rows(r0, r1), g.rows(r0, r1));
            }
        }

        if (t + 1) % self.cfg.interval as u64 == 0 {
            self.flush_adapters()?;
        }
        Ok((loss, acc))
    }

    /// Drain buffers -> dispatch FitJobs -> apply replies. With
    /// async_offload the PREVIOUS interval's in-flight replies are
    /// collected *before* dispatching, so this interval's fits overlap
    /// the next server steps and at most one interval of FitJobs is ever
    /// outstanding (one-interval bounded staleness). The old condition
    /// checked `pending` *after* dispatch, which let two intervals pile
    /// up and then drained both synchronously — every other flush
    /// blocked on work submitted microseconds earlier, erasing the
    /// overlap async_offload exists for.
    fn flush_adapters(&mut self) -> Result<()> {
        if self.pool.is_none() {
            return Ok(());
        }
        if self.cfg.async_offload {
            // the previous interval's fits ran while we served steps;
            // apply them now so the in-flight window never exceeds one
            // interval of jobs
            self.collect_pending()?;
        }
        // proactive liveness sweep at the interval boundary — detect a
        // dead member BEFORE dispatching this interval into its socket
        self.sweep_pool()?;
        if !self.buffers.is_empty() {
            let merged = self.cfg.mode == Mode::Merged;
            let keep_jobs = self
                .supervisor
                .as_ref()
                .map(|s| s.migrate_enabled())
                .unwrap_or(false);
            let jobs = self.buffers.drain_all();
            // re-check instead of unwrap: a worker link error earlier in
            // this interval must not turn into a server panic here
            let pool = self.pool.as_ref().ok_or_else(|| {
                anyhow!("adaptation buffers are non-empty but no worker pool \
                         exists (coupled methods never buffer)")
            })?;
            // Group the interval's jobs per worker so batching transports
            // ship one FitBatch frame per worker instead of one round-trip
            // per job — but KEEP the buffers' drain order for the pending
            // list. Replies are applied in pending order, and merged-mode
            // delta adds are float sums whose order is part of the
            // determinism contract; grouping must never reorder applies.
            let n = jobs.len();
            let mut meta: Vec<(usize, String, Option<FitJob>)> = Vec::with_capacity(n);
            let mut per_worker: BTreeMap<usize, (Vec<usize>, Vec<FitJob>)> =
                BTreeMap::new();
            for (i, (user, site, x, ghat, grad_scale)) in jobs.into_iter().enumerate()
            {
                let job = FitJob { user, site: site.clone(), x, ghat, grad_scale, merged };
                // under failover = "migrate" the job is retained until
                // its reply applies, so a copy can be re-dispatched
                // against a restored checkpoint
                meta.push((user, site, keep_jobs.then(|| job.clone())));
                let slot = per_worker.entry(pool.shard_of(user)?).or_default();
                slot.0.push(i);
                slot.1.push(job);
            }
            let mut slots: Vec<Option<std::sync::mpsc::Receiver<Result<FitResult>>>> =
                (0..n).map(|_| None).collect();
            for (w, (idxs, batch)) in per_worker {
                self.timings.round_trips += pool.worker(w).fit_frames(batch.len());
                let rxs = pool.worker(w).fit_many(batch)?;
                for (i, rx) in idxs.into_iter().zip(rxs) {
                    slots[i] = Some(rx);
                }
            }
            for ((user, site, job), rx) in meta.into_iter().zip(slots) {
                let rx = rx.ok_or_else(|| {
                    anyhow!("fit dispatch returned no reply channel for user \
                             {user} site {site}")
                })?;
                self.pending.push(PendingFit { user, site, job, rx });
            }
        }
        if self.cfg.async_offload {
            // leave exactly this interval in flight
            return Ok(());
        }
        self.collect_pending()
    }

    /// Heartbeat the pool when a sweep is due, fail dead members over
    /// (buddy promotion / standby promotion / checkpoint restore)
    /// BEFORE any dispatch, then admit pending `--join` workers — all
    /// at the same deterministic interval boundary, so membership never
    /// changes mid-interval. The probe also snapshots per-member loads
    /// for load-aware placement. Only active under `failover =
    /// "migrate"`: with `"fail"` the trainer sends no v3 control
    /// traffic at all — the wire stays exactly as compatible as before
    /// this feature, and a death surfaces reactively through the lost
    /// fits themselves.
    fn sweep_pool(&mut self) -> Result<()> {
        let Trainer { supervisor, pool, timings, .. } = self;
        let (Some(sup), Some(pool)) = (supervisor.as_mut(), pool.as_mut()) else {
            return Ok(());
        };
        if !sup.migrate_enabled() || !sup.sweep_due() {
            return Ok(());
        }
        let dead = sup.find_dead(pool);
        if !dead.is_empty() {
            let stats = sup.fail_over(pool, &dead)?;
            timings.migrations += 1;
            timings.migrated_state_bytes += stats.bytes_moved as u64;
            timings.shard_promotions += stats.shards_promoted as u64;
        }
        let stats = sup.admit_joiners(pool)?;
        if stats.users_moved > 0 || stats.shards_moved > 0 {
            timings.migrations += 1;
            timings.migrated_state_bytes += stats.bytes_moved as u64;
        }
        Ok(())
    }

    /// Number of FitJob replies dispatched but not yet applied — the
    /// async-offload staleness window (<= users * sites by construction).
    pub fn in_flight(&self) -> usize {
        self.pending.len()
    }

    /// Fits transiently lost to dying workers and recovered by
    /// re-dispatch, in loss order — each names its (user, site). Empty
    /// on an undisturbed run.
    pub fn lost_fits(&self) -> &[(usize, String)] {
        &self.lost
    }

    /// Gracefully remove the daemon at `addr` from the pool mid-run:
    /// pending fits settle first, then every shard it owns migrates
    /// bit-exactly to its new rendezvous owner. The daemon is left
    /// running (and empty) — stopping it is the operator's call. Loss
    /// curves are unaffected by construction.
    pub fn drain_worker(&mut self, addr: &str) -> Result<()> {
        self.collect_pending()?;
        let Trainer { supervisor, pool, timings, .. } = self;
        let (Some(sup), Some(pool)) = (supervisor.as_mut(), pool.as_mut()) else {
            bail!("drain_worker needs a supervised tcp worker pool");
        };
        let stats = sup.drain(pool, addr)?;
        timings.migrations += 1;
        timings.migrated_state_bytes += stats.bytes_moved as u64;
        println!(
            "drained worker {addr}: moved {} users / {} shards ({} bytes)",
            stats.users_moved, stats.shards_moved, stats.bytes_moved
        );
        Ok(())
    }

    /// Grow the pool by one daemon mid-run: pending fits settle first,
    /// then the users the new member wins migrate onto it (live,
    /// bit-exact). The old `verify_shard_count` hard error is gone —
    /// this IS the resize path.
    pub fn add_worker(&mut self, addr: &str) -> Result<()> {
        self.collect_pending()?;
        let Trainer { supervisor, pool, timings, .. } = self;
        let (Some(sup), Some(pool)) = (supervisor.as_mut(), pool.as_mut()) else {
            bail!("add_worker needs a supervised tcp worker pool");
        };
        let stats = sup.add(pool, addr)?;
        timings.migrations += 1;
        timings.migrated_state_bytes += stats.bytes_moved as u64;
        println!(
            "added worker {addr}: moved {} users / {} shards ({} bytes)",
            stats.users_moved, stats.shards_moved, stats.bytes_moved
        );
        Ok(())
    }

    /// Apply all in-flight worker replies to the server state. With
    /// `failover = "migrate"`, replies lost to a dying daemon trigger a
    /// recovery round instead of aborting: the pool fails over, the
    /// affected shards restore from shadow checkpoints, the lost jobs
    /// re-dispatch, and ONLY THEN does anything apply — in the original
    /// dispatch order, exactly once, so the loss curve stays
    /// byte-identical to an undisturbed run.
    fn collect_pending(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        let mut slots: Vec<IntervalSlot> = Vec::with_capacity(self.pending.len());
        for p in self.pending.drain(..) {
            // recv fails only when the worker link died before replying
            // (remote daemon crash / dropped connection mid-interval)
            let outcome = match p.rx.recv() {
                Ok(r) => r.with_context(|| {
                    format!("fit failed for user {} site {}", p.user, p.site)
                }),
                Err(_) => Err(anyhow!(
                    "worker link dropped mid-interval: no fit reply for user \
                     {} site {}",
                    p.user,
                    p.site
                )),
            };
            slots.push(IntervalSlot {
                user: p.user,
                site: p.site,
                job: p.job,
                outcome,
                refreshed: false,
            });
        }
        self.settle_interval(&mut slots)?;
        let mut results = Vec::with_capacity(slots.len());
        for s in slots {
            results.push(s.outcome?);
        }
        self.apply_fit_results(results)?;
        // every reply is in, so every request write has completed —
        // safe point to drain the per-link wire-byte ledgers
        self.drain_wire_bytes();
        Ok(())
    }

    /// Fold each transport's request-byte ledger into the run timings
    /// (`Timings::wire_bytes`). Ledgers are drained (swap-to-zero), so
    /// calling this repeatedly never double-counts.
    fn drain_wire_bytes(&mut self) {
        if let Some(pool) = self.pool.as_ref() {
            let mut total = 0u64;
            for i in 0..pool.len() {
                total += pool.worker(i).take_wire_bytes();
            }
            self.timings.wire_bytes += total;
        }
    }

    /// Drive an interval's slots to all-Ok with fresh checkpoints, or
    /// fail. Each recovery round can absorb one more member death;
    /// failures that a dead member does NOT explain (remote shape
    /// errors, busy keys, ...) propagate untouched — recovery must
    /// never mask a real bug as a transient.
    fn settle_interval(&mut self, slots: &mut [IntervalSlot]) -> Result<()> {
        let mut rounds = 0;
        loop {
            if slots.iter().any(|s| s.outcome.is_err()) {
                rounds += 1;
                if rounds == 1 {
                    // one stalled interval, however many recovery rounds
                    // a cascading failure ends up costing it
                    self.timings.stall_intervals += 1;
                }
                if rounds > MAX_RECOVERY_ROUNDS {
                    let e = match slots.iter_mut().find(|s| s.outcome.is_err()) {
                        Some(first) => take_slot_error(first),
                        None => anyhow!("interval recovery lost track of its failing slot"),
                    };
                    return Err(e.context(format!(
                        "interval recovery did not converge after \
                         {MAX_RECOVERY_ROUNDS} rounds"
                    )));
                }
                self.recover_round(slots)?;
                continue;
            }
            // every fit is in; refresh the shadow checkpoints. A worker
            // dying DURING refresh re-marks its slots as lost (their
            // post-step state died unexported) and loops back into
            // recovery.
            if !self.refresh_checkpoints(slots)? {
                continue;
            }
            return Ok(());
        }
    }

    /// One recovery round: heartbeat the pool, fail dead members over
    /// (standby promotion + rendezvous remap + checkpoint restore), and
    /// re-dispatch every slot whose shard's step died with its owner.
    fn recover_round(&mut self, slots: &mut [IntervalSlot]) -> Result<()> {
        let Trainer { supervisor, pool, timings, lost, .. } = self;
        let sup = match supervisor.as_mut() {
            Some(s) if s.migrate_enabled() => s,
            _ => {
                return Err(match slots.iter_mut().find(|s| s.outcome.is_err()) {
                    Some(s) => take_slot_error(s),
                    None => anyhow!("recover_round called with no failed slot"),
                });
            }
        };
        let pool = pool.as_mut().ok_or_else(|| anyhow!("no worker pool"))?;
        let old_keys = pool.keys();
        // per-slot owner snapshot BEFORE failover mutates the pool —
        // with load-aware placement the owner is whatever shard_of
        // says (overrides included), not the plain rendezvous winner
        let slot_owners: Vec<String> = slots
            .iter()
            .map(|s| pool.owner_key(s.user))
            .collect::<Result<_>>()?;
        let dead = sup.find_dead(pool);
        let dead_keys: std::collections::BTreeSet<&String> =
            dead.iter().map(|&i| &old_keys[i]).collect();
        // a failure whose owner is alive is a real error, not a transient
        for (s, owner) in slots.iter_mut().zip(&slot_owners) {
            if s.outcome.is_err() && !dead_keys.contains(owner) {
                return Err(take_slot_error(s).context(format!(
                    "fit for (user {}, site {}) failed but its worker \
                     {owner} is alive — not a failover case",
                    s.user, s.site
                )));
            }
        }
        let stats = sup.fail_over(pool, &dead)?;
        timings.migrations += 1;
        timings.migrated_state_bytes += stats.bytes_moved as u64;
        timings.shard_promotions += stats.shards_promoted as u64;
        // Re-dispatch everything the dead members owned whose step is
        // not yet in a checkpoint. That includes fits that SUCCEEDED on
        // a dead daemon before it died: their reply was real, but the
        // stepped state burned with the daemon, and the checkpoint
        // restore rewound the shard to pre-step — re-running the same
        // job against it reproduces the identical update (same inputs,
        // same state, bit-identical kernels). Refreshed slots keep
        // their results: their checkpoints already carry the step.
        let mut retries: Vec<(usize, std::sync::mpsc::Receiver<Result<FitResult>>)> =
            Vec::new();
        for (i, s) in slots.iter_mut().enumerate() {
            let owner = &slot_owners[i];
            if !dead_keys.contains(owner) || s.refreshed {
                continue;
            }
            if s.outcome.is_err() {
                eprintln!(
                    "warning: fit for (user {}, site {}) was lost to dying \
                     worker {owner}; re-dispatching after failover",
                    s.user, s.site
                );
                lost.push((s.user, s.site.clone()));
                timings.lost_fits += 1;
            }
            let job = s.job.clone().ok_or_else(|| {
                anyhow!(
                    "no retained job for (user {}, site {}) — cannot re-dispatch \
                     (failover bookkeeping bug)",
                    s.user,
                    s.site
                )
            })?;
            timings.round_trips += 1;
            retries.push((i, pool.for_user(s.user)?.fit(job)?));
        }
        for (i, rx) in retries {
            let s = &mut slots[i];
            s.outcome = match rx.recv() {
                Ok(r) => r.with_context(|| {
                    format!("re-dispatched fit failed for user {} site {}", s.user, s.site)
                }),
                Err(_) => Err(anyhow!(
                    "worker link dropped during recovery: no fit reply for \
                     user {} site {}",
                    s.user,
                    s.site
                )),
            };
        }
        Ok(())
    }

    /// Export every slot's post-step state into the shadow checkpoint
    /// (`failover = "migrate"` only — otherwise a no-op). Returns false
    /// when an export failed and its slots were re-marked lost.
    fn refresh_checkpoints(&mut self, slots: &mut [IntervalSlot]) -> Result<bool> {
        let Trainer { supervisor, pool, .. } = self;
        let (Some(sup), Some(pool)) = (supervisor.as_mut(), pool.as_ref()) else {
            return Ok(true);
        };
        if !sup.migrate_enabled() {
            return Ok(true);
        }
        let mut clean = true;
        for s in slots.iter_mut() {
            if s.refreshed {
                continue;
            }
            match pool
                .for_user(s.user)
                .and_then(|w| w.export_state(s.user, &s.site))
            {
                Ok(blob) => {
                    // the post-interval push point: the same blob seeds
                    // the shadow checkpoint AND the buddy replica, so a
                    // promoted replica is bit-identical to a checkpoint
                    // restore by construction
                    if sup.replicate_enabled() {
                        sup.replicate_shard(pool, s.user, &s.site, blob.clone());
                    }
                    sup.checkpoint(s.user, &s.site, blob);
                    s.refreshed = true;
                }
                Err(e) => {
                    eprintln!(
                        "warning: post-interval checkpoint export for (user {}, \
                         site {}) failed ({e:#}); treating the fit as lost",
                        s.user, s.site
                    );
                    s.outcome = Err(e.context(format!(
                        "checkpoint export failed for user {} site {}",
                        s.user, s.site
                    )));
                    clean = false;
                }
            }
        }
        Ok(clean)
    }

    /// Apply a settled interval's results to the server state, in
    /// dispatch order (merged-mode float adds make this order part of
    /// the determinism contract).
    fn apply_fit_results(&mut self, results: Vec<FitResult>) -> Result<()> {
        // lint:allow(determinism): timing ledger only — durations never feed curve math
        let t0 = Instant::now();
        let mut touched_weights: Vec<String> = Vec::new();
        for r in results {
            self.timings.worker += r.compute;
            self.timings.transfer += r.transfer;
            self.timings.bytes_returned += r.bytes_out as u64;
            let site_spec = self
                .driver
                .sites
                .iter()
                .find(|s| s.site == r.site)
                .ok_or_else(|| anyhow!("unknown site {}", r.site))?;
            if let Some(diff) = r.delta_diff {
                // merged: W += s * (D_new - D_old) on the host copy
                let w = self
                    .weights
                    .get_mut(&site_spec.weight_name)
                    .ok_or_else(|| anyhow!("no weight {}", site_spec.weight_name))?;
                tensor::axpy(w, 1.0, &diff);
                if !touched_weights.contains(&site_spec.weight_name) {
                    touched_weights.push(site_spec.weight_name.clone());
                }
            } else if let Some(ps) = r.new_params {
                // unmerged: refresh server-resident adapter buffers
                let names = match ps.len() {
                    2 => vec!["A", "B"],
                    1 => vec!["W"],
                    4 => vec!["W1", "b1", "W2", "b2"],
                    n => bail!("unexpected adapter tensor count {n}"),
                };
                for (p, n) in ps.into_iter().zip(names) {
                    self.rt.server.upload(
                        &format!("u{}.{}.{n}", r.user, r.site),
                        Value::F32(p),
                    )?;
                }
            }
        }
        // re-upload merged weights the deltas touched
        for name in touched_weights {
            self.rt.server.upload(
                &format!("w.{name}"),
                Value::F32(self.weights[&name].clone()),
            )?;
        }
        self.timings.merge += t0.elapsed();
        Ok(())
    }

    fn step_coupled(&mut self, t: u64, method: Method) -> Result<(f32, Option<f32>)> {
        let artifact = self.driver.coupled_artifact(method, self.cfg.batch);
        let data: BTreeMap<String, Value> = self
            .driver
            .data_inputs(self.cfg.batch, 0, Split::Train, t)
            .into_iter()
            .collect();
        let inputs = self.rt.assemble(&artifact, |io| {
            if let Some(v) = data.get(&io.name) {
                return Ok(Input::Val(v.clone()));
            }
            if let Some(w) = self.tunables.get(&io.name) {
                return Ok(Input::Val(Value::F32(w.clone())));
            }
            // frozen base weight
            Ok(Input::Ref(format!("w.{}", io.name)))
        })?;
        let spec = self.rt.manifest.artifact(&artifact)?;
        let mut fetch: Vec<&str> = vec!["loss"];
        if spec.outputs.iter().any(|o| o == "acc") {
            fetch.push("acc");
        }
        let grad_names: Vec<String> =
            self.tunables.keys().map(|n| format!("d.{n}")).collect();
        for g in &grad_names {
            fetch.push(g);
        }
        // lint:allow(determinism): timing ledger only — durations never feed curve math
        let t0 = Instant::now();
        let (outs, res) = self.rt.execute_fetch(&self.rt.server, &artifact,
                                                inputs, &fetch)?;
        self.timings.fwdbwd += res.exec_time;
        self.timings.compile += res.compile_time;
        self.timings.transfer += t0
            .elapsed()
            .saturating_sub(res.exec_time)
            .saturating_sub(res.compile_time);

        let loss = outs["loss"].scalar_f32()?;
        let acc = outs.get("acc").and_then(|v| v.scalar_f32().ok());

        // optimizer on the server (the coupled cost ColA avoids)
        let grads: Vec<Tensor> = self
            .tunables
            .keys()
            .map(|n| {
                let key = format!("d.{n}");
                outs.get(&key)
                    .and_then(|v| v.as_f32())
                    .cloned()
                    .ok_or_else(|| anyhow!("missing f32 gradient output {key}"))
            })
            .collect::<Result<_>>()?;
        let opt = self
            .coupled_opt
            .as_mut()
            .ok_or_else(|| anyhow!("coupled optimizer state missing for {method}"))?;
        let mut refs: Vec<&mut Tensor> = self.tunables.values_mut().collect();
        opt.apply(&mut refs, &grads);
        Ok((loss, acc))
    }

    /// Evaluate on held-out batches. Returns (mean loss, mean acc).
    pub fn eval(&mut self, t: u64) -> Result<(f64, Option<f64>)> {
        let mut losses = Vec::new();
        let mut accs = Vec::new();
        for i in 0..self.cfg.eval_batches as u64 {
            let (loss, acc) = match self.cfg.method {
                Method::Cola(_) => {
                    let (outs, _, _, _, _) =
                        self.exec_decoupled(Split::Eval, t * 1000 + i, false)?;
                    (outs["loss"].scalar_f32()?,
                     outs.get("acc").and_then(|v| v.scalar_f32().ok()))
                }
                m => {
                    let artifact = self.driver.coupled_artifact(m, self.cfg.batch);
                    let data: BTreeMap<String, Value> = self
                        .driver
                        .data_inputs(self.cfg.batch, 0, Split::Eval, t * 1000 + i)
                        .into_iter()
                        .collect();
                    let inputs = self.rt.assemble(&artifact, |io| {
                        if let Some(v) = data.get(&io.name) {
                            return Ok(Input::Val(v.clone()));
                        }
                        if let Some(w) = self.tunables.get(&io.name) {
                            return Ok(Input::Val(Value::F32(w.clone())));
                        }
                        Ok(Input::Ref(format!("w.{}", io.name)))
                    })?;
                    let spec = self.rt.manifest.artifact(&artifact)?;
                    let mut fetch = vec!["loss"];
                    if spec.outputs.iter().any(|o| o == "acc") {
                        fetch.push("acc");
                    }
                    let (outs, _) = self.rt.execute_fetch(
                        &self.rt.server, &artifact, inputs, &fetch)?;
                    (outs["loss"].scalar_f32()?,
                     outs.get("acc").and_then(|v| v.scalar_f32().ok()))
                }
            };
            losses.push(loss as f64);
            if let Some(a) = acc {
                accs.push(a as f64);
            }
        }
        let ml = losses.iter().sum::<f64>() / losses.len().max(1) as f64;
        let ma = if accs.is_empty() {
            None
        } else {
            Some(accs.iter().sum::<f64>() / accs.len() as f64)
        };
        Ok((ml, ma))
    }

    /// Evaluate on a specific instruction category (Table 4 columns) by
    /// temporarily overriding the LM data variant.
    pub fn eval_category(&mut self, category: usize) -> Result<(f64, Option<f64>)> {
        use super::driver::LmVariant;
        let old = match &mut self.driver.data {
            TaskData::Lm { variant, .. } => {
                std::mem::replace(variant, LmVariant::Instruct(Some(category)))
            }
            _ => bail!("eval_category only applies to LM tasks"),
        };
        let r = self.eval(7777 + category as u64);
        if let TaskData::Lm { variant, .. } = &mut self.driver.data {
            *variant = old;
        }
        r
    }

    /// Export every (user, site) adapter as one deterministic bundle:
    /// a u32-LE blob count, then each `StateExport` blob ([`wire::encode_state`],
    /// always raw-bit f32) length-prefixed with a u32-LE. Blobs are
    /// ordered user-major over `0..cfg.users`, site order as the driver
    /// enumerates them — a fixed traversal, so two runs of the same
    /// config produce bitwise-equal bundles regardless of transport.
    /// This is the payload behind the gateway's `/v1/jobs/{id}/adapter`
    /// endpoint and `cola train --adapter_out`; decode it with
    /// [`wire::decode_state`] per blob.
    ///
    /// Errors for coupled baselines (no worker pool — their tunables
    /// live on the server, not in exportable per-user adapters).
    pub fn export_adapter_bundle(&self) -> Result<Vec<u8>> {
        let pool = self
            .pool
            .as_ref()
            .ok_or_else(|| anyhow!("no worker pool (coupled methods keep their \
                                    tunables on the server — nothing to export)"))?;
        let mut blobs: Vec<Vec<u8>> = Vec::new();
        for user in 0..self.cfg.users {
            for s in &self.driver.sites {
                blobs.push(pool.for_user(user)?.export_state(user, &s.site)?);
            }
        }
        let total: usize = blobs.iter().map(|b| b.len() + 4).sum();
        let mut out = Vec::with_capacity(4 + total);
        out.extend_from_slice(&(blobs.len() as u32).to_le_bytes());
        for b in blobs {
            out.extend_from_slice(&(b.len() as u32).to_le_bytes());
            out.extend_from_slice(&b);
        }
        Ok(out)
    }

    /// Snapshot a user's adapter for a site (from its worker).
    pub fn adapter_snapshot(&self, user: usize, site: &str) -> Result<AdapterParams> {
        self.pool
            .as_ref()
            .ok_or_else(|| anyhow!("no worker pool (coupled method?)"))?
            .for_user(user)?
            .snapshot(user, site)
    }

    /// Host copy of a (merged) weight.
    pub fn weight(&self, name: &str) -> Option<&Tensor> {
        self.weights.get(name)
    }

    /// Merge a user's current adapters into the host weights (post-
    /// training merge for inference, 'Alone -> merged' arm of Table 4).
    pub fn merge_user_adapters(&mut self, user: usize) -> Result<()> {
        let pool = self
            .pool
            .as_ref()
            .ok_or_else(|| anyhow!("no worker pool"))?;
        let sites: Vec<String> =
            self.driver.sites.iter().map(|s| s.site.clone()).collect();
        for site in sites {
            let params = pool.for_user(user)?.snapshot(user, &site)?;
            merge::merge_into(&mut self.weights, &site, &params)?;
        }
        Ok(())
    }
}

/// Concatenate per-user data inputs row-wise (same key sets).
fn concat_user_batches(parts: Vec<Vec<(String, Value)>>) -> Result<Vec<(String, Value)>> {
    use crate::runtime::value::IntTensor;
    let keys: Vec<String> = parts[0].iter().map(|(k, _)| k.clone()).collect();
    let mut out = Vec::new();
    for key in keys {
        let vals: Vec<&Value> = parts
            .iter()
            .map(|p| {
                p.iter()
                    .find(|(k, _)| *k == key)
                    .map(|(_, v)| v)
                    .ok_or_else(|| anyhow!("missing key {key}"))
            })
            .collect::<Result<_>>()?;
        let cat = match vals[0] {
            Value::F32(_) => {
                let mut shape = vals[0].shape().to_vec();
                let mut data = Vec::new();
                shape[0] = 0;
                for v in &vals {
                    let t = v.as_f32().ok_or_else(|| {
                        anyhow!("user batches for {key} mix f32 and i32 values")
                    })?;
                    shape[0] += t.shape()[0];
                    data.extend_from_slice(t.data());
                }
                Value::F32(Tensor::new(shape, data))
            }
            Value::I32(_) => {
                let mut shape = vals[0].shape().to_vec();
                let mut data = Vec::new();
                shape[0] = 0;
                for v in &vals {
                    if let Value::I32(t) = v {
                        shape[0] += t.shape()[0];
                        data.extend_from_slice(t.data());
                    }
                }
                Value::I32(IntTensor::new(shape, data))
            }
        };
        out.push((key, cat));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::value::IntTensor;

    #[test]
    fn concat_user_batches_rows() {
        let a = vec![
            ("tokens".to_string(), Value::I32(IntTensor::new(vec![2, 3], vec![1; 6]))),
            ("mask".to_string(), Value::F32(Tensor::zeros(&[2, 3]))),
        ];
        let b = vec![
            ("tokens".to_string(), Value::I32(IntTensor::new(vec![2, 3], vec![2; 6]))),
            ("mask".to_string(), Value::F32(Tensor::zeros(&[2, 3]))),
        ];
        let cat = concat_user_batches(vec![a, b]).unwrap();
        assert_eq!(cat[0].1.shape(), &[4, 3]);
        if let Value::I32(t) = &cat[0].1 {
            assert_eq!(t.data()[0], 1);
            assert_eq!(t.data()[6], 2);
        }
    }
}
