//! FTaaS service facade — the programmatic front end of Figure 1.
//!
//! Users register fine-tuning jobs (their data category + adapter
//! architecture preference); the service runs collaborative rounds on
//! the shared base model (merged mode: server memory independent of the
//! number of users) and users can fetch their trained adapters or
//! per-category quality at any time.

use anyhow::{bail, Result};

use super::server::Trainer;
use crate::adapters::AdapterParams;
use crate::config::{AdapterKind, Method, Mode, TrainConfig};

/// A registered FTaaS user.
#[derive(Clone, Debug)]
pub struct UserJob {
    pub user: usize,
    pub category: usize,
    pub kind: AdapterKind,
}

/// Service status snapshot.
#[derive(Clone, Debug)]
pub struct ServiceStatus {
    pub users: usize,
    pub rounds_completed: u64,
    pub last_train_loss: Option<f64>,
    pub server_resident_bytes: usize,
    pub worker_state_bytes: usize,
}

pub struct FtaasService {
    trainer: Trainer,
    jobs: Vec<UserJob>,
    rounds: u64,
    last_loss: Option<f64>,
}

impl FtaasService {
    /// Start a service for `users` collaborators. All users share the
    /// merged base model; each trains on their own data category
    /// (Table 4 'Collaboration').
    pub fn start(mut cfg: TrainConfig, kind: AdapterKind) -> Result<FtaasService> {
        if cfg.users == 0 {
            bail!("need at least one user");
        }
        cfg.method = Method::Cola(kind);
        cfg.mode = Mode::Merged;
        cfg.dataset = "per-user".into();
        cfg.validate()?;
        let users = cfg.users;
        let trainer = Trainer::new(cfg)?;
        let jobs = (0..users)
            .map(|u| UserJob { user: u, category: u % 8, kind })
            .collect();
        Ok(FtaasService { trainer, jobs, rounds: 0, last_loss: None })
    }

    pub fn jobs(&self) -> &[UserJob] {
        &self.jobs
    }

    /// Run `n` collaborative training rounds (each = one Algorithm-1
    /// iteration over all users' data).
    pub fn run_rounds(&mut self, n: u64) -> Result<()> {
        for _ in 0..n {
            let (loss, _) = self.trainer.step(self.rounds)?;
            self.last_loss = Some(loss as f64);
            self.rounds += 1;
        }
        Ok(())
    }

    /// Per-category quality of the current shared model.
    pub fn category_score(&mut self, category: usize) -> Result<f64> {
        let (_, acc) = self.trainer.eval_category(category)?;
        Ok(acc.map(|a| a * 100.0).unwrap_or(f64::NAN))
    }

    /// A user downloads their trained adapter (Figure 1's local path).
    pub fn fetch_adapter(&self, user: usize, site: &str) -> Result<AdapterParams> {
        self.trainer.adapter_snapshot(user, site)
    }

    pub fn status(&self) -> Result<ServiceStatus> {
        Ok(ServiceStatus {
            users: self.jobs.len(),
            rounds_completed: self.rounds,
            last_train_loss: self.last_loss,
            server_resident_bytes: self.trainer.rt.server.resident_bytes()?,
            worker_state_bytes: 0,
        })
    }

    pub fn trainer_mut(&mut self) -> &mut Trainer {
        &mut self.trainer
    }
}
