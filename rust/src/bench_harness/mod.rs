//! Bench harness (criterion is unavailable offline): warmup + timed
//! iterations + robust stats, with markdown emission. All
//! `rust/benches/*.rs` binaries (one per paper table/figure) run on
//! this.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchStats {
    pub fn row(&self) -> Vec<String> {
        vec![
            self.name.clone(),
            format!("{}", self.iters),
            format!("{:.4}", self.mean.as_secs_f64()),
            format!("{:.4}", self.median.as_secs_f64()),
            format!("{:.4}", self.p95.as_secs_f64()),
        ]
    }
}

/// Time `f` with `warmup` unmeasured runs then `iters` measured runs.
pub fn bench<T>(name: &str, warmup: usize, iters: usize,
                mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    times.sort();
    let mean = times.iter().sum::<Duration>() / iters.max(1) as u32;
    BenchStats {
        name: name.to_string(),
        iters,
        mean,
        median: times[times.len() / 2],
        p95: times[((times.len() as f64 * 0.95) as usize).min(times.len() - 1)],
        min: times[0],
    }
}

/// Standard bench-result output: print + append to a results file.
pub struct BenchReport {
    pub title: String,
    sections: Vec<String>,
}

impl BenchReport {
    pub fn new(title: &str) -> Self {
        BenchReport { title: title.to_string(), sections: Vec::new() }
    }

    pub fn section(&mut self, heading: &str, body: String) {
        self.sections.push(format!("### {heading}\n\n{body}"));
    }

    pub fn render(&self) -> String {
        format!("## {}\n\n{}\n", self.title, self.sections.join("\n\n"))
    }

    /// Print to stdout and append to `results/<slug>.md`.
    pub fn emit(&self, slug: &str) -> std::io::Result<()> {
        let text = self.render();
        println!("{text}");
        std::fs::create_dir_all("results")?;
        std::fs::write(format!("results/{slug}.md"), &text)?;
        Ok(())
    }

    pub fn write_csv(&self, slug: &str, csv: &str) -> std::io::Result<()> {
        std::fs::create_dir_all("results")?;
        std::fs::write(format!("results/{slug}.csv"), csv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let s = bench("noop", 1, 5, || 1 + 1);
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median <= s.p95);
    }

    #[test]
    fn report_renders() {
        let mut r = BenchReport::new("T");
        r.section("a", "body".into());
        let t = r.render();
        assert!(t.contains("## T") && t.contains("### a"));
    }
}
