//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `cola <subcommand> [--key value]... [--flag]...`
//! `--key=value` is also accepted. Unknown keys are rejected by the
//! consumer (`TrainConfig::set`), so typos fail loudly.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// One row per `cola` subcommand: (name, one-line summary). The single
/// source of truth behind `cola help` and the README "Command
/// reference" table — `tests/cli_docs.rs` asserts all three stay in
/// sync with the dispatch match in `main.rs`.
pub const SUBCOMMANDS: &[(&str, &str)] = &[
    ("train", "run one fine-tuning job from flags and/or a --config TOML"),
    ("serve", "FTaaS HTTP gateway: token-auth REST API over std::net"),
    ("http", "stdlib-only HTTP client for driving a gateway (CI has no curl)"),
    ("worker", "gradient-offload worker daemon (distributed mode)"),
    ("pool", "elastic-pool resize between runs (add/drain/remove daemons)"),
    ("curvediff", "numerically compare two --loss_out curve files"),
    ("scale", "million-user traffic harness over the LRU-paged state store"),
    ("demo", "FTaaS collaboration demo: K users sharing one base model"),
    ("memory", "analytic memory report for the paper's model profiles"),
    ("table1", "print the Table-1 computation-space complexity summary"),
    ("lint", "zero-dep determinism / panic-safety static analysis"),
    ("help", "this overview"),
];

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.next_if(|a| !a.starts_with('-')) {
            out.subcommand = first.clone();
        }
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some(eq) = body.find('=') {
                    out.options
                        .insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else if let Some(val) = it.next_if(|n| !n.starts_with("--")) {
                    out.options.insert(body.to_string(), val.clone());
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg.clone());
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The option map minus launcher-only keys — what's left must all be
    /// valid `TrainConfig` keys, so typos still fail loudly downstream.
    pub fn options_except(&self, skip: &[&str]) -> BTreeMap<String, String> {
        self.options
            .iter()
            .filter(|(k, _)| !skip.contains(&k.as_str()))
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        match self.get(key) {
            Some(v) => Ok(v),
            None => bail!("missing required option --{key}"),
        }
    }

    /// Loud-typo guard for subcommands whose options all take values:
    /// a bare `--offload_batch` (no value) parses as a *flag*, which a
    /// value-driven consumer would otherwise silently ignore — the
    /// worst possible failure mode for a boolean config key.
    pub fn require_no_flags(&self, what: &str) -> Result<()> {
        if let Some(f) = self.flags.first() {
            bail!("{what} options take values: --{f} <value> (e.g. --{f} true)");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let v: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&v).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --method cola-lowrank --steps=100 --verbose");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get("method"), Some("cola-lowrank"));
        assert_eq!(a.get("steps"), Some("100"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn flag_before_option() {
        let a = parse("bench --quick --out x.md");
        assert!(a.has_flag("quick"));
        assert_eq!(a.get("out"), Some("x.md"));
    }

    #[test]
    fn parse_or_types() {
        let a = parse("x --n 5");
        assert_eq!(a.parse_or("n", 0usize).unwrap(), 5);
        assert_eq!(a.parse_or("m", 7usize).unwrap(), 7);
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse("x --lr -0.5");
        // "-0.5" doesn't start with --, so it is taken as the value
        assert_eq!(a.get("lr"), Some("-0.5"));
    }

    #[test]
    fn require_no_flags_names_the_flag() {
        let a = parse("train --offload_batch --steps 5");
        let err = a.require_no_flags("train").unwrap_err();
        assert!(format!("{err}").contains("offload_batch"), "{err}");
        assert!(parse("train --steps 5").require_no_flags("train").is_ok());
    }

    #[test]
    fn options_except_filters() {
        let a = parse("train --config c.toml --steps 5 --loss_out out.json");
        let ov = a.options_except(&["config", "loss_out"]);
        assert_eq!(ov.len(), 1);
        assert_eq!(ov.get("steps").map(String::as_str), Some("5"));
    }
}
