//! Minimal dense f32 tensor — the "low-cost device" native math substrate.
//!
//! ColA's worker devices are CPUs; their native update path (surrogate
//! fit + optimizer, `adapters::`) runs on this type, and it is also the
//! interchange value between device threads (PJRT `Literal`s are !Send,
//! so only `Tensor`s cross thread boundaries — which doubles as the
//! transfer-size ledger the memory accountant charges).
//!
//! Row-major, shapes up to rank 4. The matmul family is a parallel
//! cache-blocked engine — B-panel packing + row-band fan-out over the
//! scoped-thread pool in [`pool`]; see `ops::matmul` for the hot-path
//! notes and EXPERIMENTS.md §Perf for the measured trajectory.

pub mod ops;
pub mod pool;
pub mod simd;

pub use ops::*;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} vs data len {}",
            shape,
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> f32) -> Self {
        let n = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..n).map(|i| f(i)).collect(),
        }
    }

    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::rng::Rng) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: rng.normal_vec(n, std) }
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Size in bytes (the unit of the memory accountant / transfer model).
    #[inline]
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// (rows, cols) of a rank-2 tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.shape.len(), 2, "want rank-2, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Flatten all leading dims into rows: (.., d) -> (n, d).
    pub fn to_rows(self) -> Self {
        // lint:allow(panic-safety): Tensor construction rejects rank-0 shapes, so `last()` always holds
        let d = *self.shape.last().expect("rank >= 1");
        let n = self.data.len() / d;
        self.reshape(&[n, d])
    }

    /// Select a contiguous row range of a rank-2 tensor.
    pub fn rows(&self, start: usize, end: usize) -> Tensor {
        let (n, d) = self.dims2();
        assert!(start <= end && end <= n);
        Tensor::new(vec![end - start, d], self.data[start * d..end * d].to_vec())
    }

    /// Concatenate rank-2 tensors along rows.
    pub fn cat_rows(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let d = parts[0].dims2().1;
        let mut data = Vec::with_capacity(parts.iter().map(|t| t.len()).sum());
        let mut n = 0;
        for p in parts {
            assert_eq!(p.dims2().1, d);
            n += p.dims2().0;
            data.extend_from_slice(&p.data);
        }
        Tensor::new(vec![n, d], data)
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// allclose with combined rtol/atol (numpy semantics).
    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_bytes() {
        let t = Tensor::zeros(&[3, 4]);
        assert_eq!(t.bytes(), 48);
        assert_eq!(t.dims2(), (3, 4));
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0; 5]);
    }

    #[test]
    fn rows_and_cat() {
        let a = Tensor::from_fn(&[4, 2], |i| i as f32);
        let top = a.rows(0, 2);
        let bot = a.rows(2, 4);
        assert_eq!(Tensor::cat_rows(&[&top, &bot]), a);
    }

    #[test]
    fn reshape_flatten() {
        let t = Tensor::from_fn(&[2, 3, 4], |i| i as f32);
        let r = t.clone().to_rows();
        assert_eq!(r.shape(), &[6, 4]);
        assert_eq!(r.data(), t.data());
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::new(vec![2], vec![1.0, 100.0]);
        let b = Tensor::new(vec![2], vec![1.0 + 1e-6, 100.0 + 1e-4]);
        assert!(a.allclose(&b, 1e-5, 1e-5));
        let c = Tensor::new(vec![2], vec![1.1, 100.0]);
        assert!(!a.allclose(&c, 1e-5, 1e-5));
    }
}
