//! Linear-algebra ops for the native worker path.
//!
//! `matmul` is the worker hot path (the surrogate-fit contractions). It
//! uses an ikj loop order with a column-blocked inner kernel so the
//! innermost loop is a contiguous axpy over the output row — this
//! auto-vectorizes well. Perf iterations are logged in EXPERIMENTS.md
//! §Perf.

use super::Tensor;

const BLOCK_J: usize = 256;

/// C = A @ B. A: (m, k), B: (k, n).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (k2, n) = b.dims2();
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for j0 in (0..n).step_by(BLOCK_J) {
        let j1 = (j0 + BLOCK_J).min(n);
        for i in 0..m {
            let orow = &mut out[i * n..(i + 1) * n];
            for p in 0..k {
                let av = ad[i * k + p];
                if av == 0.0 {
                    continue;
                }
                let brow = &bd[p * n..(p + 1) * n];
                for j in j0..j1 {
                    orow[j] += av * brow[j];
                }
            }
        }
    }
    Tensor::new(vec![m, n], out)
}

/// C = A^T @ B. A: (k, m), B: (k, n) -> (m, n). Avoids materializing A^T.
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (k, m) = a.dims2();
    let (k2, n) = b.dims2();
    assert_eq!(k, k2);
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for p in 0..k {
        let arow = &ad[p * m..(p + 1) * m];
        let brow = &bd[p * n..(p + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    Tensor::new(vec![m, n], out)
}

/// C = A @ B^T. A: (m, k), B: (n, k) -> (m, n). Dot-product kernel.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (n, k2) = b.dims2();
    assert_eq!(k, k2);
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &bd[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            out[i * n + j] = acc;
        }
    }
    Tensor::new(vec![m, n], out)
}

/// Transpose a rank-2 tensor.
pub fn transpose(a: &Tensor) -> Tensor {
    let (m, n) = a.dims2();
    let ad = a.data();
    Tensor::from_fn(&[n, m], |i| {
        let (r, c) = (i / m, i % m);
        ad[c * n + r]
    })
}

/// out = a + b (elementwise).
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape(), b.shape());
    let data = a.data().iter().zip(b.data()).map(|(x, y)| x + y).collect();
    Tensor::new(a.shape().to_vec(), data)
}

/// out = a - b.
pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape(), b.shape());
    let data = a.data().iter().zip(b.data()).map(|(x, y)| x - y).collect();
    Tensor::new(a.shape().to_vec(), data)
}

/// a += alpha * b, in place.
pub fn axpy(a: &mut Tensor, alpha: f32, b: &Tensor) {
    assert_eq!(a.shape(), b.shape());
    for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
        *x += alpha * y;
    }
}

/// out = alpha * a.
pub fn scale(a: &Tensor, alpha: f32) -> Tensor {
    Tensor::new(a.shape().to_vec(), a.data().iter().map(|x| alpha * x).collect())
}

/// in-place scale.
pub fn scale_mut(a: &mut Tensor, alpha: f32) {
    for x in a.data_mut() {
        *x *= alpha;
    }
}

/// relu(a).
pub fn relu(a: &Tensor) -> Tensor {
    Tensor::new(a.shape().to_vec(), a.data().iter().map(|x| x.max(0.0)).collect())
}

/// Column-sum of a rank-2 tensor -> (n,).
pub fn col_sum(a: &Tensor) -> Tensor {
    let (m, n) = a.dims2();
    let mut out = vec![0.0f32; n];
    for i in 0..m {
        for j in 0..n {
            out[j] += a.data()[i * n + j];
        }
    }
    Tensor::new(vec![n], out)
}

/// Add a row vector to every row: a (m,n) + v (n,).
pub fn add_row(a: &Tensor, v: &Tensor) -> Tensor {
    let (m, n) = a.dims2();
    assert_eq!(v.len(), n);
    let mut data = a.data().to_vec();
    for i in 0..m {
        for j in 0..n {
            data[i * n + j] += v.data()[j];
        }
    }
    Tensor::new(vec![m, n], data)
}

/// Frobenius norm.
pub fn norm(a: &Tensor) -> f32 {
    a.data().iter().map(|x| x * x).sum::<f32>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (_, n) = b.dims2();
        Tensor::from_fn(&[m, n], |idx| {
            let (i, j) = (idx / n, idx % n);
            (0..k).map(|p| a.data()[i * k + p] * b.data()[p * n + j]).sum()
        })
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 16, 300)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            assert!(matmul(&a, &b).allclose(&naive_matmul(&a, &b), 1e-4, 1e-4));
        }
    }

    #[test]
    fn matmul_tn_nt_match_transpose() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[7, 5], 1.0, &mut rng);
        let b = Tensor::randn(&[7, 9], 1.0, &mut rng);
        assert!(matmul_tn(&a, &b).allclose(&matmul(&transpose(&a), &b), 1e-4, 1e-4));
        let c = Tensor::randn(&[9, 5], 1.0, &mut rng);
        let at = Tensor::randn(&[4, 5], 1.0, &mut rng);
        assert!(matmul_nt(&at, &c).allclose(&matmul(&at, &transpose(&c)), 1e-4, 1e-4));
    }

    #[test]
    fn elementwise_ops() {
        let a = Tensor::new(vec![3], vec![1.0, -2.0, 3.0]);
        let b = Tensor::new(vec![3], vec![0.5, 0.5, 0.5]);
        assert_eq!(add(&a, &b).data(), &[1.5, -1.5, 3.5]);
        assert_eq!(sub(&a, &b).data(), &[0.5, -2.5, 2.5]);
        assert_eq!(relu(&a).data(), &[1.0, 0.0, 3.0]);
        assert_eq!(scale(&a, 2.0).data(), &[2.0, -4.0, 6.0]);
        let mut c = a.clone();
        axpy(&mut c, 2.0, &b);
        assert_eq!(c.data(), &[2.0, -1.0, 4.0]);
    }

    #[test]
    fn col_sum_and_add_row() {
        let a = Tensor::from_fn(&[2, 3], |i| i as f32); // [[0,1,2],[3,4,5]]
        assert_eq!(col_sum(&a).data(), &[3.0, 5.0, 7.0]);
        let v = Tensor::new(vec![3], vec![10.0, 20.0, 30.0]);
        assert_eq!(add_row(&a, &v).data(), &[10.0, 21.0, 32.0, 13.0, 24.0, 35.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[6, 11], 1.0, &mut rng);
        assert_eq!(transpose(&transpose(&a)), a);
    }
}
