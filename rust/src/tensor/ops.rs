//! Linear-algebra ops for the native hot paths.
//!
//! The matmul family is the engine under everything: the server's
//! fwd/bwd trunk, per-head attention, and the worker surrogate-fit
//! contractions. `matmul` packs B into cache-resident column panels and
//! splits the output into row bands across the scoped-thread pool
//! (`tensor::pool`); the innermost loop is a contiguous axpy over the
//! output row, dispatched through the runtime-detected microkernels in
//! [`tensor::simd`](super::simd) (scalar / AVX2 / opt-in FMA — see the
//! determinism notes there). Bands and panels never change per-element
//! accumulation order, so results are bit-identical for every thread
//! count. Perf iterations are logged in EXPERIMENTS.md
//! §Perf; the throughput bench (`cargo bench --bench throughput`) emits
//! the BENCH_throughput.json baseline.
//!
//! IEEE note: earlier revisions skipped the inner axpy when the A
//! element was exactly 0.0, silently rewriting `0 * NaN` and `0 * inf`
//! to 0 — diverging from the naive reference and the PJRT backend. The
//! fast path is gone; non-finite inputs now propagate exactly like the
//! reference (pinned by `matmul_ieee_nonfinite_parity`).

use super::pool;
use super::simd;
use super::Tensor;

/// Column-panel width for B packing (f32 lane-friendly, fits L1 rows).
const BLOCK_J: usize = 256;

/// Flop count above which packing B into panels pays for its copy.
const PACK_MIN_WORK: usize = 1 << 20;

/// One row band against one column panel of B. `panel` starts at output
/// column `j0` and holds `k` rows of width `pw` at stride `pstride`
/// (`pw` when packed, `n` when reading B in place).
fn mm_band(
    arows: &[f32],
    k: usize,
    n: usize,
    panel: &[f32],
    pstride: usize,
    j0: usize,
    pw: usize,
    oband: &mut [f32],
) {
    let rows = oband.len() / n;
    // runtime-dispatched axpy (tensor::simd): scalar, AVX2 (bit-identical
    // to scalar — separate mul+add per lane), or opt-in FMA (documented
    // tolerance). Hoisted out of the loops so the tier check runs once.
    let axpy = simd::axpy_kernel();
    for i in 0..rows {
        let arow = &arows[i * k..(i + 1) * k];
        let orow = &mut oband[i * n + j0..i * n + j0 + pw];
        for (p, &av) in arow.iter().enumerate() {
            let brow = &panel[p * pstride..p * pstride + pw];
            axpy(orow, brow, av);
        }
    }
}

/// Pack B (k x n, row-major) into contiguous column panels of width
/// <= BLOCK_J: (j0, pw, k x pw buffer). Shared read-only by all bands.
fn pack_panels(bd: &[f32], k: usize, n: usize) -> Vec<(usize, usize, Vec<f32>)> {
    let mut panels = Vec::with_capacity(n.div_ceil(BLOCK_J));
    let mut j0 = 0;
    while j0 < n {
        let pw = BLOCK_J.min(n - j0);
        let mut panel = vec![0.0f32; k * pw];
        for p in 0..k {
            panel[p * pw..(p + 1) * pw].copy_from_slice(&bd[p * n + j0..p * n + j0 + pw]);
        }
        panels.push((j0, pw, panel));
        j0 += pw;
    }
    panels
}

/// C = A @ B. A: (m, k), B: (k, n).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (k2, n) = b.dims2();
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    if m == 0 || n == 0 || k == 0 {
        return Tensor::new(vec![m, n], out);
    }
    let ad = a.data();
    let bd = b.data();
    let work = 2 * m * k * n;
    let packed = if n > BLOCK_J && work >= PACK_MIN_WORK {
        Some(pack_panels(bd, k, n))
    } else {
        None
    };
    let band_kernel = |arows: &[f32], oband: &mut [f32]| match &packed {
        Some(panels) => {
            for (j0, pw, panel) in panels {
                mm_band(arows, k, n, panel, *pw, *j0, *pw, oband);
            }
        }
        None => {
            let mut j0 = 0;
            while j0 < n {
                let pw = BLOCK_J.min(n - j0);
                mm_band(arows, k, n, &bd[j0..], n, j0, pw, oband);
                j0 += pw;
            }
        }
    };
    pool::join_row_bands(ad, k, &mut out, n, work, &band_kernel);
    Tensor::new(vec![m, n], out)
}

/// C = A^T @ B. A: (k, m), B: (k, n) -> (m, n). The explicit transpose
/// is O(km) against the O(kmn) contraction and buys the packed banded
/// kernel (and its thread fan-out) for the backward contractions.
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (k, _m) = a.dims2();
    let (k2, _n) = b.dims2();
    assert_eq!(k, k2, "matmul_tn inner dims: {k} vs {k2}");
    matmul(&transpose(a), b)
}

/// C = A @ B^T. A: (m, k), B: (n, k) -> (m, n). Dot-product kernel,
/// row-band parallel; both operands stream contiguously.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (n, k2) = b.dims2();
    assert_eq!(k, k2, "matmul_nt inner dims: {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    if m == 0 || n == 0 || k == 0 {
        return Tensor::new(vec![m, n], out);
    }
    let ad = a.data();
    let bd = b.data();
    let work = 2 * m * k * n;
    let band_kernel = |arows: &[f32], oband: &mut [f32]| {
        let rows = oband.len() / n;
        for i in 0..rows {
            let arow = &arows[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &bd[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (x, y) in arow.iter().zip(brow) {
                    acc += x * y;
                }
                oband[i * n + j] = acc;
            }
        }
    };
    pool::join_row_bands(ad, k, &mut out, n, work, &band_kernel);
    Tensor::new(vec![m, n], out)
}

/// Transpose a rank-2 tensor (32x32 tiles so both sides stay in cache).
pub fn transpose(a: &Tensor) -> Tensor {
    let (m, n) = a.dims2();
    let ad = a.data();
    let mut out = vec![0.0f32; m * n];
    const TB: usize = 32;
    for i0 in (0..m).step_by(TB) {
        for j0 in (0..n).step_by(TB) {
            for i in i0..(i0 + TB).min(m) {
                for j in j0..(j0 + TB).min(n) {
                    out[j * m + i] = ad[i * n + j];
                }
            }
        }
    }
    Tensor::new(vec![n, m], out)
}

/// out = a + b (elementwise).
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape(), b.shape());
    let data = a.data().iter().zip(b.data()).map(|(x, y)| x + y).collect();
    Tensor::new(a.shape().to_vec(), data)
}

/// out = a - b.
pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape(), b.shape());
    let data = a.data().iter().zip(b.data()).map(|(x, y)| x - y).collect();
    Tensor::new(a.shape().to_vec(), data)
}

/// a += alpha * b, in place.
pub fn axpy(a: &mut Tensor, alpha: f32, b: &Tensor) {
    assert_eq!(a.shape(), b.shape());
    for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
        *x += alpha * y;
    }
}

/// out = alpha * a.
pub fn scale(a: &Tensor, alpha: f32) -> Tensor {
    Tensor::new(a.shape().to_vec(), a.data().iter().map(|x| alpha * x).collect())
}

/// in-place scale.
pub fn scale_mut(a: &mut Tensor, alpha: f32) {
    for x in a.data_mut() {
        *x *= alpha;
    }
}

/// relu(a).
pub fn relu(a: &Tensor) -> Tensor {
    Tensor::new(a.shape().to_vec(), a.data().iter().map(|x| x.max(0.0)).collect())
}

/// Column-sum of a rank-2 tensor -> (n,).
pub fn col_sum(a: &Tensor) -> Tensor {
    let (m, n) = a.dims2();
    let mut out = vec![0.0f32; n];
    for i in 0..m {
        for j in 0..n {
            out[j] += a.data()[i * n + j];
        }
    }
    Tensor::new(vec![n], out)
}

/// Add a row vector to every row: a (m,n) + v (n,).
pub fn add_row(a: &Tensor, v: &Tensor) -> Tensor {
    let (m, n) = a.dims2();
    assert_eq!(v.len(), n);
    let mut data = a.data().to_vec();
    for i in 0..m {
        for j in 0..n {
            data[i * n + j] += v.data()[j];
        }
    }
    Tensor::new(vec![m, n], data)
}

/// Frobenius norm.
pub fn norm(a: &Tensor) -> f32 {
    a.data().iter().map(|x| x * x).sum::<f32>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (_, n) = b.dims2();
        Tensor::from_fn(&[m, n], |idx| {
            let (i, j) = (idx / n, idx % n);
            (0..k).map(|p| a.data()[i * k + p] * b.data()[p * n + j]).sum()
        })
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 16, 300)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            assert!(matmul(&a, &b).allclose(&naive_matmul(&a, &b), 1e-4, 1e-4));
        }
    }

    #[test]
    fn large_matmul_matches_naive() {
        // big enough to hit both the packed-panel and the parallel paths
        let mut rng = Rng::new(8);
        let (m, k, n) = (61, 47, 300);
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        assert!(matmul(&a, &b).allclose(&naive_matmul(&a, &b), 1e-4, 1e-4));
    }

    #[test]
    fn matmul_tn_nt_match_transpose() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[7, 5], 1.0, &mut rng);
        let b = Tensor::randn(&[7, 9], 1.0, &mut rng);
        assert!(matmul_tn(&a, &b).allclose(&matmul(&transpose(&a), &b), 1e-4, 1e-4));
        let c = Tensor::randn(&[9, 5], 1.0, &mut rng);
        let at = Tensor::randn(&[4, 5], 1.0, &mut rng);
        assert!(matmul_nt(&at, &c).allclose(&matmul(&at, &transpose(&c)), 1e-4, 1e-4));
    }

    #[test]
    fn matmul_ieee_nonfinite_parity() {
        // the old zero-skip fast path rewrote 0 * NaN and 0 * inf to 0;
        // the engine must match the naive reference (and the PJRT
        // backend) on non-finite inputs instead
        let a = Tensor::new(vec![2, 2], vec![0.0, 1.0, 2.0, 0.0]);
        let b = Tensor::new(
            vec![2, 3],
            vec![f32::NAN, f32::INFINITY, 1.0, 1.0, 2.0, f32::NEG_INFINITY],
        );
        let c = matmul(&a, &b);
        let r = naive_matmul(&a, &b);
        for (x, y) in c.data().iter().zip(r.data()) {
            assert_eq!(x.is_nan(), y.is_nan(), "{x} vs {y}");
            if !x.is_nan() {
                assert_eq!(x, y);
            }
        }
        // 0 * NaN must poison the accumulator, not vanish
        assert!(c.data()[0].is_nan());
        // matmul_tn sees the same contraction through the transpose
        let ct = matmul_tn(&transpose(&a), &b);
        for (x, y) in ct.data().iter().zip(c.data()) {
            assert_eq!(x.is_nan(), y.is_nan());
            if !x.is_nan() {
                assert_eq!(x, y);
            }
        }
    }

    #[test]
    fn threaded_matches_single_thread_bitwise() {
        // band splits and panel packing never change accumulation order,
        // so every thread count must produce identical bits
        let mut rng = Rng::new(17);
        let a = Tensor::randn(&[97, 64], 1.0, &mut rng);
        let b = Tensor::randn(&[64, 300], 1.0, &mut rng);
        let c = Tensor::randn(&[97, 300], 1.0, &mut rng);
        let y = Tensor::randn(&[500, 64], 1.0, &mut rng);
        pool::set_threads(1);
        let m1 = matmul(&a, &b);
        let t1 = matmul_tn(&a, &c);
        let n1 = matmul_nt(&y, &a);
        pool::set_threads(4);
        let m4 = matmul(&a, &b);
        let t4 = matmul_tn(&a, &c);
        let n4 = matmul_nt(&y, &a);
        pool::set_threads(0);
        assert_eq!(m1, m4);
        assert_eq!(t1, t4);
        assert_eq!(n1, n4);
    }

    #[test]
    fn simd_matmul_matches_scalar_bitwise() {
        // the AVX2 tier issues a separate mul+add per lane, so forcing
        // the scalar fallback must not move a single bit (the same
        // contract the path-parity CI job checks on whole loss curves)
        let _g = simd::test_policy_lock();
        let mut rng = Rng::new(29);
        let a = Tensor::randn(&[33, 64], 1.0, &mut rng);
        let b = Tensor::randn(&[64, 300], 1.0, &mut rng);
        simd::set_policy(Some(simd::Policy::Off));
        let scalar = matmul(&a, &b);
        simd::set_policy(Some(simd::Policy::Auto));
        let vector = matmul(&a, &b);
        simd::set_policy(None);
        assert_eq!(scalar, vector);
    }

    #[test]
    fn elementwise_ops() {
        let a = Tensor::new(vec![3], vec![1.0, -2.0, 3.0]);
        let b = Tensor::new(vec![3], vec![0.5, 0.5, 0.5]);
        assert_eq!(add(&a, &b).data(), &[1.5, -1.5, 3.5]);
        assert_eq!(sub(&a, &b).data(), &[0.5, -2.5, 2.5]);
        assert_eq!(relu(&a).data(), &[1.0, 0.0, 3.0]);
        assert_eq!(scale(&a, 2.0).data(), &[2.0, -4.0, 6.0]);
        let mut c = a.clone();
        axpy(&mut c, 2.0, &b);
        assert_eq!(c.data(), &[2.0, -1.0, 4.0]);
    }

    #[test]
    fn col_sum_and_add_row() {
        let a = Tensor::from_fn(&[2, 3], |i| i as f32); // [[0,1,2],[3,4,5]]
        assert_eq!(col_sum(&a).data(), &[3.0, 5.0, 7.0]);
        let v = Tensor::new(vec![3], vec![10.0, 20.0, 30.0]);
        assert_eq!(add_row(&a, &v).data(), &[10.0, 21.0, 32.0, 13.0, 24.0, 35.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[6, 11], 1.0, &mut rng);
        assert_eq!(transpose(&transpose(&a)), a);
        // non-multiple-of-tile shapes
        let b = Tensor::randn(&[33, 65], 1.0, &mut rng);
        assert_eq!(transpose(&transpose(&b)), b);
        let naive = Tensor::from_fn(&[65, 33], |i| {
            let (r, c) = (i / 33, i % 33);
            b.data()[c * 65 + r]
        });
        assert_eq!(transpose(&b), naive);
    }
}
