//! Scoped-thread pool for the native tensor engine.
//!
//! There is no persistent thread object: parallel sections spawn scoped
//! threads (`std::thread::scope`) on demand, and a global *core budget*
//! — leases taken from one atomic counter — bounds the total number of
//! *extra* helper threads across every concurrent section in the
//! process (the server's fwd/bwd, each offload worker's surrogate fit,
//! nested kernels) to `max_threads() - 1`. Calling threads are not
//! registered, so K concurrent sections can still run up to
//! `cap - 1 + K` compute threads — mild, bounded oversubscription in
//! exchange for never blocking: a section that cannot lease extra cores
//! simply runs serially.
//!
//! Determinism: splits are row/item-contiguous and every output element
//! is produced by exactly one thread with the same accumulation order as
//! the serial kernel, so results are **bit-identical for every thread
//! count** (pinned by `tensor::ops` tests). The knobs below only move
//! wall-clock time, never numerics:
//!
//! - `COLA_THREADS` env var — engine width for the process (CI pins it);
//! - [`set_threads`] — runtime override (benches sweep 1..N, configs via
//!   `TrainConfig::threads`); `0` clears back to env/auto;
//! - default — `std::thread::available_parallelism()`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Work (in flops) below which band-parallel kernels stay serial —
/// thread spawn latency would dominate the compute.
pub const MIN_PAR_WORK: usize = 1 << 20;

/// Buffer length (elements) below which [`parallel_chunks_mut`] stays
/// serial.
pub const MIN_PAR_ELEMS: usize = 1 << 15;

static OVERRIDE: AtomicUsize = AtomicUsize::new(0);
static ACTIVE: AtomicUsize = AtomicUsize::new(0);

fn default_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(s) = std::env::var("COLA_THREADS") {
            if let Ok(n) = s.trim().parse::<usize>() {
                if n >= 1 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// Current engine width (always >= 1).
pub fn max_threads() -> usize {
    match OVERRIDE.load(Ordering::Relaxed) {
        0 => default_threads(),
        n => n,
    }
}

/// Override the engine width at runtime. `set_threads(0)` clears the
/// override back to `COLA_THREADS`/auto. Results are thread-count
/// independent; this only changes how wide parallel sections fan out.
pub fn set_threads(n: usize) {
    OVERRIDE.store(n, Ordering::Relaxed);
}

/// A lease of extra cores from the global budget. The calling thread
/// always counts as one; `extra` is how many helper threads were
/// granted. Dropping the lease returns the cores.
struct Lease {
    extra: usize,
}

impl Lease {
    fn grab(want: usize) -> Lease {
        if want <= 1 {
            return Lease { extra: 0 };
        }
        let cap = max_threads();
        let mut extra = 0;
        let _ = ACTIVE.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |cur| {
            let free = cap.saturating_sub(cur + 1);
            extra = (want - 1).min(free);
            if extra == 0 {
                None
            } else {
                Some(cur + extra)
            }
        });
        Lease { extra }
    }

    fn threads(&self) -> usize {
        self.extra + 1
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        if self.extra > 0 {
            ACTIVE.fetch_sub(self.extra, Ordering::SeqCst);
        }
    }
}

/// Row-band parallelism over a pair of row-major buffers: `a` is split
/// into bands of whole `a_cols`-wide rows, `out` into the matching
/// `o_cols`-wide bands, and `f(a_band, out_band)` runs once per band
/// across the pool. Serial when `work < MIN_PAR_WORK` or no cores are
/// free. Preconditions: `a_cols > 0`, `o_cols > 0`,
/// `a.len() == rows * a_cols`, `out.len() == rows * o_cols`.
pub fn join_row_bands<F>(
    a: &[f32],
    a_cols: usize,
    out: &mut [f32],
    o_cols: usize,
    work: usize,
    f: &F,
) where
    F: Fn(&[f32], &mut [f32]) + Sync,
{
    assert!(a_cols > 0 && o_cols > 0, "join_row_bands: zero-width rows");
    let rows = out.len() / o_cols;
    debug_assert_eq!(a.len(), rows * a_cols);
    let lease = Lease::grab(if work >= MIN_PAR_WORK { rows } else { 1 });
    let threads = lease.threads().min(rows.max(1));
    if threads <= 1 {
        f(a, out);
        return;
    }
    let band = rows.div_ceil(threads);
    std::thread::scope(|s| {
        let mut ai = a.chunks(band * a_cols);
        let mut oi = out.chunks_mut(band * o_cols);
        // run the first band on the calling thread, the rest on helpers
        let (Some(a0), Some(o0)) = (ai.next(), oi.next()) else {
            return; // rows == 0: no bands to run
        };
        for (ab, ob) in ai.zip(oi) {
            s.spawn(move || f(ab, ob));
        }
        f(a0, o0);
    });
}

/// Parallel map over `0..n`, preserving order. Each item should be
/// substantial (an attention head, a conv image) — tiny closures belong
/// in a serial loop.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let lease = Lease::grab(n);
    let threads = lease.threads().min(n);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        let f = &f;
        let mut iter = out.chunks_mut(chunk).enumerate();
        let Some((i0, c0)) = iter.next() else {
            return; // n == 0 is handled above; empty only if out is empty
        };
        for (ci, csl) in iter {
            s.spawn(move || {
                for (i, slot) in csl.iter_mut().enumerate() {
                    *slot = Some(f(ci * chunk + i));
                }
            });
        }
        for (i, slot) in c0.iter_mut().enumerate() {
            *slot = Some(f(i0 * chunk + i));
        }
    });
    out.into_iter()
        // lint:allow(panic-safety): the band loops above fill every slot; a None here is a plain bug, not a runtime condition
        .map(|o| o.expect("parallel_map: missing slot"))
        .collect()
}

/// Split `buf` into `chunk_len`-sized pieces and run `f(chunk_index,
/// chunk)` for each across the pool (serial below `MIN_PAR_ELEMS`).
/// `buf.len()` must be a multiple of `chunk_len`.
pub fn parallel_chunks_mut<F>(buf: &mut [f32], chunk_len: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert!(chunk_len > 0 && buf.len() % chunk_len == 0);
    let n_chunks = buf.len() / chunk_len;
    let lease = Lease::grab(if buf.len() >= MIN_PAR_ELEMS { n_chunks } else { 1 });
    let threads = lease.threads().min(n_chunks.max(1));
    if threads <= 1 {
        for (ci, c) in buf.chunks_mut(chunk_len).enumerate() {
            f(ci, c);
        }
        return;
    }
    let group = n_chunks.div_ceil(threads);
    std::thread::scope(|s| {
        let f = &f;
        let mut gi = buf.chunks_mut(group * chunk_len).enumerate();
        let Some((g0, first)) = gi.next() else {
            return; // empty buffer: no chunks to run
        };
        for (g, gsl) in gi {
            s.spawn(move || {
                for (ci, c) in gsl.chunks_mut(chunk_len).enumerate() {
                    f(g * group + ci, c);
                }
            });
        }
        for (ci, c) in first.chunks_mut(chunk_len).enumerate() {
            f(g0 * group + ci, c);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_threads_at_least_one() {
        assert!(max_threads() >= 1);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let v = parallel_map(257, |i| i * 3);
        assert_eq!(v.len(), 257);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * 3);
        }
    }

    #[test]
    fn parallel_map_empty() {
        let v: Vec<usize> = parallel_map(0, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn join_row_bands_covers_all_rows() {
        let rows = 97;
        let a: Vec<f32> = (0..rows * 4).map(|i| i as f32).collect();
        let mut out = vec![0.0f32; rows * 2];
        let f = |ar: &[f32], ob: &mut [f32]| {
            let r = ob.len() / 2;
            for i in 0..r {
                let s: f32 = ar[i * 4..(i + 1) * 4].iter().sum();
                ob[i * 2] = s;
                ob[i * 2 + 1] = -s;
            }
        };
        // a huge nominal work value forces the parallel path (when cores
        // are free); the result must equal the serial run either way
        join_row_bands(&a, 4, &mut out, 2, usize::MAX, &f);
        let mut expect = vec![0.0f32; rows * 2];
        f(&a, &mut expect);
        assert_eq!(out, expect);
    }

    #[test]
    fn parallel_chunks_mut_indexes_correctly() {
        let mut buf = vec![0.0f32; 64 * 1024]; // above MIN_PAR_ELEMS
        parallel_chunks_mut(&mut buf, 1024, |ci, c| {
            for x in c.iter_mut() {
                *x = ci as f32;
            }
        });
        for (i, x) in buf.iter().enumerate() {
            assert_eq!(*x, (i / 1024) as f32);
        }
    }

    #[test]
    fn lease_grants_and_restores() {
        // (no global-counter assertions here: other tests hold leases
        // concurrently and may move the override, so only per-lease
        // invariants are race-free)
        let l = Lease::grab(1000);
        assert!(l.threads() >= 1);
        assert!(l.extra < 1000);
        drop(l);
        let l2 = Lease::grab(2);
        assert!(l2.threads() <= 2);
    }
}
