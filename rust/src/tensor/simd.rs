//! Runtime-detected SIMD microkernels for the native hot paths.
//!
//! Zero-dependency AVX2/FMA fast paths (`std::arch` +
//! `is_x86_feature_detected!`) for the three per-element loops that
//! dominate a training step: the matmul B-panel axpy
//! (`tensor::ops::matmul`), the attention-softmax row pass
//! (`runtime::native::kernels::attention_head`), and the SGD/AdamW
//! updates (`adapters::optimizer`). Every kernel keeps its original
//! scalar loop here as the pinned fallback, selected at runtime:
//!
//! - `COLA_SIMD=0` (or `off`) — scalar everywhere;
//! - `COLA_SIMD=1` / unset — AVX2 when the CPU has it (**bit-identical**
//!   to scalar, see below);
//! - `COLA_SIMD=fma` — additionally allows the FMA-contracted panel
//!   kernel (documented tolerance, see [`FMA_CONTRACTION_EPS`]);
//! - the `simd` config key / [`set_policy`] override the env at runtime.
//!
//! **Determinism contract.** The default AVX2 tier vectorizes only
//! lane-wise IEEE-exact operations: the panel axpy issues a separate
//! multiply and add per lane (no contraction), the optimizer updates are
//! purely elementwise (`_mm256_sqrt_ps`/`_mm256_div_ps` are correctly
//! rounded), and softmax vectorizes the shift-subtract and normalize
//! passes while `exp` and the row-sum stay scalar — `exp` because libm
//! is the reference, the sum because it is an ordered reduction and
//! 8-lane partial sums would reorder adds. No accumulation order
//! changes anywhere, so scalar and AVX2 runs produce **byte-identical
//! loss curves** (pinned by the in-module bitwise parity tests and the
//! `path-parity` CI job). Only the opt-in FMA tier trades that for
//! speed: one fused multiply-add per accumulation step skips an
//! intermediate rounding, bounded by [`FMA_CONTRACTION_EPS`].

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Documented tolerance for the opt-in FMA-contracted panel kernel
/// (`COLA_SIMD=fma` / `simd = "fma"`). Each fused multiply-add skips
/// one intermediate f32 rounding (at most one ulp, `2^-23`, relative),
/// so after `k` accumulation steps the FMA result may drift from the
/// scalar/AVX2 path by at most `FMA_CONTRACTION_EPS * k` relative to
/// the accumulated absolute magnitude. Pinned by
/// `fma_panel_within_documented_tolerance`.
pub const FMA_CONTRACTION_EPS: f32 = 1.2e-7;

/// What the user asked for (env/config); [`level`] intersects it with
/// what the CPU offers.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Policy {
    /// scalar fallbacks everywhere
    Off,
    /// AVX2 when detected, bit-identical tier only (the default)
    Auto,
    /// additionally allow the FMA-contracted panel kernel
    Fma,
}

/// The kernel tier actually dispatched on this process right now.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Level {
    Scalar,
    Avx2,
    Avx2Fma,
}

const P_UNSET: u8 = 0;
const P_OFF: u8 = 1;
const P_AUTO: u8 = 2;
const P_FMA: u8 = 3;

static OVERRIDE: AtomicU8 = AtomicU8::new(P_UNSET);

fn env_policy() -> Policy {
    static P: OnceLock<Policy> = OnceLock::new();
    *P.get_or_init(|| match std::env::var("COLA_SIMD") {
        Ok(s) => match s.trim().to_ascii_lowercase().as_str() {
            "0" | "off" | "false" => Policy::Off,
            "fma" => Policy::Fma,
            _ => Policy::Auto,
        },
        Err(_) => Policy::Auto,
    })
}

/// Current policy: the [`set_policy`] override, else `COLA_SIMD`, else
/// [`Policy::Auto`].
pub fn policy() -> Policy {
    match OVERRIDE.load(Ordering::Relaxed) {
        P_OFF => Policy::Off,
        P_AUTO => Policy::Auto,
        P_FMA => Policy::Fma,
        _ => env_policy(),
    }
}

/// Serializes tests that mutate the process-global policy override
/// ([`OVERRIDE`] is shared state; concurrent set/assert would be flaky).
#[cfg(test)]
pub(crate) fn test_policy_lock() -> std::sync::MutexGuard<'static, ()> {
    static L: std::sync::Mutex<()> = std::sync::Mutex::new(());
    L.lock().unwrap_or_else(|e| e.into_inner())
}

/// Override the policy at runtime (the `simd` config key routes here);
/// `None` clears back to `COLA_SIMD`/auto.
pub fn set_policy(p: Option<Policy>) {
    let v = match p {
        None => P_UNSET,
        Some(Policy::Off) => P_OFF,
        Some(Policy::Auto) => P_AUTO,
        Some(Policy::Fma) => P_FMA,
    };
    OVERRIDE.store(v, Ordering::Relaxed);
}

/// (avx2, fma) as reported by the CPU, detected once.
fn detect() -> (bool, bool) {
    static D: OnceLock<(bool, bool)> = OnceLock::new();
    *D.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            (
                std::arch::is_x86_feature_detected!("avx2"),
                std::arch::is_x86_feature_detected!("fma"),
            )
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            (false, false)
        }
    })
}

/// The tier actually in effect: policy ∩ detection.
pub fn level() -> Level {
    let (avx2, fma) = detect();
    match policy() {
        Policy::Off => Level::Scalar,
        Policy::Auto => {
            if avx2 {
                Level::Avx2
            } else {
                Level::Scalar
            }
        }
        Policy::Fma => {
            if avx2 && fma {
                Level::Avx2Fma
            } else if avx2 {
                Level::Avx2
            } else {
                Level::Scalar
            }
        }
    }
}

/// Human-readable tier for logs ("scalar" / "avx2" / "avx2+fma").
pub fn describe() -> &'static str {
    match level() {
        Level::Scalar => "scalar",
        Level::Avx2 => "avx2",
        Level::Avx2Fma => "avx2+fma",
    }
}

// ---------------------------------------------------------------- axpy

/// The matmul B-panel inner loop: `o[j] += a * b[j]`. This is the
/// pinned scalar kernel every fast path must match (bitwise for AVX2,
/// within [`FMA_CONTRACTION_EPS`] for FMA).
pub fn axpy_accum_scalar(o: &mut [f32], b: &[f32], a: f32) {
    for (x, &y) in o.iter_mut().zip(b) {
        *x += a * y;
    }
}

/// AVX2 axpy: separate 8-lane multiply and add, so every lane computes
/// exactly `round(o + round(a * b))` — bit-identical to
/// [`axpy_accum_scalar`]. Falls back to scalar when AVX2 is absent.
pub fn axpy_accum_avx2(o: &mut [f32], b: &[f32], a: f32) {
    #[cfg(target_arch = "x86_64")]
    if detect().0 {
        // SAFETY: detect().0 is is_x86_feature_detected!("avx2"), checked on
        // this very branch; the kernel's only other contract (in-bounds lane
        // access for any o/b lengths) is upheld internally by its 8-wide
        // loop guard + scalar tail
        return unsafe { x86::axpy_accum_avx2(o, b, a) };
    }
    axpy_accum_scalar(o, b, a)
}

/// FMA-contracted axpy (`_mm256_fmadd_ps`; scalar tail uses
/// `f32::mul_add`): one rounding per step instead of two. NOT
/// bit-identical to scalar — documented by [`FMA_CONTRACTION_EPS`].
/// Falls back to scalar when FMA is absent.
pub fn axpy_accum_fma(o: &mut [f32], b: &[f32], a: f32) {
    #[cfg(target_arch = "x86_64")]
    {
        let (avx2, fma) = detect();
        if avx2 && fma {
            // SAFETY: both is_x86_feature_detected! results are required true
            // on this branch, matching the kernel's target_feature(avx2,fma)
            // contract; lane bounds are upheld internally
            return unsafe { x86::axpy_accum_fma(o, b, a) };
        }
    }
    axpy_accum_scalar(o, b, a)
}

/// Dispatch the panel axpy once per band (hoists the tier check out of
/// the k-loop). `tensor::ops::matmul` calls this.
pub fn axpy_kernel() -> fn(&mut [f32], &[f32], f32) {
    match level() {
        Level::Scalar => axpy_accum_scalar,
        Level::Avx2 => axpy_accum_avx2,
        Level::Avx2Fma => axpy_accum_fma,
    }
}

// ------------------------------------------------------------- softmax

/// Numerically stable in-place row softmax — the pinned scalar kernel
/// from `attention_head`: a row whose every logit is `-inf` degrades to
/// all-zero probs instead of NaN.
pub fn softmax_row_scalar(row: &mut [f32]) {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let shift = if m.is_finite() { m } else { 0.0 };
    let mut sum = 0.0f32;
    for x in row.iter_mut() {
        *x = (*x - shift).exp();
        sum += *x;
    }
    if sum > 0.0 {
        for x in row.iter_mut() {
            *x /= sum;
        }
    } else {
        for x in row.iter_mut() {
            *x = 0.0;
        }
    }
}

/// AVX2 row softmax, bit-identical to [`softmax_row_scalar`]: the
/// shift-subtract and the normalize division vectorize (lane-wise exact
/// IEEE ops); `exp` stays the scalar libm call and the row-sum keeps
/// its serial order, because either vectorized would change values the
/// determinism contract pins. Falls back to scalar when AVX2 is absent.
pub fn softmax_row_avx2(row: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if detect().0 {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let shift = if m.is_finite() { m } else { 0.0 };
        // SAFETY: AVX2 verified by detect().0 on this branch; unaligned
        // loads/stores + scalar tail keep any row length in bounds
        unsafe { x86::sub_scalar_avx2(row, shift) };
        let mut sum = 0.0f32;
        for x in row.iter_mut() {
            *x = x.exp();
            sum += *x;
        }
        if sum > 0.0 {
            // SAFETY: AVX2 verified by detect().0 on the enclosing branch;
            // unaligned loads/stores + scalar tail keep any row length in
            // bounds
            unsafe { x86::div_scalar_avx2(row, sum) };
        } else {
            for x in row.iter_mut() {
                *x = 0.0;
            }
        }
        return;
    }
    softmax_row_scalar(row)
}

/// Runtime-dispatched row softmax (`attention_head` calls this). The
/// FMA tier has no contracted softmax — it shares the AVX2 kernel.
pub fn softmax_row(row: &mut [f32]) {
    match level() {
        Level::Scalar => softmax_row_scalar(row),
        Level::Avx2 | Level::Avx2Fma => softmax_row_avx2(row),
    }
}

// ----------------------------------------------------------- optimizer

/// Per-step AdamW constants ([`adamw_update`]): the config scalars plus
/// the step's bias corrections `bc1 = 1 - beta1^t`, `bc2 = 1 - beta2^t`.
#[derive(Clone, Copy, Debug)]
pub struct AdamwStep {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub bc1: f32,
    pub bc2: f32,
}

/// The pinned scalar AdamW element update from `adapters::optimizer`.
pub fn adamw_update_scalar(w: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], s: &AdamwStep) {
    for ((w, gv), (mi, vi)) in
        w.iter_mut().zip(g).zip(m.iter_mut().zip(v.iter_mut()))
    {
        *mi = s.beta1 * *mi + (1.0 - s.beta1) * gv;
        *vi = s.beta2 * *vi + (1.0 - s.beta2) * gv * gv;
        let mhat = *mi / s.bc1;
        let vhat = *vi / s.bc2;
        *w -= s.lr * (mhat / (vhat.sqrt() + s.eps) + s.weight_decay * *w);
    }
}

/// AVX2 AdamW: purely elementwise, every lane runs the exact scalar
/// operation sequence (`_mm256_sqrt_ps` and `_mm256_div_ps` are IEEE
/// correctly rounded, no contraction) — bit-identical to
/// [`adamw_update_scalar`]. Falls back to scalar when AVX2 is absent.
pub fn adamw_update_avx2(w: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], s: &AdamwStep) {
    #[cfg(target_arch = "x86_64")]
    if detect().0 {
        // SAFETY: AVX2 verified by detect().0 on this branch; the kernel
        // debug-asserts the four slices share one length and bounds its
        // lane accesses with an 8-wide guard + scalar tail
        return unsafe { x86::adamw_update_avx2(w, g, m, v, s) };
    }
    adamw_update_scalar(w, g, m, v, s)
}

/// Runtime-dispatched AdamW update. The FMA tier shares the AVX2
/// kernel: the optimizer trajectory stays bit-exact under every policy
/// except `off`-vs-rest never differing at all.
pub fn adamw_update(w: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], s: &AdamwStep) {
    match level() {
        Level::Scalar => adamw_update_scalar(w, g, m, v, s),
        Level::Avx2 | Level::Avx2Fma => adamw_update_avx2(w, g, m, v, s),
    }
}

/// The pinned scalar SGD element update (`w -= lr * (g + wd * w)`).
pub fn sgd_update_scalar(w: &mut [f32], g: &[f32], lr: f32, weight_decay: f32) {
    for (w, gv) in w.iter_mut().zip(g) {
        *w -= lr * (gv + weight_decay * *w);
    }
}

/// AVX2 SGD, bit-identical to [`sgd_update_scalar`] (lane-wise exact
/// mul/add/sub). Falls back to scalar when AVX2 is absent.
pub fn sgd_update_avx2(w: &mut [f32], g: &[f32], lr: f32, weight_decay: f32) {
    #[cfg(target_arch = "x86_64")]
    if detect().0 {
        // SAFETY: AVX2 verified by detect().0 on this branch; lane bounds
        // are upheld internally (8-wide guard + scalar tail)
        return unsafe { x86::sgd_update_avx2(w, g, lr, weight_decay) };
    }
    sgd_update_scalar(w, g, lr, weight_decay)
}

/// Runtime-dispatched SGD update.
pub fn sgd_update(w: &mut [f32], g: &[f32], lr: f32, weight_decay: f32) {
    match level() {
        Level::Scalar => sgd_update_scalar(w, g, lr, weight_decay),
        Level::Avx2 | Level::Avx2Fma => sgd_update_avx2(w, g, lr, weight_decay),
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    use super::AdamwStep;

    /// # Safety
    /// Caller must have verified AVX2 support
    /// (`is_x86_feature_detected!("avx2")`); executing the body without
    /// it is an illegal-instruction fault. Alignment: only `loadu`/
    /// `storeu` (alignment-free) intrinsics touch memory. Lane width:
    /// the `i + 8 <= n` guard keeps every 8-lane access inside both
    /// slices (which `debug_assert_eq!` pins to one length); the tail
    /// is scalar.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_accum_avx2(o: &mut [f32], b: &[f32], a: f32) {
        debug_assert_eq!(o.len(), b.len());
        let n = o.len();
        let op = o.as_mut_ptr();
        let bp = b.as_ptr();
        let va = _mm256_set1_ps(a);
        let mut i = 0;
        while i + 8 <= n {
            // separate mul + add (no fmadd): per-lane identical to scalar
            let prod = _mm256_mul_ps(va, _mm256_loadu_ps(bp.add(i)));
            let sum = _mm256_add_ps(_mm256_loadu_ps(op.add(i)), prod);
            _mm256_storeu_ps(op.add(i), sum);
            i += 8;
        }
        while i < n {
            *op.add(i) += a * *bp.add(i);
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 **and** FMA support — this body
    /// emits `vfmadd` encodings gated by both feature bits. Alignment:
    /// `loadu`/`storeu` only. Lane width: `i + 8 <= n` guard + scalar
    /// tail keep all accesses inside the equal-length slices.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy_accum_fma(o: &mut [f32], b: &[f32], a: f32) {
        debug_assert_eq!(o.len(), b.len());
        let n = o.len();
        let op = o.as_mut_ptr();
        let bp = b.as_ptr();
        let va = _mm256_set1_ps(a);
        let mut i = 0;
        while i + 8 <= n {
            let acc = _mm256_fmadd_ps(va, _mm256_loadu_ps(bp.add(i)), _mm256_loadu_ps(op.add(i)));
            _mm256_storeu_ps(op.add(i), acc);
            i += 8;
        }
        while i < n {
            // keep the tail contracted too, so the whole row shares one
            // rounding regime
            *op.add(i) = a.mul_add(*bp.add(i), *op.add(i));
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support. Alignment: `loadu`/
    /// `storeu` only, so `row` may start anywhere. Lane width: the
    /// `i + 8 <= n` guard + scalar tail cover every row length,
    /// including 0..8.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sub_scalar_avx2(row: &mut [f32], shift: f32) {
        let n = row.len();
        let rp = row.as_mut_ptr();
        let vs = _mm256_set1_ps(shift);
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_sub_ps(_mm256_loadu_ps(rp.add(i)), vs);
            _mm256_storeu_ps(rp.add(i), v);
            i += 8;
        }
        while i < n {
            *rp.add(i) -= shift;
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support. Alignment: `loadu`/
    /// `storeu` only. Lane width: `i + 8 <= n` guard + scalar tail
    /// cover every row length.
    #[target_feature(enable = "avx2")]
    pub unsafe fn div_scalar_avx2(row: &mut [f32], d: f32) {
        let n = row.len();
        let rp = row.as_mut_ptr();
        let vd = _mm256_set1_ps(d);
        let mut i = 0;
        while i + 8 <= n {
            // true division (not reciprocal-multiply): correctly rounded,
            // so each lane matches the scalar `x / d`
            let v = _mm256_div_ps(_mm256_loadu_ps(rp.add(i)), vd);
            _mm256_storeu_ps(rp.add(i), v);
            i += 8;
        }
        while i < n {
            *rp.add(i) /= d;
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support. Alignment: `loadu`/
    /// `storeu` only. Lane width: the `i + 8 <= n` guard bounds every
    /// 8-lane access by `n = w.len()`, which the `debug_assert_eq!`s
    /// pin to the g/m/v lengths as well; the tail reuses the scalar
    /// kernel on safe subslices.
    #[target_feature(enable = "avx2")]
    pub unsafe fn adamw_update_avx2(
        w: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
        s: &AdamwStep,
    ) {
        debug_assert_eq!(w.len(), g.len());
        debug_assert_eq!(w.len(), m.len());
        debug_assert_eq!(w.len(), v.len());
        let n = w.len();
        let (wp, gp, mp, vp) = (w.as_mut_ptr(), g.as_ptr(), m.as_mut_ptr(), v.as_mut_ptr());
        let b1 = _mm256_set1_ps(s.beta1);
        let omb1 = _mm256_set1_ps(1.0 - s.beta1);
        let b2 = _mm256_set1_ps(s.beta2);
        let omb2 = _mm256_set1_ps(1.0 - s.beta2);
        let bc1 = _mm256_set1_ps(s.bc1);
        let bc2 = _mm256_set1_ps(s.bc2);
        let eps = _mm256_set1_ps(s.eps);
        let lr = _mm256_set1_ps(s.lr);
        let wd = _mm256_set1_ps(s.weight_decay);
        let mut i = 0;
        while i + 8 <= n {
            let vg = _mm256_loadu_ps(gp.add(i));
            let vw = _mm256_loadu_ps(wp.add(i));
            // m = b1*m + (1-b1)*g — two rounded muls then a rounded add,
            // the scalar operation sequence exactly
            let vm = _mm256_add_ps(
                _mm256_mul_ps(b1, _mm256_loadu_ps(mp.add(i))),
                _mm256_mul_ps(omb1, vg),
            );
            // v = b2*v + ((1-b2)*g)*g — scalar `(1-b2) * gv * gv` is
            // left-associated, so square after the (1-b2) mul
            let vv = _mm256_add_ps(
                _mm256_mul_ps(b2, _mm256_loadu_ps(vp.add(i))),
                _mm256_mul_ps(_mm256_mul_ps(omb2, vg), vg),
            );
            _mm256_storeu_ps(mp.add(i), vm);
            _mm256_storeu_ps(vp.add(i), vv);
            let mhat = _mm256_div_ps(vm, bc1);
            let vhat = _mm256_div_ps(vv, bc2);
            let denom = _mm256_add_ps(_mm256_sqrt_ps(vhat), eps);
            let upd = _mm256_mul_ps(
                lr,
                _mm256_add_ps(_mm256_div_ps(mhat, denom), _mm256_mul_ps(wd, vw)),
            );
            _mm256_storeu_ps(wp.add(i), _mm256_sub_ps(vw, upd));
            i += 8;
        }
        if i < n {
            super::adamw_update_scalar(&mut w[i..], &g[i..], &mut m[i..], &mut v[i..], s);
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support. Alignment: `loadu`/
    /// `storeu` only. Lane width: `i + 8 <= n` guard + scalar tail,
    /// with `debug_assert_eq!` pinning `w.len() == g.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sgd_update_avx2(w: &mut [f32], g: &[f32], lr: f32, weight_decay: f32) {
        debug_assert_eq!(w.len(), g.len());
        let n = w.len();
        let (wp, gp) = (w.as_mut_ptr(), g.as_ptr());
        let vlr = _mm256_set1_ps(lr);
        let vwd = _mm256_set1_ps(weight_decay);
        let mut i = 0;
        while i + 8 <= n {
            let vw = _mm256_loadu_ps(wp.add(i));
            let vg = _mm256_loadu_ps(gp.add(i));
            // w -= lr * (g + wd*w)
            let upd = _mm256_mul_ps(vlr, _mm256_add_ps(vg, _mm256_mul_ps(vwd, vw)));
            _mm256_storeu_ps(wp.add(i), _mm256_sub_ps(vw, upd));
            i += 8;
        }
        while i < n {
            *wp.add(i) -= lr * (*gp.add(i) + weight_decay * *wp.add(i));
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() * 2.0).collect()
    }

    #[test]
    fn policy_override_and_describe() {
        let _g = test_policy_lock();
        // never force a tier on (detection may be absent); only check the
        // off override and that clearing restores the env default
        let before = policy();
        set_policy(Some(Policy::Off));
        assert_eq!(policy(), Policy::Off);
        assert_eq!(level(), Level::Scalar);
        assert_eq!(describe(), "scalar");
        set_policy(None);
        assert_eq!(policy(), before);
    }

    #[test]
    fn avx2_axpy_matches_scalar_bitwise() {
        let mut rng = Rng::new(11);
        // lengths cover the vector body, the scalar tail, and both empty
        for n in [0, 1, 7, 8, 9, 16, 31, 63, 250, 256] {
            let b = randvec(&mut rng, n);
            let base = randvec(&mut rng, n);
            for a in [0.0f32, -1.5, 0.73, f32::MIN_POSITIVE, -3.0e30] {
                let mut o_s = base.clone();
                let mut o_v = base.clone();
                axpy_accum_scalar(&mut o_s, &b, a);
                axpy_accum_avx2(&mut o_v, &b, a);
                for (x, y) in o_s.iter().zip(&o_v) {
                    assert_eq!(x.to_bits(), y.to_bits(), "axpy n={n} a={a}");
                }
            }
        }
    }

    #[test]
    fn avx2_axpy_nonfinite_parity() {
        // NaN/inf must propagate exactly like the scalar loop (the IEEE
        // contract `matmul_ieee_nonfinite_parity` pins end to end)
        let b = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1.0, 0.0, -0.0, 2.0, 3.0, 4.0];
        let base = vec![1.0f32; 9];
        let mut o_s = base.clone();
        let mut o_v = base;
        axpy_accum_scalar(&mut o_s, &b, 0.0);
        axpy_accum_avx2(&mut o_v, &b, 0.0);
        for (x, y) in o_s.iter().zip(&o_v) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn fma_panel_within_documented_tolerance() {
        // the contracted kernel may drift, but only within the documented
        // per-step bound relative to the accumulated magnitude
        let mut rng = Rng::new(23);
        let k = 64;
        let n = 250;
        let mut o_ref = vec![0.0f32; n];
        let mut o_fma = vec![0.0f32; n];
        let mut mag = vec![0.0f32; n];
        for _ in 0..k {
            let a = rng.normal();
            let b = randvec(&mut rng, n);
            axpy_accum_scalar(&mut o_ref, &b, a);
            axpy_accum_fma(&mut o_fma, &b, a);
            for (mj, bj) in mag.iter_mut().zip(&b) {
                *mj += (a * bj).abs();
            }
        }
        for j in 0..n {
            let bound = FMA_CONTRACTION_EPS * k as f32 * mag[j].max(1.0);
            let diff = (o_ref[j] - o_fma[j]).abs();
            assert!(
                diff <= bound,
                "fma drift {diff} exceeds documented bound {bound} at {j}"
            );
        }
    }

    #[test]
    fn avx2_softmax_matches_scalar_bitwise() {
        let mut rng = Rng::new(37);
        for n in [1, 3, 8, 9, 17, 40, 250] {
            let mut r_s = randvec(&mut rng, n);
            // spread the logits so shift/exp/normalize all do real work
            for (i, x) in r_s.iter_mut().enumerate() {
                *x = *x * 4.0 + (i % 5) as f32;
            }
            let mut r_v = r_s.clone();
            softmax_row_scalar(&mut r_s);
            softmax_row_avx2(&mut r_v);
            for (x, y) in r_s.iter().zip(&r_v) {
                assert_eq!(x.to_bits(), y.to_bits(), "softmax n={n}");
            }
        }
        // the degenerate all-masked row (every logit -inf) zeroes on both
        let mut d_s = vec![f32::NEG_INFINITY; 11];
        let mut d_v = d_s.clone();
        softmax_row_scalar(&mut d_s);
        softmax_row_avx2(&mut d_v);
        assert_eq!(d_s, vec![0.0; 11]);
        assert_eq!(d_s, d_v);
    }

    #[test]
    fn avx2_optimizers_match_scalar_bitwise() {
        let mut rng = Rng::new(51);
        for n in [1, 8, 13, 100, 257] {
            let w0 = randvec(&mut rng, n);
            let g = randvec(&mut rng, n);
            let m0 = randvec(&mut rng, n);
            let v0: Vec<f32> = randvec(&mut rng, n).iter().map(|x| x * x).collect();
            let s = AdamwStep {
                lr: 0.01,
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
                weight_decay: 0.001,
                bc1: 1.0 - 0.9f32.powi(3),
                bc2: 1.0 - 0.999f32.powi(3),
            };
            let (mut ws, mut ms, mut vs) = (w0.clone(), m0.clone(), v0.clone());
            let (mut wv, mut mv, mut vv) = (w0.clone(), m0.clone(), v0.clone());
            adamw_update_scalar(&mut ws, &g, &mut ms, &mut vs, &s);
            adamw_update_avx2(&mut wv, &g, &mut mv, &mut vv, &s);
            for i in 0..n {
                assert_eq!(ws[i].to_bits(), wv[i].to_bits(), "adamw w n={n} i={i}");
                assert_eq!(ms[i].to_bits(), mv[i].to_bits(), "adamw m n={n} i={i}");
                assert_eq!(vs[i].to_bits(), vv[i].to_bits(), "adamw v n={n} i={i}");
            }
            let (mut ss, mut sv) = (w0.clone(), w0.clone());
            sgd_update_scalar(&mut ss, &g, 0.05, 0.01);
            sgd_update_avx2(&mut sv, &g, 0.05, 0.01);
            for i in 0..n {
                assert_eq!(ss[i].to_bits(), sv[i].to_bits(), "sgd n={n} i={i}");
            }
        }
    }
}
