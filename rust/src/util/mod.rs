//! Small shared substrates (offline stand-ins for serde etc.).

pub mod json;
