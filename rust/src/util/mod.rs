//! Small shared substrates (offline stand-ins for serde etc.).

pub mod json;

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Acquire a mutex, stripping poison.
///
/// THE one audited place where `PoisonError` is swallowed: a panicking
/// fit on one daemon connection must not wedge every other tenant
/// forever, so shared coordinator/daemon/runtime state always locks
/// through here. The data under these locks stays structurally valid
/// across a panic — the fit paths hand adapters out by value
/// (checkout/checkin) and discard any state a panic may have torn, so
/// recovering the lock is sound. Enforced by the `mutex-poison` rule
/// of `cola lint`: ad-hoc `lock().unwrap_or_else(…)` recovery (and of
/// course `lock().unwrap()`) is flagged everywhere else.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // lint:allow(mutex-poison): this IS the audited recovery helper
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Render a `catch_unwind` payload as text for error messages; panics
/// almost always carry `&str` or `String`.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}
