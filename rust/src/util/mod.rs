//! Small shared substrates (offline stand-ins for crates the repo
//! cannot depend on).
//!
//! - [`json`] — a hand-rolled JSON reader/writer (serde substitute),
//!   used by the artifact manifest, `--loss_out` curve files, the
//!   [`crate::gateway`] HTTP responses, and the usage ledger.
//! - [`lock_recover`] / [`wait_timeout_recover`] — the audited
//!   mutex-poison recovery points shared by every concurrent subsystem
//!   (worker daemons, the gateway, the tensor pool). See the
//!   `mutex-poison` rule in [`crate::lint`].
//! - [`panic_message`] — render a `catch_unwind` payload for error
//!   reporting (daemon fits, gateway jobs).

pub mod json;

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// Acquire a mutex, stripping poison.
///
/// THE one audited place where `PoisonError` is swallowed: a panicking
/// fit on one daemon connection must not wedge every other tenant
/// forever, so shared coordinator/daemon/runtime state always locks
/// through here. The data under these locks stays structurally valid
/// across a panic — the fit paths hand adapters out by value
/// (checkout/checkin) and discard any state a panic may have torn, so
/// recovering the lock is sound. Enforced by the `mutex-poison` rule
/// of `cola lint`: ad-hoc `lock().unwrap_or_else(…)` recovery (and of
/// course `lock().unwrap()`) is flagged everywhere else.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // lint:allow(mutex-poison): this IS the audited recovery helper
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Condvar companion to [`lock_recover`]: wait on `cv` with a timeout,
/// stripping poison from the reacquired guard.
///
/// `Condvar::wait_timeout` hands the poison flag back on reacquisition
/// just like `Mutex::lock`, so any waiter sharing a mutex with
/// panic-prone holders needs the same audited recovery. The soundness
/// argument is identical to [`lock_recover`] (state under these locks
/// is kept structurally valid across panics); callers must re-check
/// their predicate in a loop, as with any condvar wait.
///
/// The `timed_out` flag from the underlying wait is intentionally not
/// returned: every caller loops on its own predicate plus a stop flag,
/// so "why did we wake" never matters.
pub fn wait_timeout_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(guard, dur) {
        Ok((g, _timeout)) => g,
        Err(poisoned) => poisoned.into_inner().0,
    }
}

/// Render a `catch_unwind` payload as text for error messages; panics
/// almost always carry `&str` or `String`.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}
