//! Minimal JSON reader/writer (serde is unavailable offline).
//!
//! Parses the subset emitted by `aot.py` (objects, arrays, strings,
//! numbers, booleans, null) — which is all of JSON — with positions in
//! error messages. Used for `artifacts/manifest.json`, init indexes, and
//! experiment result emission.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.src.len()
            && matches!(self.src[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn expect_byte(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.src[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect_byte(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect_byte(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                &self.src[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| "bad \\u".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u".to_string())?;
                            out.push(char::from_u32(code).unwrap_or('?'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    let start = self.pos;
                    while self.pos < self.src.len()
                        && self.src[self.pos] != b'"'
                        && self.src[self.pos] != b'\\'
                    {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.src[start..self.pos])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.src[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

/// Serialize (for experiment result files).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let src = r#"{"artifacts": {"a": {"file": "a.hlo.txt",
            "inputs": [["x", "float32", [8, 64]], ["t", "int32", []]],
            "outputs": ["loss"]}}, "rank": 8}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("rank").unwrap().as_usize(), Some(8));
        let a = j.get("artifacts").unwrap().get("a").unwrap();
        let ins = a.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(ins[0].as_arr().unwrap()[0].as_str(), Some("x"));
        assert_eq!(ins[0].as_arr().unwrap()[2].as_arr().unwrap().len(), 2);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,-3],"b":"hi\nthere","c":true,"d":null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{]").is_err());
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn escapes() {
        let j = Json::parse(r#""a\"b\\cA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\cA"));
    }
}
