//! Million-user scale harness (`cola scale`): a deterministic traffic
//! generator that drives 10^5–10^6 lightweight users through the
//! coordinator's worker pool with a seeded, realistic (Zipf) arrival
//! distribution, against the LRU-paged state store in [`store`].
//!
//! The harness closes the ROADMAP's "heavy traffic from millions of
//! users" item with two measurable claims:
//!
//! 1. **Bounded memory.** Resident adapter bytes depend on the
//!    working-set size, not the user count: 10^6 registered users with
//!    `working_set = 1024` hold ~1024 adapters per worker in memory and
//!    page the rest to disk.
//! 2. **Paging never moves a curve.** Every interval's summed merged
//!    delta (dispatch order, same float-add order as the trainer) is
//!    recorded as a curve point; the curve is byte-identical with
//!    paging on or off at any working-set size, because a faulted-in
//!    adapter is bitwise the adapter that was evicted.
//!
//! Determinism: everything the curve depends on — arrivals, adapter
//! init, job data, dispatch order — is a pure function of
//! [`ScaleCfg::seed`]. The harness itself reads no clocks; wall-time
//! measurement (users/sec, p99 interval latency) belongs to the
//! callers (`cola scale`, `benches/scale.rs`), which time
//! [`ScaleHarness::run_interval`] from outside. This module is in
//! `cola lint`'s curve-scoped deny set, so a clock or HashMap here
//! fails CI.

pub mod store;

use std::collections::BTreeSet;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::adapters::{AdapterParams, OptimizerCfg, SiteAdapter};
use crate::config::{AdapterKind, OffloadTarget};
use crate::coordinator::{FitJob, WorkerPool};
use crate::rng::Rng;
use crate::tensor::Tensor;
use store::{PageStats, PagerCfg};

/// Adapter dims: deliberately tiny — the harness measures state
/// logistics (placement, paging, dispatch) at user-count scale, not
/// kernel throughput.
const D_IN: usize = 6;
const D_OUT: usize = 4;
const RANK: usize = 2;
const SITE: &str = "s";

/// Domain-separation tags for the per-purpose RNG streams.
const TAG_ARRIVALS: u64 = 0xA11;
const TAG_INIT: u64 = 0x1417;
const TAG_DATA: u64 = 0xDA7A;

#[derive(Clone, Debug)]
pub struct ScaleCfg {
    /// Total user population arrivals are drawn from.
    pub users: usize,
    /// Adaptation intervals to run.
    pub intervals: usize,
    /// Zipf draws per interval (deduped — the active set per interval
    /// is at most this big).
    pub touches_per_interval: usize,
    /// Local worker threads (each one event loop + one state store).
    pub workers: usize,
    /// Max resident adapters per worker; 0 = paging off.
    pub working_set: usize,
    /// Page-file root (each worker gets `<dir>/w<id>`). Required iff
    /// `working_set > 0`.
    pub page_dir: Option<PathBuf>,
    pub seed: u64,
    /// Rows per fit job.
    pub rows: usize,
}

impl ScaleCfg {
    /// Both-or-neither: a working set without a page dir (or vice
    /// versa) is a half-configured pager, and silently ignoring half a
    /// config is how curves stop being reproducible.
    pub fn validate(&self) -> Result<()> {
        if self.users == 0 || self.intervals == 0 || self.workers == 0 {
            bail!("cola scale: users, intervals, and workers must all be >= 1");
        }
        if self.touches_per_interval == 0 || self.rows == 0 {
            bail!("cola scale: touches and rows must be >= 1");
        }
        match (self.working_set, &self.page_dir) {
            (0, Some(_)) => bail!(
                "cola scale: --page_dir set but --working_set is 0 — refusing \
                 to silently ignore it (set --working_set >= 1 to page)"
            ),
            (ws, None) if ws > 0 => bail!(
                "cola scale: --working_set {ws} needs --page_dir (evicted \
                 state has to live somewhere)"
            ),
            _ => Ok(()),
        }
    }

    fn pager(&self) -> Option<PagerCfg> {
        self.page_dir.as_ref().map(|dir| PagerCfg {
            dir: dir.clone(),
            capacity: self.working_set,
        })
    }
}

/// One interval's outcome.
#[derive(Clone, Copy, Debug, Default)]
pub struct IntervalReport {
    /// distinct users touched this interval
    pub touched: usize,
    /// users registered for the first time (lazy registration)
    pub new_users: usize,
    /// fits that returned a result
    pub fits_ok: u64,
    /// fits that errored (must be 0 on a healthy run)
    pub fits_lost: u64,
    /// the curve point: summed merged deltas, dispatch order
    pub curve_point: f32,
}

/// Cumulative run summary — the figures `BENCH_scale.json` and the
/// scale-smoke CI gate read.
#[derive(Clone, Copy, Debug, Default)]
pub struct ScaleSummary {
    pub users_registered: usize,
    pub fits_ok: u64,
    pub fits_lost: u64,
    /// resident adapter+optimizer bytes across the fleet, right now
    pub resident_bytes: usize,
    pub page_stats: PageStats,
}

pub struct ScaleHarness {
    cfg: ScaleCfg,
    pool: WorkerPool,
    arrivals: Rng,
    registered: BTreeSet<usize>,
    curve: Vec<f32>,
    fits_ok: u64,
    fits_lost: u64,
    interval: usize,
}

impl ScaleHarness {
    pub fn new(cfg: ScaleCfg) -> Result<ScaleHarness> {
        cfg.validate()?;
        let manifest = std::sync::Arc::new(
            crate::runtime::native::builtin::builtin_manifest(std::path::Path::new(
                "artifacts",
            )),
        );
        let pool = WorkerPool::spawn_paged(
            cfg.workers,
            OffloadTarget::NativeCpu,
            manifest,
            None,
            cfg.pager(),
        )
        .context("spawning the scale-harness worker pool")?;
        let mut seed_rng = Rng::new(cfg.seed);
        let arrivals = seed_rng.fork(TAG_ARRIVALS);
        Ok(ScaleHarness {
            cfg,
            pool,
            arrivals,
            registered: BTreeSet::new(),
            curve: Vec::new(),
            fits_ok: 0,
            fits_lost: 0,
            interval: 0,
        })
    }

    /// Deterministic per-user adapter: init params from a user-keyed
    /// stream so registration order can't change anyone's weights.
    fn adapter_for(&self, user: usize) -> SiteAdapter {
        let mut rng = Rng::new(self.cfg.seed ^ TAG_INIT).fork(user as u64);
        let params =
            AdapterParams::init(AdapterKind::LowRank, D_IN, D_OUT, RANK, RANK, &mut rng);
        SiteAdapter::new(SITE, params, &OptimizerCfg::adamw(1e-3, 1e-4))
    }

    /// Deterministic per-(user, interval) job payload.
    fn job_for(&self, user: usize, interval: usize) -> FitJob {
        let mut rng =
            Rng::new(self.cfg.seed ^ TAG_DATA).fork(user as u64).fork(interval as u64);
        let rows = self.cfg.rows;
        let x = Tensor::new(vec![rows, D_IN], rng.normal_vec(rows * D_IN, 1.0));
        let ghat = Tensor::new(vec![rows, D_OUT], rng.normal_vec(rows * D_OUT, 1.0));
        FitJob {
            user,
            site: SITE.to_string(),
            x,
            ghat,
            grad_scale: 1.0,
            merged: true,
        }
    }

    /// Run one adaptation interval: draw the interval's active users
    /// (Zipf-skewed — a hot head and a long cold tail, which is what
    /// makes an LRU working set realistic), lazily register first-time
    /// arrivals, dispatch one fit per active user, and fold the merged
    /// deltas into this interval's curve point in dispatch order.
    pub fn run_interval(&mut self) -> Result<IntervalReport> {
        let interval = self.interval;
        self.interval += 1;
        // dedup via BTreeSet: the active set is sorted, so dispatch
        // order is a pure function of the draw — not of set iteration
        let mut active: BTreeSet<usize> = BTreeSet::new();
        for _ in 0..self.cfg.touches_per_interval {
            active.insert(self.arrivals.zipf(self.cfg.users));
        }
        let mut report = IntervalReport { touched: active.len(), ..Default::default() };
        // lazy registration: a user costs nothing until it first shows
        // up — 10^6 configured users don't mean 10^6 upfront adapters
        for &user in &active {
            if self.registered.insert(user) {
                report.new_users += 1;
                let adapter = self.adapter_for(user);
                self.pool.for_user(user)?.register(user, SITE, adapter)?;
            }
        }
        // dispatch everything, then collect in dispatch order: fits on
        // different workers overlap, and the float-add order of the
        // curve point stays fixed (same contract as the trainer's
        // buffer-drain order)
        let mut pending = Vec::with_capacity(active.len());
        for &user in &active {
            let job = self.job_for(user, interval);
            pending.push((user, self.pool.for_user(user)?.fit(job)?));
        }
        let mut point = 0.0f32;
        for (user, rx) in pending {
            match rx.recv() {
                Ok(Ok(r)) => {
                    report.fits_ok += 1;
                    if let Some(d) = &r.delta_diff {
                        point += d.data().iter().sum::<f32>();
                    }
                }
                Ok(Err(e)) => {
                    report.fits_lost += 1;
                    eprintln!("warning: scale fit lost for user {user}: {e:#}");
                }
                Err(_) => {
                    report.fits_lost += 1;
                    eprintln!("warning: scale fit reply channel died for user {user}");
                }
            }
        }
        report.curve_point = point;
        self.curve.push(point);
        self.fits_ok += report.fits_ok;
        self.fits_lost += report.fits_lost;
        Ok(report)
    }

    /// Run all configured intervals back to back (tests and the bench's
    /// non-timed warmup use this; `cola scale` loops `run_interval`
    /// itself to time each one).
    pub fn run_all(&mut self) -> Result<ScaleSummary> {
        for _ in self.interval..self.cfg.intervals {
            self.run_interval()?;
        }
        Ok(self.summary())
    }

    pub fn cfg(&self) -> &ScaleCfg {
        &self.cfg
    }

    pub fn curve(&self) -> &[f32] {
        &self.curve
    }

    /// The curve as lossless hex f32 bit patterns, one per line — the
    /// byte-comparable artifact the paging-determinism tests and the
    /// `--curve_out` flag emit. (`{:.6}` formatting would hide a 1-ulp
    /// divergence; bit patterns can't.)
    pub fn curve_hex(&self) -> String {
        let mut out = String::with_capacity(self.curve.len() * 9);
        for p in &self.curve {
            out.push_str(&format!("{:08x}\n", p.to_bits()));
        }
        out
    }

    pub fn summary(&self) -> ScaleSummary {
        ScaleSummary {
            users_registered: self.registered.len(),
            fits_ok: self.fits_ok,
            fits_lost: self.fits_lost,
            resident_bytes: self.pool.total_state_bytes(),
            page_stats: self.pool.total_page_stats(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("cola_scale_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn cfg(working_set: usize, page_dir: Option<PathBuf>) -> ScaleCfg {
        ScaleCfg {
            users: 64,
            intervals: 4,
            touches_per_interval: 24,
            workers: 2,
            working_set,
            page_dir,
            seed: 7,
            rows: 3,
        }
    }

    #[test]
    fn half_configured_pager_is_rejected() {
        assert!(cfg(2, None).validate().is_err());
        assert!(cfg(0, Some(PathBuf::from("/tmp/x"))).validate().is_err());
        assert!(cfg(0, None).validate().is_ok());
    }

    #[test]
    fn arrivals_are_deterministic_and_zipf_skewed() {
        let mut a = Rng::new(7).fork(TAG_ARRIVALS);
        let mut b = Rng::new(7).fork(TAG_ARRIVALS);
        let mut head = 0;
        for _ in 0..1000 {
            let u = a.zipf(1000);
            assert_eq!(u, b.zipf(1000));
            if u < 100 {
                head += 1;
            }
        }
        // zipf is u^3-concentrated: P(rank < n/10) = 0.1^(1/3) ~ 46% —
        // uniform would put ~100 of 1000 in the top decile
        assert!(head > 300, "arrival skew looks uniform: {head}/1000 in head");
    }

    #[test]
    fn paged_run_matches_unpaged_run_byte_for_byte() {
        let mut plain = ScaleHarness::new(cfg(0, None)).unwrap();
        let plain_summary = plain.run_all().unwrap();
        assert_eq!(plain_summary.fits_lost, 0);
        assert_eq!(plain_summary.page_stats, PageStats::default());

        let dir = tmpdir("match");
        let mut paged = ScaleHarness::new(cfg(2, Some(dir.clone()))).unwrap();
        let paged_summary = paged.run_all().unwrap();
        assert_eq!(paged_summary.fits_lost, 0);
        // ws=2 under ~12 active users per worker MUST page...
        assert!(paged_summary.page_stats.faults > 0, "working set never faulted");
        assert_eq!(paged_summary.page_stats.page_errors, 0);
        // ...and the curves are byte-identical anyway
        assert_eq!(plain.curve_hex(), paged.curve_hex());
        // bounded residency: at most ws adapters resident per worker
        assert!(paged_summary.resident_bytes < plain_summary.resident_bytes);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
