//! Keyed adapter/optimizer state store with a bounded LRU working set.
//!
//! Every worker holds its users' `(tenant, user, site)` adapter state
//! here. Unpaged, the store is exactly the old in-memory table: a
//! `BTreeMap` plus the busy set the checkout/checkin protocol needs.
//! With a [`PagerCfg`], the resident map is capped at `capacity`
//! entries and cold state is paged to disk — which is what lets one
//! worker serve 10^5–10^6 users with memory proportional to the
//! working set, not the user count (ADR 006).
//!
//! # Page format
//!
//! A page file is the bit-exact migration blob
//! [`crate::transport::wire::encode_state`] produces — the same bytes
//! that cross the wire for shard migration and buddy replication. That
//! buys three things for free: the round trip is already proven
//! bit-exact (params AND optimizer moments), corruption is detected by
//! the blob's own framing checks, and an exported page can be imported
//! by any other worker unchanged. Paging therefore can never move a
//! loss curve: a faulted-in adapter is bitwise the adapter that was
//! evicted.
//!
//! # Recency without wall clocks
//!
//! LRU ordering uses a logical u64 clock bumped on every insert and
//! checkin — never `Instant`/`SystemTime`, so eviction order is a pure
//! function of the access sequence and the store stays inside the
//! curve-scoped determinism deny set (`cola lint` scans this module).
//!
//! # Failure semantics
//!
//! - A page that fails to *read* (missing, truncated, corrupted, or
//!   decoding to a different key) is a per-key error naming the
//!   (tenant, user, site); it never panics and never poisons other
//!   keys.
//! - A page that fails to *write* during eviction keeps the entry
//!   resident and warns: the working set degrades (memory grows past
//!   the cap) but state is never lost to a full disk.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::adapters::SiteAdapter;

/// Fully-qualified state key: `(tenant, user, site)`. Structurally the
/// coordinator's `TenantKey`; redeclared here so `scale` never depends
/// on `coordinator` (the dependency points the other way).
pub type StoreKey = (String, usize, String);

/// Where and how much to page.
#[derive(Clone, Debug)]
pub struct PagerCfg {
    /// Directory the page files live in (created if missing). Each
    /// worker must get its OWN directory — pages are keyed per store.
    pub dir: PathBuf,
    /// Max resident (in-memory) entries; must be >= 1. Checked-out
    /// adapters don't count against it (they live on the fitting
    /// thread's stack), so the true ceiling is `capacity` + in-flight.
    pub capacity: usize,
}

/// Paging counters, cheap enough to read every interval.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PageStats {
    /// cold accesses served from disk
    pub faults: u64,
    /// entries written out to make room
    pub evictions: u64,
    /// page files written (== evictions unless writes failed)
    pub page_writes: u64,
    /// failed page reads/writes (each also warned or errored per key)
    pub page_errors: u64,
}

struct Entry {
    adapter: SiteAdapter,
    /// logical-clock stamp of the last insert/checkin — LRU order
    stamp: u64,
}

struct Pager {
    dir: PathBuf,
    capacity: usize,
    /// The authority on what lives on disk. A file without a `paged`
    /// entry is stale garbage (tolerated, overwritten on next evict);
    /// a `paged` entry without a readable file is a per-key error.
    paged: BTreeSet<StoreKey>,
}

/// The store. Not internally locked — callers (the worker core) wrap
/// it in their own mutex, exactly like the table it replaced.
pub struct KeyedStateStore {
    resident: BTreeMap<StoreKey, Entry>,
    /// keys checked out by an in-flight fit
    busy: BTreeSet<StoreKey>,
    clock: u64,
    pager: Option<Pager>,
    stats: PageStats,
}

impl KeyedStateStore {
    /// Unpaged store: plain in-memory table, zero behavior change.
    pub fn new() -> KeyedStateStore {
        KeyedStateStore {
            resident: BTreeMap::new(),
            busy: BTreeSet::new(),
            clock: 0,
            pager: None,
            stats: PageStats::default(),
        }
    }

    /// Paged store rooted at `cfg.dir` (created here so the first
    /// eviction can't fail on a missing directory).
    pub fn with_pager(cfg: PagerCfg) -> Result<KeyedStateStore> {
        std::fs::create_dir_all(&cfg.dir)
            .with_context(|| format!("creating page dir {}", cfg.dir.display()))?;
        let mut s = KeyedStateStore::new();
        s.pager = Some(Pager {
            dir: cfg.dir,
            capacity: cfg.capacity.max(1),
            paged: BTreeSet::new(),
        });
        Ok(s)
    }

    pub fn stats(&self) -> PageStats {
        self.stats
    }

    pub fn is_busy(&self, key: &StoreKey) -> bool {
        self.busy.contains(key)
    }

    pub fn busy_len(&self) -> usize {
        self.busy.len()
    }

    /// Un-busy a key (checkin and panic-release paths). Returns whether
    /// it was busy.
    pub fn clear_busy(&mut self, key: &StoreKey) -> bool {
        self.busy.remove(key)
    }

    /// Bytes of RESIDENT adapter + optimizer state. Paged-out entries
    /// deliberately don't count — bounding this figure is the whole
    /// point of paging, and it is what the memory ledger reports.
    pub fn resident_bytes(&self) -> usize {
        self.resident
            .values()
            .map(|e| e.adapter.params.bytes() + e.adapter.opt.bytes())
            .sum()
    }

    /// Install (or replace) state for a key, evicting over-capacity
    /// cold entries to disk. Callers must have rejected busy keys
    /// already (registration/import during an in-flight fit).
    pub fn insert(&mut self, key: StoreKey, adapter: SiteAdapter) {
        if let Some(p) = &mut self.pager {
            // a fresh insert supersedes any page on disk for the key
            if p.paged.remove(&key) {
                let _ = std::fs::remove_file(page_path(&p.dir, &key));
            }
        }
        self.clock += 1;
        let stamp = self.clock;
        self.resident.insert(key, Entry { adapter, stamp });
        self.enforce_capacity();
    }

    /// Check a key out for a fit: remove it from the resident map (or
    /// fault it in from disk), mark it busy. `Ok(None)` = the key is
    /// neither resident, paged, nor busy — the caller turns that into
    /// its "no adapter" / "busy" error. `Err` = the key IS paged but
    /// its page failed to read — a per-key error, never a panic.
    pub fn take(&mut self, key: &StoreKey) -> Result<Option<SiteAdapter>> {
        if let Some(e) = self.resident.remove(key) {
            self.busy.insert(key.clone());
            return Ok(Some(e.adapter));
        }
        if self.pager.as_ref().is_some_and(|p| p.paged.contains(key)) {
            let adapter = self.fault_in(key)?;
            if let Some(p) = self.pager.as_mut() {
                p.paged.remove(key);
                let _ = std::fs::remove_file(page_path(&p.dir, key));
            }
            self.busy.insert(key.clone());
            return Ok(Some(adapter));
        }
        Ok(None)
    }

    /// A clone of a key's state without checking it out (snapshots).
    /// Paged keys are read from disk but stay paged — a read-only peek
    /// must not churn the working set.
    pub fn peek_clone(&mut self, key: &StoreKey) -> Result<Option<SiteAdapter>> {
        if let Some(e) = self.resident.get(key) {
            return Ok(Some(e.adapter.clone()));
        }
        if self.pager.as_ref().is_some_and(|p| p.paged.contains(key)) {
            return self.fault_in(key).map(Some);
        }
        Ok(None)
    }

    /// The key's state as a migration blob — from memory or straight
    /// off disk (page files ARE migration blobs).
    pub fn export_blob(&mut self, key: &StoreKey) -> Result<Option<Vec<u8>>> {
        if let Some(e) = self.resident.get(key) {
            return Ok(Some(crate::transport::wire::encode_state(
                key.1, &key.2, &e.adapter,
            )));
        }
        if self.pager.as_ref().is_some_and(|p| p.paged.contains(key)) {
            // round-trip through decode so a corrupted page surfaces
            // here as this key's error, not later on a peer's import
            let adapter = self.fault_in(key)?;
            return Ok(Some(crate::transport::wire::encode_state(
                key.1, &key.2, &adapter,
            )));
        }
        Ok(None)
    }

    /// Whether the key has state, resident or paged.
    pub fn contains(&self, key: &StoreKey) -> bool {
        self.resident.contains_key(key)
            || self.pager.as_ref().is_some_and(|p| p.paged.contains(key))
    }

    /// Drop a key's state everywhere (evict-after-migration). Absent
    /// keys are a no-op.
    pub fn remove(&mut self, key: &StoreKey) {
        self.resident.remove(key);
        if let Some(p) = &mut self.pager {
            if p.paged.remove(key) {
                let _ = std::fs::remove_file(page_path(&p.dir, key));
            }
        }
    }

    /// Return a checked-out adapter. Infallible by contract (the fit
    /// path cannot handle a failing checkin); an over-capacity page
    /// WRITE failure degrades to keeping the entry resident, loudly.
    pub fn checkin(&mut self, key: StoreKey, adapter: SiteAdapter) {
        self.busy.remove(&key);
        self.clock += 1;
        let stamp = self.clock;
        self.resident.insert(key, Entry { adapter, stamp });
        self.enforce_capacity();
    }

    fn fault_in(&mut self, key: &StoreKey) -> Result<SiteAdapter> {
        let p = self.pager.as_ref().ok_or_else(|| {
            anyhow!("state store: fault for {} without a pager", label(key))
        })?;
        let path = page_path(&p.dir, key);
        let blob = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) => {
                self.stats.page_errors += 1;
                return Err(anyhow!(
                    "state page for {} unreadable at {} ({e}); this \
                     (user, site) is lost but no other key is affected",
                    label(key),
                    path.display()
                ));
            }
        };
        let decoded = crate::transport::wire::decode_state(&blob);
        let (user, site, adapter) = match decoded {
            Ok(t) => t,
            Err(e) => {
                self.stats.page_errors += 1;
                return Err(anyhow!(
                    "state page for {} at {} is corrupted ({e:#}); this \
                     (user, site) is lost but no other key is affected",
                    label(key),
                    path.display()
                ));
            }
        };
        if user != key.1 || site != key.2 {
            self.stats.page_errors += 1;
            return Err(anyhow!(
                "state page for {} at {} decodes to (user {user}, site \
                 {site}) — wrong key; refusing to serve it",
                label(key),
                path.display()
            ));
        }
        self.stats.faults += 1;
        Ok(adapter)
    }

    fn enforce_capacity(&mut self) {
        let Some(cap) = self.pager.as_ref().map(|p| p.capacity) else {
            return;
        };
        while self.resident.len() > cap {
            // least-recent stamp = coldest entry (busy keys are never
            // resident, so everything here is evictable)
            let Some(victim) = self
                .resident
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| k.clone())
            else {
                return;
            };
            let Some(e) = self.resident.get(&victim) else {
                return;
            };
            let blob =
                crate::transport::wire::encode_state(victim.1, &victim.2, &e.adapter);
            let Some(p) = self.pager.as_mut() else {
                return;
            };
            match write_page(&p.dir, &victim, &blob) {
                Ok(()) => {
                    p.paged.insert(victim.clone());
                    self.resident.remove(&victim);
                    self.stats.evictions += 1;
                    self.stats.page_writes += 1;
                }
                Err(e) => {
                    // keep the entry resident: exceeding the working
                    // set beats losing optimizer state to a full disk
                    self.stats.page_errors += 1;
                    eprintln!(
                        "warning: paging {} out failed ({e:#}); keeping it \
                         resident (working set exceeds its cap until disk \
                         recovers)",
                        label(&victim)
                    );
                    return;
                }
            }
        }
    }
}

impl Default for KeyedStateStore {
    fn default() -> Self {
        KeyedStateStore::new()
    }
}

fn label(key: &StoreKey) -> String {
    if key.0.is_empty() {
        format!("({}, {})", key.1, key.2)
    } else {
        format!("(tenant {}, user {}, site {})", key.0, key.1, key.2)
    }
}

/// FNV-1a over the full key label — disambiguates keys whose sanitized
/// filename prefixes collide (e.g. sites `a.b` and `a_b`).
fn fnv1a64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .take(40)
        .collect()
}

fn page_path(dir: &Path, key: &StoreKey) -> PathBuf {
    let full = format!("{}\u{1f}{}\u{1f}{}", key.0, key.1, key.2);
    dir.join(format!(
        "{}__{}__{}.{:016x}.page",
        sanitize(&key.0),
        key.1,
        sanitize(&key.2),
        fnv1a64(&full)
    ))
}

/// Write-then-rename so a crash mid-write leaves no half page under the
/// real name (a stale `.tmp` is garbage the next write overwrites).
fn write_page(dir: &Path, key: &StoreKey, blob: &[u8]) -> Result<()> {
    let path = page_path(dir, key);
    let tmp = path.with_extension("page.tmp");
    std::fs::write(&tmp, blob)
        .with_context(|| format!("writing page {}", tmp.display()))?;
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("publishing page {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters::{AdapterParams, OptimizerCfg};
    use crate::config::AdapterKind;

    fn adapter(seed: u64) -> SiteAdapter {
        let mut rng = crate::rng::Rng::new(seed);
        let params = AdapterParams::init(AdapterKind::LowRank, 6, 4, 3, 5, &mut rng);
        SiteAdapter::new("s", params, &OptimizerCfg::adamw(1e-3, 1e-4))
    }

    fn key(user: usize) -> StoreKey {
        (String::new(), user, "s".to_string())
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("cola_store_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn unpaged_store_is_a_plain_table() {
        let mut s = KeyedStateStore::new();
        s.insert(key(0), adapter(1));
        s.insert(key(1), adapter(2));
        assert!(s.contains(&key(0)));
        let a = s.take(&key(0)).unwrap().unwrap();
        assert!(s.is_busy(&key(0)));
        assert_eq!(s.take(&key(0)).unwrap().map(|_| ()), None);
        s.checkin(key(0), a);
        assert!(!s.is_busy(&key(0)));
        assert_eq!(s.stats(), PageStats::default());
    }

    #[test]
    fn lru_evicts_the_coldest_and_faults_it_back_bitwise() {
        let dir = tmpdir("lru");
        let mut s = KeyedStateStore::with_pager(PagerCfg {
            dir: dir.clone(),
            capacity: 2,
        })
        .unwrap();
        for u in 0..3 {
            s.insert(key(u), adapter(10 + u as u64));
        }
        // capacity 2: user 0 (coldest) went to disk
        assert_eq!(s.stats().evictions, 1);
        assert_eq!(s.resident.len(), 2);
        assert!(s.contains(&key(0)));
        let reference = crate::transport::wire::encode_state(0, "s", &adapter(10));
        // touch user 0: faulted back bit-identical to what was stored
        let a0 = s.take(&key(0)).unwrap().unwrap();
        assert_eq!(s.stats().faults, 1);
        assert_eq!(crate::transport::wire::encode_state(0, "s", &a0), reference);
        // checking it back in pushes the new coldest (user 1) out
        s.checkin(key(0), a0);
        assert_eq!(s.stats().evictions, 2);
        assert!(s.contains(&key(1)));
        // export of a paged key round-trips through the page file
        let blob = s.export_blob(&key(1)).unwrap().unwrap();
        assert_eq!(
            blob,
            crate::transport::wire::encode_state(1, "s", &adapter(11))
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_page_is_a_per_key_error_not_a_panic() {
        let dir = tmpdir("corrupt");
        let mut s = KeyedStateStore::with_pager(PagerCfg {
            dir: dir.clone(),
            capacity: 1,
        })
        .unwrap();
        s.insert(key(0), adapter(1));
        s.insert(key(1), adapter(2)); // pages user 0 out
        let path = page_path(&dir, &key(0));
        std::fs::write(&path, b"definitely not a state blob").unwrap();
        let err = s.take(&key(0)).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("(0, s)"), "{msg}");
        assert!(msg.contains("no other key is affected"), "{msg}");
        assert_eq!(s.stats().page_errors, 1);
        // the OTHER key still serves fine
        assert!(s.take(&key(1)).unwrap().is_some());
        // a missing page errors the same way (named, no panic)
        let _ = std::fs::remove_file(&path);
        let err = s.peek_clone(&key(0)).unwrap_err();
        assert!(format!("{err}").contains("unreadable"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn remove_drops_the_page_file_too() {
        let dir = tmpdir("rm");
        let mut s = KeyedStateStore::with_pager(PagerCfg {
            dir: dir.clone(),
            capacity: 1,
        })
        .unwrap();
        s.insert(key(0), adapter(1));
        s.insert(key(1), adapter(2));
        let p0 = page_path(&dir, &key(0));
        assert!(p0.exists());
        s.remove(&key(0));
        assert!(!p0.exists());
        assert!(!s.contains(&key(0)));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
