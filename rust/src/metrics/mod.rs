//! Metrics: loss/accuracy curves, per-step timing breakdowns, and the
//! markdown/CSV emitters the benches use to regenerate the paper's
//! tables and figures.

use std::fmt::Write as _;
use std::time::Duration;

/// A (step, value) series — learning curves (Figs 2-17).
#[derive(Clone, Debug, Default)]
pub struct Curve {
    pub name: String,
    pub points: Vec<(u64, f64)>,
}

impl Curve {
    pub fn new(name: &str) -> Curve {
        Curve { name: name.to_string(), points: Vec::new() }
    }

    pub fn push(&mut self, step: u64, value: f64) {
        self.points.push((step, value));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|(_, v)| *v)
    }

    /// Mean of the final `k` points (stable end-of-training estimate).
    pub fn tail_mean(&self, k: usize) -> f64 {
        if self.points.is_empty() {
            return f64::NAN;
        }
        let s = self.points.len().saturating_sub(k);
        let tail = &self.points[s..];
        tail.iter().map(|(_, v)| v).sum::<f64>() / tail.len() as f64
    }

    pub fn to_csv(&self) -> String {
        let mut out = format!("step,{}\n", self.name);
        for (s, v) in &self.points {
            let _ = writeln!(out, "{s},{v}");
        }
        out
    }
}

/// Write multiple aligned curves as one CSV (one column per curve).
pub fn curves_to_csv(curves: &[&Curve]) -> String {
    let mut out = String::from("step");
    for c in curves {
        out.push(',');
        out.push_str(&c.name);
    }
    out.push('\n');
    let n = curves.iter().map(|c| c.points.len()).max().unwrap_or(0);
    for i in 0..n {
        let step = curves
            .iter()
            .find_map(|c| c.points.get(i).map(|(s, _)| *s))
            .unwrap_or(i as u64);
        let _ = write!(out, "{step}");
        for c in curves {
            match c.points.get(i) {
                Some((_, v)) => {
                    let _ = write!(out, ",{v}");
                }
                None => out.push(','),
            }
        }
        out.push('\n');
    }
    out
}

/// Accumulated wall-clock breakdown of the training loop (the run-time
/// columns of Tables 10-18).
#[derive(Clone, Debug, Default)]
pub struct Timings {
    pub steps: u64,
    /// one-time XLA compilation (setup, not per-step cost)
    pub compile: Duration,
    /// server fwd+bwd execute time
    pub fwdbwd: Duration,
    /// host<->device + inter-device transfer time (adaptation data,
    /// adapter updates)
    pub transfer: Duration,
    /// worker fit + optimizer time
    pub worker: Duration,
    /// merge/unmerge bookkeeping
    pub merge: Duration,
    /// bytes shipped server -> workers
    pub bytes_offloaded: u64,
    /// bytes shipped workers -> server (adapter updates / deltas)
    pub bytes_returned: u64,
    /// request/reply wire exchanges spent dispatching fits — the
    /// quantity FitBatch batching collapses (one frame per worker per
    /// interval instead of one per job); see EXPERIMENTS.md
    pub round_trips: u64,
    /// fits transiently lost to a dying worker and recovered by
    /// re-dispatch (`failover = "migrate"`); each one was also reported
    /// with its (user, site) when it happened
    pub lost_fits: u64,
    /// pool membership changes that moved state (failovers, drains,
    /// adds)
    pub migrations: u64,
    /// migration-blob bytes shipped between workers (live exports +
    /// checkpoint restores)
    pub migrated_state_bytes: u64,
    /// adaptation intervals that stalled on a recovery round before
    /// their replies could apply
    pub stall_intervals: u64,
    /// shards recovered by promoting a buddy replica in place (zero
    /// wire bytes, zero recovery rounds) instead of restoring a shadow
    /// checkpoint — the `replicate = true` fast path
    pub shard_promotions: u64,
    /// actual request bytes put on the wire by TCP transports (frame
    /// headers included) — the quantity `offload_wire = "bf16"`
    /// shrinks; 0 for in-process transports. Unlike `bytes_offloaded`
    /// (the logical f32 tensor ledger), this reflects the negotiated
    /// wire encoding.
    pub wire_bytes: u64,
}

impl Timings {
    pub fn per_step(&self, d: Duration) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        d.as_secs_f64() / self.steps as f64
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "steps {} | compile {:.1}s once | base {:.4}s/step | transfer {:.4}s/step | worker {:.4}s/step | merge {:.4}s/step | offloaded {:.1} MiB | returned {:.1} MiB | fit round-trips {}",
            self.steps,
            self.compile.as_secs_f64(),
            self.per_step(self.fwdbwd),
            self.per_step(self.transfer),
            self.per_step(self.worker),
            self.per_step(self.merge),
            self.bytes_offloaded as f64 / (1024.0 * 1024.0),
            self.bytes_returned as f64 / (1024.0 * 1024.0),
            self.round_trips,
        );
        if self.wire_bytes > 0 {
            // greppable exact count: distributed_smoke.sh's wire mode
            // reads this to compute the measured f32 -> bf16 reduction
            s.push_str(&format!(" | wire bytes {}", self.wire_bytes));
        }
        if self.migrations > 0 || self.lost_fits > 0 {
            s.push_str(&format!(
                " | migrations {} ({:.2} MiB state moved) | lost fits recovered {} | stalled intervals {}",
                self.migrations,
                self.migrated_state_bytes as f64 / (1024.0 * 1024.0),
                self.lost_fits,
                self.stall_intervals,
            ));
        }
        if self.shard_promotions > 0 {
            // greppable exact count: distributed_smoke.sh's registry mode
            // asserts the kill was absorbed by buddy promotion, not by a
            // checkpoint-restore recovery round
            s.push_str(&format!(" | shards promoted {}", self.shard_promotions));
        }
        s
    }
}

/// Render a markdown table.
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "| {} |", headers.join(" | "));
    let _ = writeln!(out, "|{}|", headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for row in rows {
        let _ = writeln!(out, "| {} |", row.join(" | "));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_tail_mean() {
        let mut c = Curve::new("loss");
        for i in 0..10 {
            c.push(i, i as f64);
        }
        assert_eq!(c.tail_mean(2), 8.5);
        assert_eq!(c.last(), Some(9.0));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut a = Curve::new("a");
        a.push(0, 1.0);
        a.push(1, 2.0);
        let mut b = Curve::new("b");
        b.push(0, 3.0);
        let csv = curves_to_csv(&[&a, &b]);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "step,a,b");
        assert_eq!(lines[1], "0,1,3");
        assert_eq!(lines[2], "1,2,");
    }

    #[test]
    fn markdown_shape() {
        let t = markdown_table(&["x", "y"], &[vec!["1".into(), "2".into()]]);
        assert!(t.contains("| x | y |"));
        assert!(t.contains("| 1 | 2 |"));
    }

    #[test]
    fn timings_report_nonpanic() {
        let t = Timings::default();
        assert!(t.report().contains("steps 0"));
    }
}
