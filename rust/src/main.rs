//! `cola` CLI — launcher for training runs, the FTaaS demo service,
//! memory reports, and experiment drivers.

use anyhow::{bail, Context, Result};

use cola::cli::Args;
use cola::config::{apply_overrides, Method, TrainConfig};
use cola::coordinator::{FtaasService, Trainer};
use cola::memory::{footprint, Arrangement, ModelProfile, GB};
use cola::metrics::markdown_table;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "memory" => cmd_memory(&args),
        "table1" => cmd_table1(),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try `cola help`)"),
    }
}

fn print_help() {
    println!(
        "cola — Collaborative Adaptation with Gradient Learning\n\n\
         USAGE: cola <subcommand> [--key value]...\n\n\
         SUBCOMMANDS\n\
           train    run one fine-tuning job\n\
                    --task clm|s2s|seqcls --size tiny|small|base\n\
                    --method ft|lora|ia3|prompt|ptuning|prefix|cola-lowrank|cola-linear|cola-mlp\n\
                    --mode merged|unmerged --interval I --steps N --users K\n\
                    --offload cpu|gpu --dataset <name> --seed S\n\
           serve    FTaaS collaboration demo (--users K --rounds N)\n\
           memory   analytic memory report\n\
                    --profile llama2-qv|llama2-all|gpt2|roberta-base|bart-base|tiny|small\n\
                    --batch B --interval I\n\
           table1   print the Table-1 computation-space complexity summary\n"
    );
}

fn config_from_args(args: &Args) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    if let Some(m) = args.get("method") {
        cfg = cfg.preset_for_method(m.parse()?);
    }
    apply_overrides(&mut cfg, &args.options)?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    println!("config: {cfg:?}");
    let mut trainer = Trainer::new(cfg).context("building trainer")?;
    let report = trainer.run()?;
    println!("train loss (last): {:.4}", report.train_loss.last().unwrap_or(f64::NAN));
    println!("eval  loss (tail): {:.4}", report.eval_loss.tail_mean(3));
    if report.eval_acc.last().is_some() {
        println!("score            : {:.1}", report.score());
    }
    println!("trainable params : {}", report.trainable_params);
    println!("server resident  : {:.1} MiB",
             report.server_resident_bytes as f64 / (1024.0 * 1024.0));
    println!("worker state     : {:.1} MiB",
             report.worker_state_bytes as f64 / (1024.0 * 1024.0));
    println!("timings: {}", report.timings.report());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = config_from_args(args)?;
    if !args.options.contains_key("users") {
        cfg.users = 4;
    }
    if cfg.batch % cfg.users != 0 {
        cfg.batch = cfg.users * (cfg.batch / cfg.users).max(1);
    }
    let rounds: u64 = args.parse_or("rounds", 64)?;
    let kind = match cfg.method {
        Method::Cola(k) => k,
        _ => cola::config::AdapterKind::LowRank,
    };
    println!("FTaaS service: {} users, adapter {kind}, {rounds} rounds", cfg.users);
    let mut svc = FtaasService::start(cfg, kind)?;
    for job in svc.jobs() {
        println!("  user {} -> category {} ({})", job.user, job.category,
                 cola::data::lm::CATEGORIES[job.category]);
    }
    let chunk = (rounds / 8).max(1);
    let mut done = 0;
    while done < rounds {
        let n = chunk.min(rounds - done);
        svc.run_rounds(n)?;
        done += n;
        let st = svc.status()?;
        println!("round {done}/{rounds}: loss {:.4}, server resident {:.1} MiB",
                 st.last_train_loss.unwrap_or(f64::NAN),
                 st.server_resident_bytes as f64 / (1024.0 * 1024.0));
    }
    println!("\nper-category quality of the shared model:");
    for c in 0..8 {
        println!("  {:24} {:.1}", cola::data::lm::CATEGORIES[c],
                 svc.category_score(c)?);
    }
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<()> {
    let name = args.get_or("profile", "llama2-qv");
    let profile = ModelProfile::by_name(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown profile {name}"))?;
    let batch: usize = args.parse_or("batch", 8)?;
    let interval: usize = args.parse_or("interval", 1)?;
    let users: usize = args.parse_or("users", 1)?;
    use cola::config::AdapterKind::*;
    let mut rows = Vec::new();
    let mut push = |label: &str, arr: Arrangement| {
        let fp = footprint(&profile, arr, batch, interval, 8, 64);
        rows.push(vec![
            label.to_string(),
            format!("{:.2}", fp.server_total() as f64 / GB),
            format!("{:.2}", fp.worker_total() as f64 / GB),
            format!("{:.3}", fp.transfer_per_step as f64 / GB),
        ]);
    };
    push("FT", Arrangement::FullFt);
    push("LoRA", Arrangement::Peft { kind: LowRank, users });
    push("ColA(LowRank, unmerged)", Arrangement::Cola { kind: LowRank, merged: false, users });
    push("ColA(LowRank, merged)", Arrangement::Cola { kind: LowRank, merged: true, users });
    push("ColA(Linear, merged)", Arrangement::Cola { kind: Linear, merged: true, users });
    push("ColA(MLP, unmerged)", Arrangement::Cola { kind: Mlp, merged: false, users });
    println!("profile {name}: {} params, batch {batch}, interval {interval}, users {users}",
             profile.params());
    println!("{}", markdown_table(
        &["method", "server GB", "worker GB", "transfer GB/step"], &rows));
    Ok(())
}

fn cmd_table1() -> Result<()> {
    println!("Table 1 — computation-space complexity (see memory/ for bytes)\n");
    let rows = vec![
        vec!["FT".into(), "theta".into(), "h".into(), "grad h".into(), "grad theta".into()],
        vec!["PEFT (unmerged)".into(), "theta, w".into(), "h, h~".into(),
             "grad h, grad h~".into(), "grad w".into()],
        vec!["ColA (unmerged)".into(), "theta, w".into(), "h, h~".into(),
             "grad h, grad h~".into(), "{grad w}".into()],
        vec!["ColA (merged)".into(), "theta-hat, {w}".into(), "h, {h~}".into(),
             "grad h, {h~}".into(), "{grad w}".into()],
    ];
    println!("{}", markdown_table(
        &["method", "params", "fwd reps", "bwd reps", "param grads"], &rows));
    println!("{{.}} = lives on low-cost devices (offloaded)");
    Ok(())
}
