//! `cola` CLI — launcher for training runs, the worker daemon
//! (distributed offload), the FTaaS HTTP gateway (`cola serve`),
//! memory reports, and experiment drivers.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use cola::cli::Args;
use cola::config::{apply_overrides, Method, OffloadTarget, SimdMode, TomlDoc,
                   TrainConfig, TransportKind};
use cola::coordinator::{rebalance_daemons, Driver, FtaasService, TransferModel,
                        Trainer};
use cola::gateway::{client as gateway_client, Gateway, ServeConfig};
use cola::transport::tcp::TcpLinkOpts;
use cola::memory::{footprint, Arrangement, ModelProfile, GB};
use cola::metrics::markdown_table;
use cola::runtime::Manifest;
use cola::transport::tcp::{request_daemon_shutdown, WorkerDaemon};
use cola::util::json::Json;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "http" => cmd_http(&args),
        "worker" => cmd_worker(&args),
        "pool" => cmd_pool(&args),
        "curvediff" => cmd_curvediff(&args),
        "scale" => cmd_scale(&args),
        "demo" => cmd_demo(&args),
        "memory" => cmd_memory(&args),
        "table1" => cmd_table1(),
        "lint" => cmd_lint(&args),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try `cola help`)"),
    }
}

fn print_help() {
    println!(
        "cola — Collaborative Adaptation with Gradient Learning\n\n\
         USAGE: cola <subcommand> [--key value]...\n\n\
         SUBCOMMANDS"
    );
    // generated from the same table the README command reference uses
    // (`cola::cli::SUBCOMMANDS`); tests/cli_docs.rs keeps all three in
    // sync with the dispatch match above
    for (name, summary) in cola::cli::SUBCOMMANDS {
        println!("  {name:<10} {summary}");
    }
    println!(
        "\nOPTIONS BY SUBCOMMAND\n\
           train    --config <file.toml> (CLI options override file keys)\n\
                    --task clm|s2s|seqcls --size tiny|small|base\n\
                    --method ft|lora|ia3|prompt|ptuning|prefix|cola-lowrank|cola-linear|cola-mlp\n\
                    --mode merged|unmerged --interval I --steps N --users K\n\
                    --offload cpu|gpu --dataset <name> --seed S\n\
                    --offload_transport local|tcp --worker_addrs host:port,...\n\
                    --offload_tenant <name> (namespace on a shared daemon)\n\
                    --offload_batch true|false (one FitBatch frame per interval)\n\
                    --offload_inflight N (pipelined FitBatch frames, default 1)\n\
                    --standby_addrs host:port,... (cold spare daemons)\n\
                    --failover fail|migrate (survive daemon death bit-exactly)\n\
                    --heartbeat_interval N (liveness sweep every N flushes)\n\
                    --registry_listen host:port (accept `cola worker --join`\n\
                    self-registrations; with it, worker_addrs may be empty)\n\
                    --replicate true|false (push each shard's post-interval\n\
                    state to a buddy daemon; failed shards promote the buddy\n\
                    replica in place — zero recovery rounds)\n\
                    --offload_wire f32|bf16 (bf16 halves fit-tensor bytes on\n\
                    the TCP wire; replies, snapshots, and migration state\n\
                    blobs always stay f32, so bf16 composes with\n\
                    --failover migrate)\n\
                    --simd auto|off|on|fma (kernel dispatch tier; `auto`\n\
                    defers to the COLA_SIMD env var, `fma` trades bitwise\n\
                    reproducibility for fused multiply-add speed)\n\
                    --loss_out <file.json> (write loss/acc curves for diffing)\n\
                    --adapter_out <file> (write the deterministic adapter\n\
                    bundle — same bytes the gateway's /adapter endpoint serves)\n\
           serve    long-running FTaaS gateway over HTTP/1.1 (std::net only);\n\
                    POST /v1/fit submits a [train] config TOML, progress\n\
                    streams as chunked JSONL, adapters download bit-exact;\n\
                    fair-share admission across token-authenticated tenants\n\
                    (see README \"FTaaS gateway\" + docs/decisions/)\n\
                    --config <file.toml> (its [serve] section; CLI overrides)\n\
                    --listen 127.0.0.1:7780 (port 0 = ephemeral)\n\
                    --token_file <file> (required; tenant:token per line)\n\
                    --backlog N (max queued jobs per tenant; default 8)\n\
                    --ledger <file.jsonl> (usage ledger; empty = disabled)\n\
           http     cola http <get|post> <url> — minimal client for the\n\
                    gateway API (smoke scripts run without curl)\n\
                    --token T (Bearer token) --body <file> (POST payload)\n\
                    --out <file> (write body; default stdout)\n\
                    --expect CODE (fail unless the status matches; default:\n\
                    fail on any status >= 400)\n\
           worker   gradient-offload worker daemon (distributed mode);\n\
                    serves any number of concurrent trainer connections;\n\
                    bf16 fit tensors are negotiated per connection (Hello\n\
                    capability) — daemons always reply and export state\n\
                    in raw-bit f32\n\
                    --listen 127.0.0.1:0 --offload cpu|gpu --threads N\n\
                    --simd auto|off|on|fma (kernel dispatch tier)\n\
                    --simulate_link cpu|gpu (add a modeled link delay)\n\
                    --join host:port (self-register with a coordinator's\n\
                    worker registry listener — see --registry_listen)\n\
                    --stop host:port (clean-shutdown a running daemon)\n\
           curvediff  numerically compare two --loss_out curve files\n\
                    cola curvediff a.json b.json [--tol T]\n\
                    --tol T (relative tolerance; default 0 = bit-identical)\n\
           scale    million-user traffic harness: Zipf arrivals, lazy\n\
                    registration, LRU adapter-state paging to disk; prints\n\
                    users/sec + p99 interval latency + resident bytes and\n\
                    fails on any lost fit (see README \"Scale harness &\n\
                    state paging\")\n\
                    --users N (population, default 10000) --intervals N\n\
                    --touches N (Zipf draws/interval) --workers N --seed S\n\
                    --rows N (rows per fit job)\n\
                    --working_set N (max resident adapters per worker;\n\
                    0 = paging off) --page_dir <dir> (required with a\n\
                    bounded working set)\n\
                    --curve_out <file> (per-interval curve as f32 bit\n\
                    patterns — byte-compare paged vs unpaged runs)\n\
                    --out <file.json> (machine-readable summary)\n\
                    --max_resident_bytes B (fail if the fleet's resident\n\
                    state exceeds B — the CI bounded-memory gate)\n\
           pool     elastic-pool resize between runs: migrate shard state\n\
                    so the same daemons can serve a different topology\n\
                    --config <file.toml> (names users/sites/worker_addrs)\n\
                    --add host:port    (grow: state moves TO the new daemon)\n\
                    --drain host:port  (shrink gracefully: state moves off it)\n\
                    --remove host:port (drop a DEAD daemon from the list;\n\
                    its unmigrated state is gone — prefer --drain when alive)\n\
           demo     FTaaS collaboration demo (--users K --rounds N)\n\
           memory   analytic memory report\n\
                    --profile llama2-qv|llama2-all|gpt2|roberta-base|bart-base|tiny|small\n\
                    --batch B --interval I\n\
           table1   print the Table-1 computation-space complexity summary\n\
           lint     zero-dep determinism / panic-safety static analysis\n\
                    over rust/src (see README \"Static analysis\")\n\
                    --root <dir>  (source tree; default auto-detected)\n\
                    --deny-all    (warnings also fail the run)\n\
                    --fix-report  (per-rule counts, remediation hints,\n\
                    and the audited lint:allow pragma inventory)\n"
    );
}

fn cmd_lint(args: &Args) -> Result<()> {
    let root = match args.get("root") {
        Some(r) => std::path::PathBuf::from(r),
        None => cola::lint::default_src_root()?,
    };
    let deny_all = args.has_flag("deny-all");
    let report = cola::lint::scan_tree(&root)
        .with_context(|| format!("scanning {}", root.display()))?;
    for v in &report.violations {
        println!("{v}");
    }
    if args.has_flag("fix-report") {
        println!("\nper-rule counts ({} files scanned):", report.files_scanned);
        for rule in cola::lint::RULES {
            let n = report.count_for(rule);
            if n > 0 {
                println!("  {:<20} {:>4}   fix: {}", rule.name(), n, rule.remedy());
            } else {
                println!("  {:<20} {:>4}", rule.name(), n);
            }
        }
        println!("\naudited lint:allow pragmas ({}):", report.allowed.len());
        for a in &report.allowed {
            println!("  {}:{}: [{}] {}", a.file, a.line, a.rule, a.reason);
        }
    }
    let denies = report.deny_count();
    let warns = report.warn_count();
    println!(
        "cola lint: {} deny, {} warn, {} allowed across {} files",
        denies,
        warns,
        report.allowed.len(),
        report.files_scanned
    );
    if denies > 0 {
        bail!("{denies} deny violation(s)");
    }
    if deny_all && warns > 0 {
        bail!("{warns} warning(s) under --deny-all");
    }
    Ok(())
}

/// Keys consumed by the launcher itself, not by `TrainConfig`.
const LAUNCHER_KEYS: &[&str] = &["config", "loss_out", "adapter_out"];

/// Precedence (least to most binding): built-in defaults, then the
/// CLI `--method` hyperparameter preset, then `--config` file keys,
/// then explicit CLI overrides. A preset is an implicit default — an
/// lr written in the config file must beat it, and a CLI `--lr` beats
/// everything. The same `--method` flag therefore means the same thing
/// with or without `--config`.
fn config_from_args(args: &Args) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    if let Some(m) = args.get("method") {
        cfg = cfg.preset_for_method(m.parse()?);
    }
    if let Some(path) = args.get("config") {
        let doc = TomlDoc::load(path).with_context(|| format!("loading config {path}"))?;
        for (k, v) in doc.flat() {
            let key = k.strip_prefix("train.").unwrap_or(&k);
            cfg.set(key, &v)
                .with_context(|| format!("config {path}: key {k}"))?;
        }
    }
    apply_overrides(&mut cfg, &args.options_except(LAUNCHER_KEYS))?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    // every train option takes a value; a bare `--offload_batch` would
    // otherwise parse as a flag and be silently dropped
    args.require_no_flags("train")?;
    let cfg = config_from_args(args)?;
    println!("config: {cfg:?}");
    let mut trainer = Trainer::new(cfg).context("building trainer")?;
    let report = trainer.run()?;
    println!("train loss (last): {:.4}", report.train_loss.last().unwrap_or(f64::NAN));
    println!("eval  loss (tail): {:.4}", report.eval_loss.tail_mean(3));
    if report.eval_acc.last().is_some() {
        println!("score            : {:.1}", report.score());
    }
    println!("trainable params : {}", report.trainable_params);
    println!("server resident  : {:.1} MiB",
             report.server_resident_bytes as f64 / (1024.0 * 1024.0));
    println!("worker state     : {:.1} MiB",
             report.worker_state_bytes as f64 / (1024.0 * 1024.0));
    println!("timings: {}", report.timings.report());
    if let Some(path) = args.get("loss_out") {
        // the exact bytes the gateway's /curves endpoint serves — one
        // shared serializer keeps the determinism diff honest
        std::fs::write(path, report.curves_json())
            .with_context(|| format!("writing {path}"))?;
        println!("loss curves      -> {path}");
    }
    if let Some(path) = args.get("adapter_out") {
        let bundle = trainer.export_adapter_bundle()?;
        std::fs::write(path, &bundle).with_context(|| format!("writing {path}"))?;
        println!("adapter bundle   -> {path}");
    }
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    // same loud-typo contract as train: an unknown option must not
    // silently launch a daemon with the wrong topology
    const WORKER_KEYS: &[&str] =
        &["stop", "listen", "offload", "threads", "simd", "simulate_link",
          "artifacts_dir", "join"];
    for k in args.options.keys() {
        if !WORKER_KEYS.contains(&k.as_str()) {
            bail!("unknown worker option --{k} \
                   (listen|offload|threads|simd|simulate_link|artifacts_dir|join|stop)");
        }
    }
    args.require_no_flags("worker")?;
    if let Some(addr) = args.get("stop") {
        request_daemon_shutdown(addr)?;
        println!("worker at {addr}: shutdown acknowledged");
        return Ok(());
    }
    let listen = args.get_or("listen", "127.0.0.1:0");
    let target: OffloadTarget = args.get_or("offload", "cpu").parse()?;
    let threads: usize = args.parse_or("threads", 0)?;
    cola::tensor::pool::set_threads(threads);
    // same mapping the trainer applies from its `simd` config key —
    // daemons must be pinnable too, or a bit-identical cross-process
    // run could pair a SIMD server with a scalar worker
    let simd: SimdMode = args.get_or("simd", "auto").parse()?;
    cola::tensor::simd::set_policy(match simd {
        SimdMode::Auto => None,
        SimdMode::Off => Some(cola::tensor::simd::Policy::Off),
        SimdMode::On => Some(cola::tensor::simd::Policy::Auto),
        SimdMode::Fma => Some(cola::tensor::simd::Policy::Fma),
    });
    let simulate = match args.get("simulate_link") {
        None => None,
        Some("cpu") => Some(TransferModel::cpu_link()),
        Some("gpu") => Some(TransferModel::gpu_link()),
        Some(other) => bail!("unknown --simulate_link '{other}' (cpu|gpu)"),
    };
    let artifacts_dir = args.get_or("artifacts_dir", "artifacts");
    let manifest = Arc::new(Manifest::load_or_builtin(Path::new(&artifacts_dir))?);
    let daemon = WorkerDaemon::bind(&listen, target, manifest, simulate)?;
    // launchers (CI, scripts) scrape this line for the resolved port;
    // stdout is line-buffered so it is visible immediately
    println!("cola worker listening on {}", daemon.local_addr());
    if let Some(coordinator) = args.get("join") {
        // announce before blocking in join(): a mis-pointed --join must
        // kill the daemon loudly, not leave it listening unregistered
        cola::coordinator::join_coordinator(coordinator, &daemon.local_addr().to_string())?;
        println!("cola worker: registered with coordinator at {coordinator}");
    }
    daemon.join();
    println!("cola worker: shutdown handshake complete, exiting");
    Ok(())
}

/// `cola pool --add/--drain/--remove <addr>` — resize a daemon fleet
/// between runs. The config file names the tenant, users, sites (via
/// the task driver), and the current `worker_addrs`; the command
/// computes the rendezvous remap old -> new and migrates every re-homed
/// shard's state daemon-to-daemon (export -> import -> evict,
/// bit-exact). It then prints the `worker_addrs` line the next run
/// should use. This replaces the old hard "pool size is part of the
/// run's identity" error with an actual resize path.
fn cmd_pool(args: &Args) -> Result<()> {
    const POOL_KEYS: &[&str] = &["add", "drain", "remove"];
    args.require_no_flags("pool")?;
    let actions: Vec<(&str, &str)> = POOL_KEYS
        .iter()
        .filter_map(|k| args.get(k).map(|v| (*k, v)))
        .collect();
    let &[(action, addr)] = &actions[..] else {
        bail!("pool needs exactly one of --add/--drain/--remove <addr>");
    };
    let mut launcher: Vec<&str> = LAUNCHER_KEYS.to_vec();
    launcher.extend_from_slice(POOL_KEYS);
    let mut cfg = TrainConfig::default();
    if let Some(m) = args.get("method") {
        cfg = cfg.preset_for_method(m.parse()?);
    }
    let path = args.require("config")?;
    let doc = TomlDoc::load(path).with_context(|| format!("loading config {path}"))?;
    for (k, v) in doc.flat() {
        let key = k.strip_prefix("train.").unwrap_or(&k);
        cfg.set(key, &v)
            .with_context(|| format!("config {path}: key {k}"))?;
    }
    let mut launcher_plus_method = launcher.clone();
    launcher_plus_method.push("method");
    apply_overrides(&mut cfg, &args.options_except(&launcher_plus_method))?;
    if cfg.offload_transport != TransportKind::Tcp {
        bail!("cola pool resizes TCP daemon fleets — the config must set \
               offload_transport = \"tcp\" and worker_addrs");
    }
    let manifest = Manifest::load_or_builtin(Path::new(&cfg.artifacts_dir))?;
    let driver = Driver::new(&cfg, &manifest)?;
    let sites: Vec<String> = driver.sites.iter().map(|s| s.site.clone()).collect();

    let old = cfg.worker_addrs.clone();
    let mut new = old.clone();
    match action {
        "add" => new.push(addr.to_string()),
        "drain" | "remove" => {
            // a daemon may back several slots (duplicate worker_addrs);
            // draining/removing it takes out ALL of them — leaving one
            // behind would report success while the daemon still owns
            // users
            new.retain(|a| a != addr);
            if new.len() == old.len() {
                bail!("{addr} is not in worker_addrs");
            }
        }
        // lint:allow(panic-safety): `action` is matched against these same literals by the caller before dispatch
        _ => unreachable!("filtered above"),
    }

    if action == "remove" {
        // the daemon is presumed dead: change the topology only. Any
        // state it still held is NOT migrated (a live daemon should be
        // --drain'ed; a mid-run death is what `failover = "migrate"`
        // recovers from its shadow checkpoints).
        println!(
            "removed {addr} from the pool WITHOUT migrating its state — \
             shards it owned will re-register fresh on the next run"
        );
    } else {
        let link = TcpLinkOpts {
            tenant: cfg.offload_tenant.clone(),
            ..TcpLinkOpts::default()
        };
        let stats =
            rebalance_daemons(&old, &new, cfg.users, &sites, &link).with_context(
                || format!("rebalancing the pool ({action} {addr})"),
            )?;
        println!(
            "{action} {addr}: migrated {} users / {} shards, {} state bytes moved",
            stats.users_moved, stats.shards_moved, stats.bytes_moved
        );
    }
    println!("next run: worker_addrs = \"{}\"", new.join(","));
    Ok(())
}

/// `cola serve` — the FTaaS HTTP gateway. All option plumbing lives in
/// [`ServeConfig`]; this function only resolves precedence (defaults <
/// `--config` `[serve]` section < explicit CLI keys) and then blocks on
/// the gateway until a `POST /v1/shutdown` arrives.
fn cmd_serve(args: &Args) -> Result<()> {
    const SERVE_KEYS: &[&str] = &["config", "listen", "token_file", "backlog", "ledger"];
    args.require_no_flags("serve")?;
    for k in args.options.keys() {
        if !SERVE_KEYS.contains(&k.as_str()) {
            bail!("unknown serve option --{k} (config|listen|token_file|backlog|ledger)");
        }
    }
    let mut cfg = ServeConfig::default();
    if let Some(path) = args.get("config") {
        let doc = TomlDoc::load(path).with_context(|| format!("loading config {path}"))?;
        cfg.apply_toml(&doc)
            .with_context(|| format!("config {path}: [serve] section"))?;
    }
    for key in &SERVE_KEYS[1..] {
        if let Some(v) = args.get(key) {
            cfg.set(key, v)?;
        }
    }
    let gateway = Gateway::bind(&cfg)?;
    // launchers (CI, scripts) scrape this line for the resolved port,
    // exactly like the worker daemon's banner
    println!("cola gateway listening on {}", gateway.local_addr());
    gateway.join();
    println!("cola gateway: shutdown complete, exiting");
    Ok(())
}

/// `cola http <get|post> <url>` — a stdlib-only HTTP client so smoke
/// scripts can drive the gateway on runners without curl. Streams
/// chunked bodies to completion, so `cola http get .../progress`
/// follows a job live.
fn cmd_http(args: &Args) -> Result<()> {
    const HTTP_KEYS: &[&str] = &["token", "body", "out", "expect"];
    args.require_no_flags("http")?;
    for k in args.options.keys() {
        if !HTTP_KEYS.contains(&k.as_str()) {
            bail!("unknown http option --{k} (token|body|out|expect)");
        }
    }
    let [method, url] = &args.positional[..] else {
        bail!("usage: cola http <get|post> <url> [--token T] [--body file] \
               [--out file] [--expect CODE]");
    };
    let method = method.to_ascii_uppercase();
    let body_bytes;
    let body = match args.get("body") {
        Some(path) => {
            body_bytes =
                std::fs::read(path).with_context(|| format!("reading --body {path}"))?;
            Some(("application/toml", body_bytes.as_slice()))
        }
        None => None,
    };
    let resp = gateway_client::request(&method, url, args.get("token"), body)?;
    // status goes to stderr so `--out -`-less stdout stays pipeable
    eprintln!("HTTP {}", resp.status);
    match args.get("out") {
        Some(path) => std::fs::write(path, &resp.body)
            .with_context(|| format!("writing {path}"))?,
        None => print!("{}", String::from_utf8_lossy(&resp.body)),
    }
    match args.get("expect") {
        Some(want) => {
            let want: u16 = want.parse().context("--expect takes a status code")?;
            if resp.status != want {
                bail!("expected HTTP {want}, got {}", resp.status);
            }
        }
        None if resp.status >= 400 => bail!("HTTP {} from {url}", resp.status),
        None => {}
    }
    Ok(())
}

fn cmd_demo(args: &Args) -> Result<()> {
    let mut cfg = config_from_args(args)?;
    if !args.options.contains_key("users") {
        cfg.users = 4;
    }
    if cfg.batch % cfg.users != 0 {
        cfg.batch = cfg.users * (cfg.batch / cfg.users).max(1);
    }
    let rounds: u64 = args.parse_or("rounds", 64)?;
    let kind = match cfg.method {
        Method::Cola(k) => k,
        _ => cola::config::AdapterKind::LowRank,
    };
    println!("FTaaS service: {} users, adapter {kind}, {rounds} rounds", cfg.users);
    let mut svc = FtaasService::start(cfg, kind)?;
    for job in svc.jobs() {
        println!("  user {} -> category {} ({})", job.user, job.category,
                 cola::data::lm::CATEGORIES[job.category]);
    }
    let chunk = (rounds / 8).max(1);
    let mut done = 0;
    while done < rounds {
        let n = chunk.min(rounds - done);
        svc.run_rounds(n)?;
        done += n;
        let st = svc.status()?;
        println!("round {done}/{rounds}: loss {:.4}, server resident {:.1} MiB",
                 st.last_train_loss.unwrap_or(f64::NAN),
                 st.server_resident_bytes as f64 / (1024.0 * 1024.0));
    }
    println!("\nper-category quality of the shared model:");
    for c in 0..8 {
        println!("  {:24} {:.1}", cola::data::lm::CATEGORIES[c],
                 svc.category_score(c)?);
    }
    Ok(())
}

/// `cola curvediff a.json b.json --tol T` — numeric comparison of two
/// `--loss_out` curve files. Pointwise relative criterion:
/// `|a - b| <= tol * max(1, |a|, |b|)`. With the default `--tol 0` this
/// is exactly the bit-identical contract the byte-level `diff` in CI
/// checks; `distributed_smoke.sh wire` uses `--tol 0.05` to bound the
/// bf16 wire's drift against the f32 baseline.
fn cmd_curvediff(args: &Args) -> Result<()> {
    args.require_no_flags("curvediff")?;
    let [a_path, b_path] = &args.positional[..] else {
        bail!("usage: cola curvediff <a.json> <b.json> [--tol T]");
    };
    let tol: f64 = args.parse_or("tol", 0.0)?;
    let load = |p: &str| -> Result<Json> {
        let src = std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?;
        Json::parse(&src).map_err(|e| anyhow::anyhow!("{p}: {e}"))
    };
    let (a, b) = (load(a_path)?, load(b_path)?);
    let mut worst: f64 = 0.0;
    let mut compared = 0usize;
    for key in ["train_loss", "train_acc", "eval_loss", "eval_acc"] {
        let (Some(ca), Some(cb)) = (a.get(key), b.get(key)) else {
            bail!("curve '{key}' missing from one of the files");
        };
        let (pa, pb) = (
            ca.as_arr().unwrap_or_default(),
            cb.as_arr().unwrap_or_default(),
        );
        if pa.len() != pb.len() {
            bail!(
                "curve '{key}': {} vs {} points — the runs are not comparable",
                pa.len(),
                pb.len()
            );
        }
        for (x, y) in pa.iter().zip(pb) {
            let (xs, ys) = (
                x.as_arr().unwrap_or_default(),
                y.as_arr().unwrap_or_default(),
            );
            let ([sx, vx], [sy, vy]) = (xs, ys) else {
                bail!("curve '{key}': malformed [step, value] point");
            };
            if sx.as_f64() != sy.as_f64() {
                bail!("curve '{key}': step mismatch ({sx} vs {sy})");
            }
            compared += 1;
            match (vx.as_f64(), vy.as_f64()) {
                (Some(u), Some(v)) => {
                    let dev = (u - v).abs() / f64::max(1.0, f64::max(u.abs(), v.abs()));
                    worst = worst.max(dev);
                    if dev > tol {
                        bail!(
                            "curve '{key}' step {sx}: {u} vs {v} \
                             (relative deviation {dev:.3e} > tol {tol:.3e})"
                        );
                    }
                }
                // non-finite values serialize as strings ("NaN", "inf");
                // only an exact match passes — a diverged run never
                // sneaks through a tolerance
                _ => {
                    if format!("{vx}") != format!("{vy}") {
                        bail!("curve '{key}' step {sx}: {vx} vs {vy} (non-numeric)");
                    }
                }
            }
        }
    }
    println!(
        "curvediff: {compared} points compared, max relative deviation \
         {worst:.3e} (tol {tol:.3e}) — OK"
    );
    Ok(())
}

/// `cola scale` — drive a large deterministic user population through
/// the worker pool with Zipf-skewed arrivals and (optionally) a bounded
/// LRU working set paging cold adapter state to disk. The harness
/// itself is clock-free (it lives in the lint-scanned `scale/` tree);
/// all wall-time measurement happens here, around
/// [`cola::scale::ScaleHarness::run_interval`].
fn cmd_scale(args: &Args) -> Result<()> {
    const SCALE_KEYS: &[&str] = &[
        "users", "intervals", "touches", "workers", "seed", "rows",
        "working_set", "page_dir", "curve_out", "out", "max_resident_bytes",
    ];
    args.require_no_flags("scale")?;
    for k in args.options.keys() {
        if !SCALE_KEYS.contains(&k.as_str()) {
            bail!("unknown scale option --{k} \
                   (users|intervals|touches|workers|seed|rows|working_set|\
                   page_dir|curve_out|out|max_resident_bytes)");
        }
    }
    let cfg = cola::scale::ScaleCfg {
        users: args.parse_or("users", 10_000)?,
        intervals: args.parse_or("intervals", 20)?,
        touches_per_interval: args.parse_or("touches", 256)?,
        workers: args.parse_or("workers", 4)?,
        working_set: args.parse_or("working_set", 0)?,
        page_dir: args.get("page_dir").map(std::path::PathBuf::from),
        seed: args.parse_or("seed", 0)?,
        rows: args.parse_or("rows", 4)?,
    };
    let intervals = cfg.intervals;
    println!(
        "cola scale: {} users, {} intervals x {} touches, {} workers, \
         working set {} ({}), seed {}",
        cfg.users,
        cfg.intervals,
        cfg.touches_per_interval,
        cfg.workers,
        cfg.working_set,
        if cfg.working_set == 0 { "paging off" } else { "paged" },
        cfg.seed
    );
    let mut harness = cola::scale::ScaleHarness::new(cfg)?;
    let t0 = std::time::Instant::now();
    let mut interval_secs = Vec::with_capacity(intervals);
    for i in 0..intervals {
        let s = std::time::Instant::now();
        let rep = harness.run_interval()?;
        interval_secs.push(s.elapsed().as_secs_f64());
        // progress every ~10% so a 10^6-user run isn't a silent minute
        if intervals <= 10 || (i + 1) % (intervals / 10).max(1) == 0 {
            let sum = harness.summary();
            println!(
                "  interval {:>4}/{intervals}: {} touched ({} new), \
                 {:.1} MiB resident, {} faults",
                i + 1,
                rep.touched,
                rep.new_users,
                sum.resident_bytes as f64 / (1024.0 * 1024.0),
                sum.page_stats.faults
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let sum = harness.summary();
    let users_per_sec = sum.fits_ok as f64 / wall;
    let mut sorted = interval_secs.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let p99 = sorted[((sorted.len() as f64 * 0.99).ceil() as usize)
        .saturating_sub(1)
        .min(sorted.len() - 1)];
    let faults_per_interval = sum.page_stats.faults as f64 / intervals as f64;
    println!(
        "cola scale: {} users registered, {} fits ok / {} lost in {wall:.2}s \
         ({users_per_sec:.0} users/sec, p99 interval {:.1} ms)",
        sum.users_registered, sum.fits_ok, sum.fits_lost, p99 * 1e3
    );
    println!(
        "  resident {:.1} MiB; paging: {} faults ({faults_per_interval:.1}/interval), \
         {} evictions, {} writes, {} errors",
        sum.resident_bytes as f64 / (1024.0 * 1024.0),
        sum.page_stats.faults,
        sum.page_stats.evictions,
        sum.page_stats.page_writes,
        sum.page_stats.page_errors
    );
    if let Some(path) = args.get("curve_out") {
        std::fs::write(path, harness.curve_hex())
            .with_context(|| format!("writing {path}"))?;
        println!("  curve (f32 bit patterns) -> {path}");
    }
    if let Some(path) = args.get("out") {
        let mut o = std::collections::BTreeMap::new();
        let num = |v: f64| Json::Num(v);
        o.insert("bench".to_string(), Json::Str("scale".to_string()));
        o.insert("schema".to_string(), num(1.0));
        o.insert("users".to_string(), num(harness.cfg().users as f64));
        o.insert("intervals".to_string(), num(intervals as f64));
        o.insert("workers".to_string(), num(harness.cfg().workers as f64));
        o.insert("working_set".to_string(), num(harness.cfg().working_set as f64));
        o.insert("users_registered".to_string(), num(sum.users_registered as f64));
        o.insert("fits_ok".to_string(), num(sum.fits_ok as f64));
        o.insert("fits_lost".to_string(), num(sum.fits_lost as f64));
        o.insert("users_per_sec".to_string(), num(users_per_sec));
        o.insert("p99_interval_ms".to_string(), num(p99 * 1e3));
        o.insert("resident_bytes".to_string(), num(sum.resident_bytes as f64));
        o.insert("page_faults".to_string(), num(sum.page_stats.faults as f64));
        o.insert("page_faults_per_interval".to_string(), num(faults_per_interval));
        o.insert("page_evictions".to_string(), num(sum.page_stats.evictions as f64));
        o.insert("page_writes".to_string(), num(sum.page_stats.page_writes as f64));
        o.insert("page_errors".to_string(), num(sum.page_stats.page_errors as f64));
        std::fs::write(path, format!("{}\n", Json::Obj(o)))
            .with_context(|| format!("writing {path}"))?;
        println!("  summary -> {path}");
    }
    if sum.fits_lost > 0 {
        bail!("{} fits lost — a healthy run loses none", sum.fits_lost);
    }
    if sum.page_stats.page_errors > 0 {
        bail!("{} page errors — page files are corrupt or unwritable",
              sum.page_stats.page_errors);
    }
    if let Some(cap) = args.get("max_resident_bytes") {
        let cap: usize = cap.parse().context("--max_resident_bytes")?;
        if sum.resident_bytes > cap {
            bail!(
                "resident state {} bytes exceeds --max_resident_bytes {cap} — \
                 the working set is not bounding memory",
                sum.resident_bytes
            );
        }
        println!("  resident-bytes ceiling OK ({} <= {cap})", sum.resident_bytes);
    }
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<()> {
    let name = args.get_or("profile", "llama2-qv");
    let profile = ModelProfile::by_name(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown profile {name}"))?;
    let batch: usize = args.parse_or("batch", 8)?;
    let interval: usize = args.parse_or("interval", 1)?;
    let users: usize = args.parse_or("users", 1)?;
    use cola::config::AdapterKind::*;
    let mut rows = Vec::new();
    let mut push = |label: &str, arr: Arrangement| {
        let fp = footprint(&profile, arr, batch, interval, 8, 64);
        rows.push(vec![
            label.to_string(),
            format!("{:.2}", fp.server_total() as f64 / GB),
            format!("{:.2}", fp.worker_total() as f64 / GB),
            format!("{:.3}", fp.transfer_per_step as f64 / GB),
        ]);
    };
    push("FT", Arrangement::FullFt);
    push("LoRA", Arrangement::Peft { kind: LowRank, users });
    push("ColA(LowRank, unmerged)", Arrangement::Cola { kind: LowRank, merged: false, users });
    push("ColA(LowRank, merged)", Arrangement::Cola { kind: LowRank, merged: true, users });
    push("ColA(Linear, merged)", Arrangement::Cola { kind: Linear, merged: true, users });
    push("ColA(MLP, unmerged)", Arrangement::Cola { kind: Mlp, merged: false, users });
    println!("profile {name}: {} params, batch {batch}, interval {interval}, users {users}",
             profile.params());
    println!("{}", markdown_table(
        &["method", "server GB", "worker GB", "transfer GB/step"], &rows));
    Ok(())
}

fn cmd_table1() -> Result<()> {
    println!("Table 1 — computation-space complexity (see memory/ for bytes)\n");
    let rows = vec![
        vec!["FT".into(), "theta".into(), "h".into(), "grad h".into(), "grad theta".into()],
        vec!["PEFT (unmerged)".into(), "theta, w".into(), "h, h~".into(),
             "grad h, grad h~".into(), "grad w".into()],
        vec!["ColA (unmerged)".into(), "theta, w".into(), "h, h~".into(),
             "grad h, grad h~".into(), "{grad w}".into()],
        vec!["ColA (merged)".into(), "theta-hat, {w}".into(), "h, {h~}".into(),
             "grad h, {h~}".into(), "{grad w}".into()],
    ];
    println!("{}", markdown_table(
        &["method", "params", "fwd reps", "bwd reps", "param grads"], &rows));
    println!("{{.}} = lives on low-cost devices (offloaded)");
    Ok(())
}
