//! TOML-subset parser for experiment config files.
//!
//! Supports: `[section]` headers, `key = value`, `#` comments, quoted
//! and bare scalar values. Nested tables flatten to dotted keys
//! (`[train]` + `interval = 4` -> `train.interval`). This covers every
//! config file the repo ships; anything fancier is a parse error, not a
//! silent misread.

use anyhow::{bail, Result};

#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    entries: Vec<(String, String)>,
}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc> {
        let mut section = String::new();
        let mut entries = Vec::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("line {}: unterminated section header", lineno + 1);
                };
                section = name.trim().to_string();
                continue;
            }
            let Some(eq) = line.find('=') else {
                bail!("line {}: expected 'key = value'", lineno + 1);
            };
            let key = line[..eq].trim();
            let val = unquote(line[eq + 1..].trim());
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            entries.push((full, val));
        }
        Ok(TomlDoc { entries })
    }

    pub fn load(path: &str) -> Result<TomlDoc> {
        let src = std::fs::read_to_string(path)?;
        TomlDoc::parse(&src)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn flat(&self) -> Vec<(String, String)> {
        self.entries.clone()
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> String {
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        v[1..v.len() - 1].to_string()
    } else {
        v.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_values() {
        let doc = TomlDoc::parse(
            "# experiment\nsteps = 100\n[train]\nmethod = \"cola-lowrank\"\ninterval = 4 # I\n",
        )
        .unwrap();
        assert_eq!(doc.get("steps"), Some("100"));
        assert_eq!(doc.get("train.method"), Some("cola-lowrank"));
        assert_eq!(doc.get("train.interval"), Some("4"));
    }

    #[test]
    fn later_entries_win() {
        let doc = TomlDoc::parse("a = 1\na = 2\n").unwrap();
        assert_eq!(doc.get("a"), Some("2"));
    }

    #[test]
    fn hash_inside_quotes_kept() {
        let doc = TomlDoc::parse("s = \"a#b\"\n").unwrap();
        assert_eq!(doc.get("s"), Some("a#b"));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(TomlDoc::parse("[unclosed\n").is_err());
        assert!(TomlDoc::parse("novalue\n").is_err());
    }
}
