//! Config system: typed experiment configs + a TOML-subset file format +
//! CLI overrides (clap/serde are unavailable offline — this is the
//! framework's real config substrate, exercised by every bench/example).
//!
//! File format: `[section]` headers, `key = value` lines, `#` comments.
//! Values: string (quoted or bare), int, float, bool. Flat keys override
//! via dotted names, e.g. `train.interval = 4`.
//!
//! The same `[train]` key namespace is the FTaaS gateway's job-submission
//! format: `POST /v1/fit` bodies parse through [`TrainConfig::from_toml`]
//! exactly like `cola train --config` files do, so a config means the
//! same thing over HTTP as on the CLI (see [`crate::gateway`]). A config
//! file may additionally carry a `[serve]` section for the gateway
//! process itself ([`crate::gateway::ServeConfig`]).

pub mod toml;

use std::collections::BTreeMap;
use std::fmt;
use std::str::FromStr;

use anyhow::{anyhow, bail, Context, Result};

pub use toml::TomlDoc;

/// Which fine-tuning method a run uses (paper Tables 2-4, 6-9).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Method {
    /// full fine-tuning (coupled autodiff over all weights)
    Ft,
    /// coupled LoRA baseline
    Lora,
    /// coupled IA3 baseline
    Ia3,
    /// coupled prompt tuning baseline
    Prompt,
    /// coupled p-tuning baseline
    PTuning,
    /// coupled prefix tuning baseline
    Prefix,
    /// ColA with the given auxiliary architecture
    Cola(AdapterKind),
}

impl Method {
    pub fn is_cola(&self) -> bool {
        matches!(self, Method::Cola(_))
    }

    pub fn baseline_name(&self) -> &'static str {
        match self {
            Method::Ft => "ft",
            Method::Lora => "lora",
            Method::Ia3 => "ia3",
            Method::Prompt => "prompt",
            Method::PTuning => "ptuning",
            Method::Prefix => "prefix",
            // lint:allow(panic-safety): caller contract — every call site checks `is_cola()` first; a ColA method has no coupled-baseline name
            Method::Cola(_) => panic!("cola is not a coupled baseline"),
        }
    }
}

impl FromStr for Method {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "ft" => Method::Ft,
            "lora" => Method::Lora,
            "ia3" => Method::Ia3,
            "prompt" => Method::Prompt,
            "ptuning" => Method::PTuning,
            "prefix" => Method::Prefix,
            "cola-lowrank" => Method::Cola(AdapterKind::LowRank),
            "cola-linear" => Method::Cola(AdapterKind::Linear),
            "cola-mlp" => Method::Cola(AdapterKind::Mlp),
            other => bail!("unknown method '{other}'"),
        })
    }
}

impl fmt::Display for Method {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Method::Cola(k) => write!(f, "cola-{k}"),
            m => write!(f, "{}", m.baseline_name()),
        }
    }
}

/// Auxiliary-model architecture (paper §3.2: model-agnostic).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum AdapterKind {
    LowRank,
    Linear,
    Mlp,
}

impl AdapterKind {
    pub fn name(&self) -> &'static str {
        match self {
            AdapterKind::LowRank => "lowrank",
            AdapterKind::Linear => "linear",
            AdapterKind::Mlp => "mlp",
        }
    }

    /// Prop. 2: only linear-in-input adapters can be merged.
    pub fn mergeable(&self) -> bool {
        !matches!(self, AdapterKind::Mlp)
    }
}

impl fmt::Display for AdapterKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

impl FromStr for AdapterKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "lowrank" => AdapterKind::LowRank,
            "linear" => AdapterKind::Linear,
            "mlp" => AdapterKind::Mlp,
            other => bail!("unknown adapter kind '{other}'"),
        })
    }
}

/// ColA training mode (Table 1): merged folds adapters into base weights
/// during training; unmerged keeps them live on the server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Merged,
    Unmerged,
}

impl FromStr for Mode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "merged" => Mode::Merged,
            "unmerged" => Mode::Unmerged,
            other => bail!("unknown mode '{other}'"),
        })
    }
}

/// Where the offloaded gradient computation runs (Tables 10-18: CPU vs
/// secondary GPU).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OffloadTarget {
    /// native Rust math on the worker thread (the paper's CPU device)
    NativeCpu,
    /// PJRT executable on the worker thread (the paper's low-end GPU)
    PjrtDevice,
}

impl FromStr for OffloadTarget {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "cpu" | "native" => OffloadTarget::NativeCpu,
            "gpu" | "pjrt" => OffloadTarget::PjrtDevice,
            other => bail!("unknown offload target '{other}'"),
        })
    }
}

/// How FitJobs reach the worker fleet: in-process channels, or TCP
/// sockets to `cola worker` daemons (the real offload wire). Both
/// produce bit-identical loss curves for the same config.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// in-process worker threads behind mpsc channels
    Local,
    /// remote worker daemons at `worker_addrs`
    Tcp,
}

impl FromStr for TransportKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "local" | "inproc" => TransportKind::Local,
            "tcp" => TransportKind::Tcp,
            other => bail!("unknown offload transport '{other}' (local|tcp)"),
        })
    }
}

impl fmt::Display for TransportKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportKind::Local => write!(f, "local"),
            TransportKind::Tcp => write!(f, "tcp"),
        }
    }
}

/// What the coordinator does when a worker daemon stops answering
/// (tcp transport only).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailoverPolicy {
    /// fail the run at the first lost fit (the pre-elastic behavior)
    Fail,
    /// keep shadow checkpoints of every shard, promote a standby (or
    /// shrink onto survivors), restore state bit-exactly, and re-run
    /// the lost interval's fits — loss curves stay byte-identical to an
    /// uninterrupted run
    Migrate,
}

impl FromStr for FailoverPolicy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "fail" => FailoverPolicy::Fail,
            "migrate" => FailoverPolicy::Migrate,
            other => bail!("unknown failover policy '{other}' (fail|migrate)"),
        })
    }
}

impl fmt::Display for FailoverPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FailoverPolicy::Fail => write!(f, "fail"),
            FailoverPolicy::Migrate => write!(f, "migrate"),
        }
    }
}

/// How Fit/FitBatch payload tensors are encoded on the TCP wire.
/// Negotiated per connection via the `Hello` handshake: a daemon that
/// does not acknowledge bf16 keeps receiving raw f32 frames.
///
/// State blobs (`StateExport`/`StateImport`, `failover = "migrate"`
/// shadow checkpoints) are NEVER compressed regardless of this knob —
/// migration must stay bit-exact, so only the Fit/FitBatch `x`/`ghat`
/// payloads ride as bf16.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireFormat {
    /// every f32 ships by bit pattern (the byte-identical default)
    F32,
    /// Fit/FitBatch payload tensors ship as round-to-nearest-even bf16
    /// (half the payload bytes; loss curves stay within the documented
    /// tolerance of the f32 run — see README §SIMD & wire compression)
    Bf16,
}

impl FromStr for WireFormat {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => WireFormat::F32,
            "bf16" => WireFormat::Bf16,
            other => bail!("unknown offload wire format '{other}' (f32|bf16)"),
        })
    }
}

impl fmt::Display for WireFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireFormat::F32 => write!(f, "f32"),
            WireFormat::Bf16 => write!(f, "bf16"),
        }
    }
}

/// Which kernel tier the tensor engine dispatches (`tensor::simd`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// follow the `COLA_SIMD` env var (default: AVX2 when detected)
    Auto,
    /// force the pinned scalar fallbacks
    Off,
    /// AVX2 when detected, bit-identical tier only
    On,
    /// additionally allow the FMA-contracted panel kernel (documented
    /// tolerance — `tensor::simd::FMA_CONTRACTION_EPS`)
    Fma,
}

impl FromStr for SimdMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "auto" => SimdMode::Auto,
            "off" | "false" | "0" => SimdMode::Off,
            "on" | "true" | "1" => SimdMode::On,
            "fma" => SimdMode::Fma,
            other => bail!("unknown simd mode '{other}' (auto|on|off|fma)"),
        })
    }
}

impl fmt::Display for SimdMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SimdMode::Auto => write!(f, "auto"),
            SimdMode::Off => write!(f, "off"),
            SimdMode::On => write!(f, "on"),
            SimdMode::Fma => write!(f, "fma"),
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Optimizer {
    Sgd,
    AdamW,
}

impl FromStr for Optimizer {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "sgd" => Optimizer::Sgd,
            "adamw" => Optimizer::AdamW,
            other => bail!("unknown optimizer '{other}'"),
        })
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// causal language modeling (Dolly-substitute instruction mix)
    Clm,
    /// sequence classification (GLUE substitute)
    SeqCls,
    /// sequence-to-sequence via prefix-LM masking (BART substitute)
    S2s,
}

impl FromStr for Task {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "clm" => Task::Clm,
            "seqcls" => Task::SeqCls,
            "s2s" => Task::S2s,
            other => bail!("unknown task '{other}'"),
        })
    }
}

/// Full training-run configuration (defaults follow paper Table 5,
/// scaled to this testbed).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub task: Task,
    pub size: String,
    pub method: Method,
    pub mode: Mode,
    pub offload: OffloadTarget,
    pub optimizer: Optimizer,
    pub users: usize,
    pub steps: usize,
    pub batch: usize,
    /// adaptation interval I (Algorithm 1)
    pub interval: usize,
    pub lr: f32,
    pub weight_decay: f32,
    pub seed: u64,
    pub workers: usize,
    /// dataset/task variant id (which synthetic task)
    pub dataset: String,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub artifacts_dir: String,
    /// overlap worker fits with the next server steps (§3.2: "run two
    /// decoupled gradient computations in parallel"). Updates apply one
    /// interval late (bounded staleness).
    pub async_offload: bool,
    /// tensor-engine width: 0 = auto (COLA_THREADS env, else core
    /// count). Applied process-globally when the Trainer is constructed
    /// (last constructed wins). Results are thread-count independent;
    /// pin for benchmark and CI timing determinism.
    pub threads: usize,
    /// how FitJobs reach workers: in-process channels or TCP daemons
    pub offload_transport: TransportKind,
    /// `cola worker` daemon addresses (tcp transport only); the CLI/TOML
    /// form is a comma-separated list, e.g.
    /// `worker_addrs = "127.0.0.1:7701,127.0.0.1:7702"`. The same
    /// address may appear more than once — a daemon serves any number
    /// of concurrent links, so one low-cost device can back several
    /// pool slots.
    pub worker_addrs: Vec<String>,
    /// tenant namespace this run's adapters live under on shared worker
    /// daemons (tcp transport only). Empty = the v1 default namespace.
    /// Two trainers sharing a daemon MUST use distinct tenants or they
    /// will clobber each other's (user, site) keys.
    pub offload_tenant: String,
    /// ship each interval's FitJobs as one wire-v2 `FitBatch` frame per
    /// worker instead of one `Fit` round-trip per job (tcp only).
    /// Changes framing, never numerics: loss curves stay byte-identical.
    pub offload_batch: bool,
    /// max `FitBatch` frames in flight per interval flush (>= 1;
    /// requires offload_batch). 1 = one frame per interval; 2+ splits
    /// the flush so a later chunk rides the wire while an earlier one
    /// computes on the daemon.
    pub offload_inflight: usize,
    /// liveness-sweep cadence of the elastic pool supervisor, in
    /// adaptation-interval flushes (tcp + `failover = "migrate"` only):
    /// every N flushes each daemon gets a `Ping` heartbeat and dead
    /// ones are failed over BEFORE fits are dispatched to them. 0
    /// disables proactive sweeps (death is then detected reactively, by
    /// the lost fits themselves). Under `failover = "fail"` no
    /// heartbeat is ever sent — the wire carries no v3 control traffic
    /// at all, preserving exact compatibility with older daemons.
    /// Deliberately counted in flushes, not seconds — wall-clock sweeps
    /// would make recovery timing (though never numerics)
    /// nondeterministic.
    pub heartbeat_interval: usize,
    /// what to do when a daemon dies mid-run (tcp only): "fail" aborts
    /// the run at the first lost fit; "migrate" restores the dead
    /// daemon's shards from shadow checkpoints onto a promoted standby
    /// (or the surviving members), re-runs the lost fits, and continues
    /// with byte-identical loss curves. Migrate pays for its shadow
    /// copies with one `StateExport` round-trip per (user, site) per
    /// flush — see EXPERIMENTS.md §Elastic pools.
    pub failover: FailoverPolicy,
    /// cold-standby `cola worker` addresses (tcp only), comma-separated
    /// like worker_addrs. Used twice: at connect time an unreachable
    /// primary address is substituted by the next standby (the pool
    /// degrades loudly instead of aborting), and mid-run the supervisor
    /// promotes one whenever a member dies.
    pub standby_addrs: Vec<String>,
    /// address the coordinator's worker-registry announce listener
    /// binds (tcp + `failover = "migrate"` only), e.g. `127.0.0.1:0`
    /// for an ephemeral port (printed at startup). Daemons started with
    /// `cola worker --join <this addr>` self-register and are admitted
    /// into the pool at sweep boundaries. Empty = no listener; the pool
    /// is exactly the static worker_addrs. With a listener bound,
    /// worker_addrs becomes the optional bootstrap fallback and may be
    /// empty (the trainer then waits for the first joiner).
    pub registry_listen: String,
    /// push each shard's post-interval state blob to a buddy member
    /// (its rendezvous runner-up) so a member kill is absorbed by
    /// promoting the buddy's replica in place — zero recovery bytes on
    /// the wire — instead of a checkpoint-restore round trip (tcp +
    /// `failover = "migrate"` only). Replicas are the same bit-exact
    /// `wire::encode_state` blobs as shadow checkpoints, so loss curves
    /// stay byte-identical either way.
    pub replicate: bool,
    /// Fit/FitBatch payload encoding on the TCP wire (tcp only).
    /// "f32" (default) keeps every tensor bit-exact; "bf16" halves the
    /// payload bytes with round-to-nearest-even truncation (negotiated
    /// via `Hello` — daemons that don't acknowledge it keep receiving
    /// f32). State blobs and FitResult replies always stay f32, so
    /// `failover = "migrate"` checkpoints remain bit-exact under bf16.
    pub offload_wire: WireFormat,
    /// kernel tier of the tensor engine (`tensor::simd`):
    /// auto (follow COLA_SIMD) | on | off | fma. "off"-vs-"on" never
    /// moves a loss curve (the AVX2 tier is bit-identical to scalar);
    /// "fma" trades bit-parity of the matmul panel kernel for speed
    /// within a documented tolerance.
    pub simd: SimdMode,
    /// max resident adapters per in-process worker (local transport
    /// only); 0 = unbounded, no paging. With a bound, each worker's
    /// state store pages cold `(user, site)` shards to
    /// `state_page_dir/w<id>` as bit-exact `wire::encode_state` blobs
    /// and faults them back on touch — loss curves are byte-identical
    /// paging on or off at any working-set size (see
    /// `crate::scale::store` and README §Scale harness & state paging).
    pub state_working_set: usize,
    /// page-file root for `state_working_set` (required iff the
    /// working set is bounded). Each worker owns the `w<id>`
    /// subdirectory; page files are bit-exact migration blobs.
    pub state_page_dir: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            task: Task::Clm,
            size: "tiny".into(),
            method: Method::Cola(AdapterKind::LowRank),
            mode: Mode::Unmerged,
            offload: OffloadTarget::NativeCpu,
            optimizer: Optimizer::AdamW,
            users: 1,
            steps: 200,
            batch: 8,
            interval: 1,
            lr: 3e-4,          // Table 5: PEFT/ColA lr
            weight_decay: 5e-4, // Table 5
            seed: 0,
            workers: 2,
            dataset: "default".into(),
            eval_every: 50,
            eval_batches: 8,
            artifacts_dir: "artifacts".into(),
            async_offload: false,
            threads: 0,
            offload_transport: TransportKind::Local,
            worker_addrs: Vec::new(),
            offload_tenant: String::new(),
            offload_batch: false,
            offload_inflight: 1,
            heartbeat_interval: 1,
            failover: FailoverPolicy::Fail,
            standby_addrs: Vec::new(),
            registry_listen: String::new(),
            replicate: false,
            offload_wire: WireFormat::F32,
            simd: SimdMode::Auto,
            state_working_set: 0,
            state_page_dir: String::new(),
        }
    }
}

impl TrainConfig {
    /// Paper Table 5: FT uses a smaller lr.
    pub fn preset_for_method(mut self, m: Method) -> Self {
        self.method = m;
        if m == Method::Ft {
            self.lr = 5e-5; // scaled from 5e-6; our models are untied/tiny
        }
        self
    }

    /// Apply `key=value` overrides (dotted keys from CLI or TOML).
    pub fn set(&mut self, key: &str, val: &str) -> Result<()> {
        match key {
            "task" => self.task = val.parse()?,
            "size" => self.size = val.into(),
            "method" => self.method = val.parse()?,
            "mode" => self.mode = val.parse()?,
            "offload" => self.offload = val.parse()?,
            "optimizer" => self.optimizer = val.parse()?,
            "users" => self.users = val.parse().context("users")?,
            "steps" => self.steps = val.parse().context("steps")?,
            "batch" => self.batch = val.parse().context("batch")?,
            "interval" => self.interval = val.parse().context("interval")?,
            "lr" => self.lr = val.parse().context("lr")?,
            "weight_decay" => self.weight_decay = val.parse().context("weight_decay")?,
            "seed" => self.seed = val.parse().context("seed")?,
            "workers" => self.workers = val.parse().context("workers")?,
            "dataset" => self.dataset = val.into(),
            "eval_every" => self.eval_every = val.parse().context("eval_every")?,
            "eval_batches" => self.eval_batches = val.parse().context("eval_batches")?,
            "artifacts_dir" => self.artifacts_dir = val.into(),
            "async_offload" => self.async_offload = val.parse().context("async_offload")?,
            "threads" => self.threads = val.parse().context("threads")?,
            "offload_transport" => self.offload_transport = val.parse()?,
            "worker_addrs" => {
                self.worker_addrs = val
                    .split(',')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .map(String::from)
                    .collect();
            }
            "offload_tenant" => self.offload_tenant = val.into(),
            "offload_batch" => {
                self.offload_batch = val.parse().context("offload_batch")?
            }
            "offload_inflight" => {
                self.offload_inflight = val.parse().context("offload_inflight")?
            }
            "heartbeat_interval" => {
                self.heartbeat_interval =
                    val.parse().context("heartbeat_interval")?
            }
            "failover" => self.failover = val.parse()?,
            "registry_listen" => self.registry_listen = val.into(),
            "replicate" => self.replicate = val.parse().context("replicate")?,
            "offload_wire" => self.offload_wire = val.parse()?,
            "simd" => self.simd = val.parse()?,
            "state_working_set" => {
                self.state_working_set =
                    val.parse().context("state_working_set")?
            }
            "state_page_dir" => self.state_page_dir = val.into(),
            "standby_addrs" => {
                self.standby_addrs = val
                    .split(',')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .map(String::from)
                    .collect();
            }
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    pub fn from_toml(doc: &TomlDoc) -> Result<Self> {
        let mut cfg = TrainConfig::default();
        for (k, v) in doc.flat() {
            let key = k.strip_prefix("train.").unwrap_or(&k);
            cfg.set(key, &v)
                .with_context(|| format!("config key {k}"))?;
        }
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.interval == 0 {
            bail!("interval must be >= 1");
        }
        if self.users == 0 {
            bail!("users must be >= 1");
        }
        if self.offload_inflight == 0 {
            bail!("offload_inflight must be >= 1");
        }
        match (self.state_working_set, self.state_page_dir.is_empty()) {
            (0, false) => bail!(
                "state_page_dir is set but state_working_set is 0 — an \
                 unbounded store never pages, so the directory would never \
                 be used (set state_working_set >= 1 or drop the dir; \
                 refusing to silently ignore)"
            ),
            (ws, true) if ws > 0 => bail!(
                "state_working_set = {ws} bounds resident adapters but \
                 state_page_dir is empty — evicted state has to live \
                 somewhere (set state_page_dir)"
            ),
            _ => {}
        }
        match self.offload_transport {
            TransportKind::Tcp => {
                if self.worker_addrs.is_empty() && self.registry_listen.is_empty() {
                    bail!("offload_transport = \"tcp\" requires worker_addrs \
                           (comma-separated `cola worker` daemon addresses) or \
                           registry_listen (so daemons can self-register with \
                           `cola worker --join`)");
                }
                if !self.registry_listen.is_empty()
                    && self.failover != FailoverPolicy::Migrate
                {
                    bail!("registry_listen is set but failover = \"fail\" — \
                           joiners are admitted (and dead members replaced) at \
                           liveness-sweep boundaries, which only run under \
                           failover = \"migrate\" (refusing to silently ignore)");
                }
                if self.replicate && self.failover != FailoverPolicy::Migrate {
                    bail!("replicate = true is set but failover = \"fail\" — \
                           buddy replicas are promoted by the migrate failover \
                           path; without it they would never be read (refusing \
                           to silently ignore)");
                }
                // duplicate addresses are allowed: a daemon serves any
                // number of concurrent links, so one low-cost device can
                // back several pool slots (user shards still land on
                // distinct (tenant, user, site) keys)
                if self.offload == OffloadTarget::PjrtDevice {
                    bail!("with offload_transport = \"tcp\" the compute target \
                           is chosen per daemon (`cola worker --offload ...`); \
                           leave offload = \"cpu\" on the server config");
                }
                if self.state_working_set > 0 {
                    bail!("state_working_set is set but offload_transport is \
                           \"tcp\" — adapter-state paging bounds the memory of \
                           in-process workers; a remote daemon manages its own \
                           working set (refusing to silently ignore)");
                }
                // offload_wire = "bf16" + failover = "migrate" is allowed
                // ONLY because state blobs never compress: wire::encode_state
                // has no bf16 path, so shadow checkpoints and
                // StateExport/StateImport migration stay bit-exact f32 and
                // the byte-identical-recovery contract holds. Anyone wiring
                // bf16 into state export must make this arm reject the
                // combination instead (pinned by
                // `bf16_with_migrate_allowed_because_state_stays_f32` and
                // wire.rs `state_blob_ignores_wire_format`).
            }
            TransportKind::Local => {
                if !self.worker_addrs.is_empty() {
                    bail!("worker_addrs is set but offload_transport is \
                           \"local\" — set offload_transport = \"tcp\" or \
                           drop the addresses (refusing to silently ignore)");
                }
                if !self.standby_addrs.is_empty() {
                    bail!("standby_addrs is set but offload_transport is \
                           \"local\" — standbys are spare TCP daemons; an \
                           in-process pool cannot lose a member (refusing to \
                           silently ignore)");
                }
                if self.failover == FailoverPolicy::Migrate {
                    bail!("failover = \"migrate\" is set but offload_transport \
                           is \"local\" — in-process workers cannot die \
                           independently of the trainer, so there is nothing \
                           to migrate (refusing to silently ignore)");
                }
                if !self.offload_tenant.is_empty() {
                    bail!("offload_tenant is set but offload_transport is \
                           \"local\" — tenants namespace shared TCP daemons; \
                           an in-process pool is single-tenant by construction \
                           (refusing to silently ignore)");
                }
                if self.offload_batch {
                    bail!("offload_batch is set but offload_transport is \
                           \"local\" — batching is a wire-framing feature; an \
                           in-process pool already pays no per-job round-trip \
                           (refusing to silently ignore)");
                }
                if self.offload_wire != WireFormat::F32 {
                    bail!("offload_wire = \"{}\" is set but offload_transport \
                           is \"local\" — wire compression only applies to \
                           frames on a TCP socket; in-process jobs move by \
                           reference (refusing to silently ignore)",
                          self.offload_wire);
                }
                if !self.registry_listen.is_empty() {
                    bail!("registry_listen is set but offload_transport is \
                           \"local\" — the registry admits TCP daemons; an \
                           in-process pool has fixed membership (refusing to \
                           silently ignore)");
                }
                if self.replicate {
                    bail!("replicate = true is set but offload_transport is \
                           \"local\" — buddy replicas guard against a daemon \
                           dying independently of the trainer, which an \
                           in-process pool cannot do (refusing to silently \
                           ignore)");
                }
            }
        }
        if self.offload_inflight > 1 && !self.offload_batch {
            bail!("offload_inflight > 1 pipelines FitBatch frames and \
                   requires offload_batch = true");
        }
        if self.mode == Mode::Merged {
            if let Method::Cola(k) = self.method {
                if !k.mergeable() {
                    bail!("Prop. 2: adapter kind '{k}' is not linear in its \
                           input and cannot be merged — use mode=unmerged");
                }
            } else {
                bail!("mode=merged only applies to ColA methods");
            }
        }
        Ok(())
    }
}

/// Flat override map used by CLI parsing.
pub type Overrides = BTreeMap<String, String>;

pub fn apply_overrides(cfg: &mut TrainConfig, ov: &Overrides) -> Result<()> {
    for (k, v) in ov {
        cfg.set(k, v).map_err(|e| anyhow!("--{k}: {e}"))?;
    }
    cfg.validate()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_methods() {
        assert_eq!("cola-mlp".parse::<Method>().unwrap(),
                   Method::Cola(AdapterKind::Mlp));
        assert_eq!("lora".parse::<Method>().unwrap(), Method::Lora);
        assert!("bogus".parse::<Method>().is_err());
    }

    #[test]
    fn merged_mlp_rejected() {
        let mut cfg = TrainConfig::default();
        cfg.method = Method::Cola(AdapterKind::Mlp);
        cfg.mode = Mode::Merged;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn merged_baseline_rejected() {
        let mut cfg = TrainConfig::default();
        cfg.method = Method::Lora;
        cfg.mode = Mode::Merged;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn overrides_roundtrip() {
        let mut cfg = TrainConfig::default();
        cfg.set("interval", "4").unwrap();
        cfg.set("method", "cola-linear").unwrap();
        cfg.set("mode", "merged").unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.interval, 4);
    }

    #[test]
    fn ft_preset_lowers_lr() {
        let cfg = TrainConfig::default().preset_for_method(Method::Ft);
        assert!(cfg.lr < 1e-4);
    }

    #[test]
    fn transport_parse_and_addr_list() {
        let mut cfg = TrainConfig::default();
        cfg.set("offload_transport", "tcp").unwrap();
        cfg.set("worker_addrs", "127.0.0.1:7701, 127.0.0.1:7702,").unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.offload_transport, TransportKind::Tcp);
        assert_eq!(cfg.worker_addrs,
                   vec!["127.0.0.1:7701".to_string(), "127.0.0.1:7702".into()]);
        assert!("bogus".parse::<TransportKind>().is_err());
    }

    #[test]
    fn tcp_without_addrs_rejected() {
        let mut cfg = TrainConfig::default();
        cfg.set("offload_transport", "tcp").unwrap();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn duplicate_worker_addrs_allowed() {
        // one daemon may back several pool slots (it serves N links)
        let mut cfg = TrainConfig::default();
        cfg.set("offload_transport", "tcp").unwrap();
        cfg.set("worker_addrs", "127.0.0.1:7701,127.0.0.1:7701").unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.worker_addrs.len(), 2);
    }

    #[test]
    fn batch_and_pipeline_knobs_validated() {
        let mut cfg = TrainConfig::default();
        cfg.set("offload_transport", "tcp").unwrap();
        cfg.set("worker_addrs", "127.0.0.1:7701").unwrap();
        cfg.set("offload_batch", "true").unwrap();
        cfg.set("offload_inflight", "2").unwrap();
        cfg.set("offload_tenant", "u0").unwrap();
        cfg.validate().unwrap();

        // pipelining rides FitBatch frames
        cfg.set("offload_batch", "false").unwrap();
        assert!(cfg.validate().is_err());
        cfg.set("offload_batch", "true").unwrap();

        // zero in-flight frames is meaningless
        cfg.set("offload_inflight", "0").unwrap();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn tcp_only_knobs_rejected_on_local_transport() {
        let mut cfg = TrainConfig::default();
        cfg.set("offload_tenant", "u0").unwrap();
        assert!(cfg.validate().is_err());

        let mut cfg = TrainConfig::default();
        cfg.set("offload_batch", "true").unwrap();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn elastic_pool_knobs_parse_and_validate() {
        let mut cfg = TrainConfig::default();
        cfg.set("offload_transport", "tcp").unwrap();
        cfg.set("worker_addrs", "127.0.0.1:7701,127.0.0.1:7702").unwrap();
        cfg.set("standby_addrs", "127.0.0.1:7710, 127.0.0.1:7711,").unwrap();
        cfg.set("failover", "migrate").unwrap();
        cfg.set("heartbeat_interval", "2").unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.standby_addrs.len(), 2);
        assert_eq!(cfg.failover, FailoverPolicy::Migrate);
        assert_eq!(cfg.heartbeat_interval, 2);
        // sweeping can be disabled outright
        cfg.set("heartbeat_interval", "0").unwrap();
        cfg.validate().unwrap();
        assert!("bogus".parse::<FailoverPolicy>().is_err());
    }

    #[test]
    fn elastic_knobs_rejected_on_local_transport() {
        let mut cfg = TrainConfig::default();
        cfg.set("standby_addrs", "127.0.0.1:7710").unwrap();
        assert!(cfg.validate().is_err());

        let mut cfg = TrainConfig::default();
        cfg.set("failover", "migrate").unwrap();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn wire_format_parses_and_rejects_unknown() {
        assert_eq!("f32".parse::<WireFormat>().unwrap(), WireFormat::F32);
        assert_eq!("bf16".parse::<WireFormat>().unwrap(), WireFormat::Bf16);
        assert!("fp8".parse::<WireFormat>().is_err());
        assert_eq!(WireFormat::Bf16.to_string(), "bf16");
    }

    #[test]
    fn bf16_rejected_on_local_transport() {
        let mut cfg = TrainConfig::default();
        cfg.set("offload_wire", "bf16").unwrap();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn bf16_with_migrate_allowed_because_state_stays_f32() {
        // the one combination the bugfix gate watches: bf16 payload
        // compression + migrate-on-failure checkpoints. It validates ONLY
        // because encode_state has no bf16 path — state blobs stay
        // bit-exact f32 (wire.rs `state_blob_ignores_wire_format`). If
        // state export ever learns to compress, validate() must start
        // rejecting this combination.
        let mut cfg = TrainConfig::default();
        cfg.set("offload_transport", "tcp").unwrap();
        cfg.set("worker_addrs", "127.0.0.1:7701").unwrap();
        cfg.set("offload_wire", "bf16").unwrap();
        cfg.set("failover", "migrate").unwrap();
        cfg.set("standby_addrs", "127.0.0.1:7710").unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.offload_wire, WireFormat::Bf16);
    }

    #[test]
    fn registry_and_replication_knobs_validate() {
        // registry listener with no static addrs: the all-dynamic fleet
        let mut cfg = TrainConfig::default();
        cfg.set("offload_transport", "tcp").unwrap();
        cfg.set("registry_listen", "127.0.0.1:0").unwrap();
        cfg.set("failover", "migrate").unwrap();
        cfg.set("replicate", "true").unwrap();
        cfg.validate().unwrap();
        assert!(cfg.worker_addrs.is_empty());

        // registry + static addrs: static members become the bootstrap
        cfg.set("worker_addrs", "127.0.0.1:7701").unwrap();
        cfg.validate().unwrap();

        // joiners are admitted at sweep boundaries, which need migrate
        cfg.set("failover", "fail").unwrap();
        assert!(cfg.validate().is_err());

        // replicas are only ever read by the migrate failover path
        let mut cfg = TrainConfig::default();
        cfg.set("offload_transport", "tcp").unwrap();
        cfg.set("worker_addrs", "127.0.0.1:7701").unwrap();
        cfg.set("replicate", "true").unwrap();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn registry_and_replication_rejected_on_local_transport() {
        let mut cfg = TrainConfig::default();
        cfg.set("registry_listen", "127.0.0.1:0").unwrap();
        assert!(cfg.validate().is_err());

        let mut cfg = TrainConfig::default();
        cfg.set("replicate", "true").unwrap();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn simd_mode_parses_and_rejects_unknown() {
        assert_eq!("auto".parse::<SimdMode>().unwrap(), SimdMode::Auto);
        assert_eq!("off".parse::<SimdMode>().unwrap(), SimdMode::Off);
        assert_eq!("on".parse::<SimdMode>().unwrap(), SimdMode::On);
        assert_eq!("fma".parse::<SimdMode>().unwrap(), SimdMode::Fma);
        assert!("avx512".parse::<SimdMode>().is_err());
        let mut cfg = TrainConfig::default();
        cfg.set("simd", "off").unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.simd, SimdMode::Off);
    }

    #[test]
    fn state_paging_knobs_validate() {
        // both set: the bounded-memory local configuration
        let mut cfg = TrainConfig::default();
        cfg.set("state_working_set", "64").unwrap();
        cfg.set("state_page_dir", "/tmp/cola_pages").unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.state_working_set, 64);

        // half-configured pager: dir without a bound
        let mut cfg = TrainConfig::default();
        cfg.set("state_page_dir", "/tmp/cola_pages").unwrap();
        assert!(cfg.validate().is_err());

        // ...or a bound without a dir
        let mut cfg = TrainConfig::default();
        cfg.set("state_working_set", "64").unwrap();
        assert!(cfg.validate().is_err());

        // paging is an in-process concern; daemons bound themselves
        let mut cfg = TrainConfig::default();
        cfg.set("offload_transport", "tcp").unwrap();
        cfg.set("worker_addrs", "127.0.0.1:7701").unwrap();
        cfg.set("state_working_set", "64").unwrap();
        cfg.set("state_page_dir", "/tmp/cola_pages").unwrap();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn local_with_addrs_rejected() {
        let mut cfg = TrainConfig::default();
        cfg.set("worker_addrs", "127.0.0.1:7701").unwrap();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn tcp_with_pjrt_target_rejected() {
        let mut cfg = TrainConfig::default();
        cfg.set("offload_transport", "tcp").unwrap();
        cfg.set("worker_addrs", "127.0.0.1:7701").unwrap();
        cfg.set("offload", "gpu").unwrap();
        assert!(cfg.validate().is_err());
    }
}
