//! # ColA: Collaborative Adaptation with Gradient Learning
//!
//! A production-grade reproduction of *ColA: Collaborative Adaptation
//! with Gradient Learning* (Diao et al., 2024) as a three-layer system:
//!
//! - **L3 (this crate)** — the FTaaS coordinator: server device hosting
//!   the base model, Gradient Offloading to low-cost worker devices
//!   (in-process threads or remote `cola worker` daemons over the
//!   [`transport`] wire — same bit-identical loss curves either way),
//!   adaptation-interval buffering, Prop.-2 parameter merging, a memory
//!   accountant, synthetic task generators, and the full bench suite
//!   regenerating every table/figure of the paper.
//! - **L2 (`runtime`)** — execution of the artifact contract. Two
//!   interchangeable backends:
//!   - [`runtime::native`] (default): a hermetic pure-Rust executor that
//!     implements every artifact in the manifest — the decoupled fwd/bwd
//!     transformer graphs, coupled PEFT baselines, IC models, surrogate
//!     `fit_step`s and optimizer references — directly on
//!     [`tensor::Tensor`]. No Python, no XLA, no artifacts directory.
//!   - `runtime::device` (`--features xla`): PJRT execution of JAX
//!     graphs AOT-lowered to HLO by `make artifacts` (Python + JAX
//!     build-time only; requires the `xla` bindings crate).
//! - **L1 (python/compile/kernels, build time)** — Pallas kernels for
//!   the adapter-apply and surrogate-fit hot spots, with pure-jnp
//!   references (`ref.py`) that double as the spec for
//!   [`runtime::native::kernels`].
//!
//! Backend selection is automatic: `Runtime::load` uses the AOT
//! artifacts when `artifacts/manifest.json` exists (and the `xla`
//! feature is on), and synthesizes the built-in native manifest
//! otherwise — so a clean checkout with only stable Rust installed
//! builds, tests and trains end to end.
//!
//! Start at [`coordinator::Trainer`] (Algorithm 1),
//! [`coordinator::FtaasService`] (Figure 1), and [`gateway::Gateway`]
//! (`cola serve` — the FTaaS HTTP front door).

// Docs are part of the test surface: CI builds with
// `RUSTDOCFLAGS="-D warnings"`, and a link to a renamed item must fail
// the build rather than rot silently.
#![deny(rustdoc::broken_intra_doc_links)]

pub mod adapters;
pub mod bench_harness;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod gateway;
pub mod lint;
pub mod memory;
pub mod merge;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod scale;
pub mod tensor;
pub mod transport;
pub mod util;

pub use anyhow::Result;
