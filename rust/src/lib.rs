//! # ColA: Collaborative Adaptation with Gradient Learning
//!
//! A production-grade reproduction of *ColA: Collaborative Adaptation
//! with Gradient Learning* (Diao et al., 2024) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — the FTaaS coordinator: server device hosting
//!   the base model, Gradient Offloading to low-cost worker devices,
//!   adaptation-interval buffering, Prop.-2 parameter merging, a memory
//!   accountant, synthetic task generators, and the full bench suite
//!   regenerating every table/figure of the paper.
//! - **L2 (python/compile, build time)** — JAX graphs AOT-lowered to
//!   HLO text (`artifacts/`), executed here via PJRT.
//! - **L1 (python/compile/kernels, build time)** — Pallas kernels for
//!   the adapter-apply and surrogate-fit hot spots.
//!
//! Python never runs at serving/training time: `make artifacts` once,
//! then the `cola` binary is self-contained.
//!
//! Start at [`coordinator::Trainer`] (Algorithm 1) and
//! [`coordinator::FtaasService`] (Figure 1).

pub mod adapters;
pub mod bench_harness;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod memory;
pub mod merge;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod tensor;
pub mod util;

pub use anyhow::Result;
