//! Runtime: execution of the artifact contract (L2 -> L3 bridge).
//!
//! - `manifest` — the artifact interface contract written by `aot.py`
//!   (or synthesized natively when no `artifacts/` directory exists)
//! - `value`    — Send-able tensors crossing device threads
//! - `native`   — the hermetic pure-Rust executor (default backend)
//! - `device`   — PJRT device threads (`--features xla` + `make artifacts`)
//!
//! Backend selection: `Runtime::load` parses `artifacts/manifest.json`
//! when it exists; otherwise it synthesizes the built-in manifest and
//! every execution runs on the native backend. With the `xla` feature
//! enabled AND artifacts on disk, devices execute the lowered HLO via
//! PJRT instead — the two backends implement the same contract and are
//! asserted equivalent in `rust/tests/`.
//!
//! `Runtime` owns the manifest and the *server* device (the paper's GPU
//! hosting the base model); worker devices are spawned by
//! `coordinator::offload`.

#[cfg(feature = "xla")]
pub mod device;
pub mod manifest;
pub mod native;
pub mod value;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

pub use manifest::{ArtifactSpec, DType, IoSpec, Manifest, SizeConfig};
pub use value::{IntTensor, Value};

/// One positional input to an execution.
#[derive(Clone, Debug)]
pub enum Input {
    /// a named buffer resident on the device
    Ref(String),
    /// an inline value (uploaded for this call)
    Val(Value),
}

/// What to do with each output of an execution.
#[derive(Clone, Debug, Default)]
pub struct OutputPlan {
    /// output index -> keep resident on the device under this name
    pub keep: Vec<(usize, String)>,
    /// output indices to return to the caller as Values
    pub fetch: Vec<usize>,
}

#[derive(Debug)]
pub struct ExecResult {
    /// (output index, value) for every fetched index
    pub fetched: Vec<(usize, Value)>,
    /// pure execute wall time on the device
    pub exec_time: Duration,
    /// one-time XLA compile on first use of the artifact (0 afterwards,
    /// and always 0 on the native backend)
    pub compile_time: Duration,
    /// host->device input literal construction time
    pub upload_time: Duration,
    /// device->host output conversion time
    pub fetch_time: Duration,
    /// bytes uploaded (inline inputs) and downloaded (fetched outputs)
    pub bytes_up: usize,
    pub bytes_down: usize,
}

/// Handle to an execution device — the unit of "a device" in the FTaaS
/// topology. Cloneable and Send; clones share the same buffer store.
#[derive(Clone)]
pub enum Device {
    /// hermetic pure-Rust executor
    Native(native::NativeDevice),
    /// PJRT device thread serving AOT-lowered HLO
    #[cfg(feature = "xla")]
    Pjrt(device::PjrtDevice),
}

impl Device {
    /// Spawn a device serving artifacts from `manifest`, picking the
    /// backend the manifest was built for.
    pub fn spawn(name: &str, manifest: Arc<Manifest>) -> Result<Device> {
        #[cfg(feature = "xla")]
        if manifest.from_disk {
            return Ok(Device::Pjrt(device::PjrtDevice::spawn(name, manifest)?));
        }
        Ok(Device::Native(native::NativeDevice::new(name, manifest)))
    }

    pub fn name(&self) -> &str {
        match self {
            Device::Native(d) => d.name(),
            #[cfg(feature = "xla")]
            Device::Pjrt(d) => d.name(),
        }
    }

    pub fn upload(&self, name: &str, value: Value) -> Result<()> {
        match self {
            Device::Native(d) => d.upload(name, value),
            #[cfg(feature = "xla")]
            Device::Pjrt(d) => d.upload(name, value),
        }
    }

    pub fn read(&self, name: &str) -> Result<Value> {
        match self {
            Device::Native(d) => d.read(name),
            #[cfg(feature = "xla")]
            Device::Pjrt(d) => d.read(name),
        }
    }

    pub fn free(&self, name: &str) -> Result<()> {
        match self {
            Device::Native(d) => d.free(name),
            #[cfg(feature = "xla")]
            Device::Pjrt(d) => d.free(name),
        }
    }

    pub fn execute(
        &self,
        artifact: &str,
        inputs: Vec<Input>,
        plan: OutputPlan,
    ) -> Result<ExecResult> {
        match self {
            Device::Native(d) => d.execute(artifact, inputs, plan),
            #[cfg(feature = "xla")]
            Device::Pjrt(d) => d.execute(artifact, inputs, plan),
        }
    }

    pub fn resident_bytes(&self) -> Result<usize> {
        match self {
            Device::Native(d) => d.resident_bytes(),
            #[cfg(feature = "xla")]
            Device::Pjrt(d) => d.resident_bytes(),
        }
    }

    pub fn shutdown(&self) {
        match self {
            Device::Native(_) => {}
            #[cfg(feature = "xla")]
            Device::Pjrt(d) => d.shutdown(),
        }
    }
}

/// Cloning shares the same server device (and its executable cache) —
/// quality benches reuse one device across arms; memory benches construct
/// fresh `Runtime`s so residency is per-run.
#[derive(Clone)]
pub struct Runtime {
    pub manifest: Arc<Manifest>,
    pub server: Device,
}

impl Runtime {
    /// Load a runtime. When `artifacts_dir` holds a `manifest.json` it is
    /// parsed from disk (and, under `--features xla`, executed via PJRT);
    /// otherwise the built-in native manifest is synthesized and every
    /// execution runs on the hermetic pure-Rust backend.
    pub fn load(artifacts_dir: &str) -> Result<Runtime> {
        let manifest = Arc::new(Manifest::load_or_builtin(Path::new(artifacts_dir))?);
        #[cfg(feature = "xla")]
        if !manifest.from_disk {
            // once per process: benches construct many Runtimes
            static FALLBACK_NOTE: std::sync::Once = std::sync::Once::new();
            FALLBACK_NOTE.call_once(|| {
                eprintln!(
                    "runtime: no {artifacts_dir}/manifest.json — falling back to \
                     the native backend (run `make artifacts` to enable PJRT)"
                );
            });
        }
        let server = Device::spawn("server", manifest.clone())?;
        Ok(Runtime { manifest, server })
    }

    /// Spawn an additional device thread (a "low-cost device").
    pub fn spawn_device(&self, name: &str) -> Result<Device> {
        Device::spawn(name, self.manifest.clone())
    }

    /// Assemble positional inputs for `artifact` by looking each input
    /// name up through `lookup`.
    pub fn assemble(
        &self,
        artifact: &str,
        mut lookup: impl FnMut(&IoSpec) -> Result<Input>,
    ) -> Result<Vec<Input>> {
        let spec = self.manifest.artifact(artifact)?;
        spec.inputs
            .iter()
            .map(|io| lookup(io).map_err(|e| anyhow!("{artifact} input '{}': {e}", io.name)))
            .collect()
    }

    /// Execute with named fetch outputs; returns name -> Value.
    pub fn execute_fetch(
        &self,
        device: &Device,
        artifact: &str,
        inputs: Vec<Input>,
        fetch_names: &[&str],
    ) -> Result<(BTreeMap<String, Value>, ExecResult)> {
        let spec = self.manifest.artifact(artifact)?;
        let fetch: Vec<usize> = fetch_names
            .iter()
            .map(|n| spec.output_index(n))
            .collect::<Result<_>>()?;
        let plan = OutputPlan { keep: vec![], fetch };
        let res = device.execute(artifact, inputs, plan)?;
        let mut out = BTreeMap::new();
        for (idx, v) in &res.fetched {
            out.insert(spec.outputs[*idx].clone(), v.clone());
        }
        Ok((out, res))
    }

    /// Execute fetching ALL outputs.
    pub fn execute_all(
        &self,
        device: &Device,
        artifact: &str,
        inputs: Vec<Input>,
    ) -> Result<(BTreeMap<String, Value>, ExecResult)> {
        let spec = self.manifest.artifact(artifact)?;
        let names: Vec<&str> = spec.outputs.iter().map(|s| s.as_str()).collect();
        self.execute_fetch(device, artifact, inputs, &names)
    }
}
