//! Runtime: PJRT loading/execution of the AOT artifacts (L2 -> L3 bridge).
//!
//! - `manifest` — the artifact interface contract written by `aot.py`
//! - `value`    — Send-able tensors crossing device threads
//! - `device`   — a device thread owning a PJRT client + resident buffers
//!
//! `Runtime` wires them together: it owns the manifest and the *server*
//! device (the paper's GPU hosting the base model); worker devices are
//! spawned by `coordinator::offload`.

pub mod device;
pub mod manifest;
pub mod value;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, Result};

pub use device::{Device, ExecResult, Input, OutputPlan};
pub use manifest::{ArtifactSpec, DType, IoSpec, Manifest, SizeConfig};
pub use value::{IntTensor, Value};

/// Cloning shares the same server device thread (and its executable
/// cache) — quality benches reuse one device across arms; memory
/// benches construct fresh `Runtime`s so residency is per-run.
#[derive(Clone)]
pub struct Runtime {
    pub manifest: Arc<Manifest>,
    pub server: Device,
}

impl Runtime {
    pub fn load(artifacts_dir: &str) -> Result<Runtime> {
        let manifest = Arc::new(Manifest::load(Path::new(artifacts_dir))?);
        let server = Device::spawn("server", manifest.clone())?;
        Ok(Runtime { manifest, server })
    }

    /// Spawn an additional device thread (a "low-cost device").
    pub fn spawn_device(&self, name: &str) -> Result<Device> {
        Device::spawn(name, self.manifest.clone())
    }

    /// Assemble positional inputs for `artifact` by looking each input
    /// name up through `lookup`.
    pub fn assemble(
        &self,
        artifact: &str,
        mut lookup: impl FnMut(&IoSpec) -> Result<Input>,
    ) -> Result<Vec<Input>> {
        let spec = self.manifest.artifact(artifact)?;
        spec.inputs.iter().map(|io| {
            lookup(io).map_err(|e| anyhow!("{artifact} input '{}': {e}", io.name))
        }).collect()
    }

    /// Execute with named fetch outputs; returns name -> Value.
    pub fn execute_fetch(
        &self,
        device: &Device,
        artifact: &str,
        inputs: Vec<Input>,
        fetch_names: &[&str],
    ) -> Result<(BTreeMap<String, Value>, ExecResult)> {
        let spec = self.manifest.artifact(artifact)?;
        let fetch: Vec<usize> = fetch_names
            .iter()
            .map(|n| spec.output_index(n))
            .collect::<Result<_>>()?;
        let plan = OutputPlan { keep: vec![], fetch };
        let res = device.execute(artifact, inputs, plan)?;
        let mut out = BTreeMap::new();
        for (idx, v) in &res.fetched {
            out.insert(spec.outputs[*idx].clone(), v.clone());
        }
        Ok((out, res))
    }

    /// Execute fetching ALL outputs.
    pub fn execute_all(
        &self,
        device: &Device,
        artifact: &str,
        inputs: Vec<Input>,
    ) -> Result<(BTreeMap<String, Value>, ExecResult)> {
        let spec = self.manifest.artifact(artifact)?;
        let names: Vec<&str> = spec.outputs.iter().map(|s| s.as_str()).collect();
        self.execute_fetch(device, artifact, inputs, &names)
    }
}
