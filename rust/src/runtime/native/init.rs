//! Native initial-value groups: the in-process twin of the
//! `artifacts/init/<group>/` export from `aot.py`.
//!
//! Same distributions as the python exporters (zero-output adapter init,
//! LN gains at one, fan-in-scaled normals), deterministically seeded from
//! the group name so repeated loads return identical values. Exact bit
//! patterns differ from the JAX export (different PRNG) — nothing in the
//! coordinator depends on them, only on the init *structure* (e.g. B = 0
//! so every adapter starts at zero output).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use super::super::manifest::Manifest;
use super::builtin;
use crate::rng::Rng;
use crate::tensor::Tensor;

fn group_seed(group: &str) -> u64 {
    // FNV-1a over the group name: stable, well-spread seeds.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in group.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Tensor {
    Tensor::randn(shape, std, rng)
}

/// LM base weights (model.init_lm_params): LN gains 1, biases 0, matrices
/// N(0, 1/fan_in).
fn lm_weights(shapes: &[(String, Vec<usize>)], rng: &mut Rng) -> BTreeMap<String, Tensor> {
    let mut out = BTreeMap::new();
    for (name, shp) in shapes {
        let t = if name.ends_with("ln1g") || name.ends_with("ln2g") || name.ends_with("lnfg") {
            Tensor::from_fn(shp, |_| 1.0)
        } else if name.ends_with("ln1b")
            || name.ends_with("ln2b")
            || name.ends_with("lnfb")
            || name.ends_with(".b1")
            || name.ends_with(".b2")
        {
            Tensor::zeros(shp)
        } else {
            let std = (1.0 / shp[0] as f32).sqrt();
            randn(shp, std, rng)
        };
        out.insert(name.clone(), t);
    }
    out
}

/// Zero-output adapter init (model.init_adapter_params /
/// ic_models.init_ic_adapters): A/W1 ~ N(0, 1/fan_in), the rest zero.
fn adapter_init(shapes: &[(String, Vec<usize>)], rng: &mut Rng) -> BTreeMap<String, Tensor> {
    let mut out = BTreeMap::new();
    for (name, shp) in shapes {
        let t = if name.ends_with(".A") || name.ends_with(".W1") {
            randn(shp, (1.0 / shp[0] as f32).sqrt(), rng)
        } else {
            Tensor::zeros(shp)
        };
        out.insert(name.clone(), t);
    }
    out
}

/// Coupled-baseline tunables (baselines.init_tunables).
fn tunable_init(
    shapes: &[(String, Vec<usize>)],
    method: &str,
    rng: &mut Rng,
) -> BTreeMap<String, Tensor> {
    let mut out = BTreeMap::new();
    for (name, shp) in shapes {
        let t = if method == "ft" {
            // FT starts from the pretrained stand-in; the coordinator
            // passes those in, this group is a placeholder.
            Tensor::zeros(shp)
        } else if name.ends_with(".A")
            || name.ends_with(".W1")
            || name == "prompt"
            || name == "anchor"
            || name.starts_with("pt.W")
            || name.contains(".p")
        {
            randn(shp, 0.1, rng)
        } else if name.ends_with(".lk") || name.ends_with(".lv") || name.ends_with(".lff") {
            Tensor::from_fn(shp, |_| 1.0) // IA3 starts at identity
        } else {
            Tensor::zeros(shp)
        };
        out.insert(name.clone(), t);
    }
    out
}

/// Generate an init group by name. Mirrors the groups `aot.py` exports.
pub fn generate(m: &Manifest, group: &str) -> Result<BTreeMap<String, Tensor>> {
    let mut rng = Rng::new(group_seed(group));

    if let Some(size) = group.strip_prefix("lm_") {
        let cfg = m.size(size)?;
        return Ok(lm_weights(&builtin::lm_param_shapes(cfg), &mut rng));
    }
    if let Some(rest) = group.strip_prefix("adapters_") {
        let (size, kind) = rest
            .split_once('_')
            .ok_or_else(|| anyhow!("bad adapter group '{group}'"))?;
        let cfg = m.size(size)?;
        return Ok(adapter_init(&builtin::lm_adapter_shapes(cfg, kind), &mut rng));
    }
    if let Some(rest) = group.strip_prefix("tunables_seqcls_") {
        let (size, meth) = rest
            .split_once('_')
            .ok_or_else(|| anyhow!("bad tunables group '{group}'"))?;
        let cfg = m.size(size)?;
        let shapes = builtin::tunable_shapes(cfg, meth, Some(m.n_classes_seqcls));
        return Ok(tunable_init(&shapes, meth, &mut rng));
    }
    if let Some(rest) = group.strip_prefix("tunables_") {
        let (size, meth) = rest
            .split_once('_')
            .ok_or_else(|| anyhow!("bad tunables group '{group}'"))?;
        let cfg = m.size(size)?;
        let shapes = builtin::tunable_shapes(cfg, meth, None);
        return Ok(tunable_init(&shapes, meth, &mut rng));
    }
    if let Some(model) = group.strip_prefix("ic_base_") {
        // He-style random frozen base (ic_models.init_ic_base)
        let mut out = BTreeMap::new();
        for (site, (din, dout, _)) in builtin::ic_site_dims(model) {
            let std = (2.0 / din as f32).sqrt();
            out.insert(format!("{site}.Wbase"), randn(&[din, dout], std, &mut rng));
        }
        return Ok(out);
    }
    if let Some(rest) = group.strip_prefix("ic_") {
        let (model, kind) = rest
            .split_once('_')
            .ok_or_else(|| anyhow!("bad ic adapter group '{group}'"))?;
        return Ok(adapter_init(&builtin::ic_adapter_shapes(model, kind), &mut rng));
    }
    bail!("native backend: unknown init group '{group}'")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor;
    use std::path::Path;

    fn manifest() -> Manifest {
        builtin::builtin_manifest(Path::new("artifacts"))
    }

    #[test]
    fn deterministic_across_calls() {
        let m = manifest();
        let a = generate(&m, "lm_tiny").unwrap();
        let b = generate(&m, "lm_tiny").unwrap();
        assert_eq!(a.len(), b.len());
        for (k, t) in &a {
            assert_eq!(t, &b[k], "{k}");
        }
    }

    #[test]
    fn lm_group_structure() {
        let m = manifest();
        let w = generate(&m, "lm_tiny").unwrap();
        assert_eq!(w["embed"].shape(), &[512, 128]);
        assert!(w["l0.ln1g"].data().iter().all(|&x| x == 1.0));
        assert!(w["l1.b2"].data().iter().all(|&x| x == 0.0));
        assert!(tensor::norm(&w["l0.wq"]) > 0.0);
    }

    #[test]
    fn adapters_start_at_zero_output() {
        let m = manifest();
        for kind in ["lowrank", "linear", "mlp"] {
            let a = generate(&m, &format!("adapters_tiny_{kind}")).unwrap();
            for (name, t) in &a {
                if name.ends_with(".A") || name.ends_with(".W1") {
                    assert!(tensor::norm(t) > 0.0, "{name}");
                } else {
                    assert_eq!(tensor::norm(t), 0.0, "{name}");
                }
            }
        }
    }

    #[test]
    fn tunables_structure() {
        let m = manifest();
        let ia3 = generate(&m, "tunables_tiny_ia3").unwrap();
        assert!(ia3["l0.lk"].data().iter().all(|&x| x == 1.0));
        let lora = generate(&m, "tunables_seqcls_tiny_lora").unwrap();
        assert_eq!(lora["head.W"].shape(), &[128, 4]);
        assert_eq!(tensor::norm(&lora["head.W"]), 0.0);
        assert_eq!(tensor::norm(&lora["l0.q.B"]), 0.0);
        assert!(tensor::norm(&lora["l0.q.A"]) > 0.0);
        let pfx = generate(&m, "tunables_tiny_prefix").unwrap();
        assert!(tensor::norm(&pfx["l0.pk"]) > 0.0);
        let pt = generate(&m, "tunables_tiny_ptuning").unwrap();
        assert_eq!(tensor::norm(&pt["pt.b1"]), 0.0);
        assert!(tensor::norm(&pt["pt.W2"]) > 0.0);
    }

    #[test]
    fn ic_groups() {
        let m = manifest();
        let base = generate(&m, "ic_base_cnn").unwrap();
        assert_eq!(base["conv2.Wbase"].shape(), &[144, 32]);
        let a = generate(&m, "ic_mlp_lowrank").unwrap();
        assert_eq!(a["fc1.A"].shape(), &[784, 8]);
        assert_eq!(tensor::norm(&a["fc1.B"]), 0.0);
        assert!(generate(&m, "no_such_group").is_err());
    }
}
