//! Built-in manifest: the native twin of `python/compile/aot.py`.
//!
//! Synthesizes the exact artifact inventory (names, positional input
//! lists, output names) that `make artifacts` would write to
//! `artifacts/manifest.json`, so the coordinator runs unchanged on the
//! hermetic native backend. Any drift between this file and `aot.py` is
//! a contract bug — the round-trip tests serialize this manifest through
//! the JSON reader to keep both sides honest.

use std::collections::BTreeMap;
use std::path::Path;

use super::super::manifest::{ArtifactSpec, DType, IoSpec, Manifest, SizeConfig};

pub const RANK: usize = 8;
pub const MLP_HIDDEN: usize = 64;
pub const N_CLASSES_SEQCLS: usize = 4;
pub const IMG: usize = 28;
pub const N_CLASSES_IC: usize = 10;
pub const PROMPT_LEN: usize = 8;
pub const PREFIX_LEN: usize = 8;
pub const PTUNE_HIDDEN: usize = 32;

pub const BASELINE_METHODS: [&str; 6] = ["ft", "lora", "ia3", "prompt", "ptuning", "prefix"];
pub const ADAPTER_KINDS: [&str; 3] = ["lowrank", "linear", "mlp"];
pub const IC_MODELS: [&str; 3] = ["linear", "mlp", "cnn"];

/// model.CONFIGS with batch = 8 (what aot.py echoes into the manifest).
pub fn builtin_configs() -> BTreeMap<String, SizeConfig> {
    let mut m = BTreeMap::new();
    m.insert(
        "tiny".to_string(),
        SizeConfig { vocab: 512, d: 128, layers: 2, heads: 4, dff: 512, seq: 64, batch: 8 },
    );
    m.insert(
        "small".to_string(),
        SizeConfig { vocab: 2048, d: 256, layers: 4, heads: 8, dff: 1024, seq: 128, batch: 8 },
    );
    m.insert(
        "base".to_string(),
        SizeConfig { vocab: 4096, d: 384, layers: 8, heads: 8, dff: 1536, seq: 128, batch: 8 },
    );
    m
}

/// Canonical (ordered) base-weight names + shapes (model.lm_param_shapes).
pub fn lm_param_shapes(cfg: &SizeConfig) -> Vec<(String, Vec<usize>)> {
    let (v, d, dff, s) = (cfg.vocab, cfg.d, cfg.dff, cfg.seq);
    let mut out = vec![
        ("embed".to_string(), vec![v, d]),
        ("pos".to_string(), vec![s, d]),
    ];
    for i in 0..cfg.layers {
        out.push((format!("l{i}.ln1g"), vec![d]));
        out.push((format!("l{i}.ln1b"), vec![d]));
        out.push((format!("l{i}.wq"), vec![d, d]));
        out.push((format!("l{i}.wk"), vec![d, d]));
        out.push((format!("l{i}.wv"), vec![d, d]));
        out.push((format!("l{i}.wo"), vec![d, d]));
        out.push((format!("l{i}.ln2g"), vec![d]));
        out.push((format!("l{i}.ln2b"), vec![d]));
        out.push((format!("l{i}.w1"), vec![d, dff]));
        out.push((format!("l{i}.b1"), vec![dff]));
        out.push((format!("l{i}.w2"), vec![dff, d]));
        out.push((format!("l{i}.b2"), vec![d]));
    }
    out.push(("lnfg".to_string(), vec![d]));
    out.push(("lnfb".to_string(), vec![d]));
    out
}

/// Ordered adapter parameter shapes for the LM q/v sites
/// (model.adapter_param_shapes).
pub fn lm_adapter_shapes(cfg: &SizeConfig, kind: &str) -> Vec<(String, Vec<usize>)> {
    let d = cfg.d;
    let mut out = Vec::new();
    for i in 0..cfg.layers {
        for proj in ["q", "v"] {
            let p = format!("l{i}.{proj}");
            match kind {
                "lowrank" => {
                    out.push((format!("{p}.A"), vec![d, RANK]));
                    out.push((format!("{p}.B"), vec![RANK, d]));
                }
                "linear" => out.push((format!("{p}.W"), vec![d, d])),
                "mlp" => {
                    out.push((format!("{p}.W1"), vec![d, MLP_HIDDEN]));
                    out.push((format!("{p}.b1"), vec![MLP_HIDDEN]));
                    out.push((format!("{p}.W2"), vec![MLP_HIDDEN, d]));
                    out.push((format!("{p}.b2"), vec![d]));
                }
                _ => {} // "none"
            }
        }
    }
    out
}

/// Ordered tunable shapes per coupled-baseline method
/// (baselines.tunable_shapes).
pub fn tunable_shapes(
    cfg: &SizeConfig,
    method: &str,
    n_classes: Option<usize>,
) -> Vec<(String, Vec<usize>)> {
    let (d, dff) = (cfg.d, cfg.dff);
    let mut out = Vec::new();
    match method {
        "ft" => out.extend(lm_param_shapes(cfg)),
        "lora" => out.extend(lm_adapter_shapes(cfg, "lowrank")),
        "ia3" => {
            for i in 0..cfg.layers {
                out.push((format!("l{i}.lk"), vec![d]));
                out.push((format!("l{i}.lv"), vec![d]));
                out.push((format!("l{i}.lff"), vec![dff]));
            }
        }
        "prompt" => out.push(("prompt".to_string(), vec![PROMPT_LEN, d])),
        "ptuning" => {
            out.push(("anchor".to_string(), vec![PROMPT_LEN, d]));
            out.push(("pt.W1".to_string(), vec![d, PTUNE_HIDDEN]));
            out.push(("pt.b1".to_string(), vec![PTUNE_HIDDEN]));
            out.push(("pt.W2".to_string(), vec![PTUNE_HIDDEN, d]));
            out.push(("pt.b2".to_string(), vec![d]));
        }
        "prefix" => {
            for i in 0..cfg.layers {
                out.push((format!("l{i}.pk"), vec![PREFIX_LEN, d]));
                out.push((format!("l{i}.pv"), vec![PREFIX_LEN, d]));
            }
        }
        // lint:allow(panic-safety): the method list is compiled into the builtin manifest — an unknown name is a build-time bug, not input
        other => panic!("unknown baseline method '{other}'"),
    }
    if let Some(c) = n_classes {
        out.push(("head.W".to_string(), vec![d, c]));
    }
    out
}

/// Ordered {site: (d_in, d_out, rows_per_image)} (ic_models.ic_site_dims).
pub fn ic_site_dims(model: &str) -> Vec<(&'static str, (usize, usize, usize))> {
    match model {
        "linear" => vec![("fc", (IMG * IMG, N_CLASSES_IC, 1))],
        "mlp" => vec![
            ("fc1", (IMG * IMG, 128, 1)),
            ("fc2", (128, N_CLASSES_IC, 1)),
        ],
        "cnn" => vec![
            ("conv1", (9, 16, IMG * IMG)),
            ("conv2", (16 * 9, 32, 14 * 14)),
            ("fc", (32 * 7 * 7, N_CLASSES_IC, 1)),
        ],
        // lint:allow(panic-safety): model names are compiled into the builtin manifest — an unknown one is a build-time bug, not input
        other => panic!("unknown ic model '{other}'"),
    }
}

/// Ordered IC adapter shapes (ic_models.ic_adapter_shapes).
pub fn ic_adapter_shapes(model: &str, kind: &str) -> Vec<(String, Vec<usize>)> {
    let mut out = Vec::new();
    for (site, (din, dout, _)) in ic_site_dims(model) {
        match kind {
            "lowrank" => {
                let r = RANK.min(din).min(dout);
                out.push((format!("{site}.A"), vec![din, r]));
                out.push((format!("{site}.B"), vec![r, dout]));
            }
            "linear" => out.push((format!("{site}.W"), vec![din, dout])),
            "mlp" => {
                out.push((format!("{site}.W1"), vec![din, MLP_HIDDEN]));
                out.push((format!("{site}.b1"), vec![MLP_HIDDEN]));
                out.push((format!("{site}.W2"), vec![MLP_HIDDEN, dout]));
                out.push((format!("{site}.b2"), vec![dout]));
            }
            // lint:allow(panic-safety): adapter kinds are compiled into the builtin manifest — an unknown one is a build-time bug, not input
            other => panic!("unknown adapter kind '{other}'"),
        }
    }
    out
}

fn f32io(name: &str, dims: Vec<usize>) -> IoSpec {
    IoSpec { name: name.to_string(), dtype: DType::F32, dims }
}

fn i32io(name: &str, dims: Vec<usize>) -> IoSpec {
    IoSpec { name: name.to_string(), dtype: DType::I32, dims }
}

fn f32ios(shapes: &[(String, Vec<usize>)]) -> Vec<IoSpec> {
    shapes.iter().map(|(n, s)| f32io(n, s.clone())).collect()
}

struct Builder {
    dir: std::path::PathBuf,
    artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Builder {
    fn emit(&mut self, name: &str, inputs: Vec<IoSpec>, outputs: Vec<String>) {
        self.artifacts.insert(
            name.to_string(),
            ArtifactSpec {
                name: name.to_string(),
                file: self.dir.join(format!("{name}.hlo.txt")),
                inputs,
                outputs,
            },
        );
    }
}

fn lm_decoupled_outputs(layers: usize) -> Vec<String> {
    let mut out = vec!["loss".to_string(), "acc".to_string()];
    out.extend((0..layers).map(|i| format!("l{i}.x")));
    out.extend((0..layers).map(|i| format!("l{i}.gq")));
    out.extend((0..layers).map(|i| format!("l{i}.gv")));
    out
}

fn emit_lm_fwdbwd(b: &mut Builder, name: &str, cfg: &SizeConfig, kind: &str, batch: usize) {
    let mut inputs = f32ios(&lm_param_shapes(cfg));
    inputs.extend(f32ios(&lm_adapter_shapes(cfg, kind)));
    inputs.push(i32io("tokens", vec![batch, cfg.seq]));
    inputs.push(i32io("targets", vec![batch, cfg.seq]));
    inputs.push(f32io("mask", vec![batch, cfg.seq]));
    b.emit(name, inputs, lm_decoupled_outputs(cfg.layers));
}

fn emit_seqcls_fwdbwd(b: &mut Builder, name: &str, cfg: &SizeConfig, kind: &str) {
    let batch = cfg.batch;
    let mut inputs = f32ios(&lm_param_shapes(cfg));
    inputs.extend(f32ios(&lm_adapter_shapes(cfg, kind)));
    inputs.push(f32io("head.W", vec![cfg.d, N_CLASSES_SEQCLS]));
    inputs.push(i32io("tokens", vec![batch, cfg.seq]));
    inputs.push(i32io("labels", vec![batch]));
    inputs.push(f32io("mask", vec![batch, cfg.seq]));
    let mut outputs = vec!["loss".to_string(), "acc".to_string()];
    outputs.extend((0..cfg.layers).map(|i| format!("l{i}.x")));
    outputs.push("head.x".to_string());
    outputs.extend((0..cfg.layers).map(|i| format!("l{i}.gq")));
    outputs.extend((0..cfg.layers).map(|i| format!("l{i}.gv")));
    outputs.push("head.g".to_string());
    b.emit(name, inputs, outputs);
}

fn emit_coupled_clm(b: &mut Builder, name: &str, cfg: &SizeConfig, method: &str, batch: usize) {
    let tun = tunable_shapes(cfg, method, None);
    let mut inputs = Vec::new();
    if method != "ft" {
        inputs.extend(f32ios(&lm_param_shapes(cfg)));
    }
    inputs.extend(f32ios(&tun));
    inputs.push(i32io("tokens", vec![batch, cfg.seq]));
    inputs.push(i32io("targets", vec![batch, cfg.seq]));
    inputs.push(f32io("mask", vec![batch, cfg.seq]));
    let mut outputs = vec!["loss".to_string(), "acc".to_string()];
    outputs.extend(tun.iter().map(|(n, _)| format!("d.{n}")));
    b.emit(name, inputs, outputs);
}

fn emit_coupled_seqcls(b: &mut Builder, name: &str, cfg: &SizeConfig, method: &str) {
    let batch = cfg.batch;
    let tun = tunable_shapes(cfg, method, Some(N_CLASSES_SEQCLS));
    let mut inputs = Vec::new();
    if method != "ft" {
        inputs.extend(f32ios(&lm_param_shapes(cfg)));
    }
    inputs.extend(f32ios(&tun));
    inputs.push(i32io("tokens", vec![batch, cfg.seq]));
    inputs.push(i32io("labels", vec![batch]));
    inputs.push(f32io("mask", vec![batch, cfg.seq]));
    let mut outputs = vec!["loss".to_string(), "acc".to_string()];
    outputs.extend(tun.iter().map(|(n, _)| format!("d.{n}")));
    b.emit(name, inputs, outputs);
}

fn emit_fit(b: &mut Builder, kind: &str, d_in: usize, d_out: usize, rows: usize) {
    let name = format!("fit_{kind}_{d_in}x{d_out}_n{rows}");
    let mut inputs = vec![
        f32io("x", vec![rows, d_in]),
        f32io("ghat", vec![rows, d_out]),
    ];
    let outputs: Vec<String> = match kind {
        "lowrank" => {
            inputs.push(f32io("A", vec![d_in, RANK]));
            inputs.push(f32io("B", vec![RANK, d_out]));
            vec!["dA".into(), "dB".into()]
        }
        "linear" => {
            inputs.push(f32io("W", vec![d_in, d_out]));
            vec!["dW".into()]
        }
        "mlp" => {
            inputs.push(f32io("W1", vec![d_in, MLP_HIDDEN]));
            inputs.push(f32io("b1", vec![MLP_HIDDEN]));
            inputs.push(f32io("W2", vec![MLP_HIDDEN, d_out]));
            inputs.push(f32io("b2", vec![d_out]));
            vec!["dW1".into(), "db1".into(), "dW2".into(), "db2".into()]
        }
        // lint:allow(panic-safety): fit kinds are compiled into the builtin manifest — an unknown one is a build-time bug, not input
        other => panic!("unknown fit kind '{other}'"),
    };
    b.emit(&name, inputs, outputs);
}

fn emit_ic(b: &mut Builder, batch: usize) {
    for model in IC_MODELS {
        let dims = ic_site_dims(model);
        let img_in = f32io("images", vec![batch, IMG, IMG, 1]);
        let lab_in = i32io("labels", vec![batch]);
        let decoupled_outputs = |dims: &[(&str, (usize, usize, usize))]| {
            let mut o = vec!["loss".to_string(), "acc".to_string()];
            o.extend(dims.iter().map(|(s, _)| format!("{s}.x")));
            o.extend(dims.iter().map(|(s, _)| format!("{s}.g")));
            o
        };
        for kind in ADAPTER_KINDS {
            let mut inputs: Vec<IoSpec> = dims
                .iter()
                .map(|(s, (din, dout, _))| f32io(&format!("{s}.Wbase"), vec![*din, *dout]))
                .collect();
            inputs.extend(f32ios(&ic_adapter_shapes(model, kind)));
            inputs.push(img_in.clone());
            inputs.push(lab_in.clone());
            b.emit(&format!("ic_{model}_fwdbwd_{kind}"), inputs, decoupled_outputs(&dims));
        }
        let mut inputs: Vec<IoSpec> = dims
            .iter()
            .map(|(s, (din, dout, _))| f32io(&format!("{s}.W"), vec![*din, *dout]))
            .collect();
        inputs.push(img_in.clone());
        inputs.push(lab_in.clone());
        b.emit(&format!("ic_{model}_fwdbwd_merged"), inputs, decoupled_outputs(&dims));
        // coupled ft / lora
        {
            let tun: Vec<(String, Vec<usize>)> = dims
                .iter()
                .map(|(s, (din, dout, _))| (format!("{s}.W"), vec![*din, *dout]))
                .collect();
            let mut inputs = f32ios(&tun);
            inputs.push(img_in.clone());
            inputs.push(lab_in.clone());
            let mut outputs = vec!["loss".to_string(), "acc".to_string()];
            outputs.extend(tun.iter().map(|(n, _)| format!("d.{n}")));
            b.emit(&format!("ic_{model}_coupled_ft"), inputs, outputs);
        }
        {
            let tun = ic_adapter_shapes(model, "lowrank");
            let mut inputs: Vec<IoSpec> = dims
                .iter()
                .map(|(s, (din, dout, _))| f32io(&format!("{s}.Wbase"), vec![*din, *dout]))
                .collect();
            inputs.extend(f32ios(&tun));
            inputs.push(img_in.clone());
            inputs.push(lab_in.clone());
            let mut outputs = vec!["loss".to_string(), "acc".to_string()];
            outputs.extend(tun.iter().map(|(n, _)| format!("d.{n}")));
            b.emit(&format!("ic_{model}_coupled_lora"), inputs, outputs);
        }
        // fit graphs for every site shape of this model
        for (_, (din, dout, rows)) in &dims {
            for kind in ADAPTER_KINDS {
                emit_fit(b, kind, *din, *dout, batch * rows);
            }
        }
    }
}

fn emit_opt_refs(b: &mut Builder) {
    for n in [64usize, 1024] {
        let vecio = |name: &str| f32io(name, vec![n]);
        let sc = |name: &str| f32io(name, vec![]);
        b.emit(
            &format!("adamw_n{n}"),
            vec![
                vecio("w"), vecio("g"), vecio("m"), vecio("v"),
                sc("t"), sc("lr"), sc("beta1"), sc("beta2"), sc("eps"), sc("wd"),
            ],
            vec!["w2".into(), "m2".into(), "v2".into()],
        );
        b.emit(
            &format!("sgd_n{n}"),
            vec![vecio("w"), vecio("g"), sc("lr"), sc("wd")],
            vec!["w2".into()],
        );
    }
}

/// Synthesize the full built-in manifest (the native twin of
/// `aot.py main()` with sizes tiny,small,base).
pub fn builtin_manifest(dir: &Path) -> Manifest {
    let configs = builtin_configs();
    let mut b = Builder { dir: dir.to_path_buf(), artifacts: BTreeMap::new() };

    for (size, cfg) in &configs {
        let full = size != "base";
        let kinds: &[&str] = if full {
            &["lowrank", "linear", "mlp", "none"]
        } else {
            &["none", "linear"]
        };
        for &kind in kinds {
            emit_lm_fwdbwd(&mut b, &format!("lm_fwdbwd_{size}_{kind}"), cfg, kind, cfg.batch);
        }
        {
            // inference graph: weights + tokens -> logits
            let mut inputs = f32ios(&lm_param_shapes(cfg));
            inputs.push(i32io("tokens", vec![cfg.batch, cfg.seq]));
            b.emit(&format!("lm_fwd_{size}"), inputs, vec!["logits".into()]);
        }
        let fit_kinds: &[&str] = if full { &["lowrank", "linear", "mlp"] } else { &["linear"] };
        for &kind in fit_kinds {
            emit_fit(&mut b, kind, cfg.d, cfg.d, cfg.batch * cfg.seq);
        }
        if size == "tiny" {
            for kind in ["lowrank", "linear", "mlp", "none"] {
                emit_seqcls_fwdbwd(&mut b, &format!("seqcls_fwdbwd_{size}_{kind}"), cfg, kind);
            }
            for meth in BASELINE_METHODS {
                emit_coupled_clm(&mut b, &format!("coupled_clm_{size}_{meth}"), cfg, meth,
                                 cfg.batch);
                emit_coupled_seqcls(&mut b, &format!("coupled_seqcls_{size}_{meth}"), cfg, meth);
            }
            // head-site fit (B rows per batch)
            emit_fit(&mut b, "linear", cfg.d, N_CLASSES_SEQCLS, cfg.batch);
            // batch variants for Tables 10-18
            for bsz in [1usize, 32] {
                emit_lm_fwdbwd(&mut b, &format!("lm_fwdbwd_{size}_lowrank_b{bsz}"), cfg,
                               "lowrank", bsz);
                emit_lm_fwdbwd(&mut b, &format!("lm_fwdbwd_{size}_none_b{bsz}"), cfg,
                               "none", bsz);
                emit_coupled_clm(&mut b, &format!("coupled_clm_{size}_lora_b{bsz}"), cfg,
                                 "lora", bsz);
                emit_coupled_clm(&mut b, &format!("coupled_clm_{size}_ft_b{bsz}"), cfg,
                                 "ft", bsz);
                emit_fit(&mut b, "lowrank", cfg.d, cfg.d, bsz * cfg.seq);
            }
        }
    }
    emit_ic(&mut b, 32);
    emit_opt_refs(&mut b);

    Manifest {
        dir: dir.to_path_buf(),
        artifacts: b.artifacts,
        configs,
        rank: RANK,
        mlp_hidden: MLP_HIDDEN,
        n_classes_seqcls: N_CLASSES_SEQCLS,
        from_disk: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inventory_covers_driver_names() {
        let m = builtin_manifest(Path::new("artifacts"));
        for name in [
            "lm_fwdbwd_tiny_lowrank",
            "lm_fwdbwd_tiny_none",
            "lm_fwdbwd_small_mlp",
            "lm_fwdbwd_base_none",
            "lm_fwdbwd_base_linear",
            "lm_fwdbwd_tiny_lowrank_b1",
            "lm_fwdbwd_tiny_none_b32",
            "lm_fwd_base",
            "seqcls_fwdbwd_tiny_linear",
            "coupled_clm_tiny_ft",
            "coupled_clm_tiny_prefix",
            "coupled_clm_tiny_lora_b32",
            "coupled_seqcls_tiny_ia3",
            "ic_cnn_fwdbwd_lowrank",
            "ic_mlp_fwdbwd_merged",
            "ic_linear_coupled_ft",
            "fit_lowrank_128x128_n512",
            "fit_lowrank_128x128_n2048",
            "fit_linear_128x4_n8",
            "fit_linear_384x384_n1024",
            "fit_mlp_9x16_n25088",
            "adamw_n64",
            "sgd_n1024",
        ] {
            assert!(m.artifacts.contains_key(name), "missing artifact {name}");
        }
        // base size is not 'full': no lowrank graph, no mlp fit
        assert!(!m.artifacts.contains_key("lm_fwdbwd_base_lowrank"));
        assert!(!m.artifacts.contains_key("fit_mlp_384x384_n1024"));
    }

    #[test]
    fn input_orders_match_aot_contract() {
        let m = builtin_manifest(Path::new("artifacts"));
        let a = m.artifact("lm_fwdbwd_tiny_lowrank").unwrap();
        // weights, then adapters, then data
        assert_eq!(a.inputs[0].name, "embed");
        assert_eq!(a.inputs[1].name, "pos");
        let n_w = lm_param_shapes(m.size("tiny").unwrap()).len();
        assert_eq!(a.inputs[n_w].name, "l0.q.A");
        let last = a.inputs.len() - 1;
        assert_eq!(a.inputs[last].name, "mask");
        assert_eq!(a.inputs[last - 2].name, "tokens");
        assert_eq!(a.outputs[0], "loss");
        assert_eq!(a.outputs[2], "l0.x");
        // ft has no frozen-weight inputs
        let ft = m.artifact("coupled_clm_tiny_ft").unwrap();
        assert_eq!(ft.inputs[0].name, "embed");
        assert_eq!(ft.inputs.len(), n_w + 3);
        assert!(ft.outputs.iter().any(|o| o == "d.l0.wq"));
        // seqcls head input precedes data
        let sc = m.artifact("seqcls_fwdbwd_tiny_none").unwrap();
        let hw = sc.input_index("head.W").unwrap();
        assert_eq!(sc.inputs[hw + 1].name, "tokens");
        assert_eq!(*sc.outputs.last().unwrap(), "head.g");
    }

    #[test]
    fn shapes_consistent() {
        let m = builtin_manifest(Path::new("artifacts"));
        let f = m.artifact("fit_lowrank_128x128_n512").unwrap();
        assert_eq!(f.inputs[0].dims, vec![512, 128]);
        assert_eq!(f.inputs[2].dims, vec![128, RANK]);
        let o = m.artifact("adamw_n1024").unwrap();
        assert_eq!(o.inputs[0].dims, vec![1024]);
        assert_eq!(o.inputs[4].dims, Vec::<usize>::new());
    }
}
