//! The hermetic pure-Rust execution backend (default).
//!
//! `NativeDevice` implements the same device contract as the PJRT device
//! thread — named resident buffers, positional artifact execution, an
//! `OutputPlan` of fetches and keeps — but executes every artifact in
//! the manifest directly on `tensor::Tensor`:
//!
//! - `builtin` — synthesizes the manifest (names, input orders, outputs)
//! - `init`    — generates the initial-value groups
//! - `kernels` — LN / attention / CE primitives + backwards (ref.py twins)
//! - `lm`      — decoupled + coupled transformer graphs
//! - `ic`      — image-classification graphs (im2col convs)
//!
//! Surrogate-fit artifacts reuse `adapters::AdapterParams::fit_grads`
//! (Prop. 1: the residual at w^t collapses to grad_hhat), and the
//! `adamw_n*`/`sgd_n*` reference steps match `adapters::optimizer`
//! bit for bit.
//!
//! Native tensors are Send, so a "device" is shared state, not a thread:
//! clones share one buffer store (mirroring how PJRT device handles
//! share their device thread).

pub mod builtin;
pub mod init;
pub mod kernels;

mod ic;
mod lm;

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::manifest::{ArtifactSpec, Manifest};
use super::value::Value;
use super::{ExecResult, Input, OutputPlan};
use crate::adapters::AdapterParams;
use crate::tensor::Tensor;

use lm::{f32_in, Named};

/// Handle to a native execution device. Cloneable, Send and Sync;
/// clones share the same buffer store.
#[derive(Clone)]
pub struct NativeDevice {
    name: Arc<String>,
    manifest: Arc<Manifest>,
    store: Arc<Mutex<BTreeMap<String, Value>>>,
}

impl NativeDevice {
    pub fn new(name: &str, manifest: Arc<Manifest>) -> NativeDevice {
        NativeDevice {
            name: Arc::new(name.to_string()),
            manifest,
            store: Arc::new(Mutex::new(BTreeMap::new())),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    fn store(&self) -> MutexGuard<'_, BTreeMap<String, Value>> {
        crate::util::lock_recover(&self.store)
    }

    pub fn upload(&self, name: &str, value: Value) -> Result<()> {
        self.store().insert(name.to_string(), value);
        Ok(())
    }

    pub fn read(&self, name: &str) -> Result<Value> {
        self.store()
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("no buffer '{name}'"))
    }

    pub fn free(&self, name: &str) -> Result<()> {
        self.store().remove(name);
        Ok(())
    }

    pub fn resident_bytes(&self) -> Result<usize> {
        Ok(self.store().values().map(Value::bytes).sum())
    }

    pub fn execute(
        &self,
        artifact: &str,
        inputs: Vec<Input>,
        plan: OutputPlan,
    ) -> Result<ExecResult> {
        let spec = self.manifest.artifact(artifact)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{artifact}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        // Resolve positional values. Inline values are owned; resident
        // refs are borrowed from the store for the duration of the run
        // (no per-step copy of the resident base model).
        // lint:allow(determinism): timing ledger only — durations never feed curve math
        let t_up = Instant::now();
        let mut bytes_up = 0usize;
        enum Slot {
            Store(String),
            Owned(usize),
        }
        let mut slots = Vec::with_capacity(inputs.len());
        let mut owned: Vec<Value> = Vec::new();
        for inp in inputs {
            match inp {
                Input::Ref(name) => slots.push(Slot::Store(name)),
                Input::Val(v) => {
                    bytes_up += v.bytes();
                    slots.push(Slot::Owned(owned.len()));
                    owned.push(v);
                }
            }
        }
        let upload_time = t_up.elapsed();

        // Backward outputs (index >= 2 on fwdbwd/coupled graphs) are only
        // computed when the plan actually wants one — eval fetches just
        // loss/acc and skips the whole reverse pass.
        let need_back = plan
            .fetch
            .iter()
            .copied()
            .chain(plan.keep.iter().map(|(i, _)| *i))
            .any(|i| i >= 2);

        // lint:allow(determinism): timing ledger only — durations never feed curve math
        let t0 = Instant::now();
        let mut by_name = {
            let store = self.store();
            let vals: Vec<&Value> = slots
                .iter()
                .map(|s| match s {
                    Slot::Store(name) => store.get(name).ok_or_else(|| {
                        anyhow!("{artifact}: no resident buffer '{name}'")
                    }),
                    Slot::Owned(i) => Ok(&owned[*i]),
                })
                .collect::<Result<_>>()?;
            // Enforce the manifest contract like the PJRT path would: a
            // stale or mis-shaped buffer must fail loudly, not index
            // silently into the wrong layout.
            for (io, v) in spec.inputs.iter().zip(&vals) {
                let dtype_ok = match v {
                    Value::F32(_) => io.dtype == super::manifest::DType::F32,
                    Value::I32(_) => io.dtype == super::manifest::DType::I32,
                };
                if !dtype_ok {
                    bail!("{artifact}: input '{}' has wrong dtype", io.name);
                }
                if v.shape() != io.dims.as_slice() {
                    bail!(
                        "{artifact}: input '{}' has shape {:?}, manifest expects {:?}",
                        io.name,
                        v.shape(),
                        io.dims
                    );
                }
            }
            run_artifact(&self.manifest, artifact, spec, &vals, need_back)?
        };
        let ordered: Vec<Value> = spec
            .outputs
            .iter()
            .map(|n| {
                by_name
                    .remove(n)
                    .ok_or_else(|| anyhow!("{artifact}: native executor missing output '{n}'"))
            })
            .collect::<Result<_>>()?;
        let exec_time = t0.elapsed();

        // lint:allow(determinism): timing ledger only — durations never feed curve math
        let t_fetch = Instant::now();
        let mut fetched = Vec::new();
        let mut bytes_down = 0usize;
        for idx in &plan.fetch {
            let v = ordered
                .get(*idx)
                .ok_or_else(|| anyhow!("{artifact}: no output index {idx}"))?
                .clone();
            bytes_down += v.bytes();
            fetched.push((*idx, v));
        }
        if !plan.keep.is_empty() {
            let mut slots: Vec<Option<Value>> = ordered.into_iter().map(Some).collect();
            let mut store = self.store();
            for (idx, name) in &plan.keep {
                let v = slots
                    .get_mut(*idx)
                    .and_then(Option::take)
                    .ok_or_else(|| anyhow!("{artifact}: keep index {idx} invalid/duplicate"))?;
                store.insert(name.clone(), v);
            }
        }
        let fetch_time = t_fetch.elapsed();
        Ok(ExecResult {
            fetched,
            exec_time,
            compile_time: Duration::ZERO,
            upload_time,
            fetch_time,
            bytes_up,
            bytes_down,
        })
    }
}

fn two_tokens(rest: &str) -> Result<(&str, &str)> {
    let mut it = rest.split('_');
    let a = it.next().ok_or_else(|| anyhow!("bad artifact name '{rest}'"))?;
    let b = it.next().ok_or_else(|| anyhow!("bad artifact name '{rest}'"))?;
    Ok((a, b))
}

fn run_artifact(
    manifest: &Manifest,
    name: &str,
    spec: &ArtifactSpec,
    vals: &[&Value],
    need_back: bool,
) -> Result<BTreeMap<String, Value>> {
    let named: Named = spec
        .inputs
        .iter()
        .zip(vals.iter())
        .map(|(io, v)| (io.name.as_str(), *v))
        .collect();

    if let Some(rest) = name.strip_prefix("lm_fwdbwd_") {
        let (size, kind) = two_tokens(rest)?;
        return lm::decoupled(manifest, size, kind, &named, false, need_back);
    }
    if let Some(rest) = name.strip_prefix("seqcls_fwdbwd_") {
        let (size, kind) = two_tokens(rest)?;
        return lm::decoupled(manifest, size, kind, &named, true, need_back);
    }
    if let Some(rest) = name.strip_prefix("coupled_clm_") {
        let (size, method) = two_tokens(rest)?;
        return lm::coupled(manifest, size, method, &named, false, need_back);
    }
    if let Some(rest) = name.strip_prefix("coupled_seqcls_") {
        let (size, method) = two_tokens(rest)?;
        return lm::coupled(manifest, size, method, &named, true, need_back);
    }
    if let Some(size) = name.strip_prefix("lm_fwd_") {
        return lm::lm_fwd(manifest, size, &named);
    }
    if let Some(rest) = name.strip_prefix("ic_") {
        let mut it = rest.splitn(3, '_');
        let model = it.next().unwrap_or_default();
        let family = it.next().unwrap_or_default();
        let tail = it.next().unwrap_or_default();
        let variant = match (family, tail) {
            ("fwdbwd", "merged") => ic::Variant::Merged,
            ("fwdbwd", kind) => ic::Variant::Decoupled(kind.to_string()),
            ("coupled", "ft") => ic::Variant::CoupledFt,
            ("coupled", "lora") => ic::Variant::CoupledLora,
            _ => bail!("native backend: unsupported ic artifact '{name}'"),
        };
        return ic::run(manifest, model, variant, &named, need_back);
    }
    if let Some(rest) = name.strip_prefix("fit_") {
        let kind = rest.split('_').next().unwrap_or_default();
        return run_fit(kind, &named);
    }
    if name.starts_with("adamw_n") {
        return run_adamw(&named);
    }
    if name.starts_with("sgd_n") {
        return run_sgd(&named);
    }
    bail!("native backend cannot execute artifact '{name}'")
}

/// Surrogate-fit artifacts: `target = g_w(x) - ghat`, so the residual at
/// w^t is exactly ghat and the gradients are `AdapterParams::fit_grads`
/// (mirrors `adapter_update.make_fit_grad` + `kernels/fit_step.py`).
fn run_fit(kind: &str, named: &Named) -> Result<BTreeMap<String, Value>> {
    let x = f32_in(named, "x")?;
    let ghat = f32_in(named, "ghat")?;
    let (params, onames): (AdapterParams, Vec<&str>) = match kind {
        "lowrank" => (
            AdapterParams::LowRank {
                a: f32_in(named, "A")?.clone(),
                b: f32_in(named, "B")?.clone(),
            },
            vec!["dA", "dB"],
        ),
        "linear" => (
            AdapterParams::Linear { w: f32_in(named, "W")?.clone() },
            vec!["dW"],
        ),
        "mlp" => (
            AdapterParams::Mlp {
                w1: f32_in(named, "W1")?.clone(),
                b1: f32_in(named, "b1")?.clone(),
                w2: f32_in(named, "W2")?.clone(),
                b2: f32_in(named, "b2")?.clone(),
            },
            vec!["dW1", "db1", "dW2", "db2"],
        ),
        other => bail!("unknown fit kind '{other}'"),
    };
    let grads = params.fit_grads(x, ghat);
    let mut res = BTreeMap::new();
    for (name, g) in onames.into_iter().zip(grads) {
        res.insert(name.to_string(), Value::F32(g));
    }
    Ok(res)
}

fn scalar_in(named: &Named, name: &str) -> Result<f32> {
    let t = f32_in(named, name)?;
    if t.len() != 1 {
        bail!("input '{name}' must be a scalar");
    }
    Ok(t.data()[0])
}

/// Reference AdamW step — arithmetic identical to `adapters::OptState`
/// so the two worker paths produce bit-identical trajectories.
fn run_adamw(named: &Named) -> Result<BTreeMap<String, Value>> {
    let w = f32_in(named, "w")?;
    let g = f32_in(named, "g")?;
    let m = f32_in(named, "m")?;
    let v = f32_in(named, "v")?;
    let t = scalar_in(named, "t")?;
    let lr = scalar_in(named, "lr")?;
    let beta1 = scalar_in(named, "beta1")?;
    let beta2 = scalar_in(named, "beta2")?;
    let eps = scalar_in(named, "eps")?;
    let wd = scalar_in(named, "wd")?;
    let n = w.len();
    let bc1 = 1.0 - beta1.powi(t as i32);
    let bc2 = 1.0 - beta2.powi(t as i32);
    let mut w2 = vec![0.0f32; n];
    let mut m2 = vec![0.0f32; n];
    let mut v2 = vec![0.0f32; n];
    for j in 0..n {
        let gv = g.data()[j];
        let mi = beta1 * m.data()[j] + (1.0 - beta1) * gv;
        let vi = beta2 * v.data()[j] + (1.0 - beta2) * gv * gv;
        let mhat = mi / bc1;
        let vhat = vi / bc2;
        w2[j] = w.data()[j] - lr * (mhat / (vhat.sqrt() + eps) + wd * w.data()[j]);
        m2[j] = mi;
        v2[j] = vi;
    }
    let shape = w.shape().to_vec();
    let mut res = BTreeMap::new();
    res.insert("w2".to_string(), Value::F32(Tensor::new(shape.clone(), w2)));
    res.insert("m2".to_string(), Value::F32(Tensor::new(shape.clone(), m2)));
    res.insert("v2".to_string(), Value::F32(Tensor::new(shape, v2)));
    Ok(res)
}

fn run_sgd(named: &Named) -> Result<BTreeMap<String, Value>> {
    let w = f32_in(named, "w")?;
    let g = f32_in(named, "g")?;
    let lr = scalar_in(named, "lr")?;
    let wd = scalar_in(named, "wd")?;
    let data: Vec<f32> = w
        .data()
        .iter()
        .zip(g.data())
        .map(|(wv, gv)| wv - lr * (gv + wd * wv))
        .collect();
    let mut res = BTreeMap::new();
    res.insert(
        "w2".to_string(),
        Value::F32(Tensor::new(w.shape().to_vec(), data)),
    );
    Ok(res)
}
