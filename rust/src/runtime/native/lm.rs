//! Native LM graphs: the pure-Rust twin of `python/compile/model.py` and
//! `python/compile/baselines.py`.
//!
//! One manual reverse-mode pass covers every LM artifact family:
//!
//! - decoupled fwd/bwd (`lm_fwdbwd_*`, `seqcls_fwdbwd_*`): loss, acc,
//!   per-site hidden inputs x_m and grad_hhat_m (the eps-probe gradients)
//!   and deliberately NO parameter gradients (Gradient Decoupling);
//! - coupled baselines (`coupled_clm_*`, `coupled_seqcls_*`): loss, acc
//!   and the tunable-parameter gradients for ft / lora / ia3 / prompt /
//!   ptuning / prefix;
//! - inference (`lm_fwd_*`): logits.
//!
//! Every gradient path here was validated against central finite
//! differences in a numpy reference before porting; the backward order
//! and caches mirror that derivation exactly.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{anyhow, bail, Result};

use super::super::manifest::{Manifest, SizeConfig};
use super::super::value::{IntTensor, Value};
use super::builtin::{self, PREFIX_LEN};
use super::kernels;
use crate::tensor::{self, pool, Tensor};

pub(super) type Named<'a> = BTreeMap<&'a str, &'a Value>;

pub(super) fn f32_in<'a>(named: &Named<'a>, name: &str) -> Result<&'a Tensor> {
    let v: &'a Value = named
        .get(name)
        .copied()
        .ok_or_else(|| anyhow!("missing input '{name}'"))?;
    match v {
        Value::F32(t) => Ok(t),
        Value::I32(_) => bail!("input '{name}' must be f32"),
    }
}

pub(super) fn i32_in<'a>(named: &Named<'a>, name: &str) -> Result<&'a IntTensor> {
    let v: &'a Value = named
        .get(name)
        .copied()
        .ok_or_else(|| anyhow!("missing input '{name}'"))?;
    match v {
        Value::I32(t) => Ok(t),
        Value::F32(_) => bail!("input '{name}' must be i32"),
    }
}

/// Parameter maps for one run, keyed by canonical names.
#[derive(Default)]
struct Params<'a> {
    w: BTreeMap<&'a str, &'a Tensor>,      // base/merged weights
    a: BTreeMap<&'a str, &'a Tensor>,      // adapter tensors ("l0.q.A", ...)
    ia3: BTreeMap<&'a str, &'a Tensor>,    // "l0.lk" / "l0.lv" / "l0.lff"
    prefix: BTreeMap<&'a str, &'a Tensor>, // "l0.pk" / "l0.pv"
}

impl<'a> Params<'a> {
    fn w(&self, name: &str) -> Result<&'a Tensor> {
        self.w
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("missing weight '{name}'"))
    }

    fn ia3(&self, name: &str) -> Result<&'a Tensor> {
        self.ia3
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("missing ia3 tunable '{name}'"))
    }

    fn prefix(&self, name: &str) -> Result<&'a Tensor> {
        self.prefix
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("missing prefix tunable '{name}'"))
    }
}

enum Task<'a> {
    Clm { targets: &'a IntTensor, mask: &'a Tensor },
    SeqCls { labels: &'a IntTensor, mask: &'a Tensor, head_w: &'a Tensor },
}

struct Opts {
    kind: String,
    causal: bool,
    ia3: bool,
    prefix: bool,
    prompt: Option<Tensor>, // materialized (P, d)
    want_w_grads: bool,
    want_a_grads: bool,
    want_logits: bool,
    /// emit per-site xs and eps-gradients (the decoupled outputs);
    /// coupled graphs skip the copies
    want_xs: bool,
    need_back: bool,
}

impl Opts {
    fn new(kind: &str) -> Opts {
        Opts {
            kind: kind.to_string(),
            causal: true,
            ia3: false,
            prefix: false,
            prompt: None,
            want_w_grads: false,
            want_a_grads: false,
            want_logits: false,
            want_xs: false,
            need_back: true,
        }
    }
}

#[derive(Default)]
struct RunOut {
    loss: f32,
    acc: f32,
    xs: Vec<Tensor>,
    gq: Vec<Tensor>,
    gv: Vec<Tensor>,
    head_x: Option<Tensor>,
    head_g: Option<Tensor>,
    dhead_w: Option<Tensor>,
    wgrads: BTreeMap<String, Tensor>,
    agrads: BTreeMap<String, Tensor>,
    ia3_grads: BTreeMap<String, Tensor>,
    dprompt: Option<Tensor>,
    prefix_grads: BTreeMap<String, Tensor>,
    logits: Option<Tensor>, // (rows_with_loss, V)
}

struct LayerCache {
    xhat1: Tensor,
    rstd1: Vec<f32>,
    pre: Tensor, // LN1 output = every site's hidden input (rows, d)
    k_raw: Option<Tensor>,
    v2_raw: Option<Tensor>,
    heads_q: Vec<Tensor>, // B*H of (st, dh)
    heads_k: Vec<Tensor>, // B*H of (skv, dh)
    heads_v: Vec<Tensor>,
    probs: Vec<Tensor>, // B*H of (st, skv)
    att: Tensor,        // merged attention output (rows, d)
    xhat2: Tensor,
    rstd2: Vec<f32>,
    pre2: Tensor,
    z: Tensor,            // pre-relu FFN activation (rows, dff)
    mid: Tensor,          // relu(z), pre-IA3
    mid2: Option<Tensor>, // IA3-scaled mid (None when no IA3)
    pp: usize,
}

fn extract(t: &Tensor, row0: usize, nrows: usize, col0: usize, ncols: usize) -> Tensor {
    let (_, width) = t.dims2();
    let mut out = vec![0.0f32; nrows * ncols];
    for r in 0..nrows {
        let src = (row0 + r) * width + col0;
        out[r * ncols..(r + 1) * ncols].copy_from_slice(&t.data()[src..src + ncols]);
    }
    Tensor::new(vec![nrows, ncols], out)
}

fn add_at(dst: &mut Tensor, src: &Tensor, row0: usize, col0: usize) {
    let (_, width) = dst.dims2();
    let (nr, nc) = src.dims2();
    let dd = dst.data_mut();
    let sd = src.data();
    for r in 0..nr {
        let d0 = (row0 + r) * width + col0;
        for c in 0..nc {
            dd[d0 + c] += sd[r * nc + c];
        }
    }
}

/// hhat - h = g(x) for one site. None for kind "none".
pub(super) fn adapter_apply(
    kind: &str,
    a: &BTreeMap<&str, &Tensor>,
    prefix: &str,
    x: &Tensor,
) -> Result<Option<Tensor>> {
    let get = |suffix: &str| -> Result<&Tensor> {
        let key = format!("{prefix}.{suffix}");
        a.get(key.as_str())
            .copied()
            .ok_or_else(|| anyhow!("missing adapter tensor '{key}'"))
    };
    Ok(match kind {
        "none" => None,
        "lowrank" => {
            let (aa, bb) = (get("A")?, get("B")?);
            Some(tensor::matmul(&tensor::matmul(x, aa), bb))
        }
        "linear" => Some(tensor::matmul(x, get("W")?)),
        "mlp" => {
            let (w1, b1, w2, b2) = (get("W1")?, get("b1")?, get("W2")?, get("b2")?);
            let z = tensor::add_row(&tensor::matmul(x, w1), b1);
            let hmid = tensor::relu(&z);
            Some(tensor::add_row(&tensor::matmul(&hmid, w2), b2))
        }
        other => bail!("unknown adapter kind '{other}'"),
    })
}

/// Backward through one site adapter: returns the dx contribution and
/// (optionally) accumulates parameter gradients keyed `{prefix}.{name}`.
pub(super) fn adapter_back(
    kind: &str,
    a: &BTreeMap<&str, &Tensor>,
    prefix: &str,
    x: &Tensor,
    dout: &Tensor,
    mut grads: Option<&mut BTreeMap<String, Tensor>>,
) -> Result<Option<Tensor>> {
    let get = |suffix: &str| -> Result<&Tensor> {
        let key = format!("{prefix}.{suffix}");
        a.get(key.as_str())
            .copied()
            .ok_or_else(|| anyhow!("missing adapter tensor '{key}'"))
    };
    Ok(match kind {
        "none" => None,
        "lowrank" => {
            let (aa, bb) = (get("A")?, get("B")?);
            let gbt = tensor::matmul_nt(dout, bb); // (n, r)
            if let Some(g) = grads.as_deref_mut() {
                g.insert(format!("{prefix}.A"), tensor::matmul_tn(x, &gbt));
                g.insert(
                    format!("{prefix}.B"),
                    tensor::matmul_tn(&tensor::matmul(x, aa), dout),
                );
            }
            Some(tensor::matmul_nt(&gbt, aa))
        }
        "linear" => {
            let w = get("W")?;
            if let Some(g) = grads.as_deref_mut() {
                g.insert(format!("{prefix}.W"), tensor::matmul_tn(x, dout));
            }
            Some(tensor::matmul_nt(dout, w))
        }
        "mlp" => {
            let (w1, b1, w2) = (get("W1")?, get("b1")?, get("W2")?);
            let z = tensor::add_row(&tensor::matmul(x, w1), b1);
            let hmid = tensor::relu(&z);
            let mut dz = tensor::matmul_nt(dout, w2);
            kernels::relu_mask(&mut dz, &z);
            if let Some(g) = grads.as_deref_mut() {
                g.insert(format!("{prefix}.W2"), tensor::matmul_tn(&hmid, dout));
                g.insert(format!("{prefix}.b2"), tensor::col_sum(dout));
                g.insert(format!("{prefix}.W1"), tensor::matmul_tn(x, &dz));
                g.insert(format!("{prefix}.b1"), tensor::col_sum(&dz));
            }
            Some(tensor::matmul_nt(&dz, w1))
        }
        other => bail!("unknown adapter kind '{other}'"),
    })
}

/// The unified forward + backward pass.
fn lm_run(cfg: &SizeConfig, p: &Params, tokens: &IntTensor, task: &Task, opts: &Opts)
          -> Result<RunOut> {
    let d = cfg.d;
    let heads = cfg.heads;
    let hd = d / heads; // per-head width
    let layers = cfg.layers;
    let (bsz, s) = (tokens.shape()[0], tokens.shape()[1]);
    let pl = opts.prompt.as_ref().map(|t| t.dims2().0).unwrap_or(0);
    let st = s + pl;
    let rows = bsz * st;

    // ---- embedding (+ optional prompt prepend) ----
    let embed = p.w("embed")?;
    let pos = p.w("pos")?;
    let mut hdat = vec![0.0f32; rows * d];
    for b in 0..bsz {
        for t in 0..st {
            let dst = (b * st + t) * d;
            if t < pl {
                // lint:allow(panic-safety): pl > 0 only when a prompt tensor was supplied — the two travel together in FwdOpts
                let pr = opts.prompt.as_ref().unwrap();
                hdat[dst..dst + d].copy_from_slice(&pr.data()[t * d..(t + 1) * d]);
            } else {
                let tok = tokens.data()[b * s + (t - pl)] as usize;
                for j in 0..d {
                    hdat[dst + j] = embed.data()[tok * d + j] + pos.data()[(t - pl) * d + j];
                }
            }
        }
    }
    let mut h = Tensor::new(vec![rows, d], hdat);

    // ---- forward trunk ----
    let kind = opts.kind.as_str();
    let mut caches: Vec<LayerCache> = Vec::with_capacity(layers);
    for i in 0..layers {
        let (ln1g, ln1b) = (p.w(&format!("l{i}.ln1g"))?, p.w(&format!("l{i}.ln1b"))?);
        let (pre, xhat1, rstd1) = kernels::layernorm(&h, ln1g, ln1b);
        let wq = p.w(&format!("l{i}.wq"))?;
        let wk = p.w(&format!("l{i}.wk"))?;
        let wv = p.w(&format!("l{i}.wv"))?;
        let q = tensor::matmul(&pre, wq);
        let k0 = tensor::matmul(&pre, wk);
        let v0 = tensor::matmul(&pre, wv);
        let q2 = match adapter_apply(kind, &p.a, &format!("l{i}.q"), &pre)? {
            Some(delta) => tensor::add(&q, &delta),
            None => q,
        };
        let v2 = match adapter_apply(kind, &p.a, &format!("l{i}.v"), &pre)? {
            Some(delta) => tensor::add(&v0, &delta),
            None => v0,
        };
        let (k_s, v2_s, k_raw, v2_raw) = if opts.ia3 {
            let lk = p.ia3(&format!("l{i}.lk"))?;
            let lv = p.ia3(&format!("l{i}.lv"))?;
            (
                kernels::scale_cols(&k0, lk),
                kernels::scale_cols(&v2, lv),
                Some(k0),
                Some(v2),
            )
        } else {
            (k0, v2, None, None)
        };

        let pp = if opts.prefix { PREFIX_LEN } else { 0 };
        let skv = st + pp;
        // prefix K/V are materialized per example up front so the
        // per-head tasks below borrow only immutable state
        let prefix_kv: Option<Vec<(Tensor, Tensor)>> = if pp > 0 {
            let pk = p.prefix(&format!("l{i}.pk"))?;
            let pv = p.prefix(&format!("l{i}.pv"))?;
            Some(
                (0..bsz)
                    .map(|b| {
                        let kb = k_s.rows(b * st, (b + 1) * st);
                        let vb = v2_s.rows(b * st, (b + 1) * st);
                        (Tensor::cat_rows(&[pk, &kb]), Tensor::cat_rows(&[pv, &vb]))
                    })
                    .collect(),
            )
        } else {
            None
        };
        // heads are independent: fan the (batch, head) grid out across
        // the tensor-engine pool, then scatter serially (deterministic
        // accumulation order)
        let causal = opts.causal;
        let head_runs = pool::parallel_map(bsz * heads, |idx| {
            let (b, hh) = (idx / heads, idx % heads);
            let (ksrc, vsrc, row_base) = match &prefix_kv {
                Some(kv) => (&kv[b].0, &kv[b].1, 0usize),
                None => (&k_s, &v2_s, b * st),
            };
            let qh = extract(&q2, b * st, st, hh * hd, hd);
            let kh = extract(ksrc, row_base, skv, hh * hd, hd);
            let vh = extract(vsrc, row_base, skv, hh * hd, hd);
            let (o, pr) = kernels::attention_head(&qh, &kh, &vh, causal, pp);
            (qh, kh, vh, o, pr)
        });
        let mut heads_q = Vec::with_capacity(bsz * heads);
        let mut heads_k = Vec::with_capacity(bsz * heads);
        let mut heads_v = Vec::with_capacity(bsz * heads);
        let mut probs = Vec::with_capacity(bsz * heads);
        let mut att = Tensor::zeros(&[rows, d]);
        for (idx, (qh, kh, vh, o, pr)) in head_runs.into_iter().enumerate() {
            let (b, hh) = (idx / heads, idx % heads);
            add_at(&mut att, &o, b * st, hh * hd);
            heads_q.push(qh);
            heads_k.push(kh);
            heads_v.push(vh);
            probs.push(pr);
        }

        let wo = p.w(&format!("l{i}.wo"))?;
        let h_mid = tensor::add(&h, &tensor::matmul(&att, wo));
        let (ln2g, ln2b) = (p.w(&format!("l{i}.ln2g"))?, p.w(&format!("l{i}.ln2b"))?);
        let (pre2, xhat2, rstd2) = kernels::layernorm(&h_mid, ln2g, ln2b);
        let (w1, b1) = (p.w(&format!("l{i}.w1"))?, p.w(&format!("l{i}.b1"))?);
        let (w2, b2) = (p.w(&format!("l{i}.w2"))?, p.w(&format!("l{i}.b2"))?);
        let z = tensor::add_row(&tensor::matmul(&pre2, w1), b1);
        let mid = tensor::relu(&z);
        let mid2 = if opts.ia3 {
            Some(kernels::scale_cols(&mid, p.ia3(&format!("l{i}.lff"))?))
        } else {
            None
        };
        let ffn = tensor::add_row(
            &tensor::matmul(mid2.as_ref().unwrap_or(&mid), w2),
            b2,
        );
        h = tensor::add(&h_mid, &ffn);
        caches.push(LayerCache {
            xhat1, rstd1, pre, k_raw, v2_raw, heads_q, heads_k, heads_v, probs,
            att, xhat2, rstd2, pre2, z, mid, mid2, pp,
        });
    }
    let (lnfg, lnfb) = (p.w("lnfg")?, p.w("lnfb")?);
    let (hf, xhatf, rstdf) = kernels::layernorm(&h, lnfg, lnfb);

    // ---- head + loss (+ its backward into dhf) ----
    let mut out = RunOut::default();
    let mut dhf = Tensor::zeros(&[rows, d]);
    let mut embed_head_grad: Option<Tensor> = None;
    match task {
        Task::Clm { targets, mask } => {
            // rows that carry loss: positions pl.. of each example
            let hf_sl = if pl > 0 {
                let parts: Vec<Tensor> =
                    (0..bsz).map(|b| hf.rows(b * st + pl, (b + 1) * st)).collect();
                let refs: Vec<&Tensor> = parts.iter().collect();
                Tensor::cat_rows(&refs)
            } else {
                hf.clone()
            };
            let logits = tensor::matmul_nt(&hf_sl, embed); // (B*S, V)
            if opts.want_logits && !opts.need_back {
                // pure inference (lm_fwd): skip the loss entirely
                out.logits = Some(logits);
            } else {
                let (loss, acc, dlogits) =
                    kernels::masked_ce(&logits, targets.data(), mask.data());
                out.loss = loss;
                out.acc = acc;
                if opts.want_logits {
                    out.logits = Some(logits);
                }
                if opts.need_back {
                    let dhf_sl = tensor::matmul(&dlogits, embed); // (B*S, d)
                    for b in 0..bsz {
                        let part = dhf_sl.rows(b * s, (b + 1) * s);
                        add_at(&mut dhf, &part, b * st + pl, 0);
                    }
                    if opts.want_w_grads {
                        embed_head_grad = Some(tensor::matmul_tn(&dlogits, &hf_sl));
                    }
                }
            }
        }
        Task::SeqCls { labels, mask, head_w } => {
            let (labels, mask, head_w): (&IntTensor, &Tensor, &Tensor) =
                (*labels, *mask, *head_w);
            // pooled = sum(hf * pmask) / denom ; prompt positions count
            let mut pooled = vec![0.0f32; bsz * d];
            let mut denom = vec![0.0f32; bsz];
            let pm = |b: usize, t: usize| -> f32 {
                if t < pl { 1.0 } else { mask.data()[b * s + (t - pl)] }
            };
            for b in 0..bsz {
                for t in 0..st {
                    denom[b] += pm(b, t);
                }
                denom[b] = denom[b].max(1.0);
                for t in 0..st {
                    let w = pm(b, t) / denom[b];
                    if w != 0.0 {
                        let src = (b * st + t) * d;
                        for j in 0..d {
                            pooled[b * d + j] += hf.data()[src + j] * w;
                        }
                    }
                }
            }
            let pooled = Tensor::new(vec![bsz, d], pooled);
            let logits = tensor::matmul(&pooled, head_w); // (B, C)
            let (loss, acc, dlogits) = kernels::ce_labels(&logits, labels.data());
            out.loss = loss;
            out.acc = acc;
            if opts.need_back {
                out.dhead_w = Some(tensor::matmul_tn(&pooled, &dlogits));
                let dpooled = tensor::matmul_nt(&dlogits, head_w); // (B, d)
                let dd = dhf.data_mut();
                for b in 0..bsz {
                    for t in 0..st {
                        let w = pm(b, t) / denom[b];
                        if w != 0.0 {
                            let dst = (b * st + t) * d;
                            for j in 0..d {
                                dd[dst + j] += dpooled.data()[b * d + j] * w;
                            }
                        }
                    }
                }
            }
            out.head_x = Some(pooled);
            out.head_g = Some(dlogits);
        }
    }

    if opts.want_xs {
        out.xs = caches
            .iter()
            .map(|c| c.pre.clone().reshape(&[bsz, st, d]))
            .collect();
    }

    if !opts.need_back {
        return Ok(out);
    }

    // ---- backward trunk ----
    let (dh0, dgf, dbf) = kernels::layernorm_back(&dhf, &xhatf, &rstdf, lnfg);
    if opts.want_w_grads {
        out.wgrads.insert("lnfg".to_string(), dgf);
        out.wgrads.insert("lnfb".to_string(), dbf);
    }
    let mut dh = dh0;
    let mut gq: Vec<Option<Tensor>> = (0..layers).map(|_| None).collect();
    let mut gv: Vec<Option<Tensor>> = (0..layers).map(|_| None).collect();
    for i in (0..layers).rev() {
        let c = &caches[i];
        let (w1, w2) = (p.w(&format!("l{i}.w1"))?, p.w(&format!("l{i}.w2"))?);
        // FFN block
        if opts.want_w_grads {
            out.wgrads.insert(format!("l{i}.b2"), tensor::col_sum(&dh));
            out.wgrads.insert(
                format!("l{i}.w2"),
                tensor::matmul_tn(c.mid2.as_ref().unwrap_or(&c.mid), &dh),
            );
        }
        let dmid2 = tensor::matmul_nt(&dh, w2);
        let dmid = if opts.ia3 {
            let lff = p.ia3(&format!("l{i}.lff"))?;
            out.ia3_grads
                .insert(format!("l{i}.lff"), kernels::col_dot(&dmid2, &c.mid));
            kernels::scale_cols(&dmid2, lff)
        } else {
            dmid2
        };
        let mut dz = dmid;
        kernels::relu_mask(&mut dz, &c.z);
        if opts.want_w_grads {
            out.wgrads
                .insert(format!("l{i}.w1"), tensor::matmul_tn(&c.pre2, &dz));
            out.wgrads.insert(format!("l{i}.b1"), tensor::col_sum(&dz));
        }
        let dpre2 = tensor::matmul_nt(&dz, w1);
        let ln2g = p.w(&format!("l{i}.ln2g"))?;
        let (dx2, dg2, db2) = kernels::layernorm_back(&dpre2, &c.xhat2, &c.rstd2, ln2g);
        if opts.want_w_grads {
            out.wgrads.insert(format!("l{i}.ln2g"), dg2);
            out.wgrads.insert(format!("l{i}.ln2b"), db2);
        }
        dh = tensor::add(&dh, &dx2);

        // attention block
        let wo = p.w(&format!("l{i}.wo"))?;
        if opts.want_w_grads {
            out.wgrads
                .insert(format!("l{i}.wo"), tensor::matmul_tn(&c.att, &dh));
        }
        let datt = tensor::matmul_nt(&dh, wo);
        let pp = c.pp;
        let skv = st + pp;
        let mut dq2 = Tensor::zeros(&[rows, d]);
        let mut dk2 = Tensor::zeros(&[rows, d]);
        let mut dv2 = Tensor::zeros(&[rows, d]);
        let mut dpk = Tensor::zeros(&[pp.max(1), d]); // unused when pp == 0
        let mut dpv = Tensor::zeros(&[pp.max(1), d]);
        // backward twin of the forward fan-out: per-head gradients run
        // across the pool, the scatter stays serial and in-order
        let head_grads = pool::parallel_map(bsz * heads, |idx| {
            let (b, hh) = (idx / heads, idx % heads);
            let dohead = extract(&datt, b * st, st, hh * hd, hd);
            kernels::attention_head_back(
                &dohead,
                &c.heads_q[idx],
                &c.heads_k[idx],
                &c.heads_v[idx],
                &c.probs[idx],
            )
        });
        for (idx, (dqh, dkh, dvh)) in head_grads.into_iter().enumerate() {
            let (b, hh) = (idx / heads, idx % heads);
            add_at(&mut dq2, &dqh, b * st, hh * hd);
            if pp > 0 {
                add_at(&mut dpk, &extract(&dkh, 0, pp, 0, hd), 0, hh * hd);
                add_at(&mut dpv, &extract(&dvh, 0, pp, 0, hd), 0, hh * hd);
                add_at(&mut dk2, &extract(&dkh, pp, st, 0, hd), b * st, hh * hd);
                add_at(&mut dv2, &extract(&dvh, pp, st, 0, hd), b * st, hh * hd);
            } else {
                debug_assert_eq!(skv, st);
                add_at(&mut dk2, &dkh, b * st, hh * hd);
                add_at(&mut dv2, &dvh, b * st, hh * hd);
            }
        }
        if pp > 0 {
            out.prefix_grads.insert(format!("l{i}.pk"), dpk);
            out.prefix_grads.insert(format!("l{i}.pv"), dpv);
        }
        if opts.want_xs {
            gq[i] = Some(dq2.clone());
        }
        if opts.ia3 {
            let lk = p.ia3(&format!("l{i}.lk"))?;
            let lv = p.ia3(&format!("l{i}.lv"))?;
            out.ia3_grads.insert(
                format!("l{i}.lk"),
                // lint:allow(panic-safety): the forward pass caches k_raw whenever opts.ia3 is set — same flag that guards this branch
                kernels::col_dot(&dk2, c.k_raw.as_ref().unwrap()),
            );
            dk2 = kernels::scale_cols(&dk2, lk);
            out.ia3_grads.insert(
                format!("l{i}.lv"),
                // lint:allow(panic-safety): the forward pass caches v2_raw whenever opts.ia3 is set — same flag that guards this branch
                kernels::col_dot(&dv2, c.v2_raw.as_ref().unwrap()),
            );
            dv2 = kernels::scale_cols(&dv2, lv);
        }
        if opts.want_xs {
            gv[i] = Some(dv2.clone());
        }

        let wq = p.w(&format!("l{i}.wq"))?;
        let wk = p.w(&format!("l{i}.wk"))?;
        let wv = p.w(&format!("l{i}.wv"))?;
        if opts.want_w_grads {
            out.wgrads
                .insert(format!("l{i}.wq"), tensor::matmul_tn(&c.pre, &dq2));
            out.wgrads
                .insert(format!("l{i}.wk"), tensor::matmul_tn(&c.pre, &dk2));
            out.wgrads
                .insert(format!("l{i}.wv"), tensor::matmul_tn(&c.pre, &dv2));
        }
        let mut dpre = tensor::matmul_nt(&dq2, wq);
        tensor::axpy(&mut dpre, 1.0, &tensor::matmul_nt(&dk2, wk));
        tensor::axpy(&mut dpre, 1.0, &tensor::matmul_nt(&dv2, wv));
        let mut agrads = if opts.want_a_grads { Some(&mut out.agrads) } else { None };
        if let Some(dxa) = adapter_back(kind, &p.a, &format!("l{i}.q"), &c.pre, &dq2,
                                        agrads.as_deref_mut())? {
            tensor::axpy(&mut dpre, 1.0, &dxa);
        }
        if let Some(dxa) = adapter_back(kind, &p.a, &format!("l{i}.v"), &c.pre, &dv2,
                                        agrads.as_deref_mut())? {
            tensor::axpy(&mut dpre, 1.0, &dxa);
        }
        let ln1g = p.w(&format!("l{i}.ln1g"))?;
        let (dx1, dg1, db1) = kernels::layernorm_back(&dpre, &c.xhat1, &c.rstd1, ln1g);
        if opts.want_w_grads {
            out.wgrads.insert(format!("l{i}.ln1g"), dg1);
            out.wgrads.insert(format!("l{i}.ln1b"), db1);
        }
        dh = tensor::add(&dh, &dx1);
    }
    if opts.want_xs {
        out.gq = gq
            .into_iter()
            // lint:allow(panic-safety): the layer loop above fills every gq slot when opts.want_xs is set
            .map(|t| t.unwrap().reshape(&[bsz, st, d]))
            .collect();
        out.gv = gv
            .into_iter()
            // lint:allow(panic-safety): the layer loop above fills every gv slot when opts.want_xs is set
            .map(|t| t.unwrap().reshape(&[bsz, st, d]))
            .collect();
    }

    // ---- embedding backward ----
    if pl > 0 {
        let mut dprompt = Tensor::zeros(&[pl, d]);
        for b in 0..bsz {
            let part = dh.rows(b * st, b * st + pl);
            add_at(&mut dprompt, &part, 0, 0);
        }
        out.dprompt = Some(dprompt);
    }
    if opts.want_w_grads {
        let mut dpos = vec![0.0f32; cfg.seq * d];
        let mut dembed = embed_head_grad
            .unwrap_or_else(|| Tensor::zeros(&[cfg.vocab, d]));
        let de = dembed.data_mut();
        for b in 0..bsz {
            for t in 0..s {
                let src = (b * st + pl + t) * d;
                let tok = tokens.data()[b * s + t] as usize;
                for j in 0..d {
                    dpos[t * d + j] += dh.data()[src + j];
                    de[tok * d + j] += dh.data()[src + j];
                }
            }
        }
        out.wgrads
            .insert("pos".to_string(), Tensor::new(vec![cfg.seq, d], dpos));
        out.wgrads.insert("embed".to_string(), dembed);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// artifact-level wrappers
// ---------------------------------------------------------------------------

fn partition<'a>(
    cfg: &SizeConfig,
    named: &Named<'a>,
    data_names: &[&str],
) -> (Params<'a>, BTreeMap<&'a str, &'a Tensor>) {
    let wnames: BTreeSet<String> = builtin::lm_param_shapes(cfg)
        .into_iter()
        .map(|(n, _)| n)
        .collect();
    let mut p = Params::default();
    let mut rest: BTreeMap<&'a str, &'a Tensor> = BTreeMap::new();
    for (k, v) in named.iter() {
        let k: &'a str = *k;
        let v: &'a Value = *v;
        if data_names.contains(&k) {
            continue;
        }
        if let Value::F32(t) = v {
            if wnames.contains(k) {
                p.w.insert(k, t);
            } else {
                rest.insert(k, t);
            }
        }
    }
    (p, rest)
}

fn scalar(v: f32) -> Value {
    Value::F32(Tensor::scalar(v))
}

/// The decoupled ColA server graph: `lm_fwdbwd_*` / `seqcls_fwdbwd_*`.
pub(super) fn decoupled(
    m: &Manifest,
    size: &str,
    kind: &str,
    named: &Named,
    seqcls: bool,
    need_back: bool,
) -> Result<BTreeMap<String, Value>> {
    let cfg = m.size(size)?;
    let tokens = i32_in(named, "tokens")?;
    let mask = f32_in(named, "mask")?;
    let data_names = ["tokens", "targets", "labels", "mask", "head.W"];
    let (mut p, rest) = partition(cfg, named, &data_names);
    p.a = rest;
    let task = if seqcls {
        Task::SeqCls {
            labels: i32_in(named, "labels")?,
            mask,
            head_w: f32_in(named, "head.W")?,
        }
    } else {
        Task::Clm { targets: i32_in(named, "targets")?, mask }
    };
    let mut opts = Opts::new(kind);
    opts.causal = !seqcls;
    opts.need_back = need_back;
    opts.want_xs = need_back;
    let out = lm_run(cfg, &p, tokens, &task, &opts)?;

    let mut res = BTreeMap::new();
    res.insert("loss".to_string(), scalar(out.loss));
    res.insert("acc".to_string(), scalar(out.acc));
    if need_back {
        for (i, x) in out.xs.into_iter().enumerate() {
            res.insert(format!("l{i}.x"), Value::F32(x));
        }
        for (i, g) in out.gq.into_iter().enumerate() {
            res.insert(format!("l{i}.gq"), Value::F32(g));
        }
        for (i, g) in out.gv.into_iter().enumerate() {
            res.insert(format!("l{i}.gv"), Value::F32(g));
        }
    } else {
        // need_back == "some wanted output index >= 2", so none of the
        // adaptation outputs are fetched: cheap placeholders, not
        // full-size zero tensors.
        for i in 0..cfg.layers {
            res.insert(format!("l{i}.x"), Value::F32(Tensor::zeros(&[1])));
            res.insert(format!("l{i}.gq"), Value::F32(Tensor::zeros(&[1])));
            res.insert(format!("l{i}.gv"), Value::F32(Tensor::zeros(&[1])));
        }
    }
    if seqcls {
        let bsz = tokens.shape()[0];
        res.insert(
            "head.x".to_string(),
            Value::F32(out.head_x.unwrap_or_else(|| Tensor::zeros(&[bsz, cfg.d]))),
        );
        res.insert(
            "head.g".to_string(),
            Value::F32(
                out.head_g
                    .unwrap_or_else(|| Tensor::zeros(&[bsz, m.n_classes_seqcls])),
            ),
        );
    }
    Ok(res)
}

/// Coupled-baseline graphs: `coupled_clm_*` / `coupled_seqcls_*`.
pub(super) fn coupled(
    m: &Manifest,
    size: &str,
    method: &str,
    named: &Named,
    seqcls: bool,
    need_back: bool,
) -> Result<BTreeMap<String, Value>> {
    let cfg = m.size(size)?;
    let tokens = i32_in(named, "tokens")?;
    let mask = f32_in(named, "mask")?;
    let n_classes = if seqcls { Some(m.n_classes_seqcls) } else { None };
    let tun_shapes = builtin::tunable_shapes(cfg, method, n_classes);

    let data_names = ["tokens", "targets", "labels", "mask", "head.W"];
    let (mut p, rest) = partition(cfg, named, &data_names);
    let mut opts = Opts::new("none");
    opts.causal = !seqcls;
    opts.need_back = need_back;

    // Per-method wiring of the non-weight inputs.
    let mut ptune: Option<(Tensor, Tensor)> = None; // (z, mid) caches for chain
    match method {
        "ft" => {
            // FT: the frozen weights are NOT inputs; the tunables (by lm
            // names) ARE the weights. partition() already routed them
            // into p.w because the names match.
            opts.want_w_grads = need_back;
        }
        "lora" => {
            opts.kind = "lowrank".to_string();
            opts.want_a_grads = need_back;
            p.a = rest;
        }
        "ia3" => {
            opts.ia3 = true;
            p.ia3 = rest;
        }
        "prompt" => {
            opts.prompt = Some(f32_in(named, "prompt")?.clone());
        }
        "ptuning" => {
            let anchor = f32_in(named, "anchor")?;
            let w1 = f32_in(named, "pt.W1")?;
            let b1 = f32_in(named, "pt.b1")?;
            let w2 = f32_in(named, "pt.W2")?;
            let b2 = f32_in(named, "pt.b2")?;
            let z = tensor::add_row(&tensor::matmul(anchor, w1), b1);
            let mid = tensor::relu(&z);
            opts.prompt = Some(tensor::add_row(&tensor::matmul(&mid, w2), b2));
            ptune = Some((z, mid));
        }
        "prefix" => {
            opts.prefix = true;
            p.prefix = rest;
        }
        other => bail!("unknown coupled method '{other}'"),
    }

    let task = if seqcls {
        Task::SeqCls {
            labels: i32_in(named, "labels")?,
            mask,
            head_w: f32_in(named, "head.W")?,
        }
    } else {
        Task::Clm { targets: i32_in(named, "targets")?, mask }
    };
    let out = lm_run(cfg, &p, tokens, &task, &opts)?;

    let mut res = BTreeMap::new();
    res.insert("loss".to_string(), scalar(out.loss));
    res.insert("acc".to_string(), scalar(out.acc));

    // Collect tunable gradients under their manifest output names.
    let mut grads: BTreeMap<String, Tensor> = BTreeMap::new();
    match method {
        "ft" => grads.extend(out.wgrads),
        "lora" => grads.extend(out.agrads),
        "ia3" => grads.extend(out.ia3_grads),
        "prompt" => {
            if let Some(dp) = out.dprompt {
                grads.insert("prompt".to_string(), dp);
            }
        }
        "ptuning" => {
            if let Some(dpr) = out.dprompt {
                // lint:allow(panic-safety): the ptuning cache is built unconditionally on this method's forward path
                let (z, mid) = ptune.as_ref().unwrap();
                let anchor = f32_in(named, "anchor")?;
                let w1 = f32_in(named, "pt.W1")?;
                let w2 = f32_in(named, "pt.W2")?;
                grads.insert("pt.W2".to_string(), tensor::matmul_tn(mid, &dpr));
                grads.insert("pt.b2".to_string(), tensor::col_sum(&dpr));
                let mut dz = tensor::matmul_nt(&dpr, w2);
                kernels::relu_mask(&mut dz, z);
                grads.insert("pt.W1".to_string(), tensor::matmul_tn(anchor, &dz));
                grads.insert("pt.b1".to_string(), tensor::col_sum(&dz));
                grads.insert("anchor".to_string(), tensor::matmul_nt(&dz, w1));
            }
        }
        "prefix" => grads.extend(out.prefix_grads),
        // lint:allow(panic-safety): method names come from the compiled-in baseline list matched exhaustively above
        _ => unreachable!(),
    }
    if seqcls {
        if let Some(dw) = out.dhead_w {
            grads.insert("head.W".to_string(), dw);
        }
    }
    for (name, shape) in &tun_shapes {
        let g = match grads.remove(name) {
            Some(g) => g,
            // eval path: gradients were not computed and are not fetched
            None if !need_back => Tensor::zeros(shape),
            // a missing gradient with the backward run is name drift —
            // zeros here would train silently frozen parameters
            None => bail!("coupled {method}: backward produced no gradient for '{name}'"),
        };
        res.insert(format!("d.{name}"), Value::F32(g));
    }
    Ok(res)
}

/// Inference graph: `lm_fwd_*` — weights + tokens -> logits.
pub(super) fn lm_fwd(m: &Manifest, size: &str, named: &Named) -> Result<BTreeMap<String, Value>> {
    let cfg = m.size(size)?;
    let tokens = i32_in(named, "tokens")?;
    let (bsz, s) = (tokens.shape()[0], tokens.shape()[1]);
    let (p, _) = partition(cfg, named, &["tokens"]);
    let zeros_t = IntTensor::new(vec![bsz, s], vec![0; bsz * s]);
    let zeros_m = Tensor::zeros(&[bsz, s]);
    let task = Task::Clm { targets: &zeros_t, mask: &zeros_m };
    let mut opts = Opts::new("none");
    opts.need_back = false;
    opts.want_logits = true;
    let out = lm_run(cfg, &p, tokens, &task, &opts)?;
    let logits = out
        .logits
        .ok_or_else(|| anyhow!("lm_fwd: logits missing"))?
        .reshape(&[bsz, s, cfg.vocab]);
    let mut res = BTreeMap::new();
    res.insert("logits".to_string(), Value::F32(logits));
    Ok(res)
}
