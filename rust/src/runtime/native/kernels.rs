//! Native twins of the Pallas kernels (`python/compile/kernels/ref.py`)
//! plus their backward passes. These are the primitives the native
//! executor composes into full artifact graphs; the parity tests in
//! `rust/tests/native_backend.rs` pin them against independent naive
//! implementations and hand-computed fixtures.
//!
//! Gradient conventions follow the numpy reference derivation (validated
//! against central finite differences across every composition used by
//! the artifact graphs).

use crate::tensor::{self, Tensor};

/// Row-wise layer norm, eps = 1e-5 (matches `layernorm_ref`).
/// Returns `(y, xhat, rstd)` — the caches the backward needs.
pub fn layernorm(x: &Tensor, g: &Tensor, b: &Tensor) -> (Tensor, Tensor, Vec<f32>) {
    let (n, d) = x.dims2();
    let gd = g.data();
    let bd = b.data();
    let mut y = vec![0.0f32; n * d];
    let mut xhat = vec![0.0f32; n * d];
    let mut rstd = vec![0.0f32; n];
    for i in 0..n {
        let row = &x.data()[i * d..(i + 1) * d];
        let mu: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let rs = 1.0 / (var + 1e-5).sqrt();
        rstd[i] = rs;
        for j in 0..d {
            let xh = (row[j] - mu) * rs;
            xhat[i * d + j] = xh;
            y[i * d + j] = xh * gd[j] + bd[j];
        }
    }
    (
        Tensor::new(vec![n, d], y),
        Tensor::new(vec![n, d], xhat),
        rstd,
    )
}

/// Backward of [`layernorm`]. Returns `(dx, dg, db)`.
pub fn layernorm_back(
    dy: &Tensor,
    xhat: &Tensor,
    rstd: &[f32],
    g: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let (n, d) = dy.dims2();
    let gd = g.data();
    let mut dx = vec![0.0f32; n * d];
    let mut dg = vec![0.0f32; d];
    let mut db = vec![0.0f32; d];
    for i in 0..n {
        let dyr = &dy.data()[i * d..(i + 1) * d];
        let xhr = &xhat.data()[i * d..(i + 1) * d];
        let mut m1 = 0.0f32; // mean(dxhat)
        let mut m2 = 0.0f32; // mean(dxhat * xhat)
        for j in 0..d {
            dg[j] += dyr[j] * xhr[j];
            db[j] += dyr[j];
            let dxh = dyr[j] * gd[j];
            m1 += dxh;
            m2 += dxh * xhr[j];
        }
        m1 /= d as f32;
        m2 /= d as f32;
        for j in 0..d {
            let dxh = dyr[j] * gd[j];
            dx[i * d + j] = rstd[i] * (dxh - m1 - xhr[j] * m2);
        }
    }
    (
        Tensor::new(vec![n, d], dx),
        Tensor::new(vec![d], dg),
        Tensor::new(vec![d], db),
    )
}

/// Single-head scaled dot-product attention (matches `attention_ref`).
/// `q`: (s, dh); `k`/`v`: (skv, dh) with `skv = s + p_prefix`; prefix
/// positions are always attendable under the causal mask. Returns
/// `(output, probs)`.
pub fn attention_head(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    causal: bool,
    p_prefix: usize,
) -> (Tensor, Tensor) {
    let (s, dh) = q.dims2();
    let (skv, _) = k.dims2();
    let scale = 1.0 / (dh as f32).sqrt();
    let mut logits = tensor::matmul_nt(q, k);
    tensor::scale_mut(&mut logits, scale);
    let ld = logits.data_mut();
    if causal {
        for i in 0..s {
            for j in 0..skv {
                if j > i + p_prefix {
                    // -inf, not f32::MIN: exp(-inf - m) is exactly 0 for
                    // any finite m, so masked positions can never leak
                    // probability mass however the unmasked logits scale.
                    // (With the old f32::MIN sentinel, a row whose live
                    // logits underflowed to -inf made the *sentinel* the
                    // row max and softmax attended the masked future.)
                    ld[i * skv + j] = f32::NEG_INFINITY;
                }
            }
        }
    }
    // numerically stable row softmax; a row whose every logit is -inf
    // (all attendable positions underflowed) degrades to all-zero probs
    // instead of NaN. Dispatched through tensor::simd — the AVX2 tier
    // vectorizes the shift-subtract and normalize passes while exp and
    // the ordered row-sum stay scalar, so it is bit-identical to the
    // pinned scalar kernel.
    for i in 0..s {
        tensor::simd::softmax_row(&mut ld[i * skv..(i + 1) * skv]);
    }
    let p = logits;
    (tensor::matmul(&p, v), p)
}

/// Backward of [`attention_head`] given cached probs. Masked positions
/// carry p = 0, so the softmax backward zeroes them automatically.
/// Returns `(dq, dk, dv)`.
pub fn attention_head_back(
    dout: &Tensor,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    p: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let (s, dh) = q.dims2();
    let (skv, _) = k.dims2();
    let scale = 1.0 / (dh as f32).sqrt();
    let dv = tensor::matmul_tn(p, dout); // (skv, dh)
    let dp = tensor::matmul_nt(dout, v); // (s, skv)
    let mut dlog = vec![0.0f32; s * skv];
    for i in 0..s {
        let pr = &p.data()[i * skv..(i + 1) * skv];
        let dpr = &dp.data()[i * skv..(i + 1) * skv];
        let dot: f32 = pr.iter().zip(dpr).map(|(a, b)| a * b).sum();
        for j in 0..skv {
            dlog[i * skv + j] = pr[j] * (dpr[j] - dot);
        }
    }
    let dlog = Tensor::new(vec![s, skv], dlog);
    let mut dq = tensor::matmul(&dlog, k);
    tensor::scale_mut(&mut dq, scale);
    let mut dk = tensor::matmul_tn(&dlog, q);
    tensor::scale_mut(&mut dk, scale);
    (dq, dk, dv)
}

/// Mean masked cross-entropy + teacher-forced token accuracy over rows.
/// `logits`: (n, v); `targets`/`mask`: length n. Returns
/// `(loss, acc, dlogits)` with `dlogits = mask/M * (softmax - onehot)`.
pub fn masked_ce(logits: &Tensor, targets: &[i32], mask: &[f32]) -> (f32, f32, Tensor) {
    let (n, v) = logits.dims2();
    assert_eq!(targets.len(), n);
    assert_eq!(mask.len(), n);
    let msum: f32 = mask.iter().sum::<f32>().max(1.0);
    let mut dlogits = vec![0.0f32; n * v];
    let mut loss = 0.0f32;
    let mut hits = 0.0f32;
    for i in 0..n {
        let row = &logits.data()[i * v..(i + 1) * v];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for x in row {
            sum += (x - m).exp();
        }
        let lse = m + sum.ln();
        let t = targets[i] as usize;
        let w = mask[i] / msum;
        loss -= (row[t] - lse) * mask[i];
        let mut argmax = 0usize;
        let mut best = f32::NEG_INFINITY;
        for (j, x) in row.iter().enumerate() {
            let pj = (x - lse).exp();
            dlogits[i * v + j] = pj * w;
            if *x > best {
                best = *x;
                argmax = j;
            }
        }
        dlogits[i * v + t] -= w;
        if argmax == t {
            hits += mask[i];
        }
    }
    (loss / msum, hits / msum, Tensor::new(vec![n, v], dlogits))
}

/// Mean cross-entropy over class labels + accuracy. `logits`: (b, c).
/// Returns `(loss, acc, dlogits)` with `dlogits = (softmax - onehot)/b`.
pub fn ce_labels(logits: &Tensor, labels: &[i32]) -> (f32, f32, Tensor) {
    let (b, c) = logits.dims2();
    assert_eq!(labels.len(), b);
    let mut dlogits = vec![0.0f32; b * c];
    let mut loss = 0.0f32;
    let mut hits = 0usize;
    for i in 0..b {
        let row = &logits.data()[i * c..(i + 1) * c];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for x in row {
            sum += (x - m).exp();
        }
        let lse = m + sum.ln();
        let t = labels[i] as usize;
        loss -= row[t] - lse;
        let mut argmax = 0usize;
        let mut best = f32::NEG_INFINITY;
        for (j, x) in row.iter().enumerate() {
            dlogits[i * c + j] = (x - lse).exp() / b as f32;
            if *x > best {
                best = *x;
                argmax = j;
            }
        }
        dlogits[i * c + t] -= 1.0 / b as f32;
        if argmax == t {
            hits += 1;
        }
    }
    (
        loss / b as f32,
        hits as f32 / b as f32,
        Tensor::new(vec![b, c], dlogits),
    )
}

/// Multiply each column j of `a` by `s[j]`, returning a new tensor.
pub fn scale_cols(a: &Tensor, s: &Tensor) -> Tensor {
    let (n, d) = a.dims2();
    assert_eq!(s.len(), d);
    let sd = s.data();
    let mut out = a.data().to_vec();
    for i in 0..n {
        for j in 0..d {
            out[i * d + j] *= sd[j];
        }
    }
    Tensor::new(vec![n, d], out)
}

/// Column-sum of the elementwise product of two (n, d) tensors -> (d,).
/// (The IA3 scaling-vector gradient contraction.)
pub fn col_dot(a: &Tensor, b: &Tensor) -> Tensor {
    let (n, d) = a.dims2();
    assert_eq!(a.shape(), b.shape());
    let mut out = vec![0.0f32; d];
    for i in 0..n {
        for j in 0..d {
            out[j] += a.data()[i * d + j] * b.data()[i * d + j];
        }
    }
    Tensor::new(vec![d], out)
}

/// Zero `d` wherever the matching `gate` entry is <= 0 (ReLU backward).
pub fn relu_mask(d: &mut Tensor, gate: &Tensor) {
    assert_eq!(d.shape(), gate.shape());
    for (x, g) in d.data_mut().iter_mut().zip(gate.data()) {
        if *g <= 0.0 {
            *x = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn layernorm_matches_naive() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[5, 8], 1.0, &mut rng);
        let g = Tensor::randn(&[8], 0.3, &mut rng);
        let b = Tensor::randn(&[8], 0.3, &mut rng);
        let (y, _, _) = layernorm(&x, &g, &b);
        for i in 0..5 {
            let row = &x.data()[i * 8..(i + 1) * 8];
            let mu: f32 = row.iter().sum::<f32>() / 8.0;
            let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / 8.0;
            for j in 0..8 {
                let want = (row[j] - mu) / (var + 1e-5).sqrt() * g.data()[j] + b.data()[j];
                assert!((y.data()[i * 8 + j] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn layernorm_back_finite_difference() {
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[3, 6], 1.0, &mut rng);
        let g = Tensor::randn(&[6], 0.5, &mut rng);
        let b = Tensor::randn(&[6], 0.5, &mut rng);
        let w = Tensor::randn(&[3, 6], 1.0, &mut rng); // loss = <y, w>
        let (_, xhat, rstd) = layernorm(&x, &g, &b);
        let (dx, dg, db) = layernorm_back(&w, &xhat, &rstd, &g);
        let loss = |x: &Tensor, g: &Tensor, b: &Tensor| -> f32 {
            let (y, _, _) = layernorm(x, g, b);
            y.data().iter().zip(w.data()).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-3;
        for idx in [0usize, 7, 17] {
            let mut xp = x.clone();
            xp.data_mut()[idx] += eps;
            let mut xm = x.clone();
            xm.data_mut()[idx] -= eps;
            let fd = (loss(&xp, &g, &b) - loss(&xm, &g, &b)) / (2.0 * eps);
            assert!((fd - dx.data()[idx]).abs() < 2e-2, "dx[{idx}]: {fd} vs {}", dx.data()[idx]);
        }
        for idx in [0usize, 5] {
            let mut gp = g.clone();
            gp.data_mut()[idx] += eps;
            let mut gm = g.clone();
            gm.data_mut()[idx] -= eps;
            let fd = (loss(&x, &gp, &b) - loss(&x, &gm, &b)) / (2.0 * eps);
            assert!((fd - dg.data()[idx]).abs() < 2e-2);
            let mut bp = b.clone();
            bp.data_mut()[idx] += eps;
            let mut bm = b.clone();
            bm.data_mut()[idx] -= eps;
            let fd = (loss(&x, &g, &bp) - loss(&x, &g, &bm)) / (2.0 * eps);
            assert!((fd - db.data()[idx]).abs() < 2e-2);
        }
    }

    #[test]
    fn attention_matches_naive_softmax() {
        let mut rng = Rng::new(3);
        let (s, dh) = (5, 4);
        let q = Tensor::randn(&[s, dh], 1.0, &mut rng);
        let k = Tensor::randn(&[s, dh], 1.0, &mut rng);
        let v = Tensor::randn(&[s, dh], 1.0, &mut rng);
        let (o, p) = attention_head(&q, &k, &v, true, 0);
        // probs: rows sum to 1, strictly causal zeros above diagonal
        for i in 0..s {
            let row = &p.data()[i * s..(i + 1) * s];
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5);
            for j in i + 1..s {
                assert_eq!(row[j], 0.0);
            }
        }
        // first row attends only to position 0 => o[0] == v[0]
        for j in 0..dh {
            assert!((o.data()[j] - v.data()[j]).abs() < 1e-5);
        }
    }

    #[test]
    fn attention_back_finite_difference() {
        let mut rng = Rng::new(4);
        let (s, dh) = (4, 3);
        let q = Tensor::randn(&[s, dh], 1.0, &mut rng);
        let k = Tensor::randn(&[s, dh], 1.0, &mut rng);
        let v = Tensor::randn(&[s, dh], 1.0, &mut rng);
        let w = Tensor::randn(&[s, dh], 1.0, &mut rng);
        let loss = |q: &Tensor, k: &Tensor, v: &Tensor| -> f32 {
            let (o, _) = attention_head(q, k, v, true, 0);
            o.data().iter().zip(w.data()).map(|(a, b)| a * b).sum()
        };
        let (_, p) = attention_head(&q, &k, &v, true, 0);
        let (dq, dk, dv) = attention_head_back(&w, &q, &k, &v, &p);
        let eps = 1e-3;
        let bump = |t: &Tensor, idx: usize, e: f32| -> Tensor {
            let mut t2 = t.clone();
            t2.data_mut()[idx] += e;
            t2
        };
        for idx in [0usize, 5, 11] {
            let fd = (loss(&bump(&q, idx, eps), &k, &v)
                - loss(&bump(&q, idx, -eps), &k, &v)) / (2.0 * eps);
            assert!((fd - dq.data()[idx]).abs() < 2e-2, "dq fd {fd}");
            let fd = (loss(&q, &bump(&k, idx, eps), &v)
                - loss(&q, &bump(&k, idx, -eps), &v)) / (2.0 * eps);
            assert!((fd - dk.data()[idx]).abs() < 2e-2, "dk fd {fd}");
            let fd = (loss(&q, &k, &bump(&v, idx, eps))
                - loss(&q, &k, &bump(&v, idx, -eps))) / (2.0 * eps);
            assert!((fd - dv.data()[idx]).abs() < 2e-2, "dv fd {fd}");
        }
    }

    #[test]
    fn masked_rows_stay_finite_under_extreme_logits() {
        // every non-prefix logit overflows to -inf, so each row's only
        // finite mass is on the prefix columns — probs must stay finite,
        // split over the prefix, with masked positions exactly zero
        let (s, pp, dh) = (3usize, 2usize, 1usize);
        let skv = s + pp;
        let q = Tensor::from_fn(&[s, dh], |_| 1e20);
        let k = Tensor::from_fn(&[skv, dh], |i| if i < pp { 0.0 } else { -1e20 });
        let v = Tensor::from_fn(&[skv, dh], |i| i as f32);
        let (o, p) = attention_head(&q, &k, &v, true, pp);
        for i in 0..s {
            let row = &p.data()[i * skv..(i + 1) * skv];
            assert!(row.iter().all(|x| x.is_finite()), "row {i}: {row:?}");
            assert!((row[0] - 0.5).abs() < 1e-6 && (row[1] - 0.5).abs() < 1e-6);
            for &x in &row[pp..] {
                assert_eq!(x, 0.0);
            }
        }
        // output = mean of the two prefix values = 0.5
        for &x in o.data() {
            assert!((x - 0.5).abs() < 1e-6);
        }
    }

    #[test]
    fn fully_masked_row_softmax_is_zero_not_nan() {
        // a row whose only attendable logit underflowed to -inf: the old
        // f32::MIN sentinel made the masked future the row max (probs
        // leaked there); now the row degrades to zeros, never NaN
        let (s, dh) = (2usize, 1usize);
        let q = Tensor::from_fn(&[s, dh], |_| 1e20);
        let k = Tensor::from_fn(&[s, dh], |_| -1e20);
        let v = Tensor::from_fn(&[s, dh], |i| (i + 1) as f32);
        let (o, p) = attention_head(&q, &k, &v, true, 0);
        assert!(p.data().iter().all(|x| x.is_finite()));
        assert!(o.data().iter().all(|x| x.is_finite()));
        // row 0: position 0 underflowed, position 1 masked -> all zero
        assert_eq!(p.data()[0], 0.0);
        assert_eq!(p.data()[1], 0.0);
    }

    #[test]
    fn masked_ce_uniform_logits_is_log_v() {
        let logits = Tensor::zeros(&[4, 16]);
        let targets = [1i32, 2, 3, 4];
        let mask = [1.0f32, 1.0, 0.0, 1.0];
        let (loss, _, dl) = masked_ce(&logits, &targets, &mask);
        assert!((loss - (16f32).ln()).abs() < 1e-5);
        // masked row contributes no gradient
        assert!(dl.data()[2 * 16..3 * 16].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn ce_labels_gradient_sums_to_zero() {
        let mut rng = Rng::new(5);
        let logits = Tensor::randn(&[3, 4], 1.0, &mut rng);
        let labels = [0i32, 3, 1];
        let (loss, acc, dl) = ce_labels(&logits, &labels);
        assert!(loss.is_finite());
        assert!((0.0..=1.0).contains(&acc));
        for i in 0..3 {
            let s: f32 = dl.data()[i * 4..(i + 1) * 4].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }
}
