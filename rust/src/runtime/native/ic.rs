//! Native IC graphs: the pure-Rust twin of `python/compile/ic_models.py`.
//!
//! Convs are im2col + matmul (feature index = c*9 + ky*3 + kx, SAME 3x3
//! padding) so every site is a linear site and the shared adapter
//! apply/backward from `lm.rs` drives them — which is also what makes a
//! conv adapter mergeable under Prop. 2.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use super::super::manifest::Manifest;
use super::super::value::Value;
use super::builtin::{self, IMG};
use super::kernels;
use super::lm::{adapter_apply, adapter_back, f32_in, i32_in, Named};
use crate::tensor::{self, pool, Tensor};

pub(super) enum Variant {
    /// frozen random base + live adapters (ic_*_fwdbwd_{kind})
    Decoupled(String),
    /// merged site weights (ic_*_fwdbwd_merged)
    Merged,
    /// coupled FT: site weights are the tunables
    CoupledFt,
    /// coupled LoRA: frozen base + low-rank tunables, autodiff grads
    CoupledLora,
}

/// SAME-padded 3x3 patches: (B, H, W, C) -> (B*H*W, C*9). Images are
/// independent, so the patch extraction fans out per image across the
/// tensor-engine pool (each image owns a disjoint output chunk).
fn im2col(x: &Tensor, bsz: usize, h: usize, w: usize, c: usize) -> Tensor {
    let xd = x.data();
    let fc = c * 9;
    let mut out = vec![0.0f32; bsz * h * w * fc];
    pool::parallel_chunks_mut(&mut out, h * w * fc, |b, img| {
        for y in 0..h {
            for xx in 0..w {
                let orow = (y * w + xx) * fc;
                for ky in 0..3 {
                    let sy = y as isize + ky as isize - 1;
                    if sy < 0 || sy >= h as isize {
                        continue;
                    }
                    for kx in 0..3 {
                        let sx = xx as isize + kx as isize - 1;
                        if sx < 0 || sx >= w as isize {
                            continue;
                        }
                        let src = ((b * h + sy as usize) * w + sx as usize) * c;
                        for ch in 0..c {
                            img[orow + ch * 9 + ky * 3 + kx] = xd[src + ch];
                        }
                    }
                }
            }
        }
    });
    Tensor::new(vec![bsz * h * w, fc], out)
}

/// Backward of [`im2col`]: scatter-add patches back onto the image grid,
/// one image per pool task (scatter targets stay within the image).
fn col2im(dp: &Tensor, bsz: usize, h: usize, w: usize, c: usize) -> Tensor {
    let fc = c * 9;
    let dd = dp.data();
    let mut out = vec![0.0f32; bsz * h * w * c];
    pool::parallel_chunks_mut(&mut out, h * w * c, |b, img| {
        for y in 0..h {
            for xx in 0..w {
                let prow = ((b * h + y) * w + xx) * fc;
                for ky in 0..3 {
                    let sy = y as isize + ky as isize - 1;
                    if sy < 0 || sy >= h as isize {
                        continue;
                    }
                    for kx in 0..3 {
                        let sx = xx as isize + kx as isize - 1;
                        if sx < 0 || sx >= w as isize {
                            continue;
                        }
                        let dst = ((sy as usize) * w + sx as usize) * c;
                        for ch in 0..c {
                            img[dst + ch] += dd[prow + ch * 9 + ky * 3 + kx];
                        }
                    }
                }
            }
        }
    });
    Tensor::new(vec![bsz * h * w, c], out)
}

/// 2x2 average pool over rows laid out (B*H*W, C) -> (B*(H/2)*(W/2), C).
fn avgpool2(x: &Tensor, bsz: usize, h: usize, w: usize, c: usize) -> Tensor {
    let (h2, w2) = (h / 2, w / 2);
    let xd = x.data();
    let mut out = vec![0.0f32; bsz * h2 * w2 * c];
    for b in 0..bsz {
        for i in 0..h2 {
            for j in 0..w2 {
                let orow = ((b * h2 + i) * w2 + j) * c;
                for (dy, dx) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                    let src = ((b * h + 2 * i + dy) * w + 2 * j + dx) * c;
                    for ch in 0..c {
                        out[orow + ch] += xd[src + ch] * 0.25;
                    }
                }
            }
        }
    }
    Tensor::new(vec![bsz * h2 * w2, c], out)
}

/// Backward of [`avgpool2`]: spread each pooled gradient over its 2x2.
fn avgpool2_back(dy: &Tensor, bsz: usize, h: usize, w: usize, c: usize) -> Tensor {
    let (h2, w2) = (h / 2, w / 2);
    let dd = dy.data();
    let mut out = vec![0.0f32; bsz * h * w * c];
    for b in 0..bsz {
        for i in 0..h2 {
            for j in 0..w2 {
                let srow = ((b * h2 + i) * w2 + j) * c;
                for (dy_, dx) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                    let dst = ((b * h + 2 * i + dy_) * w + 2 * j + dx) * c;
                    for ch in 0..c {
                        out[dst + ch] += dd[srow + ch] * 0.25;
                    }
                }
            }
        }
    }
    Tensor::new(vec![bsz * h * w, c], out)
}

struct Sites<'a> {
    /// merged/FT mode: site -> weight
    merged: Option<BTreeMap<&'a str, &'a Tensor>>,
    /// decoupled/LoRA mode: site -> frozen base
    base: Option<BTreeMap<&'a str, &'a Tensor>>,
    a: BTreeMap<&'a str, &'a Tensor>,
    kind: String,
    want_grads: bool,
}

impl<'a> Sites<'a> {
    fn fwd(&self, site: &str, x: &Tensor) -> Result<Tensor> {
        if let Some(ws) = &self.merged {
            let w = ws
                .get(site)
                .ok_or_else(|| anyhow!("missing site weight '{site}'"))?;
            return Ok(tensor::matmul(x, w));
        }
        let base = self
            .base
            .as_ref()
            .ok_or_else(|| anyhow!("unmerged forward pass with no base weights loaded"))?;
        let w = base
            .get(site)
            .ok_or_else(|| anyhow!("missing base weight '{site}.Wbase'"))?;
        let mut out = tensor::matmul(x, w);
        if let Some(delta) = adapter_apply(&self.kind, &self.a, site, x)? {
            tensor::axpy(&mut out, 1.0, &delta);
        }
        Ok(out)
    }

    fn back(
        &self,
        site: &str,
        x: &Tensor,
        dout: &Tensor,
        grads: &mut BTreeMap<String, Tensor>,
    ) -> Result<Tensor> {
        if let Some(ws) = &self.merged {
            let w = ws
                .get(site)
                .ok_or_else(|| anyhow!("missing site weight '{site}' in backward pass"))?;
            if self.want_grads {
                grads.insert(format!("{site}.W"), tensor::matmul_tn(x, dout));
            }
            return Ok(tensor::matmul_nt(dout, w));
        }
        let base = self
            .base
            .as_ref()
            .ok_or_else(|| anyhow!("unmerged backward pass with no base weights loaded"))?;
        let w = base
            .get(site)
            .ok_or_else(|| anyhow!("missing base weight '{site}.Wbase' in backward pass"))?;
        let mut dx = tensor::matmul_nt(dout, w);
        let g = if self.want_grads { Some(&mut *grads) } else { None };
        if let Some(dxa) = adapter_back(&self.kind, &self.a, site, x, dout, g)? {
            tensor::axpy(&mut dx, 1.0, &dxa);
        }
        Ok(dx)
    }
}

pub(super) fn run(
    _m: &Manifest,
    model: &str,
    variant: Variant,
    named: &Named,
    need_back: bool,
) -> Result<BTreeMap<String, Value>> {
    let dims = builtin::ic_site_dims(model);
    let images = f32_in(named, "images")?;
    let labels = i32_in(named, "labels")?;
    let bsz = images.shape()[0];

    // Route inputs into site weights / adapters. In merged/FT artifacts
    // "{site}.W" is the site weight; in decoupled/LoRA artifacts the same
    // name is the *linear adapter* tensor, so classify by variant.
    let w_is_site_weight = matches!(variant, Variant::Merged | Variant::CoupledFt);
    let site_names: Vec<&str> = dims.iter().map(|(s, _)| *s).collect();
    let mut merged: BTreeMap<&str, &Tensor> = BTreeMap::new();
    let mut base: BTreeMap<&str, &Tensor> = BTreeMap::new();
    let mut a: BTreeMap<&str, &Tensor> = BTreeMap::new();
    for (k, v) in named.iter() {
        let k: &str = *k;
        let v: &Value = *v;
        if k == "images" || k == "labels" {
            continue;
        }
        let t = match v {
            Value::F32(t) => t,
            Value::I32(_) => continue,
        };
        if let Some(site) = k.strip_suffix(".Wbase") {
            if site_names.contains(&site) {
                base.insert(site, t);
                continue;
            }
        }
        if w_is_site_weight {
            if let Some(site) = k.strip_suffix(".W") {
                if site_names.contains(&site) {
                    merged.insert(site, t);
                    continue;
                }
            }
        }
        a.insert(k, t);
    }

    let (sites, grad_names): (Sites, Vec<(String, Vec<usize>)>) = match &variant {
        Variant::Decoupled(kind) => (
            Sites {
                merged: None,
                base: Some(base),
                a,
                kind: kind.clone(),
                want_grads: false,
            },
            vec![],
        ),
        Variant::Merged => (
            Sites {
                merged: Some(merged),
                base: None,
                a,
                kind: "none".into(),
                want_grads: false,
            },
            vec![],
        ),
        Variant::CoupledFt => (
            Sites {
                merged: Some(merged),
                base: None,
                a,
                kind: "none".into(),
                want_grads: need_back,
            },
            dims.iter()
                .map(|(s, (din, dout, _))| (format!("{s}.W"), vec![*din, *dout]))
                .collect(),
        ),
        Variant::CoupledLora => (
            Sites {
                merged: None,
                base: Some(base),
                a,
                kind: "lowrank".into(),
                want_grads: need_back,
            },
            builtin::ic_adapter_shapes(model, "lowrank"),
        ),
    };
    let coupled = !grad_names.is_empty();

    let mut grads: BTreeMap<String, Tensor> = BTreeMap::new();
    let mut xs: BTreeMap<String, Tensor> = BTreeMap::new();
    let mut geps: BTreeMap<String, Tensor> = BTreeMap::new();

    let (loss, acc) = match model {
        "linear" => {
            let x = images.clone().reshape(&[bsz, IMG * IMG]);
            let logits = sites.fwd("fc", &x)?;
            let (loss, acc, dlogits) = kernels::ce_labels(&logits, labels.data());
            if need_back {
                if coupled {
                    sites.back("fc", &x, &dlogits, &mut grads)?;
                }
                geps.insert("fc.g".into(), dlogits);
            }
            xs.insert("fc.x".into(), x);
            (loss, acc)
        }
        "mlp" => {
            let x = images.clone().reshape(&[bsz, IMG * IMG]);
            let s1 = sites.fwd("fc1", &x)?;
            let hmid = tensor::relu(&s1);
            let logits = sites.fwd("fc2", &hmid)?;
            let (loss, acc, dlogits) = kernels::ce_labels(&logits, labels.data());
            if need_back {
                let dhmid = sites.back("fc2", &hmid, &dlogits, &mut grads)?;
                let mut ds1 = dhmid;
                kernels::relu_mask(&mut ds1, &s1);
                if coupled {
                    sites.back("fc1", &x, &ds1, &mut grads)?;
                }
                geps.insert("fc2.g".into(), dlogits);
                geps.insert("fc1.g".into(), ds1);
            }
            xs.insert("fc1.x".into(), x);
            xs.insert("fc2.x".into(), hmid);
            (loss, acc)
        }
        "cnn" => {
            let p1 = im2col(images, bsz, IMG, IMG, 1); // (B*784, 9)
            let c1raw = sites.fwd("conv1", &p1)?; // (B*784, 16)
            let c1 = avgpool2(&tensor::relu(&c1raw), bsz, IMG, IMG, 16); // (B*196, 16)
            let p2 = im2col(&c1, bsz, IMG / 2, IMG / 2, 16); // (B*196, 144)
            let c2raw = sites.fwd("conv2", &p2)?; // (B*196, 32)
            let c2 = avgpool2(&tensor::relu(&c2raw), bsz, IMG / 2, IMG / 2, 32); // (B*49, 32)
            let flat = c2.reshape(&[bsz, 32 * 7 * 7]);
            let logits = sites.fwd("fc", &flat)?;
            let (loss, acc, dlogits) = kernels::ce_labels(&logits, labels.data());
            if need_back {
                let dflat = sites.back("fc", &flat, &dlogits, &mut grads)?;
                let dc2 = dflat.reshape(&[bsz * 7 * 7, 32]);
                let mut dc2raw = avgpool2_back(&dc2, bsz, IMG / 2, IMG / 2, 32);
                kernels::relu_mask(&mut dc2raw, &c2raw);
                let dp2 = sites.back("conv2", &p2, &dc2raw, &mut grads)?;
                let dc1 = col2im(&dp2, bsz, IMG / 2, IMG / 2, 16);
                let mut dc1raw = avgpool2_back(&dc1, bsz, IMG, IMG, 16);
                kernels::relu_mask(&mut dc1raw, &c1raw);
                if coupled {
                    sites.back("conv1", &p1, &dc1raw, &mut grads)?;
                }
                geps.insert("fc.g".into(), dlogits);
                geps.insert("conv2.g".into(), dc2raw);
                geps.insert("conv1.g".into(), dc1raw);
            }
            xs.insert("conv1.x".into(), p1);
            xs.insert("conv2.x".into(), p2);
            xs.insert("fc.x".into(), flat);
            (loss, acc)
        }
        other => bail!("unknown ic model '{other}'"),
    };

    let mut res = BTreeMap::new();
    res.insert("loss".to_string(), Value::F32(Tensor::scalar(loss)));
    res.insert("acc".to_string(), Value::F32(Tensor::scalar(acc)));
    if coupled {
        for (name, shape) in &grad_names {
            let g = match grads.remove(name) {
                Some(g) => g,
                None if !need_back => Tensor::zeros(shape),
                None => bail!("ic coupled: backward produced no gradient for '{name}'"),
            };
            res.insert(format!("d.{name}"), Value::F32(g));
        }
    } else {
        for (site, _) in &dims {
            let x = xs
                .remove(&format!("{site}.x"))
                .ok_or_else(|| anyhow!("ic: missing x for site {site}"))?;
            res.insert(format!("{site}.x"), Value::F32(x));
            let g = match geps.remove(&format!("{site}.g")) {
                Some(g) => g,
                // eval: grad_hhat not computed and not fetched
                None if !need_back => Tensor::zeros(&[1]),
                None => bail!("ic: backward produced no grad_hhat for '{site}'"),
            };
            res.insert(format!("{site}.g"), Value::F32(g));
        }
    }
    Ok(res)
}
