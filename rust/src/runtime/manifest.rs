//! Artifact manifest: the L2->L3 interface contract.
//!
//! `aot.py` writes `artifacts/manifest.json` recording, for every lowered
//! HLO module, the positional input list (name, dtype, dims) and output
//! names. The Rust side never guesses shapes — everything is looked up
//! here, and input assembly is by name.
//!
//! When no `artifacts/` directory exists the same contract is synthesized
//! natively (`runtime::native::builtin`) so the crate is self-contained:
//! artifact names, input orders and output names are identical between
//! the two sources, which is what lets `runtime::native` execute them.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::I32 => "int32",
        }
    }

    pub fn bytes(&self) -> usize {
        4
    }
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl IoSpec {
    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elems() * self.dtype.bytes()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<String>,
}

impl ArtifactSpec {
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|i| i.name == name)
            .ok_or_else(|| anyhow!("artifact {}: no input '{}'", self.name, name))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|o| o == name)
            .ok_or_else(|| anyhow!("artifact {}: no output '{}'", self.name, name))
    }
}

/// A model-size config echoed from python (model.CONFIGS).
#[derive(Clone, Debug)]
pub struct SizeConfig {
    pub vocab: usize,
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub dff: usize,
    pub seq: usize,
    pub batch: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub configs: BTreeMap<String, SizeConfig>,
    pub rank: usize,
    pub mlp_hidden: usize,
    pub n_classes_seqcls: usize,
    /// true when parsed from `artifacts/manifest.json` (AOT build); false
    /// for the built-in native manifest. Drives backend selection.
    pub from_disk: bool,
}

impl Manifest {
    /// Load the manifest, preferring the on-disk AOT contract: if
    /// `dir/manifest.json` exists it is parsed (errors are actionable);
    /// otherwise the built-in native manifest is synthesized — no Python,
    /// no XLA toolchain required.
    pub fn load_or_builtin(dir: &Path) -> Result<Manifest> {
        if dir.join("manifest.json").exists() {
            Self::load(dir)
        } else {
            Ok(crate::runtime::native::builtin::builtin_manifest(dir))
        }
    }

    /// Strict disk load of an AOT-generated manifest.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {path:?} — run `make artifacts` (Python + JAX) to \
                 regenerate it, or delete the {dir:?} directory to fall back \
                 to the built-in native backend"
            )
        })?;
        Self::parse(&src, dir).with_context(|| {
            format!(
                "parsing {path:?} — the artifacts directory looks stale or \
                 corrupt; re-run `make artifacts`, or delete {dir:?} to fall \
                 back to the built-in native backend"
            )
        })
    }

    /// Parse a manifest JSON document. `dir` roots the artifact files.
    pub fn parse(src: &str, dir: &Path) -> Result<Manifest> {
        let j = Json::parse(src).map_err(|e| anyhow!("manifest json: {e}"))?;

        let mut artifacts = BTreeMap::new();
        for (name, spec) in j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest: no artifacts object"))?
        {
            let file = spec
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact {name}: no file"))?;
            let mut inputs = Vec::new();
            for entry in spec
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact {name}: no inputs"))?
            {
                let t = entry.as_arr().ok_or_else(|| anyhow!("bad input entry"))?;
                if t.len() < 3 {
                    bail!("artifact {name}: malformed input entry");
                }
                inputs.push(IoSpec {
                    name: t[0].as_str().unwrap_or_default().to_string(),
                    dtype: DType::parse(t[1].as_str().unwrap_or_default())?,
                    dims: t[2]
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(Json::as_usize)
                        .collect(),
                });
            }
            let outputs = spec
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact {name}: no outputs"))?
                .iter()
                .filter_map(|o| o.as_str().map(String::from))
                .collect();
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(file),
                    inputs,
                    outputs,
                },
            );
        }

        let mut configs = BTreeMap::new();
        if let Some(cfgs) = j.get("configs").and_then(Json::as_obj) {
            for (name, c) in cfgs {
                let g = |k: &str| -> Result<usize> {
                    c.get(k)
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("config {name}: missing {k}"))
                };
                configs.insert(
                    name.clone(),
                    SizeConfig {
                        vocab: g("vocab")?,
                        d: g("d")?,
                        layers: g("layers")?,
                        heads: g("heads")?,
                        dff: g("dff")?,
                        seq: g("seq")?,
                        batch: g("batch")?,
                    },
                );
            }
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
            configs,
            rank: j.get("rank").and_then(Json::as_usize).unwrap_or(8),
            mlp_hidden: j.get("mlp_hidden").and_then(Json::as_usize).unwrap_or(64),
            n_classes_seqcls: j
                .get("n_classes_seqcls")
                .and_then(Json::as_usize)
                .unwrap_or(4),
            from_disk: true,
        })
    }

    /// Serialize back to the `manifest.json` document shape (used by the
    /// round-trip tests; artifact files are recorded by their base name).
    pub fn to_json_string(&self) -> String {
        let mut arts = BTreeMap::new();
        for (name, spec) in &self.artifacts {
            let mut obj = BTreeMap::new();
            let file = spec
                .file
                .file_name()
                .map(|f| f.to_string_lossy().into_owned())
                .unwrap_or_default();
            obj.insert("file".to_string(), Json::Str(file));
            obj.insert(
                "inputs".to_string(),
                Json::Arr(
                    spec.inputs
                        .iter()
                        .map(|io| {
                            Json::Arr(vec![
                                Json::Str(io.name.clone()),
                                Json::Str(io.dtype.name().to_string()),
                                Json::Arr(
                                    io.dims.iter().map(|&d| Json::Num(d as f64)).collect(),
                                ),
                            ])
                        })
                        .collect(),
                ),
            );
            obj.insert(
                "outputs".to_string(),
                Json::Arr(spec.outputs.iter().map(|o| Json::Str(o.clone())).collect()),
            );
            arts.insert(name.clone(), Json::Obj(obj));
        }
        let mut cfgs = BTreeMap::new();
        for (name, c) in &self.configs {
            let mut obj = BTreeMap::new();
            for (k, v) in [
                ("vocab", c.vocab),
                ("d", c.d),
                ("layers", c.layers),
                ("heads", c.heads),
                ("dff", c.dff),
                ("seq", c.seq),
                ("batch", c.batch),
            ] {
                obj.insert(k.to_string(), Json::Num(v as f64));
            }
            cfgs.insert(name.clone(), Json::Obj(obj));
        }
        let mut root = BTreeMap::new();
        root.insert("artifacts".to_string(), Json::Obj(arts));
        root.insert("configs".to_string(), Json::Obj(cfgs));
        root.insert("rank".to_string(), Json::Num(self.rank as f64));
        root.insert("mlp_hidden".to_string(), Json::Num(self.mlp_hidden as f64));
        root.insert(
            "n_classes_seqcls".to_string(),
            Json::Num(self.n_classes_seqcls as f64),
        );
        Json::Obj(root).to_string()
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("no artifact '{name}' in manifest (have {})",
                                   self.artifacts.len()))
    }

    pub fn size(&self, name: &str) -> Result<&SizeConfig> {
        self.configs
            .get(name)
            .ok_or_else(|| anyhow!("no size config '{name}'"))
    }

    /// Load an initial-value group, as name -> Tensor. AOT builds read
    /// `artifacts/init/<group>/` (exported by aot.py); the native
    /// manifest generates the same groups deterministically in-process.
    pub fn load_init(&self, group: &str) -> Result<BTreeMap<String, crate::tensor::Tensor>> {
        if !self.from_disk {
            return crate::runtime::native::init::generate(self, group);
        }
        let dir = self.dir.join("init").join(group);
        let idx_src = std::fs::read_to_string(dir.join("index.json")).with_context(|| {
            format!(
                "init group '{group}' missing under {:?} — re-run `make artifacts`",
                self.dir
            )
        })?;
        let idx = Json::parse(&idx_src).map_err(|e| anyhow!("init index: {e}"))?;
        let mut out = BTreeMap::new();
        for (name, entry) in idx.as_obj().ok_or_else(|| anyhow!("bad init index"))? {
            let file = entry
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("init group {group}: entry '{name}' has no file"))?;
            let shape: Vec<usize> = entry
                .get("shape")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let bytes = std::fs::read(dir.join(file))?;
            let mut data = vec![0f32; bytes.len() / 4];
            for (i, ch) in bytes.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
            }
            out.insert(name.clone(), crate::tensor::Tensor::new(shape, data));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("float32").unwrap(), DType::F32);
        assert_eq!(DType::parse("int32").unwrap(), DType::I32);
        assert!(DType::parse("bfloat16").is_err());
    }

    #[test]
    fn iospec_sizes() {
        let s = IoSpec { name: "x".into(), dtype: DType::F32, dims: vec![8, 64] };
        assert_eq!(s.elems(), 512);
        assert_eq!(s.bytes(), 2048);
    }

    #[test]
    fn parse_minimal_manifest() {
        let src = r#"{"artifacts": {"a": {"file": "a.hlo.txt",
            "inputs": [["x", "float32", [8, 64]], ["t", "int32", []]],
            "outputs": ["loss"]}}, "rank": 4}"#;
        let m = Manifest::parse(src, Path::new("arts")).unwrap();
        assert_eq!(m.rank, 4);
        assert!(m.from_disk);
        let a = m.artifact("a").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.output_index("loss").unwrap(), 0);
    }

    #[test]
    fn missing_artifacts_dir_falls_back_to_builtin() {
        let m = Manifest::load_or_builtin(Path::new("definitely-not-a-dir")).unwrap();
        assert!(!m.from_disk);
        assert!(m.artifacts.contains_key("lm_fwdbwd_tiny_lowrank"));
        assert!(m.configs.contains_key("tiny"));
    }
}
