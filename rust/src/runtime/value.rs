//! `Value` — the typed array that crosses device boundaries.
//!
//! PJRT `Literal`s wrap raw pointers and are !Send, so only `Value`s
//! (plain `Vec`-backed tensors) move between threads; the native backend
//! uses the same type as its resident-buffer storage. Every crossing is
//! an explicit host copy — exactly the transfer the paper's offload
//! model charges for, so the transfer ledger falls out of the type
//! system.
//!
//! For *process* boundaries (the TCP offload wire), `Value`s serialize
//! via `crate::transport::wire::{encode_value, decode_value}` — raw
//! little-endian bit patterns, so f32 payloads (NaN bits included)
//! round-trip exactly.

use crate::tensor::Tensor;

/// Integer tensor (tokens / targets / labels).
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl IntTensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        IntTensor { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F32(Tensor),
    I32(IntTensor),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => t.shape(),
            Value::I32(t) => t.shape(),
        }
    }

    pub fn bytes(&self) -> usize {
        match self {
            Value::F32(t) => t.bytes(),
            Value::I32(t) => t.bytes(),
        }
    }

    pub fn as_f32(&self) -> Option<&Tensor> {
        match self {
            Value::F32(t) => Some(t),
            _ => None,
        }
    }

    pub fn into_f32(self) -> anyhow::Result<Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(_) => anyhow::bail!("expected f32 value, got i32"),
        }
    }

    /// Scalar f32 convenience (loss outputs).
    pub fn scalar_f32(&self) -> anyhow::Result<f32> {
        match self {
            Value::F32(t) if t.len() == 1 => Ok(t.data()[0]),
            other => anyhow::bail!("expected scalar f32, got shape {:?}", other.shape()),
        }
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Self {
        Value::F32(t)
    }
}

impl From<IntTensor> for Value {
    fn from(t: IntTensor) -> Self {
        Value::I32(t)
    }
}

/// View a POD slice as bytes (f32/i32 only; used for Literal building).
pub fn as_bytes<T: Copy>(xs: &[T]) -> &[u8] {
    // SAFETY: f32/i32 are plain-old-data with no padding.
    unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, std::mem::size_of_val(xs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_accessors() {
        let v: Value = Tensor::zeros(&[2, 3]).into();
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(v.bytes(), 24);
        assert!(v.as_f32().is_some());
        let i: Value = IntTensor::new(vec![4], vec![1, 2, 3, 4]).into();
        assert_eq!(i.bytes(), 16);
        assert!(i.as_f32().is_none());
    }

    #[test]
    fn scalar() {
        let v: Value = Tensor::scalar(3.5).into();
        assert_eq!(v.scalar_f32().unwrap(), 3.5);
        let w: Value = Tensor::zeros(&[2]).into();
        assert!(w.scalar_f32().is_err());
    }

    #[test]
    fn bytes_view() {
        let xs = [1.0f32, 2.0];
        assert_eq!(as_bytes(&xs).len(), 8);
    }
}
