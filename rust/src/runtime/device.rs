//! PJRT device thread (the `--features xla` backend).
//!
//! PJRT types (`PjRtClient`, `Literal`, executables) are !Send — each
//! device thread owns its own client, its executable cache, and a store
//! of named resident buffers (base weights stay on the server device and
//! are never re-uploaded per step). The rest of the system talks to it
//! through a channel protocol with plain `Value`s, which makes every
//! host<->device transfer explicit and measurable.
//!
//! This module only compiles under `--features xla` and additionally
//! requires the `xla` PJRT bindings as a dependency plus the AOT
//! artifacts on disk (`make artifacts`). The default build uses
//! `runtime::native` instead.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::Manifest;
use super::value::{as_bytes, IntTensor, Value};
use super::{ExecResult, Input, OutputPlan};
use crate::tensor::Tensor;

enum Cmd {
    Upload(String, Value, Sender<Result<()>>),
    Read(String, Sender<Result<Value>>),
    Free(String, Sender<Result<()>>),
    Execute {
        artifact: String,
        inputs: Vec<Input>,
        plan: OutputPlan,
        reply: Sender<Result<ExecResult>>,
    },
    /// total bytes currently resident in named buffers
    ResidentBytes(Sender<usize>),
    Shutdown,
}

/// Handle to a PJRT device thread. Cloneable, Send and Sync (the channel
/// sender is mutex-wrapped so handles can live in shared statics).
#[derive(Clone)]
pub struct PjrtDevice {
    tx: Arc<Mutex<Sender<Cmd>>>,
    name: Arc<String>,
}

impl PjrtDevice {
    /// Spawn a PJRT CPU device thread serving artifacts from `manifest`.
    pub fn spawn(name: &str, manifest: Arc<Manifest>) -> Result<PjrtDevice> {
        let (tx, rx) = channel::<Cmd>();
        let thread_name = format!("device-{name}");
        std::thread::Builder::new()
            .name(thread_name)
            .spawn(move || device_main(rx, manifest))
            .context("spawning device thread")?;
        Ok(PjrtDevice {
            tx: Arc::new(Mutex::new(tx)),
            name: Arc::new(name.to_string()),
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    fn send(&self, cmd: Cmd) -> Result<()> {
        let tx = crate::util::lock_recover(&self.tx);
        tx.send(cmd).map_err(|_| anyhow!("device {} gone", self.name))
    }

    pub fn upload(&self, name: &str, value: Value) -> Result<()> {
        let (tx, rx) = channel();
        self.send(Cmd::Upload(name.to_string(), value, tx))?;
        rx.recv()?
    }

    pub fn read(&self, name: &str) -> Result<Value> {
        let (tx, rx) = channel();
        self.send(Cmd::Read(name.to_string(), tx))?;
        rx.recv()?
    }

    pub fn free(&self, name: &str) -> Result<()> {
        let (tx, rx) = channel();
        self.send(Cmd::Free(name.to_string(), tx))?;
        rx.recv()?
    }

    pub fn execute(
        &self,
        artifact: &str,
        inputs: Vec<Input>,
        plan: OutputPlan,
    ) -> Result<ExecResult> {
        let (tx, rx) = channel();
        self.send(Cmd::Execute {
            artifact: artifact.to_string(),
            inputs,
            plan,
            reply: tx,
        })?;
        rx.recv()?
    }

    pub fn resident_bytes(&self) -> Result<usize> {
        let (tx, rx) = channel();
        self.send(Cmd::ResidentBytes(tx))?;
        Ok(rx.recv()?)
    }

    pub fn shutdown(&self) {
        let _ = self.send(Cmd::Shutdown);
    }
}

struct DeviceState {
    client: xla::PjRtClient,
    manifest: Arc<Manifest>,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    store: HashMap<String, (xla::Literal, usize)>, // literal + byte size
}

fn device_main(rx: Receiver<Cmd>, manifest: Arc<Manifest>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("device: PJRT client failed: {e}");
            return;
        }
    };
    let mut st = DeviceState { client, manifest, exes: HashMap::new(),
                               store: HashMap::new() };
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Upload(name, value, reply) => {
                let r = value_to_literal(&value).map(|lit| {
                    st.store.insert(name, (lit, value.bytes()));
                });
                let _ = reply.send(r);
            }
            Cmd::Read(name, reply) => {
                let r = st
                    .store
                    .get(&name)
                    .ok_or_else(|| anyhow!("no buffer '{name}'"))
                    .and_then(|(lit, _)| literal_to_value(lit));
                let _ = reply.send(r);
            }
            Cmd::Free(name, reply) => {
                st.store.remove(&name);
                let _ = reply.send(Ok(()));
            }
            Cmd::Execute { artifact, inputs, plan, reply } => {
                let _ = reply.send(run_execute(&mut st, &artifact, inputs, plan));
            }
            Cmd::ResidentBytes(reply) => {
                let _ = reply.send(st.store.values().map(|(_, b)| b).sum());
            }
            Cmd::Shutdown => break,
        }
    }
}

fn run_execute(
    st: &mut DeviceState,
    artifact: &str,
    inputs: Vec<Input>,
    plan: OutputPlan,
) -> Result<ExecResult> {
    let t_compile = Instant::now();
    let mut compiled_now = false;
    if !st.exes.contains_key(artifact) {
        compiled_now = true;
        let spec = st.manifest.artifact(artifact)?;
        let path = spec.file.clone(); // manifest stores dir-joined paths
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .with_context(|| format!("loading HLO {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = st
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {artifact}"))?;
        st.exes.insert(artifact.to_string(), exe);
    }
    let compile_time = if compiled_now { t_compile.elapsed() } else { Duration::ZERO };

    // Assemble positional literals. Inline values become temporaries.
    let t_up = Instant::now();
    let mut bytes_up = 0usize;
    let mut temps: Vec<(usize, xla::Literal)> = Vec::new();
    for (i, input) in inputs.iter().enumerate() {
        if let Input::Val(v) = input {
            bytes_up += v.bytes();
            temps.push((i, value_to_literal(v)?));
        }
    }
    let upload_time = t_up.elapsed();
    let mut refs: Vec<&xla::Literal> = Vec::with_capacity(inputs.len());
    let mut temp_it = temps.iter().peekable();
    for (i, input) in inputs.iter().enumerate() {
        match input {
            Input::Ref(name) => {
                let (lit, _) = st
                    .store
                    .get(name)
                    .ok_or_else(|| anyhow!("{artifact}: no resident buffer '{name}'"))?;
                refs.push(lit);
            }
            Input::Val(_) => {
                // lint:allow(panic-safety): temps holds exactly one entry per Input::Val, built from this same list a few lines up
                let (ti, lit) = temp_it.next().unwrap();
                debug_assert_eq!(*ti, i);
                refs.push(lit);
            }
        }
    }

    let exe = st
        .exes
        .get(artifact)
        .ok_or_else(|| anyhow!("{artifact}: executable was never compiled"))?;
    let t0 = Instant::now();
    let result = exe
        .execute::<&xla::Literal>(&refs)
        .with_context(|| format!("executing {artifact}"))?;
    let root = result[0][0]
        .to_literal_sync()
        .with_context(|| format!("sync {artifact}"))?;
    let exec_time = t0.elapsed();
    let t_fetch = Instant::now();
    let outs = root.to_tuple()?;

    let mut fetched = Vec::new();
    let mut bytes_down = 0usize;
    for idx in &plan.fetch {
        let lit = outs
            .get(*idx)
            .ok_or_else(|| anyhow!("{artifact}: no output index {idx}"))?;
        let v = literal_to_value(lit)?;
        bytes_down += v.bytes();
        fetched.push((*idx, v));
    }
    // Keep after fetch: keeping consumes literals by index.
    let mut outs: Vec<Option<xla::Literal>> = outs.into_iter().map(Some).collect();
    for (idx, name) in &plan.keep {
        let lit = outs
            .get_mut(*idx)
            .and_then(Option::take)
            .ok_or_else(|| anyhow!("{artifact}: keep index {idx} invalid/duplicate"))?;
        let sz = lit.size_bytes();
        st.store.insert(name.clone(), (lit, sz));
    }

    let fetch_time = t_fetch.elapsed();
    Ok(ExecResult { fetched, exec_time, compile_time, upload_time, fetch_time,
                    bytes_up, bytes_down })
}

fn value_to_literal(v: &Value) -> Result<xla::Literal> {
    let dims: Vec<usize> = v.shape().to_vec();
    match v {
        Value::F32(t) => xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &dims,
            as_bytes(t.data()),
        )
        .map_err(|e| anyhow!("literal f32: {e:?}")),
        Value::I32(t) => xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            &dims,
            as_bytes(t.data()),
        )
        .map_err(|e| anyhow!("literal i32: {e:?}")),
    }
}

fn literal_to_value(lit: &xla::Literal) -> Result<Value> {
    let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?;
            Ok(Value::F32(Tensor::new(dims, data)))
        }
        xla::ElementType::S32 => {
            let data = lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))?;
            Ok(Value::I32(IntTensor::new(dims, data)))
        }
        other => bail!("unsupported element type {other:?}"),
    }
}
