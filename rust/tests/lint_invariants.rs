//! `cola lint` — the linter's own test suite.
//!
//! Each fixture under `lint_fixtures/` seeds exactly one kind of
//! violation; the fixtures are plain text to the linter and are never
//! compiled. The final test turns the linter on the live `rust/src`
//! tree: the shipped code must be lint-clean under `--deny-all`
//! semantics (zero denies AND zero warnings).

use cola::lint::{check_enum_coverage, scan_source, scan_tree, Rule, Severity};

fn denies(violations: &[cola::lint::Violation]) -> usize {
    violations.iter().filter(|v| v.severity == Severity::Deny).count()
}

#[test]
fn determinism_rule_fires_only_in_curve_scope() {
    let src = include_str!("lint_fixtures/det_hashmap.rs");
    // inside a curve-affecting module: every HashMap mention is a deny
    let (v, allowed) = scan_source("coordinator/det_hashmap.rs", src);
    assert!(!v.is_empty());
    assert!(v.iter().all(|x| x.rule == Rule::Determinism), "{v:?}");
    assert!(allowed.is_empty());
    // the same bytes outside the determinism scope: clean
    let (v, _) = scan_source("util/det_hashmap.rs", src);
    assert!(v.is_empty(), "{v:?}");
}

#[test]
fn panic_rule_skips_cfg_test_items() {
    let src = include_str!("lint_fixtures/panic_unwrap.rs");
    let (v, _) = scan_source("adapters/panic_unwrap.rs", src);
    assert_eq!(v.len(), 1, "{v:?}");
    assert_eq!(v[0].rule, Rule::PanicSafety);
    assert_eq!(v[0].line, 3, "the #[cfg(test)] unwrap must not count");
}

#[test]
fn lock_unwrap_is_mutex_poison_not_panic_safety() {
    let src = include_str!("lint_fixtures/mutex_lock.rs");
    let (v, _) = scan_source("transport/mutex_lock.rs", src);
    assert_eq!(v.len(), 1, "{v:?}");
    assert_eq!(v[0].rule, Rule::MutexPoison);
    assert_eq!(v[0].line, 5);
}

#[test]
fn unsafe_without_safety_comment_is_flagged() {
    let src = include_str!("lint_fixtures/unsafe_nosafety.rs");
    let (v, _) = scan_source("tensor/unsafe_nosafety.rs", src);
    assert_eq!(v.len(), 1, "{v:?}");
    assert_eq!(v[0].rule, Rule::UnsafeAudit);
    assert_eq!(v[0].line, 3, "the SAFETY:-covered block must pass");
}

#[test]
fn audited_pragma_suppresses_and_is_inventoried() {
    let src = include_str!("lint_fixtures/pragma_allow.rs");
    let (v, allowed) = scan_source("adapters/pragma_allow.rs", src);
    assert!(v.is_empty(), "{v:?}");
    assert_eq!(allowed.len(), 1);
    assert_eq!(allowed[0].rule, Rule::PanicSafety);
    assert_eq!(allowed[0].reason, "fixed-size array always has a last element");
}

#[test]
fn pragma_hygiene_reasonless_unknown_and_stale() {
    let src = include_str!("lint_fixtures/pragma_bad.rs");
    let (v, allowed) = scan_source("adapters/pragma_bad.rs", src);
    assert!(allowed.is_empty());
    // a matching pragma without a reason re-files the site as a deny
    assert!(
        v.iter().any(|x| x.rule == Rule::PragmaHygiene
            && x.severity == Severity::Deny
            && x.line == 4
            && x.message.contains("reason")),
        "{v:?}"
    );
    // an unknown rule name is a deny on the pragma line itself
    assert!(
        v.iter().any(|x| x.rule == Rule::PragmaHygiene
            && x.severity == Severity::Deny
            && x.line == 8
            && x.message.contains("no-such-rule")),
        "{v:?}"
    );
    // a pragma that suppresses nothing is a warning (deny under --deny-all)
    assert!(
        v.iter().any(|x| x.rule == Rule::PragmaHygiene
            && x.severity == Severity::Warn
            && x.line == 11
            && x.message.contains("stale")),
        "{v:?}"
    );
    assert_eq!(denies(&v), 2, "{v:?}");
}

#[test]
fn masking_ignores_strings_and_comments() {
    let src = "pub fn f() -> &'static str { \".unwrap() panic!(\" } // .unwrap() here too\n";
    let (v, _) = scan_source("adapters/masked.rs", src);
    assert!(v.is_empty(), "{v:?}");
}

#[test]
fn same_line_pragma_works() {
    let src = "pub fn f(v: &[u32]) -> u32 { *v.first().unwrap() } // lint:allow(panic-safety): fixture, same-line form\n";
    let (v, allowed) = scan_source("adapters/sameline.rs", src);
    assert!(v.is_empty(), "{v:?}");
    assert_eq!(allowed.len(), 1);
}

#[test]
fn synthetic_enum_coverage_cross_check() {
    let src = r#"
pub enum Color {
    Red,
    Green(u8),
    Blue { v: u8 },
}
fn encode_with(c: &Color) {
    match c {
        Color::Red => {}
        Color::Green(_) => {}
        Color::Blue { .. } => {}
    }
}
fn decode() -> Color {
    Color::Red
}
"#;
    let missing = check_enum_coverage(src, "Color", &["encode_with", "decode"]);
    // encode_with covers everything; decode misses Green and Blue
    assert!(missing.contains(&("Color::Green".to_string(), "decode".to_string())), "{missing:?}");
    assert!(missing.contains(&("Color::Blue".to_string(), "decode".to_string())), "{missing:?}");
    assert_eq!(missing.len(), 2, "{missing:?}");
    // a missing enum or fn is a sentinel finding, not a silent pass
    assert!(!check_enum_coverage(src, "Nope", &["decode"]).is_empty());
    assert!(!check_enum_coverage(src, "Color", &["encode_missing"]).is_empty());
}

#[test]
fn live_tree_is_lint_clean_deny_all() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = scan_tree(&root).unwrap();
    let msgs: Vec<String> =
        report.violations.iter().map(|v| v.to_string()).collect();
    assert_eq!(report.deny_count(), 0, "lint denies:\n{}", msgs.join("\n"));
    assert_eq!(report.warn_count(), 0, "lint warnings:\n{}", msgs.join("\n"));
    assert!(report.files_scanned > 20, "scanned {}", report.files_scanned);
    // the audited pragma inventory is non-empty by construction (e.g.
    // util::lock_recover's own mutex-poison allow)
    assert!(!report.allowed.is_empty());
}
