// lint fixture: seeded mutex-poison violation (never compiled).
use std::sync::Mutex;

pub fn read(m: &Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}
