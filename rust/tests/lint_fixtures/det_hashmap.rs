// lint fixture: seeded determinism violation (never compiled).
use std::collections::HashMap;

pub fn table() -> HashMap<String, u32> {
    HashMap::new()
}
