// lint fixture: seeded panic-safety violation (never compiled).
pub fn first(v: &[u32]) -> u32 {
    *v.first().unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn in_test_code_unwrap_is_fine() {
        assert_eq!(Some(1).unwrap(), 1);
    }
}
