// lint fixture: a properly audited pragma (never compiled).
pub fn last_of_three(v: &[u32; 3]) -> u32 {
    // lint:allow(panic-safety): fixed-size array always has a last element
    *v.last().unwrap()
}
