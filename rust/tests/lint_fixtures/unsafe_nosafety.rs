// lint fixture: seeded unsafe-audit violation (never compiled).
pub fn peek(v: &[u8]) -> u8 {
    unsafe { *v.get_unchecked(0) }
}

pub fn peek_audited(v: &[u8]) -> u8 {
    // SAFETY: caller guarantees v is non-empty
    unsafe { *v.get_unchecked(0) }
}
