// lint fixture: pragma-hygiene violations (never compiled).
pub fn a(v: &[u32]) -> u32 {
    // lint:allow(panic-safety)
    *v.first().unwrap()
}

pub fn b() {
    // lint:allow(no-such-rule): not a real rule
}

// lint:allow(determinism): nothing here to suppress
pub fn c() {}
