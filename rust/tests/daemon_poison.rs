//! Mutex-poisoning containment — a panicking fit must not wedge the
//! daemon.
//!
//! Before the `util::lock_recover` sweep, a panic that unwound while a
//! connection thread held the shared adapter-table lock poisoned the
//! mutex, and every later `lock().unwrap()` on ANY connection panicked
//! in turn: one bad tenant took the whole multi-tenant daemon down.
//! These tests inject exactly that panic (the chaos hook fires *under*
//! the table lock) and assert the daemon keeps serving everyone else —
//! and even the victim, since a pre-checkout panic leaves registered
//! state intact.

use std::sync::Arc;
use std::time::Duration;

use cola::adapters::{AdapterParams, OptimizerCfg, SiteAdapter};
use cola::config::{AdapterKind, OffloadTarget, WireFormat};
use cola::coordinator::FitJob;
use cola::rng::Rng;
use cola::runtime::Manifest;
use cola::tensor::Tensor;
use cola::transport::tcp::{request_daemon_shutdown, TcpLinkOpts, TcpWorker,
                           WorkerDaemon};
use cola::transport::Transport;

fn manifest() -> Arc<Manifest> {
    Arc::new(Manifest::load_or_builtin(std::path::Path::new("artifacts")).unwrap())
}

fn daemon() -> (WorkerDaemon, String) {
    let d = WorkerDaemon::bind("127.0.0.1:0", OffloadTarget::NativeCpu,
                               manifest(), None)
        .unwrap();
    let addr = d.local_addr().to_string();
    (d, addr)
}

fn adapter() -> SiteAdapter {
    let mut rng = Rng::new(7);
    let params = AdapterParams::init(AdapterKind::LowRank, 8, 8, 4, 4, &mut rng);
    SiteAdapter::new("s", params, &OptimizerCfg::sgd(0.1, 0.0))
}

fn job(user: usize) -> FitJob {
    FitJob {
        user,
        site: "s".into(),
        x: Tensor::zeros(&[2, 8]),
        ghat: Tensor::zeros(&[2, 8]),
        grad_scale: 1.0,
        merged: false,
    }
}

fn tenant_link(id: usize, addr: &str, tenant: &str) -> TcpWorker {
    TcpWorker::connect_with_link_opts(
        id,
        addr,
        &TcpLinkOpts {
            attempts: 3,
            base: Duration::from_millis(5),
            tenant: tenant.to_string(),
            batch: false,
            inflight: 1,
            wire: WireFormat::F32,
        },
    )
    .unwrap()
}

#[test]
fn injected_fit_panic_poisons_nothing_daemon_keeps_serving() {
    let (d, addr) = daemon();
    let w = TcpWorker::connect(0, &addr).unwrap();
    w.register(0, "s", adapter()).unwrap();

    // the panic fires inside checkout, while the connection thread
    // holds the adapter-table mutex — the poisoned-lock worst case
    d.inject_fit_panic("", 0, "s");
    let err = w.fit(job(0)).unwrap().recv().unwrap().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("panicked"), "{msg}");
    assert!(msg.contains("(0, s)"), "error must name (user, site): {msg}");
    assert!(msg.contains("state is intact"), "{msg}");

    // the chaos hook fired before checkout, so the registered state
    // survived: the SAME key fits fine on the next try, no re-register
    let r = w.fit(job(0)).unwrap().recv().unwrap().unwrap();
    assert!(r.new_params.is_some(), "unmerged fit must return fresh params");

    // and the shared table still serves every other tenant
    let other = tenant_link(1, &addr, "bob");
    other.register(1, "s", adapter()).unwrap();
    other.fit(job(1)).unwrap().recv().unwrap().unwrap();
    assert!(other.state_bytes().unwrap() > 0);
    let snap = other.snapshot(1, "s").unwrap();
    assert_eq!(snap.kind(), AdapterKind::LowRank);

    w.shutdown();
    other.shutdown();
    request_daemon_shutdown(&addr).unwrap();
    d.join();
}

#[test]
fn panic_error_is_per_key_not_per_connection() {
    let (d, addr) = daemon();
    let w = TcpWorker::connect(0, &addr).unwrap();
    w.register(2, "s", adapter()).unwrap();
    w.register(3, "s", adapter()).unwrap();

    d.inject_fit_panic("", 2, "s");
    // user 2 gets the contained error...
    let err = w.fit(job(2)).unwrap().recv().unwrap().unwrap_err();
    assert!(format!("{err:#}").contains("(2, s)"), "{err:#}");
    // ...while user 3, on the very same connection, is untouched
    w.fit(job(3)).unwrap().recv().unwrap().unwrap();

    w.shutdown();
    request_daemon_shutdown(&addr).unwrap();
    d.join();
}
