//! Paging-determinism integration tests (README §Scale harness & state
//! paging): an LRU working set paging cold adapter state to disk must
//! be invisible in every number the system produces. Three angles:
//!
//! 1. the scale harness's loss-proxy curve is byte-identical paging on
//!    or off, at ANY working-set size (including the ws=1 thrash case);
//! 2. an evict-then-touch round trip through the page file preserves
//!    AdamW optimizer moments bitwise (exercised at the WorkerPool
//!    level, through the same checkout/checkin path fits use);
//! 3. a corrupted page file is a per-key fit error — the worker keeps
//!    serving every other key and never panics.

use std::path::PathBuf;
use std::sync::Arc;

use cola::adapters::{AdapterParams, OptimizerCfg, SiteAdapter};
use cola::config::{AdapterKind, OffloadTarget};
use cola::coordinator::{FitJob, WorkerPool};
use cola::rng::Rng;
use cola::runtime::Manifest;
use cola::scale::store::PagerCfg;
use cola::scale::{ScaleCfg, ScaleHarness};
use cola::tensor::Tensor;

fn manifest() -> Arc<Manifest> {
    Arc::new(Manifest::load_or_builtin(std::path::Path::new("artifacts")).unwrap())
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("cola_scale_paging_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn harness_cfg(working_set: usize, page_dir: Option<PathBuf>) -> ScaleCfg {
    ScaleCfg {
        users: 48,
        intervals: 5,
        touches_per_interval: 20,
        workers: 2,
        working_set,
        page_dir,
        seed: 0xBEEF,
        rows: 3,
    }
}

#[test]
fn curves_are_byte_identical_at_any_working_set_size() {
    let mut reference = ScaleHarness::new(harness_cfg(0, None)).unwrap();
    let ref_summary = reference.run_all().unwrap();
    assert_eq!(ref_summary.fits_lost, 0);

    // ws=1 thrashes (every touch after the first evicts something),
    // ws=2 pages heavily, ws=64 barely pages — all must match the
    // unpaged curve byte for byte
    for ws in [1usize, 2, 64] {
        let dir = tmpdir(&format!("ws{ws}"));
        let mut paged =
            ScaleHarness::new(harness_cfg(ws, Some(dir.clone()))).unwrap();
        let summary = paged.run_all().unwrap();
        assert_eq!(summary.fits_lost, 0, "ws={ws} lost fits");
        assert_eq!(summary.page_stats.page_errors, 0, "ws={ws} page errors");
        assert_eq!(
            reference.curve_hex(),
            paged.curve_hex(),
            "ws={ws}: paging moved the curve"
        );
        // same population either way
        assert_eq!(summary.users_registered, ref_summary.users_registered);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

const D_IN: usize = 6;
const D_OUT: usize = 4;

fn adapter(seed: u64) -> SiteAdapter {
    let mut rng = Rng::new(seed);
    let params = AdapterParams::init(AdapterKind::LowRank, D_IN, D_OUT, 3, 5, &mut rng);
    SiteAdapter::new("s", params, &OptimizerCfg::adamw(1e-3, 1e-4))
}

fn fit_job(user: usize, round: u64) -> FitJob {
    let mut rng = Rng::new(user as u64 * 1000 + round);
    FitJob {
        user,
        site: "s".to_string(),
        x: Tensor::new(vec![3, D_IN], rng.normal_vec(3 * D_IN, 1.0)),
        ghat: Tensor::new(vec![3, D_OUT], rng.normal_vec(3 * D_OUT, 1.0)),
        grad_scale: 1.0,
        merged: true,
    }
}

/// Drive the same interleaved fit sequence through a pool; returns the
/// final per-user state blobs (params + optimizer moments, bit-exact).
fn run_fits(pool: &WorkerPool, users: usize, rounds: u64) -> Vec<Vec<u8>> {
    for u in 0..users {
        pool.for_user(u).unwrap().register(u, "s", adapter(u as u64)).unwrap();
    }
    for round in 0..rounds {
        // interleave so a small working set evicts and faults every key
        // repeatedly between its touches
        for u in 0..users {
            let rx = pool.for_user(u).unwrap().fit(fit_job(u, round)).unwrap();
            rx.recv().unwrap().unwrap();
        }
    }
    (0..users)
        .map(|u| pool.for_user(u).unwrap().export_state(u, "s").unwrap())
        .collect()
}

#[test]
fn evict_then_touch_round_trips_adamw_moments_bitwise() {
    let users = 5;
    let plain = WorkerPool::spawn(1, OffloadTarget::NativeCpu, manifest(), None).unwrap();
    let plain_blobs = run_fits(&plain, users, 4);
    drop(plain);

    // capacity 1 with 5 users: every single fit faults its adapter in
    // from disk and every checkin evicts another — the worst case for
    // any bit that doesn't survive the page format
    let dir = tmpdir("moments");
    let paged = WorkerPool::spawn_paged(
        1,
        OffloadTarget::NativeCpu,
        manifest(),
        None,
        Some(PagerCfg { dir: dir.clone(), capacity: 1 }),
    )
    .unwrap();
    let paged_blobs = run_fits(&paged, users, 4);
    let stats = paged.total_page_stats();
    assert!(stats.faults > 0, "capacity 1 never faulted");
    assert_eq!(stats.page_errors, 0);
    drop(paged);
    let _ = std::fs::remove_dir_all(&dir);

    // export_state blobs carry params AND optimizer moments; byte
    // equality here is the full AdamW state surviving eviction bitwise
    for (u, (a, b)) in plain_blobs.iter().zip(&paged_blobs).enumerate() {
        assert_eq!(a, b, "user {u}: state blob diverged after paging");
    }
}

#[test]
fn corrupted_page_is_a_per_key_fit_error_not_a_panic() {
    let dir = tmpdir("corrupt");
    let pool = WorkerPool::spawn_paged(
        1,
        OffloadTarget::NativeCpu,
        manifest(),
        None,
        Some(PagerCfg { dir: dir.clone(), capacity: 1 }),
    )
    .unwrap();
    // registering user 1 evicts user 0's state to disk (capacity 1)
    pool.for_user(0).unwrap().register(0, "s", adapter(0)).unwrap();
    pool.for_user(1).unwrap().register(1, "s", adapter(1)).unwrap();

    // find user 0's page file under w0/ and trash it
    let w0 = dir.join("w0");
    let mut corrupted = 0;
    for entry in std::fs::read_dir(&w0).unwrap() {
        let path = entry.unwrap().path();
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        if name.starts_with("__0__s.") {
            std::fs::write(&path, b"not a state blob").unwrap();
            corrupted += 1;
        }
    }
    assert_eq!(corrupted, 1, "expected exactly one page file for (0, s) in w0/");

    // touching the corrupted key is an error carried in the fit reply —
    // not a worker panic, not a poisoned pool
    let rx = pool.for_user(0).unwrap().fit(fit_job(0, 0)).unwrap();
    let err = rx.recv().unwrap().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("(0, s)"), "error does not name the key: {msg}");

    // every other key still serves fits on the same worker
    let rx = pool.for_user(1).unwrap().fit(fit_job(1, 0)).unwrap();
    rx.recv().unwrap().unwrap();
    assert!(pool.for_user(1).unwrap().snapshot(1, "s").is_ok());

    drop(pool);
    let _ = std::fs::remove_dir_all(&dir);
}
